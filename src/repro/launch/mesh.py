"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required by the
dry-run, whose XLA_FLAGS must be set before any jax initialization.

Single pod:  (16, 16)     axes ("data", "model")          = 256 chips
Multi-pod:   (2, 16, 16)  axes ("pod", "data", "model")   = 512 chips

"pod" composes with "data" for batch/gradient reduction (hierarchical:
reduce-scatter over ICI within a pod, all-reduce over DCN across pods).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(devices)} — the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    import numpy as np
    dev_array = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """The composed data-parallel axes ("pod","data") or ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
