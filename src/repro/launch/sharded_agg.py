"""Mesh-sharded fused segmented aggregation: the distributed grouped hot path.

``core.aggregate.shard_merge`` already merges partial aggregate states over
ICI, and the fused segment-aggregate kernel's per-segment moments are
exactly such mergeable state: sum and count rows add across shards, min and
max rows extremize.  ``sharded_fused_segment_agg`` therefore runs the
kernel once per *row shard* under ``shard_map`` and all-reduces the
(C, 4, num_segments) moment tensor — ``lax.psum`` on the sum/count rows,
``lax.pmin``/``lax.pmax`` on the min/max rows.  That is the same algebra
``shard_merge`` left-folds, expressed as native collectives so XLA
schedules one fused all-reduce per moment row instead of an all-gather
plus a sequential fold (``moment_merge_aggregate`` exposes the fold form
so tests can pin the two against each other).

Routing is transparent: ``row_sharded_mesh`` detects concrete arrays that
carry a ``NamedSharding`` split over more than one device along dim 0, and
the grouped executors (``core/executors.py`` grouped ``AggCall`` dispatch,
``relational/engine.py`` ``GroupAgg``) send such tables through the
sharded entry with no caller changes — ``Table.shard_rows(mesh, axis)`` is
all a caller does.  Under tracing, arrays carry no committed sharding, so
jitted callers keep the single-device kernel (XLA's partitioner still
shards the surrounding program).  ``REPRO_SEGAGG_SHARDED=off`` disables
routing.

Rows arrive sorted by segment (the grouped executors sort to derive
segment ids), so every contiguous row shard is itself sorted — the band
pruning of ``kernels/segment_agg.py`` applies per shard, and each shard's
pruned grid only walks the segment tiles its band actually touches.

``num_segments`` sizes the all-reduce payload: the grouped executors pass
the dense group bound (relational/group_bound.py) when one is declared, so
the per-moment collectives move (C, 4, ~group count) elements instead of
(C, 4, row capacity) — ~25× less on the default bench shape.  The bound
is independent of the shard count: rows (not segments) are padded to a
multiple of it, so a bound smaller than the mesh axis still works (tail
shards just contribute moment identities).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.aggregate import Aggregate
from repro.kernels.segment_agg import (MOMENTS, NEG_INF, POS_INF,
                                       _normalize, _pad_rows,
                                       _validate_sorted, fused_segment_agg)


def row_sharded_mesh(*arrays) -> Optional[tuple[Mesh, str]]:
    """(mesh, axis) when any array carries a NamedSharding split over >1
    device along dim 0; None for tracers, replicated arrays, composite row
    axes, or when ``REPRO_SEGAGG_SHARDED=off``."""
    if os.environ.get("REPRO_SEGAGG_SHARDED") == "off":
        return None
    for a in arrays:
        if a is None or isinstance(a, jax.core.Tracer):
            continue
        sh = getattr(a, "sharding", None)
        if not isinstance(sh, NamedSharding):
            continue
        spec = tuple(sh.spec)
        if not spec or spec[0] is None:
            continue
        ax = spec[0]
        if isinstance(ax, tuple):
            if len(ax) != 1:
                continue
            ax = ax[0]
        if sh.mesh.shape[ax] > 1:
            return sh.mesh, ax
    return None


def _merge_moments(local: jax.Array, axis_name: str) -> jax.Array:
    """Cross-shard merge of a (C, 4, S) moment tensor: the shard_merge
    algebra (sum/count add, min/max extremize) as native collectives."""
    s = lax.psum(local[:, 0], axis_name)
    c = lax.psum(local[:, 1], axis_name)
    mn = lax.pmin(local[:, 2], axis_name)
    mx = lax.pmax(local[:, 3], axis_name)
    return jnp.stack([s, c, mn, mx], axis=1)


def moment_merge_aggregate(num_cols: int, num_segments: int) -> Aggregate:
    """The (C, 4, S) moment tensor as a ``core.aggregate.Aggregate`` whose
    state is the tensor itself: ``merge`` adds the sum/count rows and
    extremizes the min/max rows.  ``shard_merge(moment_merge_aggregate(...),
    local, axis)`` computes exactly what ``_merge_moments`` computes with
    collectives — tests pin the two against each other."""
    def identity():
        return jnp.stack(
            [jnp.zeros((num_cols, num_segments), jnp.float32),
             jnp.zeros((num_cols, num_segments), jnp.float32),
             jnp.full((num_cols, num_segments), POS_INF, jnp.float32),
             jnp.full((num_cols, num_segments), NEG_INF, jnp.float32)],
            axis=1)

    def merge(a, b):
        return jnp.stack([a[:, 0] + b[:, 0], a[:, 1] + b[:, 1],
                          jnp.minimum(a[:, 2], b[:, 2]),
                          jnp.maximum(a[:, 3], b[:, 3])], axis=1)

    return Aggregate("segagg_moments", init=identity, accumulate=merge,
                     terminate=lambda st: st, merge=merge,
                     identity=identity)


def sharded_fused_segment_agg(vals: jax.Array, segs: jax.Array,
                              valid: jax.Array, num_segments: int, *,
                              mesh: Mesh, axis: str = "data",
                              backend: str = "auto", block_rows: int = 256,
                              block_segs: int | None = None,
                              moments=MOMENTS, prune: bool = True,
                              assume_sorted: bool = False) -> jax.Array:
    """Row-sharded fused segmented aggregation over ``mesh.shape[axis]``
    devices: each shard runs ``fused_segment_agg`` on its contiguous row
    slice (full segment range), then the (C, 4, num_segments) moment
    tensors merge with one all-reduce per moment row.  Same signature and
    result as ``fused_segment_agg`` (empty segments read
    [0, 0, +inf, -inf]); rows are padded to a multiple of the shard count
    with invalid rows repeating the last real segment id, so empty shards
    contribute identities and the per-shard pruned grids stay narrow.

    Exactness: counts and min/max match the single-device kernel
    bit-for-bit; per-segment f32 sums are associativity-reordered across
    shard boundaries, so they are bitwise-equal when the addends are
    exactly representable (integer-valued data, the tests' parity case)
    and within normal f32 rounding otherwise."""
    vals, valid = _normalize(jnp.asarray(vals), jnp.asarray(valid))
    segs = jnp.asarray(segs).astype(jnp.int32)
    nshards = mesh.shape[axis]

    # the sorted precondition only matters where band pruning runs — the
    # per-shard kernel backends; the jnp fallback is order-independent
    resolved = backend
    if resolved == "auto":
        resolved = "pallas" if jax.default_backend() == "tpu" else "jnp"
    check_runtime = _validate_sorted(segs, prune, assume_sorted, resolved)

    vals, segs, valid = _pad_rows(vals, segs, valid, nshards)
    sh = NamedSharding(mesh, P(axis))
    vals = jax.device_put(vals.astype(jnp.float32), sh)
    segs = jax.device_put(segs, sh)
    valid = jax.device_put(valid, sh)

    def local(v, s, g):
        out = fused_segment_agg(v, s, g, num_segments,
                                block_rows=block_rows,
                                block_segs=block_segs, backend=backend,
                                moments=moments, prune=prune,
                                assume_sorted=True)
        return _merge_moments(out, axis)

    out = shard_map(local, mesh=mesh,
                    in_specs=(P(axis), P(axis), P(axis)),
                    out_specs=P(), check_rep=False)(vals, segs, valid)
    if check_runtime:
        is_sorted = (jnp.all(segs[1:] >= segs[:-1])
                     if segs.shape[0] > 1 else jnp.bool_(True))
        out = jnp.where(is_sorted, out, jnp.float32(jnp.nan))
    return out
