"""Mesh-sharded fused segmented aggregation: the distributed grouped hot path.

``core.aggregate.shard_merge`` already merges partial aggregate states over
ICI, and the fused segment-aggregate kernel's per-segment moments are
exactly such mergeable state: sum and count rows add across shards, min and
max rows extremize.  ``sharded_fused_segment_agg`` therefore runs the
kernel once per *row shard* under ``shard_map`` and all-reduces the
(C, 4, num_segments) moment tensor — ``lax.psum`` on the sum/count rows,
``lax.pmin``/``lax.pmax`` on the min/max rows.  That is the same algebra
``shard_merge`` left-folds, expressed as native collectives so XLA
schedules one fused all-reduce per moment row instead of an all-gather
plus a sequential fold (``moment_merge_aggregate`` exposes the fold form
so tests can pin the two against each other).

Arg-extremum state is mergeable too: when the kernel's INDEX MOMENT is
requested (rows 4/5 — the tie-ordered attaining row index), the shard
merge extends to the lexicographic (key, global_row) ``pmin``/``pmax``
(``_merge_index_rows``), and payload selection stays SHARD-LOCAL: each
shard takes its own (num_segments,)-sized payload candidates from its
local rows and the winner's candidates combine with a masked ``psum``
(``payloads=``).  Every collective in the path moves O(num_segments)
elements per shard — the payload gather never touches the global row
set.

Routing is transparent: ``row_sharded_mesh`` detects concrete arrays that
carry a ``NamedSharding`` split over more than one device along dim 0, and
the grouped executors (``core/executors.py`` grouped ``AggCall`` dispatch,
``relational/engine.py`` ``GroupAgg``) send such tables through the
sharded entry with no caller changes — ``Table.shard_rows(mesh, axis)`` is
all a caller does.  Under tracing, arrays carry no committed sharding, so
jitted callers keep the single-device kernel (XLA's partitioner still
shards the surrounding program).  ``REPRO_SEGAGG_SHARDED=off`` disables
routing.

Rows arrive sorted by segment (the grouped executors sort to derive
segment ids), so every contiguous row shard is itself sorted — the band
pruning of ``kernels/segment_agg.py`` applies per shard, and each shard's
pruned grid only walks the segment tiles its band actually touches.

``sharded_sortfree_segment_agg`` is the SORT-FREE counterpart: rows
arrive in arbitrary order and each shard hash-slots its own rows
(relational/keyslot.py) before running the kernel in
``layout='unsorted'``.  Shard-local slot numbers are hash-order and
therefore NOT aligned across shards, so the merge is key-aligned
instead: every shard publishes its (num_segments,)-sized slot→key table
with one all-gather, re-slots the gathered (replicated) key set into one
global table — a deterministic computation every shard repeats
identically, no further collective — scatters its local (C, R, S) moment
tensor onto the global slots, and only then runs the same
psum/pmin/pmax + lexicographic arg-merge as the sorted path.  Every
collective still moves O(num_segments) elements per shard; no sort, no
row-sized exchange.

``num_segments`` sizes the all-reduce payload: the grouped executors pass
the dense group bound (relational/group_bound.py) when one is declared, so
the per-moment collectives move (C, 4, ~group count) elements instead of
(C, 4, row capacity) — ~25× less on the default bench shape.  The bound
is independent of the shard count: rows (not segments) are padded to a
multiple of it, so a bound smaller than the mesh axis still works (tail
shards just contribute moment identities).

Whole-plan fusion (relational/fuse.py) interacts with this routing at
one seam: a fused chain's right-side column gathers produce fresh
arrays whose sharding is whatever XLA picked, which would make
``row_sharded_mesh`` miss the route.  ``fuse._recommit_rows`` puts each
gathered column back on the left table's committed row NamedSharding
before the aggregate sees it, so sharded fused chains still take the
O(num_segments)-per-shard merge paths above with no changes here.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.aggregate import Aggregate
from repro.configs import flags
from repro.kernels.segment_agg import (ARGMAX_ROW, ARGMIN_ROW, MOMENTS,
                                       NEG_INF, POS_INF, _index_tie,
                                       _normalize, _pad_rows, _row_fills,
                                       _validate_sorted, fused_segment_agg,
                                       has_index_moments, index_moment_ok,
                                       moment_rows, normalize_moments)


def row_sharded_mesh(*arrays) -> Optional[tuple[Mesh, str]]:
    """(mesh, axis) when any array carries a NamedSharding split over >1
    device along dim 0; None for tracers, replicated arrays, composite row
    axes, or when ``REPRO_SEGAGG_SHARDED=off``."""
    if not flags.enabled("REPRO_SEGAGG_SHARDED"):
        return None
    for a in arrays:
        if a is None or isinstance(a, jax.core.Tracer):
            continue
        sh = getattr(a, "sharding", None)
        if not isinstance(sh, NamedSharding):
            continue
        spec = tuple(sh.spec)
        if not spec or spec[0] is None:
            continue
        ax = spec[0]
        if isinstance(ax, tuple):
            if len(ax) != 1:
                continue
            ax = ax[0]
        if sh.mesh.shape[ax] > 1:
            return sh.mesh, ax
    return None


def _merge_moments(local: jax.Array, axis_name: str) -> jax.Array:
    """Cross-shard merge of a (C, 4, S) moment tensor: the shard_merge
    algebra (sum/count add, min/max extremize) as native collectives."""
    s = lax.psum(local[:, 0], axis_name)
    c = lax.psum(local[:, 1], axis_name)
    mn = lax.pmin(local[:, 2], axis_name)
    mx = lax.pmax(local[:, 3], axis_name)
    return jnp.stack([s, c, mn, mx], axis=1)


def _merge_index_rows(local: jax.Array, gmin: jax.Array, gmax: jax.Array,
                      offset, moments, axis_name: str) -> jax.Array:
    """Cross-shard ARG-merge of the index rows: each shard contributes its
    local (key, global_row) pair — ``local`` still holds shard-local row
    indices; ``offset`` (axis_index × shard rows) globalizes them, with
    the ±inf tie identities surviving the shift — and the merge is the
    lexicographic extremum: only shards attaining the already-merged key
    extremum enter their global row, reduced by ``pmin`` (first-attaining
    tie order: the smallest global row wins, and contiguous row sharding
    makes global row order the loop order) or ``pmax`` (last-attaining).
    The collective payload is one (S,) row per index moment —
    O(num_segments), never O(rows).  Returns the merged (C, 2, S) index
    rows (unrequested rows hold +inf)."""
    num_cols = local.shape[0]
    cols = []
    for c in range(num_cols):
        rows = []
        for which, row, gkey in (("argmin", ARGMIN_ROW, gmin[c]),
                                 ("argmax", ARGMAX_ROW, gmax[c])):
            tie_first = _index_tie(moments[c], which)
            if tie_first is None:
                rows.append(jnp.full_like(gkey, POS_INF))
                continue
            lkey = local[c, 2 if which == "argmin" else 3]
            cand = jnp.where(lkey == gkey, local[c, row] + offset,
                             POS_INF if tie_first else NEG_INF)
            rows.append(lax.pmin(cand, axis_name) if tie_first
                        else lax.pmax(cand, axis_name))
        cols.append(jnp.stack(rows))
    return jnp.stack(cols)


def moment_merge_aggregate(num_cols: int, num_segments: int) -> Aggregate:
    """The (C, 4, S) moment tensor as a ``core.aggregate.Aggregate`` whose
    state is the tensor itself: ``merge`` adds the sum/count rows and
    extremizes the min/max rows.  ``shard_merge(moment_merge_aggregate(...),
    local, axis)`` computes exactly what ``_merge_moments`` computes with
    collectives — tests pin the two against each other."""
    def identity():
        return jnp.stack(
            [jnp.zeros((num_cols, num_segments), jnp.float32),
             jnp.zeros((num_cols, num_segments), jnp.float32),
             jnp.full((num_cols, num_segments), POS_INF, jnp.float32),
             jnp.full((num_cols, num_segments), NEG_INF, jnp.float32)],
            axis=1)

    def merge(a, b):
        return jnp.stack([a[:, 0] + b[:, 0], a[:, 1] + b[:, 1],
                          jnp.minimum(a[:, 2], b[:, 2]),
                          jnp.maximum(a[:, 3], b[:, 3])], axis=1)

    return Aggregate("segagg_moments", init=identity, accumulate=merge,
                     terminate=lambda st: st, merge=merge,
                     identity=identity)


def sharded_fused_segment_agg(vals: jax.Array, segs: jax.Array,
                              valid: jax.Array, num_segments: int, *,
                              mesh: Mesh, axis: str = "data",
                              backend: str = "auto", block_rows: int = 256,
                              block_segs: int | None = None,
                              moments=MOMENTS, prune: bool = True,
                              assume_sorted: bool = False,
                              payloads=()):
    """Row-sharded fused segmented aggregation over ``mesh.shape[axis]``
    devices: each shard runs ``fused_segment_agg`` on its contiguous row
    slice (full segment range), then the (C, R, num_segments) moment
    tensors merge with one all-reduce per moment row.  Same signature and
    result as ``fused_segment_agg`` (empty segments read
    [0, 0, +inf, -inf]); rows are padded to a multiple of the shard count
    with invalid rows repeating the last real segment id, so empty shards
    contribute identities and the per-shard pruned grids stay narrow.

    Index moments (``argmin_*``/``argmax_*`` in ``moments``) extend the
    all-reduce algebra with the cross-shard ARG-merge: each shard's local
    attaining row is globalized (axis_index × shard rows) and merged as a
    lexicographic (key, global_row) ``pmin``/``pmax`` — see
    ``_merge_index_rows``.  ``payloads`` then keeps payload selection
    shard-local: each entry is ``(col, minimize, values)`` with ``values``
    a tuple of (N,) payload arrays; every shard gathers its OWN
    num_segments-sized candidate rows (local take, local rows only) and
    the winning shard's candidates are combined with one masked ``psum``
    per payload array.  The collective therefore moves O(num_segments)
    elements per shard, never O(rows).  With payloads the return value is
    ``(moments, picks)`` where ``picks[i]`` is a tuple of (S,) arrays in
    the payload dtypes (0 for segments with no attaining row — consumers
    gate on the index-row sentinel).

    Exactness: counts and min/max match the single-device kernel
    bit-for-bit; index rows and payload picks are bit-exact too (the
    lexicographic merge is order-independent); per-segment f32 sums are
    associativity-reordered across shard boundaries, so they are
    bitwise-equal when the addends are exactly representable
    (integer-valued data, the tests' parity case) and within normal f32
    rounding otherwise."""
    from repro.reliability import faults as _faults
    _faults.fail("shard_launch")
    vals, valid = _normalize(jnp.asarray(vals), jnp.asarray(valid))
    segs = jnp.asarray(segs).astype(jnp.int32)
    nshards = mesh.shape[axis]
    num_cols = vals.shape[1]
    norm_moments = normalize_moments(moments, num_cols)
    indexed = has_index_moments(norm_moments)
    if payloads and not indexed:
        raise ValueError("shard-local payload gathering requires an index "
                         "moment on the key column (argmin_*/argmax_*)")

    # the sorted precondition only matters where band pruning runs — the
    # per-shard kernel backends; the jnp fallback is order-independent
    resolved = backend
    if resolved == "auto":
        resolved = "pallas" if jax.default_backend() == "tpu" else "jnp"
    check_runtime = _validate_sorted(segs, prune, assume_sorted, resolved)

    vals, segs, valid = _pad_rows(vals, segs, valid, nshards)
    n_p = vals.shape[0]
    if indexed and not index_moment_ok(n_p, block_rows):
        raise ValueError(
            f"index moments accumulate f32 row indices, exact only below "
            f"2^24 (padded) total rows; got {n_p}")
    shard_n = n_p // nshards
    sh = NamedSharding(mesh, P(axis))
    vals = jax.device_put(vals.astype(jnp.float32), sh)
    segs = jax.device_put(segs, sh)
    valid = jax.device_put(valid, sh)
    pv_flat: list[jax.Array] = []
    for _c, _minimize, pvs in payloads:
        for a in pvs:
            a = jnp.asarray(a)
            if a.shape[0] != n_p:       # mirror the row padding
                a = jnp.concatenate(
                    [a, jnp.zeros((n_p - a.shape[0],), a.dtype)])
            pv_flat.append(jax.device_put(a, sh))

    def local(v, s, g, *pv):
        out = fused_segment_agg(v, s, g, num_segments,
                                block_rows=block_rows,
                                block_segs=block_segs, backend=backend,
                                moments=norm_moments, prune=prune,
                                assume_sorted=True)
        if not indexed:
            return _merge_moments(out, axis), ()
        sm = lax.psum(out[:, 0], axis)
        cnt = lax.psum(out[:, 1], axis)
        mn = lax.pmin(out[:, 2], axis)
        mx = lax.pmax(out[:, 3], axis)
        offset = (lax.axis_index(axis) * shard_n).astype(out.dtype)
        gi = _merge_index_rows(out, mn, mx, offset, norm_moments, axis)
        merged = jnp.concatenate([jnp.stack([sm, cnt, mn, mx], axis=1), gi],
                                 axis=1)
        picks = []
        it = iter(pv)
        for c, minimize, pvs in payloads:
            gkey = mn[c] if minimize else mx[c]
            lkey = out[c, 2 if minimize else 3]
            lp = out[c, ARGMIN_ROW if minimize else ARGMAX_ROW]
            # exactly one shard owns the merged (key, global_row) winner:
            # global rows are unique, so the masked psum IS a select
            won = ((lkey == gkey) & (lp + offset == gi[c, 0 if minimize
                                                       else 1])
                   & (lp >= 0) & (lp < shard_n))
            safe = jnp.clip(lp, 0, shard_n - 1).astype(jnp.int32)
            per = []
            for _ in pvs:
                arr = next(it)
                gathered = jnp.take(arr, safe)       # (S,)-sized, local rows
                if gathered.dtype == jnp.bool_:
                    r = lax.psum(jnp.where(won, gathered.astype(jnp.int32),
                                           0), axis)
                    per.append(r != 0)
                else:
                    per.append(lax.psum(
                        jnp.where(won, gathered, jnp.zeros_like(gathered)),
                        axis))
            picks.append(tuple(per))
        return merged, tuple(picks)

    out_specs = (P(), tuple(tuple(P() for _ in pvs)
                            for _c, _m, pvs in payloads))
    out, picks = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis),) * (3 + len(pv_flat)),
        out_specs=out_specs, check_rep=False)(vals, segs, valid, *pv_flat)
    if check_runtime:
        is_sorted = (jnp.all(segs[1:] >= segs[:-1])
                     if segs.shape[0] > 1 else jnp.bool_(True))
        out = jnp.where(is_sorted, out, jnp.float32(jnp.nan))
    if payloads:
        return out, picks
    return out


def sharded_fold_batch(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                       pos: jax.Array, num_segments: int, *,
                       mesh: Mesh, axis: str = "data",
                       backend: str = "auto", block_rows: int = 256,
                       block_segs: int | None = None,
                       moments=MOMENTS, payloads=()):
    """Aggregate ONE micro-batch into a replicated (C, R, num_segments)
    moment tensor across a row-sharded mesh — the distributed half of the
    serving layer's incremental ingest.  The batch arrives already
    slotted against the resident table (``segs`` holds dense resident
    slot ids, so slot numbering is globally consistent by construction —
    no key exchange is needed, unlike ``sharded_sortfree_segment_agg``);
    each shard runs ``fused_segment_agg`` in ``layout='unsorted'`` over
    its row slice and the partial tensors merge with the standard
    psum/pmin/pmax algebra.  Index rows are globalized by ``pos`` — the
    batch rows' TABLE POSITIONS (f32-exact ints) — instead of the
    axis-index offset of ``_merge_index_rows``: the caller folds the
    result into a resident tensor whose index rows are position-numbered,
    and position order equals loop order over the appended table, so
    tie-order parity with a one-shot recompute holds by construction.
    ``payloads`` selects winner payload values shard-locally exactly as
    in ``sharded_fused_segment_agg`` (masked psum keyed on the merged
    (key, position) pair).  Every collective moves O(num_segments)
    elements per shard.  Returns ``(moments, picks)`` — replicated, ready
    for ``core.aggregate.fold_moments`` against the resident tensor."""
    from repro.reliability import faults as _faults
    _faults.fail("shard_launch")
    vals, valid = _normalize(jnp.asarray(vals), jnp.asarray(valid))
    segs = jnp.asarray(segs).astype(jnp.int32)
    pos = jnp.asarray(pos, jnp.float32)
    nshards = mesh.shape[axis]
    num_cols = vals.shape[1]
    norm_moments = normalize_moments(moments, num_cols)
    indexed = has_index_moments(norm_moments)
    if payloads and not indexed:
        raise ValueError("shard-local payload gathering requires an index "
                         "moment on the key column (argmin_*/argmax_*)")

    n = vals.shape[0]
    pad = (-n) % nshards
    if pad:
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        segs = jnp.concatenate(
            [segs, jnp.full((pad,), num_segments - 1, jnp.int32)])
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
        pos = jnp.pad(pos, (0, pad))
    n_p = vals.shape[0]
    if indexed and not index_moment_ok(n_p, block_rows):
        raise ValueError(
            f"index moments accumulate f32 row indices, exact only below "
            f"2^24 (padded) total rows; got {n_p}")
    shard_n = n_p // nshards
    sh = NamedSharding(mesh, P(axis))
    vals = jax.device_put(vals.astype(jnp.float32), sh)
    segs = jax.device_put(segs, sh)
    valid = jax.device_put(valid, sh)
    pos = jax.device_put(pos, sh)
    pv_flat: list[jax.Array] = []
    for _c, _minimize, pvs in payloads:
        for a in pvs:
            a = jnp.asarray(a)
            if a.shape[0] != n_p:
                a = jnp.concatenate(
                    [a, jnp.zeros((n_p - a.shape[0],), a.dtype)])
            pv_flat.append(jax.device_put(a, sh))

    def local(v, s, g, p, *pv):
        out = fused_segment_agg(v, s, g, num_segments,
                                block_rows=block_rows,
                                block_segs=block_segs, backend=backend,
                                moments=norm_moments, layout="unsorted")
        sm = lax.psum(out[:, 0], axis)
        cnt = lax.psum(out[:, 1], axis)
        mn = lax.pmin(out[:, 2], axis)
        mx = lax.pmax(out[:, 3], axis)
        if not indexed:
            return jnp.stack([sm, cnt, mn, mx], axis=1), ()
        # globalize each attaining LOCAL row to its table position, then
        # merge lexicographically on (key, position)
        gi_cols = []
        for c in range(num_cols):
            rows = []
            for which, row, gkey in (("argmin", ARGMIN_ROW, mn[c]),
                                     ("argmax", ARGMAX_ROW, mx[c])):
                tie_first = _index_tie(norm_moments[c], which)
                if tie_first is None:
                    rows.append(jnp.full_like(gkey, POS_INF))
                    continue
                ident = POS_INF if tie_first else NEG_INF
                lkey = out[c, 2 if which == "argmin" else 3]
                lp = out[c, row]
                inr = (lp >= 0) & (lp < shard_n)
                safe = jnp.clip(lp, 0, shard_n - 1).astype(jnp.int32)
                cand = jnp.where((lkey == gkey) & inr, jnp.take(p, safe),
                                 ident)
                rows.append(lax.pmin(cand, axis) if tie_first
                            else lax.pmax(cand, axis))
            gi_cols.append(jnp.stack(rows))
        gi = jnp.stack(gi_cols)
        merged = jnp.concatenate(
            [jnp.stack([sm, cnt, mn, mx], axis=1), gi], axis=1)
        picks = []
        it = iter(pv)
        for c, minimize, pvs in payloads:
            gkey = mn[c] if minimize else mx[c]
            lkey = out[c, 2 if minimize else 3]
            lp = out[c, ARGMIN_ROW if minimize else ARGMAX_ROW]
            inr = (lp >= 0) & (lp < shard_n)
            safe = jnp.clip(lp, 0, shard_n - 1).astype(jnp.int32)
            # positions are unique across the table, so exactly one shard
            # matches the merged position — the masked psum IS a select
            won = ((lkey == gkey) & inr
                   & (jnp.take(p, safe) == gi[c, 0 if minimize else 1]))
            per = []
            for _ in pvs:
                arr = next(it)
                gathered = jnp.take(arr, safe)
                if gathered.dtype == jnp.bool_:
                    r = lax.psum(jnp.where(won, gathered.astype(jnp.int32),
                                           0), axis)
                    per.append(r != 0)
                else:
                    per.append(lax.psum(
                        jnp.where(won, gathered, jnp.zeros_like(gathered)),
                        axis))
            picks.append(tuple(per))
        return merged, tuple(picks)

    out_specs = (P(), tuple(tuple(P() for _ in pvs)
                            for _c, _m, pvs in payloads))
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis),) * (4 + len(pv_flat)),
        out_specs=out_specs, check_rep=False)(vals, segs, valid, pos,
                                              *pv_flat)


def sharded_sortfree_segment_agg(vals: jax.Array, key_words: jax.Array,
                                 valid: jax.Array, rowm: jax.Array,
                                 num_segments: int, bucket: int, *,
                                 mesh: Mesh, axis: str = "data",
                                 backend: str = "auto",
                                 block_rows: int = 256,
                                 block_segs: int | None = None,
                                 moments=MOMENTS, payloads=()):
    """Sort-free row-sharded fused segmented aggregation: hash-slotted
    segment ids per shard, key-aligned cross-shard merge.

    ``key_words`` is the (N, K) canonical uint32 key matrix
    (``keyslot.key_words_for``) and ``rowm`` the (N,) row-validity mask
    the slotting honors (per-column guards still arrive via ``valid``).
    Each shard assigns its rows slots in ``[0, bucket)`` independently
    (``slot_ids_from_words``), runs ``fused_segment_agg`` in
    ``layout='unsorted'`` on its slice, then aligns slots globally:
    the shard-local slot→key tables are all-gathered (one
    O(num_segments·K) collective), every shard re-slots the identical
    gathered key set into one global table (replicated compute, so no
    further exchange), and the local moment tensor is scattered onto the
    global slots — unoccupied and unplaced slots park on the overflow
    slot, whose merged content is never read as valid output.  From
    there the merge algebra is exactly ``sharded_fused_segment_agg``'s:
    psum/pmin/pmax per moment row, the lexicographic (key, global_row)
    arg-merge for index rows, shard-local O(num_segments) payload
    gathers combined by masked psum.

    Returns ``(moments, picks, rep_rows, occupied, unplaced)``:
    ``moments`` the merged (C, R, num_segments) tensor, ``picks`` the
    per-payload (S,)-sized winner values (empty tuple without
    ``payloads``), ``rep_rows`` (S,) int32 global representative row per
    global slot (input-row indexing; ``N``-sentinel where unoccupied),
    ``occupied`` (S,) bool, and ``unplaced`` the total count of valid
    rows (plus gathered keys) the bucket could not hold — the caller
    validates it with ``keyslot.check_slot_overflow``.
    """
    from repro.relational.keyslot import slot_ids_from_words
    from repro.reliability import faults as _faults

    _faults.fail("shard_launch")
    vals, valid = _normalize(jnp.asarray(vals), jnp.asarray(valid))
    kw = jnp.asarray(key_words)
    rowm = jnp.asarray(rowm, bool)
    nshards = mesh.shape[axis]
    num_cols = vals.shape[1]
    norm_moments = normalize_moments(moments, num_cols)
    indexed = has_index_moments(norm_moments)
    if payloads and not indexed:
        raise ValueError("shard-local payload gathering requires an index "
                         "moment on the key column (argmin_*/argmax_*)")

    n = vals.shape[0]
    pad = (-n) % nshards
    if pad:
        # pad rows are invalid everywhere: they never slot, never
        # contribute, and keep padded-space row indices == input indices
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        kw = jnp.pad(kw, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
        rowm = jnp.pad(rowm, (0, pad))
    n_p = vals.shape[0]
    if indexed and not index_moment_ok(n_p, block_rows):
        raise ValueError(
            f"index moments accumulate f32 row indices, exact only below "
            f"2^24 (padded) total rows; got {n_p}")
    shard_n = n_p // nshards
    sh = NamedSharding(mesh, P(axis))
    vals = jax.device_put(vals.astype(jnp.float32), sh)
    kw = jax.device_put(kw, sh)
    valid = jax.device_put(valid, sh)
    rowm = jax.device_put(rowm, sh)
    pv_flat: list[jax.Array] = []
    for _c, _minimize, pvs in payloads:
        for a in pvs:
            a = jnp.asarray(a)
            if a.shape[0] != n_p:
                a = jnp.concatenate(
                    [a, jnp.zeros((n_p - a.shape[0],), a.dtype)])
            pv_flat.append(jax.device_put(a, sh))

    nrows_m = moment_rows(norm_moments)
    fills = jnp.asarray(_row_fills(norm_moments),
                        jnp.float32).reshape(num_cols, nrows_m, 1)

    def local(v, k, g, rm, *pv):
        seg, owner, occ, unpl = slot_ids_from_words(k, rm, bucket)
        out = fused_segment_agg(v, seg, g, num_segments,
                                block_rows=block_rows,
                                block_segs=block_segs, backend=backend,
                                moments=norm_moments, layout="unsorted")
        # publish this shard's slot→key table; re-slot the gathered set
        # into ONE global table (identical on every shard — replicated
        # compute over all-gathered data, not another collective)
        ktab = jnp.take(k, jnp.clip(owner, 0, shard_n - 1), axis=0)
        gk = lax.all_gather(ktab, axis)                # (nshards, S-1, K)
        gocc = lax.all_gather(occ, axis)
        gown = lax.all_gather(owner, axis)
        eslot, eowner, gocc_glob, unpl_glob = slot_ids_from_words(
            gk.reshape(nshards * bucket, k.shape[1]), gocc.reshape(-1),
            bucket)
        me = lax.axis_index(axis)
        mine = lax.dynamic_slice_in_dim(eslot, me * bucket, bucket)
        # scatter local moments onto global slots; unoccupied local slots
        # (identity fills) and globally-unplaced keys park on overflow
        tgt = jnp.concatenate([jnp.where(occ, mine, bucket),
                               jnp.full((1,), bucket, jnp.int32)])
        glocal = jnp.broadcast_to(fills, out.shape).at[:, :, tgt].set(out)

        sm = lax.psum(glocal[:, 0], axis)
        cnt = lax.psum(glocal[:, 1], axis)
        mn = lax.pmin(glocal[:, 2], axis)
        mx = lax.pmax(glocal[:, 3], axis)
        if indexed:
            offset = (me * shard_n).astype(out.dtype)
            gi = _merge_index_rows(glocal, mn, mx, offset, norm_moments,
                                   axis)
            merged = jnp.concatenate(
                [jnp.stack([sm, cnt, mn, mx], axis=1), gi], axis=1)
        else:
            merged = jnp.stack([sm, cnt, mn, mx], axis=1)

        picks = []
        it = iter(pv)
        for c, minimize, pvs in payloads:
            gkey = mn[c] if minimize else mx[c]
            lkey = glocal[c, 2 if minimize else 3]
            lp = glocal[c, ARGMIN_ROW if minimize else ARGMAX_ROW]
            won = ((lkey == gkey)
                   & (lp + offset == gi[c, 0 if minimize else 1])
                   & (lp >= 0) & (lp < shard_n))
            safe = jnp.clip(lp, 0, shard_n - 1).astype(jnp.int32)
            per = []
            for _ in pvs:
                arr = next(it)
                gathered = jnp.take(arr, safe)       # (S,)-sized, local rows
                if gathered.dtype == jnp.bool_:
                    r = lax.psum(jnp.where(won, gathered.astype(jnp.int32),
                                           0), axis)
                    per.append(r != 0)
                else:
                    per.append(lax.psum(
                        jnp.where(won, gathered, jnp.zeros_like(gathered)),
                        axis))
            picks.append(tuple(per))

        # global representative rows: decode each global slot's winning
        # entry back to (shard, local slot) and globalize the local owner
        # (padded-space indices == input-row indices: padding is a tail)
        safe_e = jnp.clip(eowner, 0, nshards * bucket - 1)
        rep = jnp.where(gocc_glob,
                        (safe_e // bucket) * shard_n
                        + jnp.take(gown.reshape(-1), safe_e), n_p)
        rep_full = jnp.concatenate(
            [rep.astype(jnp.int32), jnp.full((1,), n_p, jnp.int32)])
        occ_full = jnp.concatenate([gocc_glob, jnp.zeros((1,), bool)])
        unpl_tot = lax.psum(unpl, axis) + unpl_glob
        return merged, tuple(picks), rep_full, occ_full, unpl_tot

    out_specs = (P(), tuple(tuple(P() for _ in pvs)
                            for _c, _m, pvs in payloads), P(), P(), P())
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis),) * (4 + len(pv_flat)),
        out_specs=out_specs, check_rep=False)(vals, kw, valid, rowm,
                                              *pv_flat)
