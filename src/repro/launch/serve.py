"""Production serving launcher.

Two workloads share this entry point:

* ``--workload agg`` (default) — the aggregate-serving layer
  (``repro.serve.agg_server``): a synthetic dashboard of parameterized
  grouped-aggregate tiles is served through the compiled-plan +
  slot-table caches with same-shape request batching, and the launcher
  reports sustained throughput, latency quantiles, and the cache
  counters (traces / slot builds) that show the per-request work
  amortized away.

      PYTHONPATH=src python -m repro.launch.serve --rows 50000 --requests 1000

* ``--workload lm`` — the continuous-batching LM server
  (``repro.serve.serving``) over a selected arch.  ``--smoke`` serves
  the reduced config locally.

      PYTHONPATH=src python -m repro.launch.serve --workload lm --arch qwen3-14b --smoke
"""
from __future__ import annotations

import argparse
import time


def _serve_lm(args) -> None:
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import LM
    from repro.serve.serving import Request, Server

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("text-only serving driver")
    lm = LM(cfg, q_chunk=32 if args.smoke else 1024,
            kv_chunk=32 if args.smoke else 1024,
            ssd_chunk=8 if args.smoke else 128)
    params = lm.init(jax.random.PRNGKey(0))
    server = Server(lm, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        rng.integers(3, 12)).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    server.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"{sum(r.done for r in reqs)}/{len(reqs)} requests, "
          f"{toks} tokens, {toks/dt:.1f} tok/s")


def _serve_agg(args) -> None:
    import numpy as np

    from repro.relational import Table
    from repro.relational.plan import GroupAgg, Scan
    from repro.serve import AggServer, serving_enabled

    rng = np.random.default_rng(0)
    n, groups = args.rows, args.groups
    t = Table.from_columns(
        k=rng.integers(0, groups, n).astype(np.int32),
        v=rng.integers(-4, 5, n).astype(np.float32),
        w=rng.integers(0, 100, n).astype(np.float32))
    # two dashboard tiles over one fact table — no declared bound: the
    # server's distinct-count sketch infers max_groups and validates it
    tiles = [
        GroupAgg(Scan("T", ("k", "v", "w")), ("k",),
                 (("rev", "sum", "v"), ("n", "count", None),
                  ("hi", "max", "v"))),
        GroupAgg(Scan("T", ("k", "v", "w")), ("k",),
                 (("avg_w", "mean", "w"), ("lo", "min", "v"))),
    ]
    srv = AggServer({"T": t}, max_batch=args.max_batch)
    for tile in tiles:
        srv.execute(tile, {})               # warm: trace + slot build
        print("tile:", srv.describe(tile))

    lat: list = []
    t0 = time.perf_counter()
    futs = []
    for i in range(args.requests):
        ts = time.perf_counter()
        f = srv.submit(tiles[i % len(tiles)], {})
        f.add_done_callback(
            lambda _f, ts=ts: lat.append(time.perf_counter() - ts))
        futs.append(f)
    for f in futs:
        f.result(timeout=300)
    dt = time.perf_counter() - t0
    srv.close()
    q = np.quantile(np.asarray(lat), [0.5, 0.99]) * 1e3
    mode = "cached" if serving_enabled() else "kill-switch (REPRO_AGG_SERVE=off)"
    print(f"{args.requests} requests in {dt:.3f}s — "
          f"{args.requests/dt:.0f} qps, p50 {q[0]:.2f} ms, p99 {q[1]:.2f} ms "
          f"[{mode}]")
    print(f"traces={srv.stats.traces} slot_builds={srv.stats.slot_builds} "
          f"batches={srv.stats.batches}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("agg", "lm"), default="agg")
    # agg workload
    ap.add_argument("--rows", type=int, default=50_000)
    ap.add_argument("--groups", type=int, default=500)
    ap.add_argument("--max-batch", type=int, default=64)
    # lm workload
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    # shared (the LM smoke default was 8; agg streams default to 1000)
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()
    if args.requests is None:
        args.requests = 8 if args.workload == "lm" else 1000
    if args.workload == "lm":
        _serve_lm(args)
    else:
        _serve_agg(args)


if __name__ == "__main__":
    main()
