"""Production serving launcher: continuous-batching server (see
repro.serve.serving) over a selected arch.  ``--smoke`` serves the reduced
config locally; full configs are exercised via the decode-shape dry-runs.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import LM
    from repro.serve.serving import Request, Server

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("text-only serving driver")
    lm = LM(cfg, q_chunk=32 if args.smoke else 1024,
            kv_chunk=32 if args.smoke else 1024,
            ssd_chunk=8 if args.smoke else 128)
    params = lm.init(jax.random.PRNGKey(0))
    server = Server(lm, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        rng.integers(3, 12)).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    server.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"{sum(r.done for r in reqs)}/{len(reqs)} requests, "
          f"{toks} tokens, {toks/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
