"""Production training launcher.

On a real TPU fleet each host runs this under ``jax.distributed`` (one
process per host; the mesh spans all chips).  On this container it runs
single-process: ``--smoke`` trains a reduced config end-to-end; full
configs are exercised through ``dryrun.py``.

Features wired in: production mesh + sharding rules, microbatched train
step, seeded host-sharded data with prefetch, atomic checkpoints with
resume, straggler monitor, optional int8 gradient compression.

    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
        --smoke --steps 100
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, Prefetcher
    from repro.models import LM
    from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                        save_checkpoint)
    from repro.train.optimizer import (AdamWConfig, init_error_state,
                                       init_opt_state)
    from repro.train.train_step import (StepTimer, StragglerMonitor,
                                        make_train_step)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    lm = LM(cfg, q_chunk=32 if args.smoke else 1024,
            kv_chunk=32 if args.smoke else 1024,
            ssd_chunk=8 if args.smoke else 128)
    params = lm.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(total_steps=args.steps)
    opt = init_opt_state(params)
    err = init_error_state(params) if args.compress_grads else None

    step_fn = make_train_step(lm.loss, opt_cfg,
                              microbatches=args.microbatches,
                              compress=args.compress_grads)

    if not args.smoke:
        # production path: shard everything over the mesh
        from repro.launch.mesh import make_production_mesh
        from repro.launch.sharding import (as_shardings, batch_specs,
                                           opt_specs, param_specs)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        pspec = param_specs(mesh, cfg, params)
        psh = as_shardings(mesh, pspec)
        params = jax.device_put(params, psh)
        opt = jax.device_put(opt, as_shardings(
            mesh, opt_specs(mesh, cfg, opt, pspec)))

    step_fn = jax.jit(step_fn)

    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            print(f"resuming from step {last}")
            state = restore_checkpoint(args.ckpt_dir, last,
                                       {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start = last

    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.global_batch, seed=0,
                      n_hosts=jax.process_count(),
                      host_id=jax.process_index())
    pf = Prefetcher(data, start_step=start)
    mon = StragglerMonitor()
    timer = StepTimer()
    timer.tick()

    try:
        for _ in range(start, args.steps):
            step_idx, host = next(pf)
            batch = {k: jnp.asarray(v) for k, v in host.items()}
            if args.compress_grads:
                params, opt, metrics, err = step_fn(params, opt, batch, err)
            else:
                params, opt, metrics = step_fn(params, opt, batch)
            dt = timer.tick()
            if mon.observe(dt):
                print(f"[straggler] step {step_idx}: {dt*1e3:.0f} ms "
                      f"(ewma {mon.ewma*1e3:.0f} ms)")
            if (step_idx + 1) % 10 == 0:
                print(f"step {step_idx+1:5d}  loss "
                      f"{float(metrics['loss']):.4f}  "
                      f"{dt*1e3:7.1f} ms/step", flush=True)
            if args.ckpt_dir and (step_idx + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step_idx + 1,
                                {"params": params, "opt": opt})
    finally:
        pf.close()
    print("done")


if __name__ == "__main__":
    main()
