"""Sharding rules: PartitionSpec trees for params, optimizer state, batches
and decode caches, per (arch × shape × mesh).

Strategy (baseline; §Perf iterates on it):
  * TP ("model"): attention heads (or head_dim when heads don't divide),
    FFN hidden, vocab; MoE experts (EP) over the same axis.
  * DP ("data" [+ "pod"]): batch dim of activations; FSDP-style sharding of
    the non-TP weight dim (ZeRO-3) so 90B × fp32 optimizer state fits HBM.
  * Decode caches: batch over DP; cache sequence over "model"
    (sequence-parallel flash-decode — the aggregate Merge over ICI); for
    long_500k (batch=1), sequence over every axis that divides.

Dimension assignment is divisibility-driven: each dim has an ordered
preference of mesh axes; the first unused axis that divides the dim size is
assigned (``_assign``).  This keeps one rule set valid across all ten
architectures (40-head models don't 16-way shard heads; 50280-row vocabs
don't 16-way shard rows; the helper falls back per-leaf).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec

from .mesh import data_axes

PyTree = Any


def _axis_size(mesh, axis) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _assign(mesh, shape: tuple[int, ...],
            prefs: list[tuple[int, Any]]) -> P:
    """Assign mesh axes to dims: prefs is an ordered list of
    (dim_index, axis_or_tuple); an axis is used at most once and only if it
    divides the dim."""
    spec: list[Any] = [None] * len(shape)
    used: set[str] = set()
    for dim, axis in prefs:
        if dim >= len(shape) or spec[dim] is not None:
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        if any(a in used for a in axes):
            continue
        if any(a not in mesh.axis_names for a in axes):
            continue
        if shape[dim] % _axis_size(mesh, axis) == 0 and shape[dim] > 0:
            spec[dim] = axis
            used.update(axes)
    return P(*spec)


# --------------------------------------------------------------------------
# Parameter rules (matched on tree path)
# --------------------------------------------------------------------------


def param_specs(mesh, cfg: ArchConfig, param_tree: PyTree,
                fsdp: bool = True, tp: bool = True) -> PyTree:
    """PartitionSpec tree mirroring ``param_tree`` (shapes may come from
    jax.eval_shape — no allocation).

    HARD RULE (learned from the dry-run, see EXPERIMENTS.md §Dry-run): the
    bf16 params used in forward/backward shard over the "model" axis ONLY.
    Sharding a weight over the same axis as the batch makes the SPMD
    partitioner resolve the per-op conflict by REPLICATING activations
    (observed: whisper logits 13.6 GB/device; qwen attention blocks fully
    replicated).  ZeRO-style data-axis sharding lives on the fp32
    optimizer state instead (``opt_specs``): its all-gather/reduce-scatter
    happens in the purely elementwise update where no batch axis exists.

    Preference order per weight: natural TP dim (heads / ff / experts /
    vocab) over "model"; if it does not divide, the contraction (d) dim
    over "model" (weight-gather TP).  Do NOT shard head_dim: RoPE's
    rotate-half across a sharded Dh triggers involuntary full
    rematerialization in the partitioner."""

    if not tp:
        # DP-only (§Perf iteration 7): for models whose bf16 weights fit
        # replicated (≲6 GB), tensor parallelism only buys per-layer
        # activation all-reduces (2/layer × microbatches); pure DP pays
        # ONE grad all-reduce per step and the ZeRO-sharded optimizer
        # keeps the fp32 state at 1/chips.  2.7B on 256 chips is DP-shaped.
        # Everything replicated — including the embedding: with the batch
        # sharded over the model axis too (full DP), a vocab@model table
        # would recreate the batch/weight axis conflict.
        return jax.tree.map(lambda _: P(), param_tree)

    def rule(path: str, leaf) -> P:
        shape = tuple(leaf.shape)
        nd = len(shape)
        if "embedding" in path:
            return _assign(mesh, shape, [(nd - 2, "model"), (nd - 1, "model")])
        if re.search(r"(attn|xattn)/w[qkv]$", path):
            # (..., d, H, Dh): heads on model, else d on model
            return _assign(mesh, shape, [(nd - 2, "model"), (nd - 3, "model")])
        if re.search(r"(attn|xattn)/wo$", path):
            return _assign(mesh, shape, [(nd - 3, "model"), (nd - 1, "model")])
        if re.search(r"(attn|xattn)/b[qkv]$", path):
            return _assign(mesh, shape, [(nd - 2, "model")])
        # MoE experts: (..., E, d, ff) / (..., E, ff, d) — EP over model
        if re.search(r"moe/w_(gate|up|down)$", path):
            return _assign(mesh, shape, [(nd - 3, "model")])
        if "router" in path:
            return P()
        # dense MLP: (..., d, ff) and (..., ff, d)
        if re.search(r"mlp/w_(gate|up|in)$", path):
            return _assign(mesh, shape, [(nd - 1, "model"), (nd - 2, "model")])
        if re.search(r"mlp/w_(down|out)$", path):
            return _assign(mesh, shape, [(nd - 2, "model"), (nd - 1, "model")])
        # SSM: interleaved fused z|x projection (d, 2, d_inner) — the
        # d_inner dim over model; the 2-dim slices locally
        if re.search(r"ssm/w_zx$", path):
            return _assign(mesh, shape, [(nd - 1, "model"), (nd - 3, "model")])
        if re.search(r"ssm/w_(bc|dt)$", path):
            return P()   # tiny; replicated => no backward dx all-reduce
        if re.search(r"ssm/w_out$", path):
            return _assign(mesh, shape, [(nd - 2, "model"), (nd - 1, "model")])
        if re.search(r"ssm/conv_w$", path):
            return _assign(mesh, shape, [(nd - 1, "model")])
        # norms, biases, gates, small vectors: replicated
        return P()

    def with_path(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        return rule(key, leaf)

    return jax.tree_util.tree_map_with_path(with_path, param_tree)


def _densify(mesh, spec: P, shape: tuple[int, ...]) -> P:
    """Add data-axis (ZeRO) sharding on the largest free dividing dim —
    used for the fp32 optimizer state, whose ops are elementwise (no batch
    axis to conflict with).  The per-step master→bf16 cast is then exactly
    ZeRO-3's weight all-gather; the grad resharding is its reduce-scatter."""
    dp = data_axes(mesh)
    dp_size = _axis_size(mesh, dp)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    free = [(shape[i], i) for i, e in enumerate(entries)
            if e is None and shape[i] % dp_size == 0 and shape[i] > 0]
    if free:
        _, idx = max(free)
        entries[idx] = dp
    return P(*entries)


def opt_specs(mesh, cfg: ArchConfig, opt_tree: PyTree,
              params_spec: PyTree) -> PyTree:
    """fp32 master/m/v: parameter sharding + ZeRO data-axis sharding."""
    def leaf_spec(spec, leaf):
        return _densify(mesh, spec, tuple(leaf.shape))

    dense = jax.tree.map(leaf_spec, params_spec, opt_tree["master"],
                         is_leaf=lambda x: isinstance(x, P))
    return {
        "master": dense, "m": dense, "v": dense,
        "step": P(),
    }


# --------------------------------------------------------------------------
# Batch / cache rules
# --------------------------------------------------------------------------


def batch_specs(mesh, cfg: ArchConfig, batch_tree: PyTree,
                dp_axes=None) -> PyTree:
    """``dp_axes`` overrides the batch axes — DP-only small models shard
    the batch over EVERY mesh axis (256-way DP; the model axis would
    otherwise sit idle)."""
    dp = tuple(dp_axes) if dp_axes is not None else data_axes(mesh)

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        # (B, S) tokens/labels or (B, S, d) frontend embeddings
        return _assign(mesh, shape, [(0, dp), (0, "data")])

    return jax.tree_util.tree_map_with_path(
        lambda p, l: rule(p, l), batch_tree)


def cache_specs(mesh, cfg: ArchConfig, cache_tree: PyTree,
                batch: int) -> PyTree:
    """Decode-cache sharding.  KV caches (L, B, S, Hkv, Dh): batch over DP
    when it divides, cache sequence over "model" (sequence-parallel
    decode); batch=1 long-context shards the sequence over everything
    available.  SSM states shard heads/channels over "model"."""
    dp = data_axes(mesh)
    all_axes = tuple(mesh.axis_names)

    def rule(path: str, leaf) -> P:
        shape = tuple(leaf.shape)
        nd = len(shape)
        if path.endswith("len"):
            return _assign(mesh, shape, [(nd - 1, dp)])
        if re.search(r"(^|/)(k|v)$", path):
            # (L[, G], B, S, Hkv, Dh)
            if batch == 1:
                return _assign(mesh, shape,
                               [(nd - 3, all_axes), (nd - 3, ("data", "model")),
                                (nd - 3, "model"), (nd - 3, dp)])
            return _assign(mesh, shape, [(nd - 4, dp), (nd - 3, "model"),
                                         (nd - 2, "model")])
        if path.endswith("conv"):
            # (L, B, W-1, C)
            return _assign(mesh, shape, [(nd - 3, dp), (nd - 1, ("pod", "model")
                                         if "pod" in mesh.axis_names
                                         else "model")])
        if path.endswith("h"):
            # (L, B, H, N, P)
            prefs = [(nd - 4, dp), (nd - 3, "model")]
            if "pod" in mesh.axis_names:
                prefs.append((nd - 1, "pod"))
            return _assign(mesh, shape, prefs)
        return P()

    def with_path(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        return rule(key, leaf)

    return jax.tree_util.tree_map_with_path(with_path, cache_tree)


def as_shardings(mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
