import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.configs import flags
# ^ MUST precede every other import (jax locks the device count on first
# init).  Only the dry-run sees 512 placeholder devices; tests/benches see 1.

import argparse
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze_hlo
from repro.configs import ARCH_IDS, SHAPES, get_config, supports_shape
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.sharding import (as_shardings, batch_specs, cache_specs,
                                   opt_specs, param_specs)
from repro.models import LM
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any


def build_lm(cfg, shape) -> LM:
    # block sizes for the flash/SSD chunking (VMEM-scale working sets)
    return LM(cfg, q_chunk=1024, kv_chunk=1024, ssd_chunk=128,
              remat=(shape.kind == "train"), use_pallas=False)


def build_lm_opt(cfg, shape) -> LM:
    """§Perf variant: head padding (TP-shardable attention for 40/25-head
    archs) + save-sublayer remat (backward skips re-running forward TP
    collectives) — composed with the activation shard-ctx set in
    lower_cell."""
    return LM(cfg, q_chunk=1024, kv_chunk=1024, ssd_chunk=128,
              remat=(shape.kind == "train"), use_pallas=False,
              pad_heads_multiple=16, remat_policy="save_sublayer")


def input_specs(arch_id: str, shape_name: str) -> dict[str, PyTree]:
    """ShapeDtypeStruct stand-ins for every model input of the lowered
    step — weak-type-correct, shardable, no device allocation."""
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    return _specs_for_lm(build_lm(cfg, shape), cfg, shape)


def _specs_for_lm(lm: LM, cfg, shape) -> dict[str, PyTree]:
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lm.init, key)
    out: dict[str, PyTree] = {"params": params}

    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["img_ctx"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), jnp.float32)
        out["batch"] = batch
        out["opt"] = jax.eval_shape(init_opt_state, params)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.family == "vlm":
            out["img_ctx"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), jnp.float32)
    else:  # decode
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        out["cache"] = jax.eval_shape(
            lambda: lm.init_cache(b, s, start_len=s - 1))
    return out


def _microbatches(cfg, shape, mesh) -> int:
    """Gradient-accumulation depth: one sequence per device per microbatch
    (keeps remat-saved activations bounded for the 90B configs)."""
    dp = 1
    for a in data_axes(mesh):
        dp *= mesh.shape[a]
    return max(1, shape.global_batch // dp)


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool,
               lm_factory=build_lm, sharding_overrides=None,
               variant: str = "baseline"):
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name,
                "multi_pod": multi_pod, "status": "SKIP", "reason": why}

    if variant == "opt":
        lm_factory = build_lm_opt
    mesh = make_production_mesh(multi_pod=multi_pod)
    # DP-only for small models in the opt variant (train shapes): with
    # weights replicated the batch shards over EVERY axis (model axis
    # would otherwise idle) — 256/512-way DP, zero per-layer collectives.
    dp_only = (variant == "opt" and shape.kind == "train"
               and cfg.param_count() * 2 <= 6e9
               and shape.global_batch % (512 if multi_pod else 256) == 0)
    batch_axes = tuple(mesh.axis_names) if dp_only else data_axes(mesh)
    if variant == "opt":
        from repro.models.shard_ctx import set_ctx
        set_ctx(mesh, batch_axes, tp=not dp_only)
    lm = lm_factory(cfg, shape)
    specs = _specs_for_lm(lm, cfg, shape)
    pspec = param_specs(mesh, cfg, specs["params"], tp=not dp_only)
    if sharding_overrides:
        pspec = sharding_overrides(mesh, cfg, specs["params"], pspec)
    psh = as_shardings(mesh, pspec)

    with mesh:
        if shape.kind == "train":
            if dp_only:
                chips = 512 if multi_pod else 256
                mb = max(1, shape.global_batch // chips)
            else:
                mb = _microbatches(cfg, shape, mesh)
            osh = as_shardings(mesh, opt_specs(mesh, cfg, specs["opt"], pspec))
            step = make_train_step(
                lm.loss, AdamWConfig(), microbatches=mb,
                acc_shardings=osh["master"] if variant == "opt" else None)
            bsh = as_shardings(mesh, batch_specs(mesh, cfg, specs["batch"],
                                                 dp_axes=batch_axes))
            fn = jax.jit(step, in_shardings=(psh, osh, bsh),
                         donate_argnums=(0, 1))
            lowered = fn.lower(specs["params"], specs["opt"], specs["batch"])
        elif shape.kind == "prefill":
            tsh = as_shardings(mesh, batch_specs(
                mesh, cfg, {"tokens": specs["tokens"]}))["tokens"]
            kwargs = {}
            in_sh = [psh, tsh]
            args = [specs["params"], specs["tokens"]]
            if "img_ctx" in specs:
                args.append(specs["img_ctx"])
                in_sh.append(as_shardings(mesh, batch_specs(
                    mesh, cfg, {"x": specs["img_ctx"]}))["x"])
                fn = jax.jit(lambda p, t, i: lm.prefill(p, t, img_ctx=i),
                             in_shardings=tuple(in_sh))
            elif "frames" in specs:
                args.append(specs["frames"])
                in_sh.append(as_shardings(mesh, batch_specs(
                    mesh, cfg, {"x": specs["frames"]}))["x"])
                fn = jax.jit(lambda p, t, f: lm.prefill(p, t, frames=f),
                             in_shardings=tuple(in_sh))
            else:
                fn = jax.jit(lm.prefill, in_shardings=tuple(in_sh))
            lowered = fn.lower(*args)
        else:  # decode
            csh = as_shardings(mesh, cache_specs(mesh, cfg, specs["cache"],
                                                 shape.global_batch))
            tsh = NamedSharding(mesh, P(None, None)) \
                if shape.global_batch == 1 else \
                as_shardings(mesh, batch_specs(
                    mesh, cfg, {"tokens": specs["tokens"]}))["tokens"]
            fn = jax.jit(lm.decode_step, in_shardings=(psh, csh, tsh),
                         donate_argnums=(1,))
            lowered = fn.lower(specs["params"], specs["cache"],
                               specs["tokens"])

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    if variant == "opt":
        from repro.models.shard_ctx import clear_ctx
        clear_ctx()

    mem = compiled.memory_analysis()
    print(mem)
    ca = compiled.cost_analysis()
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    hlo_text = compiled.as_text()
    hlo = analyze_hlo(hlo_text)
    n_chips = 512 if multi_pod else 256

    # persist the per-device HLO (gzip) so the analyzer can be improved
    # without recompiling all 80 cells
    hlo_dir = flags.value("REPRO_HLO_DIR")
    if hlo_dir:
        import gzip
        os.makedirs(hlo_dir, exist_ok=True)
        tag = f"{arch_id}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
        if variant != "baseline":
            tag += f"__{variant}"
        with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
            f.write(hlo_text)

    return {
        "arch": arch_id, "shape": shape_name, "multi_pod": multi_pod,
        "variant": variant,
        "status": "OK", "chips": n_chips, "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost_analysis_raw": {"flops": ca.get("flops"),
                              "bytes_accessed": ca.get("bytes accessed")},
        "hlo_per_device": {
            "flops": hlo.flops,
            "traffic_bytes": hlo.traffic_bytes,
            "collective_bytes": hlo.collective_bytes,
            "collective_total": hlo.collective_total,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--variant", choices=["baseline", "opt"],
                    default="baseline")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
                if args.variant != "baseline":
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                print(f"=== {tag} ===", flush=True)
                try:
                    rec = lower_cell(arch, shape, mp, variant=args.variant)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(rec.get("status"), flush=True)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
