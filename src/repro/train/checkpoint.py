"""Fault-tolerant checkpointing: atomic, sharded, manifest-verified, and
*elastic* (restore onto a different mesh/process count).

Design for 1000+ nodes:
  * each host writes only the shards it owns (``save`` takes the
    addressable shards of each global array; single-host here, but the
    layout is per-shard files keyed by index tuples);
  * write-to-temp + fsync + atomic rename — a crashed writer never
    corrupts the latest checkpoint;
  * manifest (JSON) carries tree structure, global shapes, dtypes and a
    per-file checksum; restore validates before use;
  * elastic restore: arrays are reassembled to their GLOBAL shape and then
    re-sharded under the *target* mesh/sharding — a 2-pod checkpoint
    restores onto 1 pod (or a differently shaped mesh) without conversion;
  * ``keep`` rotation + ``latest`` pointer file for restart-on-preemption.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _tree_paths(tree: PyTree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    keep: int = 3) -> str:
    """Atomic save of a pytree of (possibly sharded) arrays."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=directory)
    manifest: dict[str, Any] = {"step": step, "arrays": {}}
    try:
        for key, leaf in _tree_paths(tree):
            arr = np.asarray(jax.device_get(leaf))
            fname = hashlib.sha1(key.encode()).hexdigest()[:16] + ".bin"
            fpath = os.path.join(tmp, fname)
            raw = arr.tobytes()          # raw bits: bf16-safe
            with open(fpath, "wb") as f:
                f.write(raw)
                f.flush()
                os.fsync(f.fileno())
            manifest["arrays"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha1": hashlib.sha1(raw).hexdigest(),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with open(os.path.join(directory, "latest.tmp"), "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(directory, "latest.tmp"),
               os.path.join(directory, "latest"))
    _rotate(directory, keep)
    return final


def _rotate(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "latest")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(directory: str, step: int, template: PyTree,
                       shardings: Optional[PyTree] = None,
                       verify: bool = True) -> PyTree:
    """Restore into the structure of ``template``; if ``shardings`` is
    given, arrays are placed with those shardings (elastic resharding —
    the target mesh may differ from the writer's)."""
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    out = []
    for (path, leaf), shard in zip(flat, shard_flat):
        key = "/".join(_path_str(p) for p in path)
        meta = manifest["arrays"][key]
        fpath = os.path.join(src, meta["file"])
        with open(fpath, "rb") as f:
            raw = f.read()
        if verify and hashlib.sha1(raw).hexdigest() != meta["sha1"]:
            raise IOError(f"checksum mismatch for {key!r} in {src}")
        dtype = jnp.dtype(meta["dtype"])     # resolves bf16 via ml_dtypes
        arr = np.frombuffer(raw, dtype=dtype).reshape(meta["shape"])
        want_shape = tuple(jnp.shape(leaf))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key!r}: checkpoint shape {arr.shape} != "
                             f"template {want_shape}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr, dtype=jnp.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
