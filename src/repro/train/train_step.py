"""The jit-compiled training step: loss → grads → (optional compressed)
reduction → AdamW — plus the straggler monitor used by the driver loop.

Distribution notes (1000+ nodes):
  * under ``jax.jit`` with sharded params/batch, gradient reduction is
    emitted by the partitioner (reduce-scatter + all-gather on the data
    axes); the multi-pod mesh reduces hierarchically (ICI within a pod,
    DCN across the "pod" axis);
  * ``compress=True`` quantizes per-microbatch gradient contributions to
    int8 with error feedback BEFORE the mean over microbatches — on a real
    deployment this is the cross-pod DCN stage; the error state keeps the
    scheme unbiased over time;
  * microbatching (gradient accumulation) runs as a ``lax.scan`` so
    arbitrarily large global batches fit.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .optimizer import (AdamWConfig, adamw_update, compress_int8,
                        decompress_int8, global_norm, init_opt_state)

PyTree = Any
F32 = jnp.float32


def make_train_step(loss_fn: Callable[[PyTree, PyTree], jax.Array],
                    opt_cfg: AdamWConfig, *, microbatches: int = 1,
                    compress: bool = False, acc_shardings: PyTree = None):
    """Returns step(params, opt_state, batch[, err]) → (params, opt,
    metrics[, err]).  ``batch`` leaves have leading dim divisible by
    ``microbatches``.

    ``acc_shardings`` (optional NamedSharding tree mirroring params):
    ZeRO-2 — the fp32 gradient accumulator is constrained to the optimizer-
    state sharding (model × data) instead of the parameter sharding (model
    only), turning the per-microbatch gradient combine into a
    reduce-scatter and cutting the accumulator's HBM footprint by the DP
    width (§Perf iteration 6)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(params, opt_state, batch, err_state=None):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def split(x):
                # STRIDED split: microbatch i takes rows [i::mb].  With the
                # global batch sharded blockwise over the data axes, each
                # microbatch stays evenly spread across every data shard —
                # the reshape+swap is local (no resharding collective),
                # unlike a contiguous split which would park a whole
                # microbatch on one shard.
                per = x.shape[0] // microbatches
                return x.reshape((per, microbatches) + x.shape[1:]) \
                    .swapaxes(0, 1)
            mb = jax.tree.map(split, batch)

            def body(carry, mbatch):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                if acc_shardings is not None:
                    g_acc = jax.lax.with_sharding_constraint(g_acc,
                                                             acc_shardings)
                return (loss_acc + loss, g_acc), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            if acc_shardings is not None:
                zero = jax.lax.with_sharding_constraint(zero, acc_shardings)
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), F32), zero),
                                            mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        if compress:
            assert err_state is not None
            qs = jax.tree.map(compress_int8, grads, err_state)
            grads = jax.tree.map(lambda t: decompress_int8(t[0], t[1]),
                                 qs, is_leaf=lambda x: isinstance(x, tuple))
            new_err = jax.tree.map(lambda t: t[2], qs,
                                   is_leaf=lambda x: isinstance(x, tuple))
        else:
            new_err = err_state

        params, opt_state, metrics = adamw_update(opt_cfg, params, grads,
                                                  opt_state)
        metrics["loss"] = loss
        if compress:
            return params, opt_state, metrics, new_err
        return params, opt_state, metrics

    return step


# --------------------------------------------------------------------------
# Straggler mitigation (driver side)
# --------------------------------------------------------------------------


@dataclass
class StragglerMonitor:
    """EWMA step-time watermark.  A deployment wires ``on_straggler`` to
    its control plane (demote/replace the slow host; with our seeded,
    stateless data pipeline any replacement host can recompute the shard).
    Tested with injected delays."""
    threshold: float = 2.0         # × EWMA ⇒ straggler
    alpha: float = 0.2
    ewma: Optional[float] = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = dt > self.threshold * self.ewma
        # stragglers do not poison the watermark
        if not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        else:
            self.flagged += 1
        return is_straggler


class StepTimer:
    def __init__(self):
        self._t = None

    def tick(self) -> float:
        now = time.perf_counter()
        dt = 0.0 if self._t is None else now - self._t
        self._t = now
        return dt
