"""AdamW with fp32 master weights, cosine schedule, global-norm clipping,
and optional int8 error-feedback gradient compression (the cross-pod
bandwidth trick; see train_step.py for where it sits in the reduction).

Pure JAX — optimizer state is a pytree mirroring the (bf16) params with
fp32 master/m/v leaves, so standard sharding rules apply leaf-wise (ZeRO-
style: the launcher shards these over the data axis)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any
F32 = jnp.float32


@dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr_peak * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: PyTree) -> PyTree:
    master = jax.tree.map(lambda p: p.astype(F32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return {"master": master, "m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params: PyTree, grads: PyTree,
                 opt: PyTree) -> tuple[PyTree, PyTree, dict]:
    step = opt["step"]
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(F32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(g, m, v, mw):
        g = g.astype(F32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * mw
        mw_new = mw - lr * step_vec
        return m_new, v_new, mw_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    flat_w = jax.tree.leaves(opt["master"])
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2); new_v.append(v2); new_w.append(w2)

    master = jax.tree.unflatten(treedef, new_w)
    new_opt = {"master": master,
               "m": jax.tree.unflatten(treedef, new_m),
               "v": jax.tree.unflatten(treedef, new_v),
               "step": step + 1}
    dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(lambda w, d: w.astype(d), master, dtypes)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_opt, metrics


# --------------------------------------------------------------------------
# int8 error-feedback gradient compression (cross-pod DCN saver)
# --------------------------------------------------------------------------


def compress_int8(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (g + carried error) to int8 with a per-tensor scale;
    returns (q, scale, new_error)."""
    g32 = g.astype(F32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(F32) * scale
    return q, scale, g32 - deq


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
