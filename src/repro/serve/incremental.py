"""Resident incremental aggregation: O(batch) micro-batch folding.

A dashboard that re-runs ``GroupAgg`` after every ingested micro-batch
pays O(table) per refresh — the whole history re-reads, re-slots, and
re-aggregates even though only ``batch`` rows changed.  This module
keeps the fused (C, R, S) moment tensor and the keyslot slot table
RESIDENT per (plan, table) pair, so a micro-batch costs:

* one ``keyslot.slot_ids_extend`` over the batch's key words — resident
  keys resolve to their existing dense slot, new keys claim the next
  ids, and resident keys NEVER renumber (the winner-always-places
  invariant keeps probe paths consistent across calls);
* one ``fused_segment_agg`` pass over the batch rows
  (``layout='unsorted'``, O(batch) rows);
* one ``core.aggregate.fold_moments`` merge of the batch tensor into
  the resident tensor — the shard_merge collective algebra applied
  host-side: sum/count add, min/max extremize, and the PR-4 index rows
  merge as the lexicographic (key, global_row) extremum.

**Tie-order parity.**  Index rows are globalized to TABLE POSITIONS
before folding (``launch.sharded_agg.sharded_fold_batch`` does the same
on a mesh).  Appended rows fill previously-invalid positions, and a
position only ever transitions invalid → valid, so no position recorded
in the resident index rows can be claimed again: folding N micro-batches
picks exactly the row a one-shot recompute over the final table picks,
including first-attaining ties (positions order the rows both ways).
The same uniqueness makes the payload update sound: a slot's merged
index row differs from its resident value exactly when the batch won it.

**Eligibility** mirrors ``engine._group_agg``'s fused gates — every agg
must be a fused moment (sum/count/min/max/mean/argmin/argmax), count and
mean need the capacity inside f32-exact range, arg-extrema need
``index_moment_ok`` plus an f32-exactly-embeddable key dtype — and the
plan must be a ``GroupAgg`` directly over a catalog ``Scan`` with a
resolvable dense bound.  Anything else (and ``REPRO_INCR_AGG=off``)
falls back to a full recompute at snapshot time; capacity growth can
revoke eligibility mid-stream (``IncrementalIneligible``), which the
server treats the same way.

**Growth.**  A batch whose keys outgrow the resident bucket raises
``GroupBoundOverflow`` *before* any state commits; the server's
double-and-retry then calls ``grow``: the resident key table re-slots
into a doubled bucket (an old→new dense-id permutation), and moments,
payloads, and representatives scatter across it over identity fills.

``snapshot`` finalizes the resident tensor to a result ``Table`` with
the exact decode of ``engine._group_agg_fused`` — no history re-read.

**Epoch publication.**  All resident state lives in ONE immutable
``Epoch`` (moments, ``SlotState``, owner, payloads, the watermark table
and its version, a monotone epoch counter).  ``seed``/``fold``/``grow``
build the complete successor epoch first and commit it with a single
reference assignment — atomic under the GIL — so a concurrent reader
that captures ``current_epoch()`` always decodes a pre-commit or
post-commit generation, never a torn mix, WITHOUT any lock.  The
``fold_publish`` fault site fires between build and swap (modeling a
crash there): the published epoch stays the pre-fold one.  Invariants
(checked by tests): ``epoch_id`` increases by exactly 1 per commit, and
the ``version`` watermark never moves backwards.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import fold_moments
from repro.core.executors import _f32_exact_key_dtype, _index_row_to_pick
from repro.kernels.segment_agg import (ARGMAX_ROW, ARGMIN_ROW, NEG_INF,
                                       POS_INF, _index_tie, _row_fills,
                                       fused_segment_agg, has_index_moments,
                                       index_moment_ok, moment_rows,
                                       normalize_moments)
from repro.configs import flags
from repro.relational.group_bound import resolve_group_bound
from repro.relational.keyslot import (check_slot_overflow, fresh_slot_state,
                                      key_words_for, overflow_extended,
                                      slot_ids_extend, slot_state_build,
                                      sortfree_result)
from repro.relational.plan import GroupAgg, Plan, Scan
from repro.relational.table import Table
from repro.reliability import faults

__all__ = ["Epoch", "IncrementalIneligible", "ResidentAgg",
           "incremental_enabled"]

_ARG_OPS = ("argmin", "argmax")
_FUSED_OPS = ("sum", "min", "max", "count", "mean", "argmin", "argmax")

#: f32-exact ceiling shared with the engine's count/mean gate
_F32_EXACT = 1 << 24


def incremental_enabled() -> bool:
    """Kill switch for resident incremental aggregation (default: on).
    ``REPRO_INCR_AGG=off`` makes ``AggServer.ingest`` a plain
    ``append_rows`` and ``AggServer.snapshot`` a full recompute — the
    mutation API keeps working, only the O(batch) fold path disarms."""
    return flags.enabled("REPRO_INCR_AGG")


class IncrementalIneligible(RuntimeError):
    """The resident state can no longer serve this plan incrementally
    (capacity outgrew an f32-exactness gate, or the bucket hit the row
    capacity); the server drops the residency and snapshots recompute."""


@dataclass(frozen=True)
class Epoch:
    """One published generation of resident state — IMMUTABLE.  A reader
    that captured this object can decode a complete, internally
    consistent snapshot at ``version`` with no further synchronization:
    every field was built before the epoch was published, and commits
    replace the whole object, never a field.  ``table`` is the catalog
    table the epoch folded to (append-only successors keep its valid
    rows bit-identical, so decoding against it is exact at the
    watermark)."""
    state: object                       # keyslot.SlotState (never mutated)
    moments: jax.Array                  # (C, nrows, bound + 1)
    owner: jax.Array                    # (bound,) representative positions
    payloads: Mapping[str, jax.Array]   # arg agg name → (bound + 1,)
    bound: int                          # dense bucket the arrays are sized by
    version: int                        # table-version watermark folded to
    epoch_id: int                       # +1 per commit (seed/fold/grow)
    folds: int                          # committed folds since seed
    table: Table                        # catalog table AT the watermark


def _backend() -> Optional[str]:
    """Backend for the resident fused passes: the engine's choice, with
    the per-op-jnp default mapped to the jnp moment-tensor path (the
    resident algebra needs the (C, R, S) tensor either way).  None means
    the fused path is killed outright (``REPRO_GROUPAGG_FUSED=off``) and
    residency is inadmissible."""
    from repro.relational.engine import _groupagg_fused_backend
    b = _groupagg_fused_backend()
    if b == "off":
        return None
    return "jnp" if b is None else b


class ResidentAgg:
    """Resident fold state for one (GroupAgg plan, catalog table) pair.

    Holds the (C, R, S) moment tensor (S = bucket + overflow slot), the
    incremental ``SlotState``, the per-slot representative table
    positions (``owner``), and one resolved payload value per
    arg-extremum agg.  All mutation is transactional: ``fold`` computes
    every successor array *before* committing any of them, so an
    exception mid-fold (an injected fault, a backend failure, an
    overflow) leaves the resident state exactly as it was.
    """

    def __init__(self, plan: GroupAgg, name: str, keys: Tuple[str, ...],
                 bound: int, backend: str):
        self.plan = plan
        self.name = name
        self.keys = keys
        self.aggs = tuple(plan.aggs)
        self.bound = int(bound)
        self.backend = backend
        self.inferred = False          # server stamps: bound growable?
        # moment layout — byte-for-byte the engine._group_agg_fused
        # construction, so the resident decode matches the one-shot one
        self.value_cols = list(dict.fromkeys(
            (col[0] if op in _ARG_OPS else col)
            for _, op, col in self.aggs if col is not None))
        self.col_idx = {c: i for i, c in enumerate(self.value_cols)}
        ms: List[set] = [set() for _ in range(max(1, len(self.value_cols)))]
        for _, op, col in self.aggs:
            if op in _ARG_OPS:
                ms[self.col_idx[col[0]]].update(
                    ("min", "argmin_first") if op == "argmin"
                    else ("max", "argmax_first"))
                continue
            i = self.col_idx.get(col, 0)
            ms[i].update({"mean": ("sum", "count"),
                          "count": ("count",)}.get(op, (op,)))
        self.norm = normalize_moments(
            tuple(tuple(sorted(s)) for s in ms),
            max(1, len(self.value_cols)))
        self.nrows = moment_rows(self.norm)
        #: the ONE mutable cell: the currently published epoch (None
        #: before seed).  Writes are single reference assignments —
        #: atomic under the GIL — done only by seed/fold/grow/the
        #: version setter; readers capture it once (``current_epoch``)
        self._epoch: Optional[Epoch] = None
        # the local fold math jits once per (batch shape, bucket) — a
        # sustained ingest stream pays kernel time, not eager dispatch
        self._fold_jit = jax.jit(self._fold_math,
                                 static_argnames=("backend",))

    # -- epoch accessors ---------------------------------------------------
    def current_epoch(self) -> Optional[Epoch]:
        """The published epoch — capture ONCE and read only its fields;
        a second call may already observe a successor."""
        return self._epoch

    @property
    def state(self):
        ep = self._epoch
        return None if ep is None else ep.state

    @property
    def moments(self) -> Optional[jax.Array]:
        ep = self._epoch
        return None if ep is None else ep.moments

    @property
    def owner(self) -> Optional[jax.Array]:
        ep = self._epoch
        return None if ep is None else ep.owner

    @property
    def payloads(self) -> Dict[str, jax.Array]:
        ep = self._epoch
        return {} if ep is None else dict(ep.payloads)

    @property
    def folds(self) -> int:
        ep = self._epoch
        return 0 if ep is None else ep.folds

    @property
    def version(self) -> Optional[int]:
        ep = self._epoch
        return None if ep is None else ep.version

    @version.setter
    def version(self, v: int) -> None:
        """Advance the watermark without changing state (an append chain
        that contributed zero rows) — still a full epoch commit, so the
        epoch-id invariant keeps counting."""
        ep = self._epoch
        if ep is None or ep.version == v:
            return
        self._epoch = dataclasses.replace(ep, version=v,
                                          epoch_id=ep.epoch_id + 1)

    # -- admission ---------------------------------------------------------
    @classmethod
    def admit(cls, plan: Plan, name: str, keys: Tuple[str, ...],
              table: Table, bound: int) -> Optional["ResidentAgg"]:
        """A ResidentAgg when every agg of ``plan`` passes the fused
        gates against ``table``; None when the plan must recompute."""
        if not isinstance(plan, GroupAgg) or not isinstance(plan.child, Scan):
            return None
        backend = _backend()
        if backend is None:
            return None
        cap = table.capacity
        for _, op, col in plan.aggs:
            if op not in _FUSED_OPS:
                return None
            if op in ("count", "mean") and cap >= _F32_EXACT:
                return None
            if op in _ARG_OPS:
                if not index_moment_ok(cap):
                    return None
                if not _f32_exact_key_dtype(table.columns[col[0]].dtype):
                    return None
                d = table.columns[col[1]].dtype
                if not (d == jnp.bool_ or (jnp.issubdtype(d, jnp.floating)
                                           and jnp.dtype(d).itemsize <= 4)
                        or jnp.issubdtype(d, jnp.integer)):
                    return None
                continue
            if col is not None:
                d = table.columns[col].dtype
                if not (jnp.issubdtype(d, jnp.floating)
                        and jnp.dtype(d).itemsize <= 4):
                    return None
        return cls(plan, name, keys, bound, backend)

    # -- gates that depend on the (growing) capacity -----------------------
    def _check_caps(self, cap: int) -> None:
        if any(op in ("count", "mean") for _, op, _ in self.aggs) \
                and cap >= _F32_EXACT:
            raise IncrementalIneligible(
                f"table capacity {cap} outgrew the f32-exact count range")
        if has_index_moments(self.norm) and not index_moment_ok(cap):
            raise IncrementalIneligible(
                f"table capacity {cap} outgrew the f32-exact index range")

    def _vals(self, columns: Mapping[str, jax.Array], n: int) -> jax.Array:
        if not self.value_cols:
            return jnp.zeros((n, 1), jnp.float32)
        return jnp.stack([jnp.asarray(columns[c]).astype(jnp.float32)
                          for c in self.value_cols], axis=1)

    def _needed_cols(self) -> List[str]:
        need = list(self.keys) + list(self.value_cols)
        for _, op, col in self.aggs:
            if op in _ARG_OPS:
                need.append(col[1])
        return list(dict.fromkeys(need))

    def _arg_aggs(self):
        for name, op, col in self.aggs:
            if op in _ARG_OPS:
                yield (name, op == "argmin", self.col_idx[col[0]], col[1])

    def _globalize(self, fused_b: jax.Array, pos: jax.Array,
                   nb: int) -> jax.Array:
        """Rewrite the batch tensor's index rows from batch-local row
        indices to table positions (the resident numbering)."""
        if self.nrows == 4:
            return fused_b
        posf = jnp.asarray(pos, jnp.float32)
        cols = []
        for c in range(fused_b.shape[0]):
            rows = []
            for which, row in (("argmin", ARGMIN_ROW), ("argmax", ARGMAX_ROW)):
                tie_first = _index_tie(self.norm[c], which)
                if tie_first is None:
                    rows.append(jnp.full_like(fused_b[c, row], POS_INF))
                    continue
                ident = POS_INF if tie_first else NEG_INF
                lp = fused_b[c, row]
                inr = (lp >= 0) & (lp < nb)
                safe = jnp.clip(lp, 0, nb - 1).astype(jnp.int32)
                rows.append(jnp.where(inr, jnp.take(posf, safe), ident))
            cols.append(jnp.stack(rows))
        return jnp.concatenate([fused_b[:, :4], jnp.stack(cols)], axis=1)

    def _fold_math(self, vals_b, seg, pos, moments, owner, new_owner,
                   payloads, pvs, *, backend):
        """The pure-array local fold: batch fused pass → globalize →
        fold → payload/owner merges.  Shapes fix everything else, so the
        jit wrapper retraces only when the batch size or the resident
        bucket changes."""
        nb = vals_b.shape[0]
        ns = moments.shape[2]
        bvalid = jnp.ones((nb,), bool)
        fused_b = fused_segment_agg(vals_b, seg, bvalid[:, None], ns,
                                    backend=backend, moments=self.norm,
                                    layout="unsorted")
        batch_moments = self._globalize(fused_b, pos, nb)
        merged = fold_moments(moments, batch_moments, moments=self.norm)
        out_payloads = []
        for (name, minimize, i, _pc), pv, p in zip(self._arg_aggs(),
                                                   pvs, payloads):
            row = ARGMIN_ROW if minimize else ARGMAX_ROW
            tie_first = _index_tie(self.norm[i],
                                   "argmin" if minimize else "argmax")
            pick = _index_row_to_pick(fused_b[i, row], nb, tie_first)
            got = (pick >= 0) & (pick < nb)
            bp = jnp.where(got,
                           jnp.take(pv, jnp.clip(pick, 0, nb - 1)),
                           jnp.zeros((), pv.dtype))
            # positions transition invalid→valid exactly once, so a batch
            # position can never equal a resident index value: inequality
            # IS "the batch row won this slot"
            wins = merged[i, row] != moments[i, row]
            out_payloads.append(jnp.where(wins, bp.astype(p.dtype), p))
        claimed = new_owner < nb
        owner2 = jnp.where(claimed,
                           jnp.take(pos, jnp.clip(new_owner, 0, nb - 1)),
                           owner)
        return merged, owner2, tuple(out_payloads)

    # -- lifecycle ---------------------------------------------------------
    @property
    def ns(self) -> int:
        return self.bound + 1

    def seed(self, table: Table) -> None:
        """Build the resident state from the full table (one O(table)
        pass — paid once per residency, never per batch)."""
        cap = table.capacity
        self._check_caps(cap)
        seg, owner, overflowed, state = slot_state_build(
            table, self.keys, self.bound)
        check_slot_overflow(int(overflowed), self.bound)   # concrete: raises
        m = table.mask()
        fused = fused_segment_agg(self._vals(table.columns, cap), seg,
                                  m[:, None], self.ns, backend=self.backend,
                                  moments=self.norm, layout="unsorted")
        payloads = {}
        for name, minimize, i, pc in self._arg_aggs():
            row = ARGMIN_ROW if minimize else ARGMAX_ROW
            tie_first = _index_tie(self.norm[i],
                                   "argmin" if minimize else "argmax")
            pick = _index_row_to_pick(fused[i, row], cap, tie_first)
            got = (pick >= 0) & (pick < cap)
            pv = table.columns[pc]
            payloads[name] = jnp.where(
                got, jnp.take(pv, jnp.clip(pick, 0, cap - 1)),
                jnp.zeros((), pv.dtype))
        jax.block_until_ready((fused, owner))
        prev = self._epoch
        ep = Epoch(state=state, moments=fused, owner=owner,
                   payloads=payloads, bound=self.bound,
                   version=table.version,
                   epoch_id=1 if prev is None else prev.epoch_id + 1,
                   folds=0, table=table)
        self._epoch = ep        # the single atomic publication

    def fold(self, table: Table, positions, *,
             backend: Optional[str] = None) -> None:
        """Fold the micro-batch living at ``positions`` of ``table`` into
        the resident state — O(batch) work plus O(num_segments) merges.
        Raises ``GroupBoundOverflow`` (state untouched) when the batch
        keys outgrow the bucket; ``backend`` overrides the fused pass for
        the degraded (jnp) retry of the serving guard."""
        cap = table.capacity
        self._check_caps(cap)
        ep = self._epoch        # captured ONCE: the pre-fold generation
        pos = jnp.asarray(np.asarray(positions), jnp.int32)
        nb = int(pos.shape[0])
        if nb == 0:
            if ep is not None and ep.version != table.version:
                self._epoch = dataclasses.replace(
                    ep, version=table.version, epoch_id=ep.epoch_id + 1,
                    table=table)
            return
        be = backend or self.backend
        bcols = {c: jnp.take(table.columns[c], pos)
                 for c in self._needed_cols()}
        bvalid = jnp.ones((nb,), bool)
        words = key_words_for(bcols[k] for k in self.keys)
        seg, new_owner, overflowed, new_state = slot_ids_extend(
            words, bvalid, ep.state)
        check_slot_overflow(int(overflowed), self.bound)   # concrete: raises
        vals_b = self._vals(bcols, nb)
        arg_names = [name for name, *_rest in self._arg_aggs()]

        from repro.launch.sharded_agg import row_sharded_mesh
        route = row_sharded_mesh(*table.columns.values(), table.valid)
        if route is not None:
            from repro.launch.sharded_agg import sharded_fold_batch
            specs = tuple((i, minimize, (bcols[pc],))
                          for _, minimize, i, pc in self._arg_aggs())
            batch_moments, picks = sharded_fold_batch(
                vals_b, seg, bvalid[:, None], pos, self.ns,
                mesh=route[0], axis=route[1], backend=be,
                moments=self.norm, payloads=specs)
            batch_pick = {name: picks[j][0] for j, (name, *_rest)
                          in enumerate(self._arg_aggs())}
            merged = fold_moments(ep.moments, batch_moments,
                                  moments=self.norm)
            payload_vals = []
            for name, minimize, i, _pc in self._arg_aggs():
                row = ARGMIN_ROW if minimize else ARGMAX_ROW
                # positions transition invalid→valid exactly once, so a
                # batch position can never equal a resident index value:
                # inequality IS "the batch row won this slot"
                wins = merged[i, row] != ep.moments[i, row]
                p = ep.payloads[name]
                payload_vals.append(jnp.where(
                    wins, batch_pick[name].astype(p.dtype), p))
            claimed = new_owner < nb
            owner = jnp.where(claimed,
                              jnp.take(pos,
                                       jnp.clip(new_owner, 0, nb - 1)),
                              ep.owner)
        else:
            merged, owner, payload_vals = self._fold_jit(
                vals_b, seg, pos, ep.moments, ep.owner, new_owner,
                tuple(ep.payloads[n] for n in arg_names),
                tuple(bcols[pc] for _, _, _, pc in self._arg_aggs()),
                backend=be)
        payloads = dict(zip(arg_names, payload_vals))
        # surface any backend failure HERE (inside the guarded fold), not
        # asynchronously at snapshot time — then build the COMPLETE
        # successor epoch and publish it with one reference swap
        jax.block_until_ready((merged, owner, tuple(payloads.values())))
        succ = Epoch(state=new_state, moments=merged, owner=owner,
                     payloads=payloads, bound=self.bound,
                     version=table.version, epoch_id=ep.epoch_id + 1,
                     folds=ep.folds + 1, table=table)
        # the crash-between-build-and-swap site: everything above is
        # garbage-collectable scratch until the assignment below runs,
        # so a failure HERE leaves readers on the pre-fold epoch
        faults.fail("fold_publish")
        self._epoch = succ

    def grow(self, table: Table) -> bool:
        """Double the resident bucket after an overflowing batch: re-slot
        the resident key table into a fresh larger state (an old→new
        dense-id permutation) and scatter moments/payloads/owners across
        it over identity fills.  False when the doubled bucket would
        reach the row capacity — the dense bound gives out and the
        residency must be dropped."""
        _, b2 = resolve_group_bound(self.bound * 2, table.capacity)
        if b2 is None or b2 <= self.bound:
            return False
        ep = self._epoch        # captured ONCE: the pre-grow generation
        cnt = int(ep.state.cnt)
        ns2 = b2 + 1
        st2 = fresh_slot_state(ep.state.ktab.shape[1], b2,
                               ep.state.expand)
        if cnt:
            segmap, _own, ovf, st2 = slot_ids_extend(
                ep.state.ktab[:cnt], jnp.ones((cnt,), bool), st2)
            if int(ovf) != 0:      # cannot happen: b2 ≥ 2·cnt
                return False
            inv_b = jnp.full((b2,), cnt, jnp.int32).at[segmap].set(
                jnp.arange(cnt, dtype=jnp.int32), mode="drop")
        else:
            inv_b = jnp.full((b2,), cnt, jnp.int32)
        occ_b = inv_b < cnt
        inv = jnp.concatenate([inv_b, jnp.full((1,), cnt, jnp.int32)])
        occ = jnp.concatenate([occ_b, jnp.zeros((1,), bool)])
        safe = jnp.clip(inv, 0, max(cnt - 1, 0))
        fills = jnp.asarray(_row_fills(self.norm), jnp.float32).reshape(
            ep.moments.shape[0], self.nrows)
        moments2 = jnp.where(occ[None, None, :],
                             ep.moments[:, :, safe], fills[:, :, None])
        payloads2 = {
            name: jnp.where(occ, jnp.take(p, safe),
                            jnp.zeros((), p.dtype))
            for name, p in ep.payloads.items()}
        owner2 = jnp.where(
            occ_b,
            jnp.take(ep.owner, jnp.clip(inv_b, 0, self.bound - 1)),
            jnp.int32(-1))
        jax.block_until_ready((moments2, owner2))
        self.bound = b2
        self._epoch = dataclasses.replace(
            ep, state=st2, moments=moments2, owner=owner2,
            payloads=payloads2, bound=b2, epoch_id=ep.epoch_id + 1)
        return True

    def snapshot(self, table: Table) -> Table:
        """Finalize the resident tensor to the result Table — the decode
        of ``engine._group_agg_fused`` over claim-order slots, assembled
        by the shared ``sortfree_result`` epilogue.  O(num_segments); the
        table's history is never re-read."""
        return self.snapshot_epoch(self._epoch, table)

    def snapshot_epoch(self, ep: Epoch, table: Optional[Table] = None
                       ) -> Table:
        """Decode one captured epoch — reads ONLY ``ep``'s fields (plus
        the optional ``table`` override, which must be the epoch's
        watermark table or an append-descendant of it), so it is safe to
        run with no lock while folds publish successors concurrently."""
        t = ep.table if table is None else table
        cap = t.capacity
        occupied = jnp.arange(ep.bound) < ep.state.cnt
        rep_b = jnp.where(occupied, ep.owner, cap).astype(jnp.int32)
        rep, out_valid = overflow_extended(rep_b, occupied, cap)
        fused = ep.moments
        out: Dict[str, jax.Array] = {}
        for name, op, col in self.aggs:
            if op == "count":
                out[name] = fused[0, 1].astype(
                    jnp.int64 if jax.config.jax_enable_x64 else jnp.int32)
                continue
            if op in _ARG_OPS:
                out[name] = ep.payloads[name]
                continue
            i = self.col_idx[col]
            d = t.columns[col].dtype
            if op == "sum":
                out[name] = fused[i, 0].astype(d)
            elif op == "mean":
                out[name] = fused[i, 0] / jnp.maximum(fused[i, 1], 1.0)
            elif op == "min":
                out[name] = fused[i, 2].astype(d)
            else:
                out[name] = fused[i, 3].astype(d)
        return sortfree_result(t, self.keys, rep, out_valid, 0,
                               ep.bound, out)
