"""Serving front ends: the continuous-batching LM server (``serving``)
and the aggregate-serving layer (``agg_server``) — compiled-plan +
slot-table caching with batched concurrent parameterized queries."""
from .agg_server import AggServer, ServeStats, serving_enabled

__all__ = ["AggServer", "ServeStats", "serving_enabled"]
