"""Serving front ends: the continuous-batching LM server (``serving``)
and the aggregate-serving layer (``agg_server``) — compiled-plan +
slot-table caching with batched concurrent parameterized queries, under
the ``guard`` failure contract (typed per-request errors, poison
detection, deadlines/backpressure, degradation circuit breaker)."""
from .agg_server import (AggServer, ServeRequest, ServeResult, ServeStats,
                         guard_enabled, serving_enabled)
from .guard import (BackendFailure, BoundOverflow, CircuitBreaker,
                    DeadlineExceeded, GuardStats, PoisonedResult, QueueFull,
                    ServeError, ServerClosed, SlotTableStale, is_poisoned)
from .incremental import IncrementalIneligible, incremental_enabled

__all__ = [
    "AggServer", "ServeStats", "ServeRequest", "ServeResult",
    "serving_enabled", "guard_enabled",
    "IncrementalIneligible", "incremental_enabled",
    "ServeError", "BoundOverflow", "SlotTableStale", "DeadlineExceeded",
    "QueueFull", "PoisonedResult", "BackendFailure", "ServerClosed",
    "GuardStats", "CircuitBreaker", "is_poisoned",
]
