"""Serving front ends: the continuous-batching LM server (``serving``)
and the aggregate-serving layer (``agg_server``) — compiled-plan +
slot-table caching with batched concurrent parameterized queries, under
the ``guard`` failure contract (typed per-request errors, poison
detection, deadlines/backpressure, degradation circuit breaker),
epoch-published resident incremental aggregates (``incremental``), and
durable resident-state checkpoints (``checkpoint``)."""
from .agg_server import (AggServer, ServeRequest, ServeResult, ServeStats,
                         guard_enabled, serving_enabled)
from .guard import (BackendFailure, BoundOverflow, CheckpointCorrupt,
                    CircuitBreaker, DeadlineExceeded, GuardStats,
                    PoisonedResult, QueueFull, ServeError, ServerClosed,
                    SlotTableStale, is_poisoned, strip_poison_stamp)
from .incremental import Epoch, IncrementalIneligible, incremental_enabled

__all__ = [
    "AggServer", "ServeStats", "ServeRequest", "ServeResult",
    "serving_enabled", "guard_enabled",
    "Epoch", "IncrementalIneligible", "incremental_enabled",
    "ServeError", "BoundOverflow", "SlotTableStale", "DeadlineExceeded",
    "QueueFull", "PoisonedResult", "BackendFailure", "ServerClosed",
    "CheckpointCorrupt", "GuardStats", "CircuitBreaker", "is_poisoned",
    "strip_poison_stamp",
]
