"""Durable resident-state checkpoints: crash-consistent persistence of
the serving layer's incremental aggregates.

A resident epoch (serve/incremental.py) is expensive state — one full
O(table) seed pass plus every fold since — living only in process
memory.  This module makes it durable with three properties the chaos
battery (tests/test_checkpoint.py) enforces:

* **Atomic visibility** — payload and manifest are written to temp
  files and ``os.replace``d into place, manifest LAST: a crash at any
  byte leaves either the previous complete checkpoint or none, never a
  half-written file a restore could mistake for complete.
* **Verified or refused** — the manifest records a sha256 over the
  payload bytes; restore recomputes it before deserializing anything.
  A torn write (``checkpoint_write`` fault), bit rot
  (``restore_corrupt`` fault), or truncation surfaces as typed
  ``CheckpointCorrupt`` and installs NOTHING — the server falls back to
  recompute, never serves partially-read durable state.
* **Replay past the watermark** — live ``Table.version`` tokens do not
  survive restarts, so the checkpoint captures each epoch's *logical*
  watermark instead: the valid-row mask plus per-column content digests
  of the rows the epoch folded.  ``rehydrate`` proves the live catalog
  table is an append-descendant of that watermark (every checkpointed
  row still present, bit-identical), publishes the recovered epoch
  under a synthetic negative version, and registers the leftover rows
  as one synthetic append step — the server's EXISTING version-chain
  catch-up then folds the suffix through the normal guarded fold path.
  Any mismatch (the table was replaced, a column diverged) quietly
  declines: the residency re-seeds from live data, which is always
  correct, just slower.

Kill switch: ``REPRO_SERVE_CKPT=off`` (checked by the ``AggServer``
entry points) makes ``checkpoint()`` a no-op and ``restore()`` return
0 — snapshots recompute/re-seed exactly as if no checkpoint existed.
"""
from __future__ import annotations

import glob
import hashlib
import io
import json
import os
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.relational import keyslot
from repro.relational.table import Table
from repro.reliability import faults

from . import incremental
from .guard import CheckpointCorrupt

__all__ = ["CheckpointCorrupt", "plan_fingerprint", "write_checkpoint",
           "read_checkpoint", "rehydrate"]

#: manifest format version — bump on any incompatible layout change;
#: restore refuses unknown formats (typed, never a misparse)
FORMAT = 1

_PREFIX = "ckpt-"


def plan_fingerprint(plan, name, keys) -> str:
    """Identity of a resident plan across processes.  ``id(plan)`` dies
    with the process, so checkpoints key on the plan's deterministic
    dataclass ``repr`` (plans are trees of dataclasses over strings,
    ints, and tuples — no memory addresses) plus the catalog table and
    key columns it serves."""
    blob = f"{name}|{tuple(keys)}|{plan!r}".encode()
    return hashlib.sha256(blob).hexdigest()


def _column_digest(table: Table, col: str, mask: np.ndarray) -> str:
    """Content digest of one column's VALID rows at a watermark (dtype
    included — a value-preserving dtype change is still a different
    table)."""
    a = np.asarray(table.columns[col])[: mask.shape[0]][mask]
    return hashlib.sha256(
        str(a.dtype).encode() + b"|" + a.tobytes()).hexdigest()


def _seq_of(path: str) -> int:
    base = os.path.basename(path)
    try:
        return int(base[len(_PREFIX):].split(".")[0])
    except ValueError:
        return -1


# ---------------------------------------------------------------------------
# Write
# ---------------------------------------------------------------------------


def write_checkpoint(server, directory: str) -> Optional[str]:
    """Serialize every published resident epoch of ``server`` (called
    under the server lock) into ``directory``; returns the manifest
    path, or None when nothing is resident.  Files are
    ``ckpt-<seq>.npz`` (one npz payload for all epochs) and
    ``ckpt-<seq>.json`` (the checksummed manifest), ``seq``
    monotonically above any checkpoint already in the directory."""
    picked = []
    for pid, res in server._residents.items():
        ep = res.current_epoch()
        ent = server._plans.get(pid)
        if ep is None or ent is None:
            continue
        picked.append((ent, res, ep))
    if not picked:
        return None
    os.makedirs(directory, exist_ok=True)
    seq = 1 + max(
        [_seq_of(p) for p in glob.glob(
            os.path.join(directory, _PREFIX + "*.json"))] or [0])
    arrays = {}
    recs = []
    catalog = {}
    for i, (ent, res, ep) in enumerate(picked):
        mask = np.asarray(ep.table.mask())
        arrays[f"r{i}__moments"] = np.asarray(ep.moments)
        arrays[f"r{i}__owner"] = np.asarray(ep.owner)
        arrays[f"r{i}__tbl"] = np.asarray(ep.state.tbl)
        arrays[f"r{i}__ktab"] = np.asarray(ep.state.ktab)
        arrays[f"r{i}__cnt"] = np.asarray(ep.state.cnt, np.int32)
        arrays[f"r{i}__mask"] = mask
        pay_names = list(ep.payloads)
        for j, n in enumerate(pay_names):
            arrays[f"r{i}__pay{j}"] = np.asarray(ep.payloads[n])
        recs.append({
            "fingerprint": plan_fingerprint(ent.submitted, res.name,
                                            res.keys),
            "table": res.name,
            "keys": list(res.keys),
            "bound": int(ep.bound),
            "bucket": int(ep.state.bucket),
            "expand": int(ep.state.expand),
            "folds": int(ep.folds),
            "inferred": bool(res.inferred),
            "payload_names": pay_names,
            "capacity": int(mask.shape[0]),
            "valid_rows": int(mask.sum()),
            "columns": {c: _column_digest(ep.table, c, mask)
                        for c in res._needed_cols()},
        })
        catalog.setdefault(res.name, {
            "capacity": int(mask.shape[0]),
            "valid_rows": int(mask.sum()),
            "mask_sha256": hashlib.sha256(mask.tobytes()).hexdigest(),
        })
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    sha = hashlib.sha256(payload).hexdigest()

    pname = f"{_PREFIX}{seq:06d}.npz"
    ppath = os.path.join(directory, pname)
    tmp = ppath + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
        if faults.fire("checkpoint_write"):
            # torn write: the process "died" mid-flush — the bytes on
            # disk are a prefix of the intended payload, but the
            # manifest checksum still names the full content, so a
            # later restore MUST detect the tear
            f.truncate(max(1, len(payload) // 2))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, ppath)

    manifest = {"format": FORMAT, "seq": seq, "payload": pname,
                "payload_sha256": sha, "catalog": catalog,
                "residents": recs}
    mpath = os.path.join(directory, f"{_PREFIX}{seq:06d}.json")
    mtmp = mpath + ".tmp"
    with open(mtmp, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(mtmp, mpath)     # manifest last: its presence IS commit
    return mpath


# ---------------------------------------------------------------------------
# Read
# ---------------------------------------------------------------------------


def read_checkpoint(server, directory: str) -> int:
    """Stage the newest checkpoint of ``directory`` into
    ``server._restored`` (called under the server lock); returns the
    number of resident payloads staged, 0 when the directory holds no
    manifest.  Raises ``CheckpointCorrupt`` — installing nothing — on
    any checksum, format, or deserialization failure."""
    manifests = sorted(glob.glob(os.path.join(directory,
                                              _PREFIX + "*.json")),
                       key=_seq_of)
    if not manifests:
        return 0
    mpath = manifests[-1]
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorrupt(
            f"checkpoint manifest unreadable: {e}", path=mpath) from e
    if manifest.get("format") != FORMAT:
        raise CheckpointCorrupt(
            f"checkpoint manifest format {manifest.get('format')!r} is "
            f"not the supported format {FORMAT}", path=mpath)
    ppath = os.path.join(directory, manifest.get("payload", ""))
    try:
        with open(ppath, "rb") as f:
            data = bytearray(f.read())
    except OSError as e:
        raise CheckpointCorrupt(
            f"checkpoint payload unreadable: {e}", path=ppath) from e
    if faults.fire("restore_corrupt") and data:
        data[len(data) // 2] ^= 0xFF       # bit rot on the read path
    sha = hashlib.sha256(bytes(data)).hexdigest()
    if sha != manifest.get("payload_sha256"):
        raise CheckpointCorrupt(
            "checkpoint payload failed its checksum (torn write or bit "
            "rot) — refusing the restore; snapshots will recompute",
            path=ppath)
    try:
        npz = np.load(io.BytesIO(bytes(data)), allow_pickle=False)
    except Exception as e:                   # noqa: BLE001 — typed out
        raise CheckpointCorrupt(
            f"checkpoint payload failed to deserialize: {e}",
            path=ppath) from e
    staged = 0
    try:
        for i, rec in enumerate(manifest.get("residents", ())):
            entry = {
                "rec": rec,
                "moments": npz[f"r{i}__moments"],
                "owner": npz[f"r{i}__owner"],
                "tbl": npz[f"r{i}__tbl"],
                "ktab": npz[f"r{i}__ktab"],
                "cnt": npz[f"r{i}__cnt"],
                "mask": npz[f"r{i}__mask"].astype(bool),
                "pays": [npz[f"r{i}__pay{j}"]
                         for j in range(len(rec["payload_names"]))],
            }
            server._restored[rec["fingerprint"]] = entry
            staged += 1
    except KeyError as e:
        # roll back this read's stagings: all-or-nothing
        for rec in manifest.get("residents", ()):
            server._restored.pop(rec.get("fingerprint"), None)
        raise CheckpointCorrupt(
            f"checkpoint payload is missing array {e} named by the "
            f"manifest", path=ppath) from e
    return staged


# ---------------------------------------------------------------------------
# Rehydrate
# ---------------------------------------------------------------------------


def rehydrate(server, ent):
    """Rebuild a ``ResidentAgg`` for plan entry ``ent`` from a staged
    checkpoint payload (called under the server lock from
    ``AggServer._rehydrate_resident``), or None when no staged payload
    matches or the live table diverged from the watermark.

    Matching is strict — the live table must be an append-descendant of
    the checkpointed watermark (every watermark row still valid, every
    needed column bit-identical over those rows).  On success the epoch
    publishes under a fresh synthetic negative version and the rows the
    live table holds beyond the watermark register as one synthetic
    append step at the bottom of the version chain, so the caller's
    normal catch-up folds them through the existing guarded fold path
    (never a special replay code path)."""
    if ent.slot_scan is None:
        return None
    fp = plan_fingerprint(ent.submitted, ent.slot_scan, ent.keys)
    got = server._restored.get(fp)
    if got is None:
        return None
    rec = got["rec"]
    live = server._catalog.get(rec["table"])
    if live is None:
        return None
    live_mask = np.asarray(live.mask())
    cmask = got["mask"]
    cap = int(cmask.shape[0])
    if cap > live.capacity:
        return None
    padded = np.zeros(live.capacity, bool)
    padded[:cap] = cmask
    if (padded & ~live_mask).any():          # a watermark row vanished
        return None
    for col, digest in rec["columns"].items():
        if col not in live.columns:
            return None
        if _column_digest(live, col, padded) != digest:
            return None
    res = incremental.ResidentAgg.admit(
        ent.plan, rec["table"], tuple(rec["keys"]), live,
        int(rec["bound"]))
    if res is None:
        return None
    res.inferred = bool(rec["inferred"])
    state = keyslot.SlotState(
        jnp.asarray(got["tbl"]), jnp.asarray(got["ktab"]),
        jnp.asarray(got["cnt"]), int(rec["bucket"]), int(rec["expand"]))
    payloads = {n: jnp.asarray(got["pays"][j])
                for j, n in enumerate(rec["payload_names"])}
    wtable = Table(live.columns, jnp.asarray(padded), live.group_bound)
    server._synth_version -= 1
    synth = server._synth_version
    ep = incremental.Epoch(
        state=state, moments=jnp.asarray(got["moments"]),
        owner=jnp.asarray(got["owner"]), payloads=payloads,
        bound=int(rec["bound"]), version=synth, epoch_id=1,
        folds=int(rec["folds"]), table=wtable)
    res._epoch = ep     # pre-publication: res is not yet visible
    # register the suffix past the watermark as the BOTTOM step of the
    # version chain: rows valid live but not at the watermark, minus any
    # already covered by recorded append steps
    name = rec["table"]
    v = live.version
    chain = []
    while True:
        step = server._appends.get((name, v))
        if step is None:
            break
        v, pos = step
        chain.append(np.asarray(pos))
    extra = np.flatnonzero(live_mask & ~padded)
    if chain:
        extra = np.setdiff1d(extra, np.concatenate(chain))
    server._appends[(name, v)] = (synth, extra.astype(np.int64))
    del server._restored[fp]                 # consumed
    return res
