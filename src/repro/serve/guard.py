"""Serving-layer fault tolerance: the structured error taxonomy, the
poison-sentinel detector, and the degradation circuit breaker.

The serving layer's failure contract (docs/serving.md, "Failure
semantics") is that **every failure is a typed per-request result**: a
``ServeError`` subclass set on the request's future (or raised from the
synchronous ``execute``), never a dispatcher-killing stray exception and
never a silent NaN handed to the caller as data.  This module is the
vocabulary of that contract plus the two detectors that enforce its
hardest clauses:

* ``is_poisoned`` — the O(num_segments) post-launch scan for the poison
  sentinels ``group_bound.poison_overflow`` writes when a *traced* dense
  bound check fails (NaN / iinfo.min / iinfo.max — the PR-3/PR-5
  contract, shared via ``group_bound.poison_sentinel``).  Traced bound
  failures are exactly the ones the eager slot-build validation cannot
  see: vmapped per-lane filters give every lane its own group count, and
  any lane can overflow an inferred bound that the unfiltered table
  validated.  Detection converts that silent whole-column corruption
  into ``PoisonedResult`` — or, for *inferred* bounds, into a bounded
  double-and-rebuild retry (``AggServer._guarded_launch``).
* ``CircuitBreaker`` — the per-(plan, parameter-signature) degradation
  ladder.  Repeated kernel-backend failure trips the breaker open; while
  open, launches route to a *degraded* executable traced under
  ``reliability.degrade.force_backend("jnp")`` — the exact segment-ops
  path that always exists (Froid's principle: keep the un-optimized form
  as a semantic fallback).  After a cool-down one trial launch probes the
  primary (half-open); success closes the breaker.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

__all__ = [
    "ServeError", "BoundOverflow", "SlotTableStale", "DeadlineExceeded",
    "QueueFull", "PoisonedResult", "BackendFailure", "ServerClosed",
    "CheckpointCorrupt", "is_poisoned", "strip_poison_stamp",
    "CircuitBreaker", "GuardStats",
]


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------


class ServeError(Exception):
    """Base of every structured serving failure.  Callers that care which
    failure they got match the subclass; callers that only care *that*
    the request failed catch this one type."""


class BoundOverflow(ServeError, ValueError):
    """A declared dense group bound could not hold the data's key set.
    Subclasses ValueError so the pre-guard eager-raise contract
    (``GroupBoundOverflow``) keeps holding for callers that matched on
    it; the original message is preserved."""


class SlotTableStale(ServeError):
    """A cached slot table claimed a ``Table.version`` the catalog no
    longer holds and rebuilding did not converge within the bounded
    attempts.  Structurally this cannot happen — the cache key carries
    the version — so surfacing it loudly (instead of serving the stale
    arrays) is the point."""


class DeadlineExceeded(ServeError):
    """The request's deadline passed while it waited in the admission
    queue; the dispatcher shed it without launching."""


class QueueFull(ServeError):
    """The bounded admission queue was at capacity at submit time; the
    request was rejected immediately (backpressure, not buffering)."""


class PoisonedResult(ServeError):
    """The launch completed but the result carries the whole-column
    poison stamp — a *traced* dense-bound check failed inside the
    executable (per-lane overflow under vmap, or a skipped eager
    validation).  The caller never sees the NaNs as data."""


class BackendFailure(ServeError):
    """The kernel backend raised and the degradation ladder could not
    serve the request either.  ``__cause__`` carries the underlying
    exception."""


class ServerClosed(ServeError, RuntimeError):
    """The request arrived after ``close()`` (or was queued when a
    non-draining close dropped the queue).  Subclasses RuntimeError for
    the pre-guard ``submit``-after-close contract."""


class CheckpointCorrupt(ServeError):
    """A checkpoint manifest or payload failed checksum / format
    verification at restore time (torn write, bit rot, truncation).
    The restore installs NOTHING — the server keeps serving from live
    state (recompute), never from partially-read durable state.  The
    ``path`` attribute names the offending file."""

    def __init__(self, msg: str, path=None):
        super().__init__(msg)
        self.path = path


# ---------------------------------------------------------------------------
# Poison detection
# ---------------------------------------------------------------------------


def is_poisoned(table) -> bool:
    """True when ``table`` carries the whole-column poison stamp of a
    failed traced bound check: every *strong-sentinel* column (floating →
    NaN, signed int → iinfo.min, unsigned int → iinfo.max) reads the
    sentinel in **all** valid rows.  Bool columns are excluded — their
    sentinel (False) is an everyday value — and a table with no strong
    column at all reports False (undetectable, documented).  Requiring
    *every* strong column to be fully stamped is what keeps legitimate
    NaN aggregates (NaN inputs propagating through a sum) from
    false-positiving: ``poison_overflow`` stamps all columns or none.

    O(num_segments) per column; blocks on the device values (the caller
    is about to hand them out anyway).
    """
    mask = np.asarray(table.mask())
    if not mask.any():
        return False
    strong = False
    for col in table.columns.values():
        a = np.asarray(col)[mask]
        d = a.dtype
        if np.issubdtype(d, np.floating):
            hit = bool(np.isnan(a).all())
        elif np.issubdtype(d, np.unsignedinteger):
            hit = bool((a == np.iinfo(d).max).all())
        elif np.issubdtype(d, np.signedinteger) and d != np.bool_:
            hit = bool((a == np.iinfo(d).min).all())
        else:
            continue
        if not hit:
            return False
        strong = True
    return strong


def strip_poison_stamp(table):
    """Drop the auxiliary ``group_bound.STAMP_COL`` from a result table
    (identity when absent).  The stamp exists only so the bool-only
    blind spot is detectable — the caller sees the columns they asked
    for; the serving layer applies this AFTER its poison scan."""
    from repro.relational.group_bound import STAMP_COL
    if STAMP_COL not in table.columns:
        return table
    from repro.relational.table import Table
    cols = {k: v for k, v in table.columns.items() if k != STAMP_COL}
    return Table(cols, table.valid, table.group_bound)


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------


@dataclass
class GuardStats:
    """Counters the guard emits; the chaos battery and the bench assert
    on them.  All monotonic since server construction."""
    poisoned: int = 0            # launches whose result carried the stamp
    poison_retries: int = 0      # double-and-rebuild retries taken
    stale_rebuilds: int = 0      # slot tables rebuilt on a version mismatch
    deadline_shed: int = 0       # requests shed expired from the queue
    queue_rejects: int = 0       # requests rejected at admission
    backend_failures: int = 0    # primary-executable launch exceptions
    degraded_launches: int = 0   # batches served by the jnp fallback
    breaker_trips: int = 0       # closed → open transitions
    breaker_recoveries: int = 0  # half-open trial successes (open → closed)
    dispatcher_restarts: int = 0  # dispatcher threads respawned after death


class CircuitBreaker:
    """Per-(plan, parameter-signature) three-state breaker.

    ``closed`` — launches take the primary executable; consecutive
    backend failures count up, and at ``threshold`` the breaker trips
    ``open``.  ``open`` — launches take the degraded (jnp) executable
    without touching the primary, until ``cooldown_s`` has passed, at
    which point the breaker is ``half-open``: ONE launch probes the
    primary; success closes the breaker (counter reset), failure re-opens
    it with a fresh cool-down.  The server calls every method under its
    own lock, so the breaker itself needs none; ``clock`` is injectable
    so the chaos tests drive the cool-down deterministically.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 5.0,
                 clock=time.monotonic):
        self.threshold = max(1, int(threshold))
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._failures = 0
        self._opened_at = None   # not None ⇔ open (or half-open probing)

    @property
    def state(self) -> str:
        if self._opened_at is None:
            return "closed"
        if self._clock() - self._opened_at >= self.cooldown_s:
            return "half-open"
        return "open"

    def use_degraded(self) -> bool:
        """Route decision for the next launch: True → degraded
        executable.  Half-open returns False exactly once per cool-down
        expiry (the probe); a failed probe re-opens before the next
        call asks."""
        return self.state == "open"

    def record_success(self) -> bool:
        """A primary launch succeeded.  Returns True when this was a
        half-open probe that just closed the breaker."""
        recovered = self._opened_at is not None
        self._failures = 0
        self._opened_at = None
        return recovered

    def record_failure(self) -> bool:
        """A primary launch raised.  Returns True when this failure
        tripped the breaker (closed → open, or a failed half-open
        probe re-arming the cool-down)."""
        self._failures += 1
        was_open = self._opened_at is not None
        if was_open or self._failures >= self.threshold:
            self._opened_at = self._clock()
            return True
        return False
