"""Batched serving loop: continuous batching over a decode-step jit.

The serve step is ONE jit (decode_step over the full batch); requests join
and leave slots between steps (continuous batching).  Slot state is
device-resident; the host only touches per-step token ids.  The decode
attention inside is the paper-contract aggregate (see models/attention.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = field(default_factory=list)
    done: bool = False


class Server:
    """Fixed-slot continuous batching server over an LM."""

    def __init__(self, lm, params, *, slots: int, max_len: int):
        self.lm = lm
        self.params = params
        self.slots = slots
        self.cache = lm.init_cache(slots, max_len, params=params)
        self.active: list[Optional[Request]] = [None] * slots
        self.pending: list[Request] = []
        self.tokens = np.zeros((slots, 1), np.int32)
        self._step = jax.jit(lm.decode_step)

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _admit(self) -> None:
        for i in range(self.slots):
            if self.active[i] is None and self.pending:
                req = self.pending.pop(0)
                self.active[i] = req
                # prefill-by-decode: feed prompt tokens one at a time
                # (prompt chunking is the serving example's job)
                req._cursor = 0
                self.tokens[i, 0] = req.prompt[0]

    def step(self) -> None:
        self._admit()
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(self.tokens))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            req._cursor += 1
            if req._cursor < len(req.prompt):
                self.tokens[i, 0] = req.prompt[req._cursor]   # still prefilling
                continue
            req.out.append(int(nxt[i]))
            self.tokens[i, 0] = nxt[i]
            if len(req.out) >= req.max_new:
                req.done = True
                self.active[i] = None

    def run(self, max_steps: int = 1000) -> None:
        steps = 0
        while (self.pending or any(self.active)) and steps < max_steps:
            self.step()
            steps += 1
