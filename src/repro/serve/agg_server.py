"""Aggregate-serving layer: compiled-plan + slot-table caching with
same-shape request batching.

Aggify turns a cursor loop into ONE pipelined aggregate query — but
production traffic is thousands of *parameterized repeats* of a few such
queries (every dashboard tile, every per-user UDF invocation), and a bare
``engine.execute`` pays three per-call costs the repeats never need:

* **jaxpr retrace + XLA compile** — the plan, catalog shapes, and
  parameter dtypes fully determine the computation; only parameter
  *values* change between calls.  The server keys an executable cache on
  exactly that: plan identity, the catalog shape/dtype signature, the
  parameter signature, the ``bucket_group_bound`` shape bucket, and the
  batch-size bucket — all finite, so the trace count is bounded by the
  number of distinct shape buckets, not the request count.
* **key→slot probing** (``relational/keyslot.py``) — the sort-free
  grouped route re-derives the same hash-slotted segment assignment from
  the same rows on every call.  The server builds it once per
  ``(table version, key columns, bucket)``, validates the dense bound
  *concretely* (overflow raises here, not inside a trace), and provides
  it to the executable as an **argument** via ``keyslot.provide_slots``.
  Passing slots as arguments — never baking them into the trace as
  constants — is what makes stale reads structurally impossible: a
  mutated table carries a fresh ``Table.version``, the slot cache misses,
  and the same compiled executable runs with the rebuilt arrays.  For
  row-sharded tables the cached assignment doubles as the *stable
  cross-call global* slot table the per-shard launcher cannot offer.
* **one-request-at-a-time launches** — concurrent parameterized calls
  with the same plan and parameter signature coalesce into one
  ``jax.vmap`` launch over stacked per-request parameter vectors
  (the grouped-decorrelation trick of ``benchmarks/tpch_loops.py``,
  generalized from benchmark code into the engine): tables and slot
  arrays broadcast, parameters batch.

When a grouped root plan declares no ``max_groups`` and its input table
carries no ``declare_group_bound`` hint, the server infers one: the
linear-counting ``distinct_count_sketch`` estimates the distinct key
count, the estimate is padded and bucketed, and the eager slot build
*validates* it (an overflowing inferred bound doubles and rebuilds —
never trusted, per the validated-not-assumed rule of
relational/group_bound.py).

**Failure semantics** (the guard layer, default on): every failure is a
typed ``serve.guard.ServeError`` set on the request's future — a bound
the data outgrew (``BoundOverflow``), a poisoned launch converted from
silent NaNs to ``PoisonedResult`` (retried with a doubled bound when the
bound was inferred), a deadline shed in the queue
(``DeadlineExceeded``), admission backpressure (``QueueFull``), a
kernel-backend failure the degradation ladder couldn't absorb
(``BackendFailure``).  The dispatcher thread is supervised (respawned on
death) and the per-(plan, signature) circuit breaker trips repeated
backend failures onto the always-correct jnp executable.  See
docs/serving.md, "Failure semantics".

Kill switches: ``REPRO_AGG_SERVE=off`` bypasses every cache and batch —
each call runs a plain eager ``engine.execute``;
``REPRO_SERVE_GUARD=off`` disables the guard layer only (PR-6 serving
behavior: caches and batching, raw exceptions).

See docs/serving.md for the cache-key / invalidation / batching contract.
"""
from __future__ import annotations

import copy
import dataclasses
import math
import threading
import time
import warnings
from concurrent.futures import Future
from contextlib import nullcontext
from dataclasses import dataclass, field
from dataclasses import replace as _dc_replace
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import flags
from repro.relational import keyslot
from repro.relational.engine import execute
from repro.relational.group_bound import GroupBoundOverflow, resolve_group_bound
from repro.relational.keyslot import check_slot_overflow
from repro.relational.plan import AggCall, GroupAgg, Plan, Scan
from repro.relational.table import Table
from repro.reliability import degrade, faults

from . import incremental
from .guard import (BackendFailure, BoundOverflow, CircuitBreaker,
                    DeadlineExceeded, GuardStats, PoisonedResult, QueueFull,
                    ServeError, ServerClosed, SlotTableStale, is_poisoned,
                    strip_poison_stamp)
from .incremental import IncrementalIneligible

__all__ = ["AggServer", "ServeStats", "ServeRequest", "ServeResult",
           "serving_enabled", "guard_enabled"]


def serving_enabled() -> bool:
    """Kill switch for the whole serving layer (default: on).
    ``REPRO_AGG_SERVE=off`` turns every call into a plain eager
    ``engine.execute`` — no executable cache, no slot-table cache, no
    batching."""
    return flags.enabled("REPRO_AGG_SERVE")


def guard_enabled() -> bool:
    """Default for ``AggServer(guard=...)``: on unless
    ``REPRO_SERVE_GUARD=off``.  Guard-off restores the PR-6 serving
    behavior exactly — caches and batching, raw exceptions on futures,
    no poison scan, no breaker, unbounded queue."""
    return flags.enabled("REPRO_SERVE_GUARD")


#: bounded poison recovery: an inferred bound that poisons a launch is
#: doubled and rebuilt at most this many times before the failure
#: surfaces as ``PoisonedResult``
_MAX_POISON_RETRIES = 2

#: bounded staleness recovery: a slot-table entry whose version tag
#: disagrees with the catalog is dropped and rebuilt at most this many
#: times per launch before ``SlotTableStale`` surfaces
_MAX_STALE_REBUILDS = 2


@dataclass
class ServeStats:
    """Counters the tests and the serving bench assert on.  ``traces``
    increments inside the jitted body (a Python side effect fires only
    while tracing), so it counts actual retraces, not calls.
    ``slot_extends`` counts incremental slot-table extensions (an append
    that reused the resident assignment instead of rebuilding);
    ``folds`` counts resident micro-batch moment folds."""
    requests: int = 0
    batches: int = 0
    traces: int = 0
    slot_builds: int = 0
    slot_hits: int = 0
    slot_extends: int = 0
    appends: int = 0
    ingests: int = 0
    folds: int = 0
    snapshots: int = 0
    epoch_reads: int = 0    # lock-free published-epoch decodes
    checkpoints: int = 0    # durable checkpoints written
    restores: int = 0       # durable checkpoints restored


@dataclass(frozen=True)
class ServeRequest:
    """The ONE request shape every serving entry point speaks (the typed
    front door; ``execute``/``submit`` are thin wrappers over it).

    * ``plan``        — the plan to serve (interned by identity);
    * ``params``      — scalar parameter bindings (values vary per call,
                        the signature keys the executable cache);
    * ``deadline``    — seconds from submission after which a QUEUED
                        request is shed with ``DeadlineExceeded``
                        (async path only);
    * ``consistency`` — ``"latest"`` (default): compute over the current
                        catalog tables; ``"snapshot"``: serve a grouped
                        plan from its resident incremental moment state
                        (``AggServer.snapshot`` — O(num_segments)
                        finalize, no history re-read), catching up on
                        pending appends first; ``"epoch"``: decode the
                        resident's currently *published* epoch with NO
                        server lock — never blocks on an in-flight fold
                        or ``update_table``, may trail the newest append
                        by the fold in flight (the result's ``version``
                        is the epoch watermark actually served).  Both
                        fall back to a full compute when the plan is
                        ineligible or ``REPRO_INCR_AGG=off``.
    """
    plan: Plan
    params: Optional[Mapping[str, Any]] = None
    deadline: Optional[float] = None
    consistency: str = "latest"


@dataclass(frozen=True)
class ServeResult:
    """What a ``ServeRequest`` resolves to: the result ``table``, the
    ``version`` of the plan's slot-scan catalog table at launch (None
    when the plan has no slot scan — e.g. joins), and a point-in-time
    copy of the server's ``stats`` counters."""
    table: Table
    version: Optional[int]
    stats: "ServeStats"


#: safety padding on the sketch estimate before bucketing: linear
#: counting is unbiased but noisy (±O(√m) keys), and the power-of-two
#: bucket only forgives undershoot up to the next boundary
_SKETCH_PAD = 1.3
_SKETCH_SLACK = 16


@dataclass
class _PlanEntry:
    """Per-plan serving state.  ``plan`` is the plan as served — when the
    bound was inferred it differs from the submitted plan by
    ``max_groups`` only.  Keyed by ``id(submitted plan)``; the entry
    holds a strong reference to the submitted plan so the id stays
    valid."""
    submitted: Plan
    plan: Plan
    keys: Tuple[str, ...] = ()
    bound: Optional[int] = None      # validated bucket; None → no slots
    slot_scan: Optional[str] = None  # catalog table the slots align to
    inferred: bool = False           # bound came from the sketch (growable)
    execs: Dict[Any, Any] = field(default_factory=dict)


class AggServer:
    """Serve parameterized aggregate plans over a named catalog.

    ``serve(ServeRequest) -> ServeResult`` is the typed request path;
    ``execute(plan, params)`` is its synchronous positional wrapper
    (cache-aware, one request per launch) and ``submit(plan, params) ->
    Future`` / ``serve_async`` the concurrent path — a dispatcher thread
    coalesces same-(plan, parameter-signature) requests into one vmapped
    launch of up to ``max_batch`` lanes.  Writes go through the typed
    mutation API: ``update_table`` (replace — full invalidation),
    ``append_rows`` (append — executables survive, slot tables extend),
    ``ingest`` (append + fold into resident incremental aggregates;
    ``snapshot(plan)`` finalizes them in O(num_segments)).
    ``execute_uncached`` reproduces the pre-serving cost model (fresh
    jit per call) for benchmarking."""

    def __init__(self, catalog: Mapping[str, Table], *,
                 max_batch: int = 64, batch_window_s: float = 0.001,
                 infer_bounds: bool = True, guard: Optional[bool] = None,
                 max_queue: int = 1024, breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 5.0, breaker_clock=None):
        self._catalog: Dict[str, Table] = dict(catalog)
        self._max_batch = max(1, int(max_batch))
        self._batch_window = float(batch_window_s)
        self._infer_bounds = bool(infer_bounds)
        self._guard = guard_enabled() if guard is None else bool(guard)
        self._max_queue = max(1, int(max_queue))
        self._breaker_threshold = int(breaker_threshold)
        self._breaker_cooldown = float(breaker_cooldown_s)
        self._breaker_clock = breaker_clock or time.monotonic
        self._lock = threading.RLock()
        #: dedicated small mutex for counter mutation and stat/breaker
        #: snapshots — ``describe()`` and ``ServeStats`` reads never
        #: contend with a fold holding ``_lock``.  Lock order where both
        #: are held: ``_lock`` then ``_stats_lock``, never the reverse.
        self._stats_lock = threading.Lock()
        self._cv = threading.Condition()
        self._plans: Dict[int, _PlanEntry] = {}
        #: (table name, table version, key names, bucket) →
        #: (version tag, slot arrays, SlotState | None) — the tag
        #: re-proves the version at every hit (see _slot_table); the
        #: state lets an append EXTEND the assignment instead of
        #: rebuilding it
        self._slots: Dict[Any, tuple] = {}
        #: (table name, new version) → (parent version, appended
        #: positions) — the append chain slot extension and snapshot
        #: catch-up walk; broken by update_table (full invalidation)
        self._appends: Dict[Any, tuple] = {}
        #: id(plan) → ResidentAgg — resident incremental moment state
        #: (the plan entry in _plans holds the strong plan reference)
        self._residents: Dict[int, incremental.ResidentAgg] = {}
        self._pending: Dict[Any, tuple] = {}
        self._breakers: Dict[Any, CircuitBreaker] = {}
        #: resident-state payloads recovered by ``restore`` awaiting a
        #: structurally matching plan: fingerprint → rehydration record
        #: (serve/checkpoint.py); consumed at first ``snapshot``
        self._restored: Dict[str, dict] = {}
        #: synthetic version tokens for rehydrated watermarks — negative
        #: (live ``Table.version`` tokens are positive, so they never
        #: collide), one per rehydration
        self._synth_version = 0
        self._dispatcher: Optional[threading.Thread] = None
        self._closed = False
        self.stats = ServeStats()
        self.guard_stats = GuardStats()

    # -- stats plumbing ----------------------------------------------------
    def _bump(self, name: str, k: int = 1) -> None:
        with self._stats_lock:
            setattr(self.stats, name, getattr(self.stats, name) + k)

    def _gbump(self, name: str, k: int = 1) -> None:
        with self._stats_lock:
            setattr(self.guard_stats, name,
                    getattr(self.guard_stats, name) + k)

    def _stats_copy(self) -> ServeStats:
        with self._stats_lock:
            return copy.copy(self.stats)

    # -- catalog writes: the typed mutation API ----------------------------
    #
    # Three verbs with three invalidation contracts (docs/serving.md):
    #
    #   update_table(name, t)  REPLACE — content may change arbitrarily.
    #       Invalidates slot tables for the table, the executables of
    #       every plan scanning it, its resident incremental state, and
    #       breaks its append chain.
    #   append_rows(name, rows)  APPEND — existing rows are immutable.
    #       Bumps the version; executables SURVIVE (shapes unchanged
    #       while rows fit the spare capacity) and slot tables EXTEND
    #       incrementally instead of rebuilding.
    #   ingest(name, batch)  APPEND + FOLD — append_rows plus an O(batch)
    #       fold of the batch's moments into every resident incremental
    #       aggregate registered on the table.

    def update_table(self, name: str, table: Table) -> None:
        """REPLACE a catalog table — the big-hammer verb: arbitrary
        content change, full invalidation (slot tables, the executables
        of every plan scanning ``name``, resident incremental state, the
        append chain).  Use ``append_rows``/``ingest`` for append-shaped
        mutations — they keep the caches warm; an append-shaped call
        here draws a ``DeprecationWarning`` pointing at them."""
        with self._lock:
            self._check_open()
            old = self._catalog.get(name)
            if old is not None and self._append_shaped(old, table):
                warnings.warn(
                    f"update_table({name!r}, ...) received an append-shaped "
                    "table (old rows intact, new rows added).  Migrate to "
                    "append_rows(name, rows) — preserves compiled "
                    "executables and extends the slot table incrementally — "
                    "or ingest(name, batch) to also fold resident "
                    "incremental aggregates.  update_table keeps "
                    "full-replace semantics: executables, slot tables, and "
                    "resident state for this table are all invalidated.",
                    DeprecationWarning, stacklevel=2)
            self._catalog[name] = table
            self._invalidate(name)

    def append_rows(self, name: str, rows) -> int:
        """APPEND rows to a catalog table; returns the new
        ``Table.version``.  ``rows`` is a Table (its invalid rows are
        dropped) or a mapping of column → array with exactly the
        table's columns.  Rows land in the first invalid positions of
        the fixed-capacity layout; when the spare capacity runs out the
        table GROWS (capacity at least doubles — this changes column
        shapes, so executables legitimately retrace; appends that fit
        the spare capacity change no shape and reuse every executable).
        The append is recorded on the version chain, so slot tables
        extend incrementally (``keyslot.slot_ids_extend``) and resident
        incremental aggregates catch up at the next snapshot.
        ``group_bound`` hints survive (unlike ``relational.concat``)."""
        with self._lock:
            self._check_open()
            t = self._catalog[name]
            prev_version = t.version
            cols, nb = self._coerce_rows(t, rows)
            if nb == 0:
                return t.version
            mask = (np.ones(t.capacity, bool) if t.valid is None
                    else np.asarray(t.valid))
            holes = np.flatnonzero(~mask)
            if len(holes) < nb:
                t = self._grow_capacity(t, nb - len(holes))
                mask = np.asarray(t.valid)
                holes = np.flatnonzero(~mask)
            pos = np.ascontiguousarray(holes[:nb])
            posj = jnp.asarray(pos, jnp.int32)
            new_cols = {c: a.at[posj].set(
                jnp.asarray(cols[c]).astype(a.dtype))
                for c, a in t.columns.items()}
            new_valid = jnp.asarray(mask).at[posj].set(True)
            t2 = Table(new_cols, new_valid, t.group_bound)
            self._catalog[name] = t2
            self._appends[(name, t2.version)] = (prev_version, pos)
            self._trim_appends(name)
            self._bump("appends")
            return t2.version

    def ingest(self, name: str, batch) -> int:
        """APPEND + FOLD: ``append_rows`` the micro-batch, then fold its
        moments into every resident incremental aggregate registered on
        ``name`` — O(batch) slotting + aggregation and O(num_segments)
        merges per resident plan, never an O(table) recompute.  Returns
        the new table version.  Under the guard a fold failure follows
        the serving ladder (degraded jnp retry → ``BackendFailure``; an
        overflowing inferred bound doubles and retries →
        ``BoundOverflow`` when declared); a failed fold NEVER corrupts
        the resident state (folds commit atomically), and the append
        itself always lands.  ``REPRO_INCR_AGG=off`` reduces this to
        ``append_rows`` (residents drop; snapshots recompute).
        Raises typed ``ServerClosed`` after ``close()`` — a fold already
        holding the lock when ``close`` lands completes and commits; it
        is never torn down mid-commit."""
        with self._lock:
            self._check_open()
            before = self._catalog[name].version
            version = self.append_rows(name, batch)
            self._bump("ingests")
            if not incremental.incremental_enabled() \
                    or not serving_enabled():
                for pid, res in list(self._residents.items()):
                    if res.name == name:
                        del self._residents[pid]
                return version
            if version != before:
                self._fold_residents(name)
            return version

    def table(self, name: str) -> Table:
        with self._lock:
            return self._catalog[name]

    # -- mutation plumbing -------------------------------------------------
    def _check_open(self) -> None:
        """Typed refusal for mutation verbs racing ``close()``: a verb
        that acquired the server lock before the close commits in full
        (fold-and-commit is atomic under the lock); one that arrives
        after loses with ``ServerClosed``, never a half-commit."""
        if self._closed:
            raise ServerClosed("AggServer is closed")

    def _invalidate(self, name: str) -> None:
        """Full invalidation for a REPLACE write on ``name``."""
        self._slots = {k: v for k, v in self._slots.items()
                       if k[0] != name}
        self._appends = {k: v for k, v in self._appends.items()
                         if k[0] != name}
        for pid, res in list(self._residents.items()):
            if res.name == name:
                del self._residents[pid]
        for ent in self._plans.values():
            if name in self._plan_tables(ent.submitted):
                ent.execs.clear()

    @staticmethod
    def _plan_tables(plan: Plan) -> set:
        """Catalog table names a plan tree scans."""
        names, stack = set(), [plan]
        while stack:
            p = stack.pop()
            if isinstance(p, Scan):
                names.add(p.table)
                continue
            if dataclasses.is_dataclass(p):
                for f in dataclasses.fields(p):
                    v = getattr(p, f.name, None)
                    if isinstance(v, Plan):
                        stack.append(v)
        return names

    @staticmethod
    def _append_shaped(old: Table, new: Table) -> bool:
        """Heuristic behind the update_table deprecation warning: True
        when ``new`` is ``old`` with rows added — same columns/dtypes,
        old rows bit-identical in the prefix, old validity preserved,
        and at least one row actually appended."""
        if set(old.columns) != set(new.columns):
            return False
        if new.capacity < old.capacity:
            return False
        oc = old.capacity
        om = np.asarray(old.mask())
        nm = np.asarray(new.mask())
        if not bool((om <= nm[:oc]).all()):      # no row was invalidated
            return False
        for c, a in old.columns.items():
            b = new.columns[c]
            if isinstance(a, jax.core.Tracer) or isinstance(b, jax.core.Tracer):
                return False
            if b.dtype != a.dtype:
                return False
            # appends may fill previously-invalid holes, so only the
            # VALID old rows must survive bit-identically
            if not np.array_equal(np.asarray(a)[om],
                                  np.asarray(b)[:oc][om]):
                return False
        return int(nm.sum()) > int(om.sum())     # and rows were added

    @staticmethod
    def _coerce_rows(t: Table, rows) -> tuple:
        """Normalize an append payload to (column → np array, row count);
        a Table payload drops its invalid rows first."""
        if isinstance(rows, Table):
            keep = np.flatnonzero(np.asarray(rows.mask()))
            cols = {c: np.asarray(a)[keep] for c, a in rows.columns.items()}
        else:
            cols = {c: np.asarray(a) for c, a in dict(rows).items()}
        if set(cols) != set(t.columns):
            raise ValueError(
                f"append columns {sorted(cols)} do not match table "
                f"columns {sorted(t.columns)}")
        lens = {a.shape[0] for a in cols.values()}
        if len(lens) > 1:
            raise ValueError(f"append columns disagree on length: {lens}")
        return cols, (lens.pop() if lens else 0)

    @staticmethod
    def _grow_capacity(t: Table, need: int) -> Table:
        """Grow a table's fixed capacity by at least ``need`` spare rows
        (geometric: at least doubles), padding columns with zeros and the
        validity mask with False.  Shape change ⇒ executables keyed on
        the catalog signature legitimately miss."""
        extra = max(int(need), t.capacity)
        cols = {c: jnp.concatenate(
            [a, jnp.zeros((extra,) + a.shape[1:], a.dtype)])
            for c, a in t.columns.items()}
        valid = jnp.concatenate([t.mask(), jnp.zeros(extra, bool)])
        return Table(cols, valid, t.group_bound)

    _MAX_APPEND_CHAIN = 64

    def _trim_appends(self, name: str) -> None:
        ours = [k for k in self._appends if k[0] == name]
        for k in ours[:-self._MAX_APPEND_CHAIN]:
            del self._appends[k]

    def _chain_positions(self, name: str, from_version: int,
                         to_version: int):
        """Appended positions between two versions of ``name`` (oldest
        first, concatenated), or None when the chain is broken (an
        update_table happened, or the chain was trimmed)."""
        pend, v = [], to_version
        while v != from_version:
            got = self._appends.get((name, v))
            if got is None:
                return None
            v, pos = got
            pend.append(pos)
        if not pend:
            return np.zeros(0, np.int64)
        return np.concatenate(pend[::-1])

    # -- introspection -----------------------------------------------------
    def describe(self, plan: Plan) -> dict:
        """Serving decisions for a plan (tests/bench introspection).
        Lock-free for an already-prepared plan: the entry lookup and the
        counter/breaker snapshot take only the small stats mutex, so a
        long fold or ``update_table`` holding the server lock never
        blocks this read.  An unprepared plan pays one locked
        ``_prepare`` (its first ``serve`` would have paid it anyway)."""
        ent = self._plans.get(id(plan))
        if ent is None:
            with self._lock:
                ent = self._prepare(plan)
        with self._stats_lock:
            breakers = {psig: br.state
                        for (pid, psig), br in self._breakers.items()
                        if pid == id(ent.submitted)}
        return {
            "max_groups": getattr(ent.plan, "max_groups", None),
            "bound": ent.bound,
            "slot_scan": ent.slot_scan,
            "inferred": ent.inferred,
            "executables": len(ent.execs),
            "guard": self._guard,
            "breakers": breakers,
        }

    # -- the typed request path --------------------------------------------
    def serve(self, request: ServeRequest) -> ServeResult:
        """Synchronous service of one ``ServeRequest`` — the primary
        entry point (``execute`` is the thin positional wrapper).
        ``consistency="latest"`` computes over the current catalog;
        ``consistency="snapshot"`` finalizes the plan's resident
        incremental moment state (``snapshot``) — parameterized plans
        and ineligible plans fall back to a latest compute.  Deadlines
        apply to QUEUED requests only, i.e. to ``serve_async``."""
        self._check_consistency(request)
        if request.consistency in ("snapshot", "epoch") \
                and not request.params:
            table, version = self._snapshot_versioned(
                request.plan, request.consistency)
            return ServeResult(table=table, version=version,
                               stats=self._stats_copy())
        table = self._execute(request.plan, request.params)
        return self._result(request, table)

    def serve_async(self, request: ServeRequest) -> Future:
        """``serve`` through the batching dispatcher: returns a Future
        resolving to a ``ServeResult`` (or a typed ``ServeError`` under
        the guard — ``request.deadline`` seconds from now sheds the
        request with ``DeadlineExceeded`` while queued).  Snapshot-
        consistency requests resolve inline (the resident finalize is
        O(num_segments) — there is nothing to batch)."""
        self._check_consistency(request)
        if request.consistency in ("snapshot", "epoch") \
                and not request.params:
            fut: Future = Future()
            try:
                fut.set_result(self.serve(request))
            except Exception as e:      # noqa: BLE001 — future carries it
                fut.set_exception(e)
            return fut
        inner = self.submit(request.plan, request.params,
                            deadline=request.deadline)
        out: Future = Future()

        def _done(f: Future) -> None:
            e = f.exception()
            if e is not None:
                out.set_exception(e)
                return
            try:
                out.set_result(self._result(request, f.result()))
            except Exception as ex:     # noqa: BLE001 — future carries it
                out.set_exception(ex)

        inner.add_done_callback(_done)
        return out

    @staticmethod
    def _check_consistency(request: ServeRequest) -> None:
        if request.consistency not in ("latest", "snapshot", "epoch"):
            raise ValueError(
                f"unknown consistency {request.consistency!r} "
                "(expected 'latest', 'snapshot' or 'epoch')")

    def _live_version(self, plan: Plan) -> Optional[int]:
        """The plan's slot-scan catalog version (None when the plan has
        no slot scan).  Lock-free: dict reads are atomic and the result
        is advisory (a concurrent writer may already have moved on)."""
        ent = self._plans.get(id(plan))
        name = ent.slot_scan if ent is not None else None
        t = self._catalog.get(name) if name is not None else None
        return t.version if t is not None else None

    def _result(self, request: ServeRequest, table: Table) -> ServeResult:
        return ServeResult(table=table,
                           version=self._live_version(request.plan),
                           stats=self._stats_copy())

    # -- synchronous path (back-compat wrapper) ----------------------------
    def execute(self, plan: Plan, params: Optional[Mapping[str, Any]] = None
                ) -> Table:
        """Cache-aware execution of one parameterized request — the
        positional wrapper over ``serve(ServeRequest(plan, params))``.
        Serialized under the server lock (deterministic trace
        accounting); use ``submit``/``serve_async`` for concurrency."""
        return self.serve(ServeRequest(plan=plan, params=params)).table

    def _execute(self, plan: Plan,
                 params: Optional[Mapping[str, Any]] = None) -> Table:
        params = dict(params or {})
        if not serving_enabled():
            return execute(plan, self._catalog, params)
        with self._lock:
            return self._launch(self._prepare(plan),
                                self._psig(params), [params])[0]

    # -- resident incremental aggregation ----------------------------------
    def snapshot(self, plan: Plan) -> Table:
        """Finalize the resident incremental aggregate for ``plan`` —
        O(num_segments) decode of the resident (C, R, S) moment tensor,
        never an O(table) re-read.  First call seeds the residency (one
        full pass); later calls catch up on any ``append_rows`` the
        table took since the last fold (via the version chain) and
        finalize.  An up-to-date residency serves LOCK-FREE from its
        published epoch — a long fold or ``update_table`` in another
        thread never blocks it.  Ineligible plans (non-GroupAgg roots,
        unfused ops, no dense bound, ``REPRO_INCR_AGG=off``) fall back
        to a plain cached compute — same result, full cost."""
        return self._snapshot_versioned(plan, "snapshot")[0]

    def _snapshot_versioned(self, plan: Plan, consistency: str
                            ) -> Tuple[Table, Optional[int]]:
        """(result table, served watermark version).

        Fast path — NO server lock: capture the resident's published
        epoch (one atomic reference read; the epoch is one immutable
        object, so the decode can never see a torn mix of pre-/post-fold
        state).  ``"snapshot"`` takes it only when the epoch is at the
        live catalog version; ``"epoch"`` takes whatever epoch is
        published (pre-fold or post-fold — the returned version says
        which), so it never waits on a fold in flight.

        Slow path — under the lock: seed/rehydrate the residency or
        fold the pending append-chain suffix, then decode."""
        if not serving_enabled() or not incremental.incremental_enabled():
            return self._execute(plan), self._live_version(plan)
        self._bump("snapshots")
        res = self._residents.get(id(plan))
        if res is not None:
            ep = res.current_epoch()
            if ep is not None:
                live = self._catalog.get(res.name)
                fresh = live is not None and ep.version == live.version
                if fresh or consistency == "epoch":
                    self._bump("epoch_reads")
                    out = res.snapshot_epoch(ep, live if fresh else None)
                    if self._guard and is_poisoned(out):
                        raise PoisonedResult(
                            "resident snapshot carries the poison stamp")
                    return strip_poison_stamp(out), ep.version
        with self._lock:
            ent = self._prepare(plan)
            res = self._residents.get(id(plan))
            if res is None:
                res = self._rehydrate_resident(ent)
                if res is None:
                    res = self._admit_resident(ent)
                if res is None:
                    out = self._launch(ent, self._psig({}), [{}])[0]
                    return out, self._live_version(plan)
                self._residents[id(plan)] = res
            t = self._catalog[res.name]
            if res.version != t.version:
                pos = self._chain_positions(res.name, res.version,
                                            t.version)
                try:
                    if pos is None:     # chain broken: re-seed
                        self._seed_resident(res)
                    elif len(pos):
                        self._guarded_fold(res, t, pos)
                        self._bump("folds")
                    else:
                        res.version = t.version
                except IncrementalIneligible:
                    del self._residents[id(plan)]
                    out = self._launch(ent, self._psig({}), [{}])[0]
                    return out, self._live_version(plan)
            out = res.snapshot(self._catalog[res.name])
            version = res.version
        if self._guard and is_poisoned(out):
            raise PoisonedResult(
                "resident snapshot carries the poison stamp")
        return strip_poison_stamp(out), version

    def _rehydrate_resident(self, ent: _PlanEntry):
        """A residency recovered from a durable checkpoint for a
        structurally matching plan, or None (serve/checkpoint.py);
        consumes the stored payload on success.  The recovered epoch
        sits at the checkpoint watermark — the normal version-chain
        catch-up right after folds the append suffix through the
        existing fold path."""
        if not self._restored:
            return None
        from . import checkpoint
        return checkpoint.rehydrate(self, ent)

    def _admit_resident(self, ent: _PlanEntry):
        """Admit + seed a residency for a prepared plan entry, or None
        when the plan cannot be served incrementally."""
        if ent.slot_scan is None or ent.bound is None:
            return None
        plan = ent.plan
        if not isinstance(plan, GroupAgg):
            return None
        t = self._catalog[ent.slot_scan]
        res = incremental.ResidentAgg.admit(plan, ent.slot_scan, ent.keys,
                                            t, ent.bound)
        if res is None:
            return None
        res.inferred = ent.inferred
        try:
            self._seed_resident(res)
        except IncrementalIneligible:
            return None
        return res

    def _seed_resident(self, res) -> None:
        """Seed (or re-seed) a residency, doubling an overflowing
        inferred bound like the slot-table build does."""
        t = self._catalog[res.name]
        while True:
            try:
                res.seed(t)
                return
            except GroupBoundOverflow:
                if not res.inferred:
                    raise
                _, bound = resolve_group_bound(res.bound * 2, t.capacity)
                if bound is None or bound <= res.bound:
                    raise IncrementalIneligible(
                        "inferred bound outgrew the row capacity")
                res.bound = bound

    def _fold_residents(self, name: str) -> None:
        """Fold the just-appended batch into every resident aggregate on
        ``name`` (the ingest path; each resident catches up through the
        version chain so a resident that missed earlier plain appends
        still converges)."""
        t = self._catalog[name]
        for pid, res in list(self._residents.items()):
            if res.name != name or res.version == t.version:
                continue
            pos = self._chain_positions(name, res.version, t.version)
            try:
                if pos is None:
                    self._seed_resident(res)
                elif len(pos):
                    self._guarded_fold(res, t, pos)
                    self._bump("folds")
                else:
                    res.version = t.version
            except IncrementalIneligible:
                del self._residents[pid]

    def _guarded_fold(self, res, t: Table, pos) -> None:
        """One resident fold under the serving failure contract: the
        ``ingest_fold`` fault site fires first (chaos battery); a
        backend exception retries the fold on the jnp path (degraded);
        an overflowing batch doubles an inferred bucket via
        ``ResidentAgg.grow`` and retries — a declared bound surfaces
        ``BoundOverflow`` (guard) / ``GroupBoundOverflow`` (raw).  Folds
        commit atomically, so every failure leaves the resident state
        untouched."""
        while True:
            try:
                faults.fail("ingest_fold")
                res.fold(t, pos)
                return
            except GroupBoundOverflow as e:
                if res.inferred and res.grow(t):
                    continue
                if not res.inferred:
                    # declared bound: residency cannot absorb the growth
                    self._residents.pop(
                        next((pid for pid, r in self._residents.items()
                              if r is res), None), None)
                    if self._guard:
                        raise BoundOverflow(str(e)) from e
                    raise
                raise IncrementalIneligible(
                    "resident bucket outgrew the row capacity") from e
            except (IncrementalIneligible, ServeError):
                raise
            except Exception as e:      # noqa: BLE001 — ladder absorbs
                if not self._guard:
                    raise
                self._gbump("backend_failures")
                try:
                    res.fold(t, pos, backend="jnp")
                    self._gbump("degraded_launches")
                    return
                except Exception as e2:  # noqa: BLE001
                    raise BackendFailure(
                        "incremental fold failed and the degraded (jnp) "
                        "fold failed too") from e2

    # -- durable checkpoints -----------------------------------------------
    def checkpoint(self, directory: str) -> Optional[str]:
        """Write a durable checkpoint of every resident incremental
        aggregate (its published epoch: moments, slot table, owner,
        payloads, watermark) to ``directory`` — a versioned, checksummed
        manifest plus one payload file, written temp-then-rename so a
        crash mid-write never leaves a file a later ``restore`` could
        mistake for complete.  Returns the manifest path, or None when
        there is nothing resident to persist or the kill switch
        (``REPRO_SERVE_CKPT=off``) / the serving layer is off."""
        if not flags.enabled("REPRO_SERVE_CKPT") or not serving_enabled():
            return None
        from . import checkpoint as _ckpt
        with self._lock:
            path = _ckpt.write_checkpoint(self, directory)
        if path is not None:
            self._bump("checkpoints")
        return path

    def restore(self, directory: str) -> int:
        """Load the newest checkpoint in ``directory`` and stage its
        resident payloads for rehydration; returns the number staged (0
        when the directory holds no checkpoint or the kill switch is
        off).  Verification is strict: a manifest or payload that fails
        its checksum raises typed ``CheckpointCorrupt`` and installs
        NOTHING — the server keeps serving from live state (recompute),
        never from partially-read durable state.  A staged payload is
        consumed at the first ``snapshot`` of a structurally matching
        plan; any rows appended past the checkpoint watermark replay
        through the normal fold path (the version chain)."""
        if not flags.enabled("REPRO_SERVE_CKPT") or not serving_enabled():
            return 0
        from . import checkpoint as _ckpt
        with self._lock:
            n = _ckpt.read_checkpoint(self, directory)
        if n:
            self._bump("restores")
        return n

    def warmup(self, plan: Plan,
               params: Optional[Mapping[str, Any]] = None,
               batch_sizes: Tuple[int, ...] = (1,)) -> None:
        """Pre-trace the executables for a plan at the given batch-size
        buckets (deploy-time warming: the request path then never pays a
        compile).  ``params`` is a representative parameter dict — only
        its signature matters."""
        params = dict(params or {})
        if not serving_enabled():
            return
        with self._lock:
            ent = self._prepare(plan)
            psig = self._psig(params)
            for nb in batch_sizes:
                self._launch(ent, psig, [params] * max(1, int(nb)))

    def execute_uncached(self, plan: Plan,
                         params: Optional[Mapping[str, Any]] = None
                         ) -> Table:
        """The pre-serving cost model, for comparison: a fresh ``jax.jit``
        closure per call, so every call retraces, recompiles, and
        re-derives its slot table inside the trace."""
        params = dict(params or {})
        env = {k: jnp.asarray(v) for k, v in params.items()}
        with self._lock:
            catalog = dict(self._catalog)
        fn = jax.jit(lambda tabs, e: execute(plan, tabs, e))
        return fn(catalog, env)

    # -- concurrent path ---------------------------------------------------
    def submit(self, plan: Plan,
               params: Optional[Mapping[str, Any]] = None, *,
               deadline: Optional[float] = None) -> Future:
        """Enqueue one parameterized request; the dispatcher coalesces
        same-shape requests into one vmapped launch.  Returns a Future
        resolving to the request's result Table — or, under the guard, to
        a typed ``ServeError``: ``deadline`` (seconds from now) makes the
        dispatcher shed the request with ``DeadlineExceeded`` if it is
        still queued when the deadline passes, and a full admission queue
        rejects immediately with ``QueueFull`` (backpressure, never
        unbounded buffering)."""
        params = dict(params or {})
        fut: Future = Future()
        if not serving_enabled():
            try:
                fut.set_result(execute(plan, self._catalog, params))
            except Exception as e:          # noqa: BLE001 — future carries it
                fut.set_exception(e)
            return fut
        key = (id(plan), self._psig(params))
        dl = None if deadline is None else time.monotonic() + float(deadline)
        with self._cv:
            if self._closed:
                raise ServerClosed("AggServer is closed")
            if self._guard:
                depth = sum(len(r) for _, r in self._pending.values())
                if depth >= self._max_queue:
                    self._gbump("queue_rejects")
                    fut.set_exception(QueueFull(
                        f"admission queue at capacity ({self._max_queue} "
                        f"requests) — retry with backoff or raise max_queue"))
                    return fut
            if self._dispatcher is None:
                self._dispatcher = threading.Thread(
                    target=self._dispatch_main, name="agg-serve-dispatch",
                    daemon=True)
                self._dispatcher.start()
            if key not in self._pending:
                self._pending[key] = (plan, [])
            self._pending[key][1].append((params, fut, dl))
            self._cv.notify()
        return fut

    def close(self, drain: bool = True) -> None:
        """Stop the dispatcher.  ``drain=True`` (default) lets every
        queued request run to completion first — submits racing the close
        still resolve, new submits after it raise ``ServerClosed``.
        ``drain=False`` fails the queue immediately: every queued
        future gets ``ServerClosed``."""
        with self._cv:
            self._closed = True
            if not drain:
                for _plan, reqs in self._pending.values():
                    for _p, fut, _dl in reqs:
                        if not fut.done():
                            fut.set_exception(ServerClosed(
                                "AggServer closed without draining"))
                self._pending.clear()
            self._cv.notify_all()
        # the dispatcher may be respawned by the supervisor mid-close, so
        # join whatever thread currently holds the role until none does
        while True:
            with self._cv:
                th = self._dispatcher
            if th is None or not th.is_alive():
                break
            th.join(timeout=0.1)
        with self._cv:
            self._dispatcher = None

    def __enter__(self) -> "AggServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _dispatch_main(self) -> None:
        """Dispatcher supervisor: a dying dispatch loop (a bug, or the
        ``dispatcher_die`` fault) respawns a fresh thread instead of
        stranding every queued future unresolved forever.  Queued
        requests live in ``_pending`` (not thread state), so they
        survive the death and the successor serves them."""
        try:
            self._dispatch_loop()
        except BaseException:   # noqa: BLE001 — supervised: respawn
            with self._cv:
                self._gbump("dispatcher_restarts")
                t = threading.Thread(
                    target=self._dispatch_main, name="agg-serve-dispatch",
                    daemon=True)
                self._dispatcher = t
                t.start()

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if self._closed and not self._pending:
                    return
            if faults.fire("dispatcher_stall"):
                time.sleep(0.25)     # deterministic queue-delay injection
            faults.fail("dispatcher_die")
            if self._batch_window > 0:
                time.sleep(self._batch_window)   # let requests coalesce
            while True:
                with self._cv:
                    if not self._pending:
                        break
                    key = next(iter(self._pending))
                    plan, reqs = self._pending[key]
                    take = reqs[:self._max_batch]
                    del reqs[:len(take)]
                    if not reqs:
                        del self._pending[key]
                take = self._shed_expired(take)
                if take:
                    self._run_batch(plan, key[1], take)

    def _shed_expired(self, reqs):
        """Drop queued requests whose deadline already passed — their
        futures fail with ``DeadlineExceeded`` and the launch they would
        have joined never pays for them."""
        now = time.monotonic()
        live = []
        for params, fut, dl in reqs:
            if dl is not None and now > dl:
                self._gbump("deadline_shed")
                if not fut.done():
                    fut.set_exception(DeadlineExceeded(
                        "request deadline passed while queued"))
            else:
                live.append((params, fut, dl))
        return live

    def _run_batch(self, plan: Plan, psig, reqs) -> None:
        try:
            with self._lock:
                outs = self._launch(self._prepare(plan), psig,
                                    [p for p, _f, _d in reqs])
            for (_, fut, _), out in zip(reqs, outs):
                fut.set_result(out)
        except Exception as e:              # noqa: BLE001 — future carries it
            for _, fut, _ in reqs:
                if not fut.done():
                    fut.set_exception(e)

    # -- plan preparation --------------------------------------------------
    @staticmethod
    def _grouped_root(plan: Plan):
        if isinstance(plan, GroupAgg):
            return plan, tuple(plan.keys)
        if isinstance(plan, AggCall) and plan.group_keys:
            return plan, tuple(plan.group_keys)
        return None, ()

    @staticmethod
    def _takes_sortfree(plan: Plan, bound: Optional[int]) -> bool:
        if bound is None or not keyslot.sortfree_enabled():
            return False
        if isinstance(plan, GroupAgg):
            return True        # every GroupAgg op is an order-insensitive moment
        from repro.core.executors import sortfree_call_route
        return sortfree_call_route(plan, bound)

    def _prepare(self, plan: Plan) -> _PlanEntry:
        ent = self._plans.get(id(plan))
        if ent is not None:
            return ent
        ent = _PlanEntry(submitted=plan, plan=plan)
        root, keys = self._grouped_root(plan)
        scan = root.child.table if (root is not None
                                    and isinstance(root.child, Scan)) else None
        # slot provisioning (and bound inference) require the grouped
        # node's input to BE a catalog table: row order and validity then
        # provably match what the slots were built from.  Anything else
        # (parameterized filters, joins) still gets the executable cache
        # and batching — slotting just happens inside the trace.
        if root is not None and scan is not None and scan in self._catalog:
            t = self._catalog[scan]
            if all(k in t.columns for k in keys):
                declared = root.max_groups if root.max_groups is not None \
                    else t.group_bound
                if declared is None and self._infer_bounds:
                    est = keyslot.distinct_count_sketch(t, keys)
                    mg = int(math.ceil(est * _SKETCH_PAD)) + _SKETCH_SLACK
                    _, bound = resolve_group_bound(mg, t.capacity)
                    if bound is not None:
                        ent.plan = _dc_replace(plan, max_groups=mg)
                        ent.inferred = True
                        declared = mg
                if declared is not None:
                    _, bound = resolve_group_bound(declared, t.capacity)
                    if bound is not None and \
                            self._takes_sortfree(ent.plan, bound):
                        ent.keys = keys
                        ent.bound = bound
                        ent.slot_scan = scan
        self._plans[id(plan)] = ent
        return ent

    # -- slot-table cache --------------------------------------------------
    def _slot_table(self, ent: _PlanEntry):
        t = self._catalog[ent.slot_scan]
        stale = 0
        while True:
            key = (ent.slot_scan, t.version, ent.keys, ent.bound)
            got = self._slots.get(key)
            if got is not None:
                tag, arrs, _state = got
                if tag == t.version:
                    self._bump("slot_hits")
                    return arrs
                # the entry claims a version the catalog no longer holds —
                # structurally impossible (the key carries the version)
                # without corruption/injection.  Never serve it: drop and
                # rebuild, bounded, then surface SlotTableStale.
                del self._slots[key]
                self._gbump("stale_rebuilds")
                stale += 1
                if stale > _MAX_STALE_REBUILDS:
                    raise SlotTableStale(
                        f"slot table for {ent.slot_scan!r} keeps claiming a "
                        f"dead Table.version after {stale - 1} rebuilds")
                continue
            try:
                if self._extend_slots(ent, t) is not None:
                    continue    # cached under the live key: take the hit path
                seg, owner, overflowed, state = keyslot.slot_state_build(
                    t, ent.keys, ent.bound)
                if not faults.fire("bound_unvalidated"):
                    check_slot_overflow(overflowed, ent.bound)  # concrete
                occupied = jnp.arange(ent.bound, dtype=jnp.int32) < state.cnt
                arrs = tuple(jax.block_until_ready(a)
                             for a in (seg, owner, occupied, overflowed))
                self._bump("slot_builds")
                tag = t.version - 1 if faults.fire("slot_stale") \
                    else t.version
                self._slots[key] = (tag, arrs, state)
                if stale:
                    continue    # recovering: re-prove the tag via the hit path
                return arrs
            except GroupBoundOverflow:
                if not ent.inferred:
                    raise        # user-declared bound: the contract raises
                # inferred bound overflowed (data grew / sketch undershot):
                # double it, re-bucket, rebuild — or give the bound up when
                # the bucket reaches the row capacity
                grown = ent.bound * 2
                _, bound = resolve_group_bound(grown, t.capacity)
                ent.execs.clear()
                if bound is None:
                    ent.plan = _dc_replace(ent.plan, max_groups=None)
                    ent.bound = None
                    ent.slot_scan = None
                    return None
                ent.plan = _dc_replace(ent.plan, max_groups=grown)
                ent.bound = bound

    def _extend_slots(self, ent: _PlanEntry, t: Table):
        """Extend a cached ancestor slot table across the pending
        ``append_rows`` chain instead of rebuilding: O(batch) per append
        step (slot the new rows against the resident ``SlotState``, patch
        ``seg`` at their positions, merge freshly claimed owners) vs the
        O(table) full rebuild.  Returns the new slot arrays cached under
        the live version, or None when no extendable ancestor exists
        (then the caller falls back to ``slot_state_build``)."""
        chain = []
        v = t.version
        while True:
            got = self._slots.get((ent.slot_scan, v, ent.keys, ent.bound))
            if got is not None and got[0] == v and got[2] is not None:
                break
            step = self._appends.get((ent.slot_scan, v))
            if step is None:
                return None
            pv, pos = step
            chain.append(pos)
            v = pv
        if not chain:
            return None
        akey = (ent.slot_scan, v, ent.keys, ent.bound)
        _tag, (seg, owner, _occ, _ovf), state = self._slots[akey]
        seg = jnp.asarray(seg)
        owner = jnp.asarray(owner)
        mask = t.mask()
        for pos in reversed(chain):             # oldest append first
            posj = jnp.asarray(pos, jnp.int32)
            nb = int(posj.shape[0])
            words = keyslot.key_words_for(
                jnp.take(t.columns[k], posj, axis=0) for k in ent.keys)
            bvalid = jnp.take(mask, posj)
            segb, new_owner, ovf, state = keyslot.slot_ids_extend(
                words, bvalid, state)
            check_slot_overflow(ovf, ent.bound)  # concrete: raises
            owner = jnp.where(
                new_owner < nb,
                jnp.take(posj, jnp.clip(new_owner, 0, nb - 1)),
                owner).astype(jnp.int32)
            if seg.shape[0] < t.capacity:        # capacity grew on append
                seg = jnp.concatenate(
                    [seg, jnp.full((t.capacity - seg.shape[0],),
                                   ent.bound, jnp.int32)])
            seg = seg.at[posj].set(segb)
            keyslot.note_slot_extend()
            self._bump("slot_extends")
        occupied = jnp.arange(ent.bound, dtype=jnp.int32) < state.cnt
        arrs = tuple(jax.block_until_ready(a)
                     for a in (seg, owner, occupied, jnp.int32(0)))
        del self._slots[akey]                    # superseded ancestor
        self._slots[(ent.slot_scan, t.version, ent.keys, ent.bound)] = (
            t.version, arrs, state)
        return arrs

    # -- executables -------------------------------------------------------
    def _catalog_sig(self):
        return tuple(
            (name, t.group_bound, t.valid is None,
             tuple((c, str(a.dtype), tuple(a.shape))
                   for c, a in sorted(t.columns.items())))
            for name, t in sorted(self._catalog.items()))

    @staticmethod
    def _psig(params: Mapping[str, Any]):
        return tuple(sorted((k, str(jnp.result_type(v)))
                            for k, v in params.items()))

    def _executable(self, ent: _PlanEntry, psig, nb: int,
                    degraded: bool = False):
        key = (self._catalog_sig(), psig, nb, ent.bound, degraded)
        fn = ent.execs.get(key)
        if fn is None:
            fn = self._build(ent, psig, nb, degraded)
            ent.execs[key] = fn
        return fn

    def _build(self, ent: _PlanEntry, psig, nb: int, degraded: bool = False):
        plan = ent.plan
        spec = (ent.keys, ent.bound) if ent.slot_scan is not None else None
        stats = self.stats

        def run(tables, slots, pvec):
            stats.traces += 1    # Python side effect: counts traces only
            # the body below runs only while tracing, so the degraded
            # executable's force_backend scope is active exactly when the
            # backend choice bakes into the jaxpr — every kernel-backend
            # resolution in the trace lowers to the jnp segment-ops path
            ctx = degrade.force_backend("jnp") if degraded else nullcontext()

            def one(env):
                if spec is None:
                    return execute(plan, tables, env)
                with keyslot.provide_slots({spec: slots}):
                    return execute(plan, tables, env)

            with ctx:
                if not psig:
                    return one({})
                return jax.vmap(one)(pvec)

        return jax.jit(run)

    # -- launch ------------------------------------------------------------
    def _launch(self, ent: _PlanEntry, psig, plist):
        """Run a same-signature request batch through one (possibly
        vmapped) cached launch per max_batch bucket; returns one Table
        per request.  Under the guard each bucket goes through the
        poison scan / retry / breaker ladder."""
        n = len(plist)
        outs = []
        for start in range(0, n, self._max_batch):
            chunk = plist[start:start + self._max_batch]
            outs.extend(self._guarded_bucket(ent, psig, chunk)
                        if self._guard
                        else self._launch_bucket(ent, psig, chunk))
        # the auxiliary bool-only poison stamp is serving-internal: the
        # guarded scan above has read it; callers get their own columns
        return [strip_poison_stamp(o) if isinstance(o, Table) else o
                for o in outs]

    def _launch_bucket(self, ent: _PlanEntry, psig, plist,
                       degraded: bool = False):
        n = len(plist)
        slots = ()
        if ent.slot_scan is not None:
            got = self._slot_table(ent)   # may grow/disable the bound
            slots = got if got is not None else ()
        nb = 1 if not psig else 1 << (n - 1).bit_length()
        fn = self._executable(ent, psig, nb, degraded)
        self._bump("requests", n)
        self._bump("batches")
        if degraded:
            self._gbump("degraded_launches")
        if not degraded:
            faults.fail("backend_exc")
        if not psig:
            out = fn(self._catalog, slots, {})
            return [out] * n
        padded = plist + [plist[-1]] * (nb - n)   # pad lanes, drop below
        pvec = {k: jnp.asarray(np.stack([np.asarray(p[k]) for p in padded]))
                for k, _ in psig}
        batched = fn(self._catalog, slots, pvec)
        return [jax.tree_util.tree_map(lambda a, i=i: a[i], batched)
                for i in range(n)]

    # -- guarded launch ----------------------------------------------------
    def _breaker(self, ent: _PlanEntry, psig) -> CircuitBreaker:
        key = (id(ent.submitted), psig)
        br = self._breakers.get(key)
        if br is None:
            br = CircuitBreaker(
                self._breaker_threshold, self._breaker_cooldown,
                self._breaker_clock)
            # insertion under the stats mutex: describe() iterates the
            # breaker dict lock-free of the big server lock
            with self._stats_lock:
                br = self._breakers.setdefault(key, br)
        return br

    def _guarded_bucket(self, ent: _PlanEntry, psig, plist):
        """One bucket launch under the full failure contract: typed
        errors out, never raw backend exceptions or silent poison.

        Ladder, in order: a backend exception from the primary
        executable records on the (plan, signature) breaker and the
        batch immediately re-runs on the degraded jnp executable (the
        request is served; only a failure of the fallback too surfaces
        ``BackendFailure``).  A result carrying the poison stamp —
        a traced bound check failed inside the launch — retries with a
        doubled bound when the bound was inferred (bounded, with a
        rebuild backoff) and surfaces ``PoisonedResult`` otherwise."""
        br = self._breaker(ent, psig)
        attempts = 0
        while True:
            degraded = br.use_degraded()
            try:
                outs = self._launch_bucket(ent, psig, plist,
                                           degraded=degraded)
                if not degraded and br.record_success():
                    self._gbump("breaker_recoveries")
            except GroupBoundOverflow as e:
                raise BoundOverflow(str(e)) from e
            except ServeError:
                raise
            except Exception as e:          # noqa: BLE001 — ladder absorbs
                if degraded:
                    raise BackendFailure(
                        "degraded (jnp) launch failed") from e
                self._gbump("backend_failures")
                if br.record_failure():
                    self._gbump("breaker_trips")
                try:
                    outs = self._launch_bucket(ent, psig, plist,
                                               degraded=True)
                except GroupBoundOverflow as e2:
                    raise BoundOverflow(str(e2)) from e2
                except ServeError:
                    raise
                except Exception as e2:     # noqa: BLE001
                    raise BackendFailure(
                        "kernel backend failed and the degraded (jnp) "
                        "fallback failed too") from e2
            # poison scan: O(num_segments) per distinct result Table
            # (parameterless batches share one object — scan it once)
            seen: Dict[int, bool] = {}
            poisoned = False
            for out in outs:
                if id(out) not in seen:
                    seen[id(out)] = is_poisoned(out)
                poisoned = poisoned or seen[id(out)]
            if not poisoned:
                return outs
            self._gbump("poisoned")
            if (not ent.inferred or ent.bound is None
                    or attempts >= _MAX_POISON_RETRIES):
                raise PoisonedResult(
                    "launch output carries the poison stamp: a traced "
                    "dense group bound check failed inside the "
                    "executable — raise max_groups or drop the "
                    "declaration")
            # inferred bound: double, rebuild, relaunch (bounded)
            attempts += 1
            self._gbump("poison_retries")
            time.sleep(0.001 * attempts)    # brief rebuild backoff
            self._grow_bound(ent)

    def _grow_bound(self, ent: _PlanEntry) -> None:
        """Double an inferred bound after a poisoned launch: drop the
        slot tables built for the old bucket, clear the executables (the
        segment range is part of their shapes), and re-bucket — or give
        the bound up entirely once the bucket reaches the row capacity
        (capacity-sized tensors cannot overflow, so poison cannot
        recur)."""
        t = self._catalog[ent.slot_scan] if ent.slot_scan else None
        old = ent.bound
        grown = old * 2
        _, bound = resolve_group_bound(grown, t.capacity if t is not None
                                       else grown + 2)
        ent.execs.clear()
        self._slots = {k: v for k, v in self._slots.items()
                       if not (k[0] == ent.slot_scan and k[2] == ent.keys
                               and k[3] == old)}
        if bound is None:
            ent.plan = _dc_replace(ent.plan, max_groups=None)
            ent.bound = None
            ent.slot_scan = None
        else:
            ent.plan = _dc_replace(ent.plan, max_groups=grown)
            ent.bound = bound
