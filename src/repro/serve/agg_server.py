"""Aggregate-serving layer: compiled-plan + slot-table caching with
same-shape request batching.

Aggify turns a cursor loop into ONE pipelined aggregate query — but
production traffic is thousands of *parameterized repeats* of a few such
queries (every dashboard tile, every per-user UDF invocation), and a bare
``engine.execute`` pays three per-call costs the repeats never need:

* **jaxpr retrace + XLA compile** — the plan, catalog shapes, and
  parameter dtypes fully determine the computation; only parameter
  *values* change between calls.  The server keys an executable cache on
  exactly that: plan identity, the catalog shape/dtype signature, the
  parameter signature, the ``bucket_group_bound`` shape bucket, and the
  batch-size bucket — all finite, so the trace count is bounded by the
  number of distinct shape buckets, not the request count.
* **key→slot probing** (``relational/keyslot.py``) — the sort-free
  grouped route re-derives the same hash-slotted segment assignment from
  the same rows on every call.  The server builds it once per
  ``(table version, key columns, bucket)``, validates the dense bound
  *concretely* (overflow raises here, not inside a trace), and provides
  it to the executable as an **argument** via ``keyslot.provide_slots``.
  Passing slots as arguments — never baking them into the trace as
  constants — is what makes stale reads structurally impossible: a
  mutated table carries a fresh ``Table.version``, the slot cache misses,
  and the same compiled executable runs with the rebuilt arrays.  For
  row-sharded tables the cached assignment doubles as the *stable
  cross-call global* slot table the per-shard launcher cannot offer.
* **one-request-at-a-time launches** — concurrent parameterized calls
  with the same plan and parameter signature coalesce into one
  ``jax.vmap`` launch over stacked per-request parameter vectors
  (the grouped-decorrelation trick of ``benchmarks/tpch_loops.py``,
  generalized from benchmark code into the engine): tables and slot
  arrays broadcast, parameters batch.

When a grouped root plan declares no ``max_groups`` and its input table
carries no ``declare_group_bound`` hint, the server infers one: the
linear-counting ``distinct_count_sketch`` estimates the distinct key
count, the estimate is padded and bucketed, and the eager slot build
*validates* it (an overflowing inferred bound doubles and rebuilds —
never trusted, per the validated-not-assumed rule of
relational/group_bound.py).

**Failure semantics** (the guard layer, default on): every failure is a
typed ``serve.guard.ServeError`` set on the request's future — a bound
the data outgrew (``BoundOverflow``), a poisoned launch converted from
silent NaNs to ``PoisonedResult`` (retried with a doubled bound when the
bound was inferred), a deadline shed in the queue
(``DeadlineExceeded``), admission backpressure (``QueueFull``), a
kernel-backend failure the degradation ladder couldn't absorb
(``BackendFailure``).  The dispatcher thread is supervised (respawned on
death) and the per-(plan, signature) circuit breaker trips repeated
backend failures onto the always-correct jnp executable.  See
docs/serving.md, "Failure semantics".

Kill switches: ``REPRO_AGG_SERVE=off`` bypasses every cache and batch —
each call runs a plain eager ``engine.execute``;
``REPRO_SERVE_GUARD=off`` disables the guard layer only (PR-6 serving
behavior: caches and batching, raw exceptions).

See docs/serving.md for the cache-key / invalidation / batching contract.
"""
from __future__ import annotations

import math
import os
import threading
import time
from concurrent.futures import Future
from contextlib import nullcontext
from dataclasses import dataclass, field
from dataclasses import replace as _dc_replace
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational import keyslot
from repro.relational.engine import execute
from repro.relational.group_bound import GroupBoundOverflow, resolve_group_bound
from repro.relational.keyslot import check_slot_overflow
from repro.relational.plan import AggCall, GroupAgg, Plan, Scan
from repro.relational.table import Table
from repro.reliability import degrade, faults

from .guard import (BackendFailure, BoundOverflow, CircuitBreaker,
                    DeadlineExceeded, GuardStats, PoisonedResult, QueueFull,
                    ServeError, ServerClosed, SlotTableStale, is_poisoned)

__all__ = ["AggServer", "ServeStats", "serving_enabled", "guard_enabled"]


def serving_enabled() -> bool:
    """Kill switch for the whole serving layer (default: on).
    ``REPRO_AGG_SERVE=off`` turns every call into a plain eager
    ``engine.execute`` — no executable cache, no slot-table cache, no
    batching."""
    return os.environ.get("REPRO_AGG_SERVE") != "off"


def guard_enabled() -> bool:
    """Default for ``AggServer(guard=...)``: on unless
    ``REPRO_SERVE_GUARD=off``.  Guard-off restores the PR-6 serving
    behavior exactly — caches and batching, raw exceptions on futures,
    no poison scan, no breaker, unbounded queue."""
    return os.environ.get("REPRO_SERVE_GUARD") != "off"


#: bounded poison recovery: an inferred bound that poisons a launch is
#: doubled and rebuilt at most this many times before the failure
#: surfaces as ``PoisonedResult``
_MAX_POISON_RETRIES = 2

#: bounded staleness recovery: a slot-table entry whose version tag
#: disagrees with the catalog is dropped and rebuilt at most this many
#: times per launch before ``SlotTableStale`` surfaces
_MAX_STALE_REBUILDS = 2


@dataclass
class ServeStats:
    """Counters the tests and the serving bench assert on.  ``traces``
    increments inside the jitted body (a Python side effect fires only
    while tracing), so it counts actual retraces, not calls."""
    requests: int = 0
    batches: int = 0
    traces: int = 0
    slot_builds: int = 0
    slot_hits: int = 0


#: safety padding on the sketch estimate before bucketing: linear
#: counting is unbiased but noisy (±O(√m) keys), and the power-of-two
#: bucket only forgives undershoot up to the next boundary
_SKETCH_PAD = 1.3
_SKETCH_SLACK = 16


@dataclass
class _PlanEntry:
    """Per-plan serving state.  ``plan`` is the plan as served — when the
    bound was inferred it differs from the submitted plan by
    ``max_groups`` only.  Keyed by ``id(submitted plan)``; the entry
    holds a strong reference to the submitted plan so the id stays
    valid."""
    submitted: Plan
    plan: Plan
    keys: Tuple[str, ...] = ()
    bound: Optional[int] = None      # validated bucket; None → no slots
    slot_scan: Optional[str] = None  # catalog table the slots align to
    inferred: bool = False           # bound came from the sketch (growable)
    execs: Dict[Any, Any] = field(default_factory=dict)


class AggServer:
    """Serve parameterized aggregate plans over a named catalog.

    ``execute(plan, params)`` is the synchronous path (cache-aware, one
    request per launch); ``submit(plan, params) -> Future`` is the
    concurrent path — a dispatcher thread coalesces same-(plan,
    parameter-signature) requests into one vmapped launch of up to
    ``max_batch`` lanes.  ``update_table`` is the ONLY write: it swaps
    the catalog entry and explicitly invalidates the slot tables derived
    from the old version.  ``execute_uncached`` reproduces the
    pre-serving cost model (fresh jit per call) for benchmarking."""

    def __init__(self, catalog: Mapping[str, Table], *,
                 max_batch: int = 64, batch_window_s: float = 0.001,
                 infer_bounds: bool = True, guard: Optional[bool] = None,
                 max_queue: int = 1024, breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 5.0, breaker_clock=None):
        self._catalog: Dict[str, Table] = dict(catalog)
        self._max_batch = max(1, int(max_batch))
        self._batch_window = float(batch_window_s)
        self._infer_bounds = bool(infer_bounds)
        self._guard = guard_enabled() if guard is None else bool(guard)
        self._max_queue = max(1, int(max_queue))
        self._breaker_threshold = int(breaker_threshold)
        self._breaker_cooldown = float(breaker_cooldown_s)
        self._breaker_clock = breaker_clock or time.monotonic
        self._lock = threading.RLock()
        self._cv = threading.Condition()
        self._plans: Dict[int, _PlanEntry] = {}
        #: (table name, table version, key names, bucket) →
        #: (version tag, slot arrays) — the tag re-proves the version at
        #: every hit (see _slot_table)
        self._slots: Dict[Any, tuple] = {}
        self._pending: Dict[Any, tuple] = {}
        self._breakers: Dict[Any, CircuitBreaker] = {}
        self._dispatcher: Optional[threading.Thread] = None
        self._closed = False
        self.stats = ServeStats()
        self.guard_stats = GuardStats()

    # -- catalog writes ----------------------------------------------------
    def update_table(self, name: str, table: Table) -> None:
        """Swap a catalog table.  Slot tables derived from the previous
        version are dropped here (explicit invalidation on write);
        executables survive — they are keyed on shapes, not versions, so
        a shape-compatible mutation reuses the compiled program with the
        rebuilt slot arrays passed in as fresh arguments."""
        with self._lock:
            self._catalog[name] = table
            self._slots = {k: v for k, v in self._slots.items()
                           if k[0] != name}

    def table(self, name: str) -> Table:
        with self._lock:
            return self._catalog[name]

    # -- introspection -----------------------------------------------------
    def describe(self, plan: Plan) -> dict:
        """Serving decisions for a plan (tests/bench introspection)."""
        with self._lock:
            ent = self._prepare(plan)
            return {
                "max_groups": getattr(ent.plan, "max_groups", None),
                "bound": ent.bound,
                "slot_scan": ent.slot_scan,
                "inferred": ent.inferred,
                "executables": len(ent.execs),
                "guard": self._guard,
                "breakers": {psig: br.state
                             for (pid, psig), br in self._breakers.items()
                             if pid == id(ent.submitted)},
            }

    # -- synchronous path --------------------------------------------------
    def execute(self, plan: Plan, params: Optional[Mapping[str, Any]] = None
                ) -> Table:
        """Cache-aware execution of one parameterized request.  Serialized
        under the server lock (deterministic trace accounting); use
        ``submit`` for concurrency."""
        params = dict(params or {})
        if not serving_enabled():
            return execute(plan, self._catalog, params)
        with self._lock:
            return self._launch(self._prepare(plan),
                                self._psig(params), [params])[0]

    def warmup(self, plan: Plan,
               params: Optional[Mapping[str, Any]] = None,
               batch_sizes: Tuple[int, ...] = (1,)) -> None:
        """Pre-trace the executables for a plan at the given batch-size
        buckets (deploy-time warming: the request path then never pays a
        compile).  ``params`` is a representative parameter dict — only
        its signature matters."""
        params = dict(params or {})
        if not serving_enabled():
            return
        with self._lock:
            ent = self._prepare(plan)
            psig = self._psig(params)
            for nb in batch_sizes:
                self._launch(ent, psig, [params] * max(1, int(nb)))

    def execute_uncached(self, plan: Plan,
                         params: Optional[Mapping[str, Any]] = None
                         ) -> Table:
        """The pre-serving cost model, for comparison: a fresh ``jax.jit``
        closure per call, so every call retraces, recompiles, and
        re-derives its slot table inside the trace."""
        params = dict(params or {})
        env = {k: jnp.asarray(v) for k, v in params.items()}
        with self._lock:
            catalog = dict(self._catalog)
        fn = jax.jit(lambda tabs, e: execute(plan, tabs, e))
        return fn(catalog, env)

    # -- concurrent path ---------------------------------------------------
    def submit(self, plan: Plan,
               params: Optional[Mapping[str, Any]] = None, *,
               deadline: Optional[float] = None) -> Future:
        """Enqueue one parameterized request; the dispatcher coalesces
        same-shape requests into one vmapped launch.  Returns a Future
        resolving to the request's result Table — or, under the guard, to
        a typed ``ServeError``: ``deadline`` (seconds from now) makes the
        dispatcher shed the request with ``DeadlineExceeded`` if it is
        still queued when the deadline passes, and a full admission queue
        rejects immediately with ``QueueFull`` (backpressure, never
        unbounded buffering)."""
        params = dict(params or {})
        fut: Future = Future()
        if not serving_enabled():
            try:
                fut.set_result(execute(plan, self._catalog, params))
            except Exception as e:          # noqa: BLE001 — future carries it
                fut.set_exception(e)
            return fut
        key = (id(plan), self._psig(params))
        dl = None if deadline is None else time.monotonic() + float(deadline)
        with self._cv:
            if self._closed:
                raise ServerClosed("AggServer is closed")
            if self._guard:
                depth = sum(len(r) for _, r in self._pending.values())
                if depth >= self._max_queue:
                    self.guard_stats.queue_rejects += 1
                    fut.set_exception(QueueFull(
                        f"admission queue at capacity ({self._max_queue} "
                        f"requests) — retry with backoff or raise max_queue"))
                    return fut
            if self._dispatcher is None:
                self._dispatcher = threading.Thread(
                    target=self._dispatch_main, name="agg-serve-dispatch",
                    daemon=True)
                self._dispatcher.start()
            if key not in self._pending:
                self._pending[key] = (plan, [])
            self._pending[key][1].append((params, fut, dl))
            self._cv.notify()
        return fut

    def close(self, drain: bool = True) -> None:
        """Stop the dispatcher.  ``drain=True`` (default) lets every
        queued request run to completion first — submits racing the close
        still resolve, new submits after it raise ``ServerClosed``.
        ``drain=False`` fails the queue immediately: every queued
        future gets ``ServerClosed``."""
        with self._cv:
            self._closed = True
            if not drain:
                for _plan, reqs in self._pending.values():
                    for _p, fut, _dl in reqs:
                        if not fut.done():
                            fut.set_exception(ServerClosed(
                                "AggServer closed without draining"))
                self._pending.clear()
            self._cv.notify_all()
        # the dispatcher may be respawned by the supervisor mid-close, so
        # join whatever thread currently holds the role until none does
        while True:
            with self._cv:
                th = self._dispatcher
            if th is None or not th.is_alive():
                break
            th.join(timeout=0.1)
        with self._cv:
            self._dispatcher = None

    def __enter__(self) -> "AggServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _dispatch_main(self) -> None:
        """Dispatcher supervisor: a dying dispatch loop (a bug, or the
        ``dispatcher_die`` fault) respawns a fresh thread instead of
        stranding every queued future unresolved forever.  Queued
        requests live in ``_pending`` (not thread state), so they
        survive the death and the successor serves them."""
        try:
            self._dispatch_loop()
        except BaseException:   # noqa: BLE001 — supervised: respawn
            with self._cv:
                self.guard_stats.dispatcher_restarts += 1
                t = threading.Thread(
                    target=self._dispatch_main, name="agg-serve-dispatch",
                    daemon=True)
                self._dispatcher = t
                t.start()

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if self._closed and not self._pending:
                    return
            if faults.fire("dispatcher_stall"):
                time.sleep(0.25)     # deterministic queue-delay injection
            faults.fail("dispatcher_die")
            if self._batch_window > 0:
                time.sleep(self._batch_window)   # let requests coalesce
            while True:
                with self._cv:
                    if not self._pending:
                        break
                    key = next(iter(self._pending))
                    plan, reqs = self._pending[key]
                    take = reqs[:self._max_batch]
                    del reqs[:len(take)]
                    if not reqs:
                        del self._pending[key]
                take = self._shed_expired(take)
                if take:
                    self._run_batch(plan, key[1], take)

    def _shed_expired(self, reqs):
        """Drop queued requests whose deadline already passed — their
        futures fail with ``DeadlineExceeded`` and the launch they would
        have joined never pays for them."""
        now = time.monotonic()
        live = []
        for params, fut, dl in reqs:
            if dl is not None and now > dl:
                self.guard_stats.deadline_shed += 1
                if not fut.done():
                    fut.set_exception(DeadlineExceeded(
                        "request deadline passed while queued"))
            else:
                live.append((params, fut, dl))
        return live

    def _run_batch(self, plan: Plan, psig, reqs) -> None:
        try:
            with self._lock:
                outs = self._launch(self._prepare(plan), psig,
                                    [p for p, _f, _d in reqs])
            for (_, fut, _), out in zip(reqs, outs):
                fut.set_result(out)
        except Exception as e:              # noqa: BLE001 — future carries it
            for _, fut, _ in reqs:
                if not fut.done():
                    fut.set_exception(e)

    # -- plan preparation --------------------------------------------------
    @staticmethod
    def _grouped_root(plan: Plan):
        if isinstance(plan, GroupAgg):
            return plan, tuple(plan.keys)
        if isinstance(plan, AggCall) and plan.group_keys:
            return plan, tuple(plan.group_keys)
        return None, ()

    @staticmethod
    def _takes_sortfree(plan: Plan, bound: Optional[int]) -> bool:
        if bound is None or not keyslot.sortfree_enabled():
            return False
        if isinstance(plan, GroupAgg):
            return True        # every GroupAgg op is an order-insensitive moment
        from repro.core.executors import sortfree_call_route
        return sortfree_call_route(plan, bound)

    def _prepare(self, plan: Plan) -> _PlanEntry:
        ent = self._plans.get(id(plan))
        if ent is not None:
            return ent
        ent = _PlanEntry(submitted=plan, plan=plan)
        root, keys = self._grouped_root(plan)
        scan = root.child.table if (root is not None
                                    and isinstance(root.child, Scan)) else None
        # slot provisioning (and bound inference) require the grouped
        # node's input to BE a catalog table: row order and validity then
        # provably match what the slots were built from.  Anything else
        # (parameterized filters, joins) still gets the executable cache
        # and batching — slotting just happens inside the trace.
        if root is not None and scan is not None and scan in self._catalog:
            t = self._catalog[scan]
            if all(k in t.columns for k in keys):
                declared = root.max_groups if root.max_groups is not None \
                    else t.group_bound
                if declared is None and self._infer_bounds:
                    est = keyslot.distinct_count_sketch(t, keys)
                    mg = int(math.ceil(est * _SKETCH_PAD)) + _SKETCH_SLACK
                    _, bound = resolve_group_bound(mg, t.capacity)
                    if bound is not None:
                        ent.plan = _dc_replace(plan, max_groups=mg)
                        ent.inferred = True
                        declared = mg
                if declared is not None:
                    _, bound = resolve_group_bound(declared, t.capacity)
                    if bound is not None and \
                            self._takes_sortfree(ent.plan, bound):
                        ent.keys = keys
                        ent.bound = bound
                        ent.slot_scan = scan
        self._plans[id(plan)] = ent
        return ent

    # -- slot-table cache --------------------------------------------------
    def _slot_table(self, ent: _PlanEntry):
        t = self._catalog[ent.slot_scan]
        stale = 0
        while True:
            key = (ent.slot_scan, t.version, ent.keys, ent.bound)
            got = self._slots.get(key)
            if got is not None:
                tag, arrs = got
                if tag == t.version:
                    self.stats.slot_hits += 1
                    return arrs
                # the entry claims a version the catalog no longer holds —
                # structurally impossible (the key carries the version)
                # without corruption/injection.  Never serve it: drop and
                # rebuild, bounded, then surface SlotTableStale.
                del self._slots[key]
                self.guard_stats.stale_rebuilds += 1
                stale += 1
                if stale > _MAX_STALE_REBUILDS:
                    raise SlotTableStale(
                        f"slot table for {ent.slot_scan!r} keeps claiming a "
                        f"dead Table.version after {stale - 1} rebuilds")
                continue
            try:
                arrs = keyslot.slot_segment_ids(t, ent.keys, ent.bound)
                if not faults.fire("bound_unvalidated"):
                    check_slot_overflow(arrs[3], ent.bound)  # concrete: raises
                arrs = tuple(jax.block_until_ready(a) for a in arrs)
                self.stats.slot_builds += 1
                tag = t.version - 1 if faults.fire("slot_stale") \
                    else t.version
                self._slots[key] = (tag, arrs)
                if stale:
                    continue    # recovering: re-prove the tag via the hit path
                return arrs
            except GroupBoundOverflow:
                if not ent.inferred:
                    raise        # user-declared bound: the contract raises
                # inferred bound overflowed (data grew / sketch undershot):
                # double it, re-bucket, rebuild — or give the bound up when
                # the bucket reaches the row capacity
                grown = ent.bound * 2
                _, bound = resolve_group_bound(grown, t.capacity)
                ent.execs.clear()
                if bound is None:
                    ent.plan = _dc_replace(ent.plan, max_groups=None)
                    ent.bound = None
                    ent.slot_scan = None
                    return None
                ent.plan = _dc_replace(ent.plan, max_groups=grown)
                ent.bound = bound

    # -- executables -------------------------------------------------------
    def _catalog_sig(self):
        return tuple(
            (name, t.group_bound, t.valid is None,
             tuple((c, str(a.dtype), tuple(a.shape))
                   for c, a in sorted(t.columns.items())))
            for name, t in sorted(self._catalog.items()))

    @staticmethod
    def _psig(params: Mapping[str, Any]):
        return tuple(sorted((k, str(jnp.result_type(v)))
                            for k, v in params.items()))

    def _executable(self, ent: _PlanEntry, psig, nb: int,
                    degraded: bool = False):
        key = (self._catalog_sig(), psig, nb, ent.bound, degraded)
        fn = ent.execs.get(key)
        if fn is None:
            fn = self._build(ent, psig, nb, degraded)
            ent.execs[key] = fn
        return fn

    def _build(self, ent: _PlanEntry, psig, nb: int, degraded: bool = False):
        plan = ent.plan
        spec = (ent.keys, ent.bound) if ent.slot_scan is not None else None
        stats = self.stats

        def run(tables, slots, pvec):
            stats.traces += 1    # Python side effect: counts traces only
            # the body below runs only while tracing, so the degraded
            # executable's force_backend scope is active exactly when the
            # backend choice bakes into the jaxpr — every kernel-backend
            # resolution in the trace lowers to the jnp segment-ops path
            ctx = degrade.force_backend("jnp") if degraded else nullcontext()

            def one(env):
                if spec is None:
                    return execute(plan, tables, env)
                with keyslot.provide_slots({spec: slots}):
                    return execute(plan, tables, env)

            with ctx:
                if not psig:
                    return one({})
                return jax.vmap(one)(pvec)

        return jax.jit(run)

    # -- launch ------------------------------------------------------------
    def _launch(self, ent: _PlanEntry, psig, plist):
        """Run a same-signature request batch through one (possibly
        vmapped) cached launch per max_batch bucket; returns one Table
        per request.  Under the guard each bucket goes through the
        poison scan / retry / breaker ladder."""
        n = len(plist)
        outs = []
        for start in range(0, n, self._max_batch):
            chunk = plist[start:start + self._max_batch]
            outs.extend(self._guarded_bucket(ent, psig, chunk)
                        if self._guard
                        else self._launch_bucket(ent, psig, chunk))
        return outs

    def _launch_bucket(self, ent: _PlanEntry, psig, plist,
                       degraded: bool = False):
        n = len(plist)
        slots = ()
        if ent.slot_scan is not None:
            got = self._slot_table(ent)   # may grow/disable the bound
            slots = got if got is not None else ()
        nb = 1 if not psig else 1 << (n - 1).bit_length()
        fn = self._executable(ent, psig, nb, degraded)
        self.stats.requests += n
        self.stats.batches += 1
        if degraded:
            self.guard_stats.degraded_launches += 1
        if not degraded:
            faults.fail("backend_exc")
        if not psig:
            out = fn(self._catalog, slots, {})
            return [out] * n
        padded = plist + [plist[-1]] * (nb - n)   # pad lanes, drop below
        pvec = {k: jnp.asarray(np.stack([np.asarray(p[k]) for p in padded]))
                for k, _ in psig}
        batched = fn(self._catalog, slots, pvec)
        return [jax.tree_util.tree_map(lambda a, i=i: a[i], batched)
                for i in range(n)]

    # -- guarded launch ----------------------------------------------------
    def _breaker(self, ent: _PlanEntry, psig) -> CircuitBreaker:
        key = (id(ent.submitted), psig)
        br = self._breakers.get(key)
        if br is None:
            br = self._breakers[key] = CircuitBreaker(
                self._breaker_threshold, self._breaker_cooldown,
                self._breaker_clock)
        return br

    def _guarded_bucket(self, ent: _PlanEntry, psig, plist):
        """One bucket launch under the full failure contract: typed
        errors out, never raw backend exceptions or silent poison.

        Ladder, in order: a backend exception from the primary
        executable records on the (plan, signature) breaker and the
        batch immediately re-runs on the degraded jnp executable (the
        request is served; only a failure of the fallback too surfaces
        ``BackendFailure``).  A result carrying the poison stamp —
        a traced bound check failed inside the launch — retries with a
        doubled bound when the bound was inferred (bounded, with a
        rebuild backoff) and surfaces ``PoisonedResult`` otherwise."""
        br = self._breaker(ent, psig)
        attempts = 0
        while True:
            degraded = br.use_degraded()
            try:
                outs = self._launch_bucket(ent, psig, plist,
                                           degraded=degraded)
                if not degraded and br.record_success():
                    self.guard_stats.breaker_recoveries += 1
            except GroupBoundOverflow as e:
                raise BoundOverflow(str(e)) from e
            except ServeError:
                raise
            except Exception as e:          # noqa: BLE001 — ladder absorbs
                if degraded:
                    raise BackendFailure(
                        "degraded (jnp) launch failed") from e
                self.guard_stats.backend_failures += 1
                if br.record_failure():
                    self.guard_stats.breaker_trips += 1
                try:
                    outs = self._launch_bucket(ent, psig, plist,
                                               degraded=True)
                except GroupBoundOverflow as e2:
                    raise BoundOverflow(str(e2)) from e2
                except ServeError:
                    raise
                except Exception as e2:     # noqa: BLE001
                    raise BackendFailure(
                        "kernel backend failed and the degraded (jnp) "
                        "fallback failed too") from e2
            # poison scan: O(num_segments) per distinct result Table
            # (parameterless batches share one object — scan it once)
            seen: Dict[int, bool] = {}
            poisoned = False
            for out in outs:
                if id(out) not in seen:
                    seen[id(out)] = is_poisoned(out)
                poisoned = poisoned or seen[id(out)]
            if not poisoned:
                return outs
            self.guard_stats.poisoned += 1
            if (not ent.inferred or ent.bound is None
                    or attempts >= _MAX_POISON_RETRIES):
                raise PoisonedResult(
                    "launch output carries the poison stamp: a traced "
                    "dense group bound check failed inside the "
                    "executable — raise max_groups or drop the "
                    "declaration")
            # inferred bound: double, rebuild, relaunch (bounded)
            attempts += 1
            self.guard_stats.poison_retries += 1
            time.sleep(0.001 * attempts)    # brief rebuild backoff
            self._grow_bound(ent)

    def _grow_bound(self, ent: _PlanEntry) -> None:
        """Double an inferred bound after a poisoned launch: drop the
        slot tables built for the old bucket, clear the executables (the
        segment range is part of their shapes), and re-bucket — or give
        the bound up entirely once the bucket reaches the row capacity
        (capacity-sized tensors cannot overflow, so poison cannot
        recur)."""
        t = self._catalog[ent.slot_scan] if ent.slot_scan else None
        old = ent.bound
        grown = old * 2
        _, bound = resolve_group_bound(grown, t.capacity if t is not None
                                       else grown + 2)
        ent.execs.clear()
        self._slots = {k: v for k, v in self._slots.items()
                       if not (k[0] == ent.slot_scan and k[2] == ent.keys
                               and k[3] == old)}
        if bound is None:
            ent.plan = _dc_replace(ent.plan, max_groups=None)
            ent.bound = None
            ent.slot_scan = None
        else:
            ent.plan = _dc_replace(ent.plan, max_groups=grown)
            ent.bound = bound
