"""Aggregate-serving layer: compiled-plan + slot-table caching with
same-shape request batching.

Aggify turns a cursor loop into ONE pipelined aggregate query — but
production traffic is thousands of *parameterized repeats* of a few such
queries (every dashboard tile, every per-user UDF invocation), and a bare
``engine.execute`` pays three per-call costs the repeats never need:

* **jaxpr retrace + XLA compile** — the plan, catalog shapes, and
  parameter dtypes fully determine the computation; only parameter
  *values* change between calls.  The server keys an executable cache on
  exactly that: plan identity, the catalog shape/dtype signature, the
  parameter signature, the ``bucket_group_bound`` shape bucket, and the
  batch-size bucket — all finite, so the trace count is bounded by the
  number of distinct shape buckets, not the request count.
* **key→slot probing** (``relational/keyslot.py``) — the sort-free
  grouped route re-derives the same hash-slotted segment assignment from
  the same rows on every call.  The server builds it once per
  ``(table version, key columns, bucket)``, validates the dense bound
  *concretely* (overflow raises here, not inside a trace), and provides
  it to the executable as an **argument** via ``keyslot.provide_slots``.
  Passing slots as arguments — never baking them into the trace as
  constants — is what makes stale reads structurally impossible: a
  mutated table carries a fresh ``Table.version``, the slot cache misses,
  and the same compiled executable runs with the rebuilt arrays.  For
  row-sharded tables the cached assignment doubles as the *stable
  cross-call global* slot table the per-shard launcher cannot offer.
* **one-request-at-a-time launches** — concurrent parameterized calls
  with the same plan and parameter signature coalesce into one
  ``jax.vmap`` launch over stacked per-request parameter vectors
  (the grouped-decorrelation trick of ``benchmarks/tpch_loops.py``,
  generalized from benchmark code into the engine): tables and slot
  arrays broadcast, parameters batch.

When a grouped root plan declares no ``max_groups`` and its input table
carries no ``declare_group_bound`` hint, the server infers one: the
linear-counting ``distinct_count_sketch`` estimates the distinct key
count, the estimate is padded and bucketed, and the eager slot build
*validates* it (an overflowing inferred bound doubles and rebuilds —
never trusted, per the validated-not-assumed rule of
relational/group_bound.py).

Kill switch: ``REPRO_AGG_SERVE=off`` bypasses every cache and batch —
each call runs a plain eager ``engine.execute``.

See docs/serving.md for the cache-key / invalidation / batching contract.
"""
from __future__ import annotations

import math
import os
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from dataclasses import replace as _dc_replace
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational import keyslot
from repro.relational.engine import execute
from repro.relational.group_bound import resolve_group_bound
from repro.relational.keyslot import check_slot_overflow
from repro.relational.plan import AggCall, GroupAgg, Plan, Scan
from repro.relational.table import Table

__all__ = ["AggServer", "ServeStats", "serving_enabled"]


def serving_enabled() -> bool:
    """Kill switch for the whole serving layer (default: on).
    ``REPRO_AGG_SERVE=off`` turns every call into a plain eager
    ``engine.execute`` — no executable cache, no slot-table cache, no
    batching."""
    return os.environ.get("REPRO_AGG_SERVE") != "off"


@dataclass
class ServeStats:
    """Counters the tests and the serving bench assert on.  ``traces``
    increments inside the jitted body (a Python side effect fires only
    while tracing), so it counts actual retraces, not calls."""
    requests: int = 0
    batches: int = 0
    traces: int = 0
    slot_builds: int = 0
    slot_hits: int = 0


#: safety padding on the sketch estimate before bucketing: linear
#: counting is unbiased but noisy (±O(√m) keys), and the power-of-two
#: bucket only forgives undershoot up to the next boundary
_SKETCH_PAD = 1.3
_SKETCH_SLACK = 16


@dataclass
class _PlanEntry:
    """Per-plan serving state.  ``plan`` is the plan as served — when the
    bound was inferred it differs from the submitted plan by
    ``max_groups`` only.  Keyed by ``id(submitted plan)``; the entry
    holds a strong reference to the submitted plan so the id stays
    valid."""
    submitted: Plan
    plan: Plan
    keys: Tuple[str, ...] = ()
    bound: Optional[int] = None      # validated bucket; None → no slots
    slot_scan: Optional[str] = None  # catalog table the slots align to
    inferred: bool = False           # bound came from the sketch (growable)
    execs: Dict[Any, Any] = field(default_factory=dict)


class AggServer:
    """Serve parameterized aggregate plans over a named catalog.

    ``execute(plan, params)`` is the synchronous path (cache-aware, one
    request per launch); ``submit(plan, params) -> Future`` is the
    concurrent path — a dispatcher thread coalesces same-(plan,
    parameter-signature) requests into one vmapped launch of up to
    ``max_batch`` lanes.  ``update_table`` is the ONLY write: it swaps
    the catalog entry and explicitly invalidates the slot tables derived
    from the old version.  ``execute_uncached`` reproduces the
    pre-serving cost model (fresh jit per call) for benchmarking."""

    def __init__(self, catalog: Mapping[str, Table], *,
                 max_batch: int = 64, batch_window_s: float = 0.001,
                 infer_bounds: bool = True):
        self._catalog: Dict[str, Table] = dict(catalog)
        self._max_batch = max(1, int(max_batch))
        self._batch_window = float(batch_window_s)
        self._infer_bounds = bool(infer_bounds)
        self._lock = threading.RLock()
        self._cv = threading.Condition()
        self._plans: Dict[int, _PlanEntry] = {}
        #: (table name, table version, key names, bucket) → slot arrays
        self._slots: Dict[Any, tuple] = {}
        self._pending: Dict[Any, tuple] = {}
        self._dispatcher: Optional[threading.Thread] = None
        self._closed = False
        self.stats = ServeStats()

    # -- catalog writes ----------------------------------------------------
    def update_table(self, name: str, table: Table) -> None:
        """Swap a catalog table.  Slot tables derived from the previous
        version are dropped here (explicit invalidation on write);
        executables survive — they are keyed on shapes, not versions, so
        a shape-compatible mutation reuses the compiled program with the
        rebuilt slot arrays passed in as fresh arguments."""
        with self._lock:
            self._catalog[name] = table
            self._slots = {k: v for k, v in self._slots.items()
                           if k[0] != name}

    def table(self, name: str) -> Table:
        with self._lock:
            return self._catalog[name]

    # -- introspection -----------------------------------------------------
    def describe(self, plan: Plan) -> dict:
        """Serving decisions for a plan (tests/bench introspection)."""
        with self._lock:
            ent = self._prepare(plan)
            return {
                "max_groups": getattr(ent.plan, "max_groups", None),
                "bound": ent.bound,
                "slot_scan": ent.slot_scan,
                "inferred": ent.inferred,
                "executables": len(ent.execs),
            }

    # -- synchronous path --------------------------------------------------
    def execute(self, plan: Plan, params: Optional[Mapping[str, Any]] = None
                ) -> Table:
        """Cache-aware execution of one parameterized request.  Serialized
        under the server lock (deterministic trace accounting); use
        ``submit`` for concurrency."""
        params = dict(params or {})
        if not serving_enabled():
            return execute(plan, self._catalog, params)
        with self._lock:
            return self._launch(self._prepare(plan),
                                self._psig(params), [params])[0]

    def warmup(self, plan: Plan,
               params: Optional[Mapping[str, Any]] = None,
               batch_sizes: Tuple[int, ...] = (1,)) -> None:
        """Pre-trace the executables for a plan at the given batch-size
        buckets (deploy-time warming: the request path then never pays a
        compile).  ``params`` is a representative parameter dict — only
        its signature matters."""
        params = dict(params or {})
        if not serving_enabled():
            return
        with self._lock:
            ent = self._prepare(plan)
            psig = self._psig(params)
            for nb in batch_sizes:
                self._launch(ent, psig, [params] * max(1, int(nb)))

    def execute_uncached(self, plan: Plan,
                         params: Optional[Mapping[str, Any]] = None
                         ) -> Table:
        """The pre-serving cost model, for comparison: a fresh ``jax.jit``
        closure per call, so every call retraces, recompiles, and
        re-derives its slot table inside the trace."""
        params = dict(params or {})
        env = {k: jnp.asarray(v) for k, v in params.items()}
        with self._lock:
            catalog = dict(self._catalog)
        fn = jax.jit(lambda tabs, e: execute(plan, tabs, e))
        return fn(catalog, env)

    # -- concurrent path ---------------------------------------------------
    def submit(self, plan: Plan,
               params: Optional[Mapping[str, Any]] = None) -> Future:
        """Enqueue one parameterized request; the dispatcher coalesces
        same-shape requests into one vmapped launch.  Returns a Future
        resolving to the request's result Table."""
        params = dict(params or {})
        fut: Future = Future()
        if not serving_enabled():
            try:
                fut.set_result(execute(plan, self._catalog, params))
            except Exception as e:          # noqa: BLE001 — future carries it
                fut.set_exception(e)
            return fut
        key = (id(plan), self._psig(params))
        with self._cv:
            if self._closed:
                raise RuntimeError("AggServer is closed")
            if self._dispatcher is None:
                self._dispatcher = threading.Thread(
                    target=self._dispatch_loop, name="agg-serve-dispatch",
                    daemon=True)
                self._dispatcher.start()
            if key not in self._pending:
                self._pending[key] = (plan, [])
            self._pending[key][1].append((params, fut))
            self._cv.notify()
        return fut

    def close(self) -> None:
        """Drain the queue and stop the dispatcher."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._dispatcher is not None:
            self._dispatcher.join()
            self._dispatcher = None

    def __enter__(self) -> "AggServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if self._closed and not self._pending:
                    return
            if self._batch_window > 0:
                time.sleep(self._batch_window)   # let requests coalesce
            while True:
                with self._cv:
                    if not self._pending:
                        break
                    key = next(iter(self._pending))
                    plan, reqs = self._pending[key]
                    take = reqs[:self._max_batch]
                    del reqs[:len(take)]
                    if not reqs:
                        del self._pending[key]
                self._run_batch(plan, key[1], take)

    def _run_batch(self, plan: Plan, psig, reqs) -> None:
        try:
            with self._lock:
                outs = self._launch(self._prepare(plan), psig,
                                    [p for p, _ in reqs])
            for (_, fut), out in zip(reqs, outs):
                fut.set_result(out)
        except Exception as e:              # noqa: BLE001 — future carries it
            for _, fut in reqs:
                if not fut.done():
                    fut.set_exception(e)

    # -- plan preparation --------------------------------------------------
    @staticmethod
    def _grouped_root(plan: Plan):
        if isinstance(plan, GroupAgg):
            return plan, tuple(plan.keys)
        if isinstance(plan, AggCall) and plan.group_keys:
            return plan, tuple(plan.group_keys)
        return None, ()

    @staticmethod
    def _takes_sortfree(plan: Plan, bound: Optional[int]) -> bool:
        if bound is None or not keyslot.sortfree_enabled():
            return False
        if isinstance(plan, GroupAgg):
            return True        # every GroupAgg op is an order-insensitive moment
        from repro.core.executors import sortfree_call_route
        return sortfree_call_route(plan, bound)

    def _prepare(self, plan: Plan) -> _PlanEntry:
        ent = self._plans.get(id(plan))
        if ent is not None:
            return ent
        ent = _PlanEntry(submitted=plan, plan=plan)
        root, keys = self._grouped_root(plan)
        scan = root.child.table if (root is not None
                                    and isinstance(root.child, Scan)) else None
        # slot provisioning (and bound inference) require the grouped
        # node's input to BE a catalog table: row order and validity then
        # provably match what the slots were built from.  Anything else
        # (parameterized filters, joins) still gets the executable cache
        # and batching — slotting just happens inside the trace.
        if root is not None and scan is not None and scan in self._catalog:
            t = self._catalog[scan]
            if all(k in t.columns for k in keys):
                declared = root.max_groups if root.max_groups is not None \
                    else t.group_bound
                if declared is None and self._infer_bounds:
                    est = keyslot.distinct_count_sketch(t, keys)
                    mg = int(math.ceil(est * _SKETCH_PAD)) + _SKETCH_SLACK
                    _, bound = resolve_group_bound(mg, t.capacity)
                    if bound is not None:
                        ent.plan = _dc_replace(plan, max_groups=mg)
                        ent.inferred = True
                        declared = mg
                if declared is not None:
                    _, bound = resolve_group_bound(declared, t.capacity)
                    if bound is not None and \
                            self._takes_sortfree(ent.plan, bound):
                        ent.keys = keys
                        ent.bound = bound
                        ent.slot_scan = scan
        self._plans[id(plan)] = ent
        return ent

    # -- slot-table cache --------------------------------------------------
    def _slot_table(self, ent: _PlanEntry):
        t = self._catalog[ent.slot_scan]
        while True:
            key = (ent.slot_scan, t.version, ent.keys, ent.bound)
            got = self._slots.get(key)
            if got is not None:
                self.stats.slot_hits += 1
                return got
            try:
                arrs = keyslot.slot_segment_ids(t, ent.keys, ent.bound)
                check_slot_overflow(arrs[3], ent.bound)  # concrete: raises
                arrs = tuple(jax.block_until_ready(a) for a in arrs)
                self.stats.slot_builds += 1
                self._slots[key] = arrs
                return arrs
            except ValueError:
                if not ent.inferred:
                    raise        # user-declared bound: the contract raises
                # inferred bound overflowed (data grew / sketch undershot):
                # double it, re-bucket, rebuild — or give the bound up when
                # the bucket reaches the row capacity
                grown = ent.bound * 2
                _, bound = resolve_group_bound(grown, t.capacity)
                ent.execs.clear()
                if bound is None:
                    ent.plan = _dc_replace(ent.plan, max_groups=None)
                    ent.bound = None
                    ent.slot_scan = None
                    return None
                ent.plan = _dc_replace(ent.plan, max_groups=grown)
                ent.bound = bound

    # -- executables -------------------------------------------------------
    def _catalog_sig(self):
        return tuple(
            (name, t.group_bound, t.valid is None,
             tuple((c, str(a.dtype), tuple(a.shape))
                   for c, a in sorted(t.columns.items())))
            for name, t in sorted(self._catalog.items()))

    @staticmethod
    def _psig(params: Mapping[str, Any]):
        return tuple(sorted((k, str(jnp.result_type(v)))
                            for k, v in params.items()))

    def _executable(self, ent: _PlanEntry, psig, nb: int):
        key = (self._catalog_sig(), psig, nb, ent.bound)
        fn = ent.execs.get(key)
        if fn is None:
            fn = self._build(ent, psig, nb)
            ent.execs[key] = fn
        return fn

    def _build(self, ent: _PlanEntry, psig, nb: int):
        plan = ent.plan
        spec = (ent.keys, ent.bound) if ent.slot_scan is not None else None
        stats = self.stats

        def run(tables, slots, pvec):
            stats.traces += 1    # Python side effect: counts traces only

            def one(env):
                if spec is None:
                    return execute(plan, tables, env)
                with keyslot.provide_slots({spec: slots}):
                    return execute(plan, tables, env)

            if not psig:
                return one({})
            return jax.vmap(one)(pvec)

        return jax.jit(run)

    # -- launch ------------------------------------------------------------
    def _launch(self, ent: _PlanEntry, psig, plist):
        """Run a same-signature request batch through one (possibly
        vmapped) cached launch; returns one Table per request."""
        n = len(plist)
        outs = []
        for start in range(0, n, self._max_batch):
            outs.extend(self._launch_bucket(ent, psig,
                                            plist[start:start + self._max_batch]))
        return outs

    def _launch_bucket(self, ent: _PlanEntry, psig, plist):
        n = len(plist)
        slots = ()
        if ent.slot_scan is not None:
            got = self._slot_table(ent)   # may grow/disable the bound
            slots = got if got is not None else ()
        nb = 1 if not psig else 1 << (n - 1).bit_length()
        fn = self._executable(ent, psig, nb)
        self.stats.requests += n
        self.stats.batches += 1
        if not psig:
            out = fn(self._catalog, slots, {})
            return [out] * n
        padded = plist + [plist[-1]] * (nb - n)   # pad lanes, drop below
        pvec = {k: jnp.asarray(np.stack([np.asarray(p[k]) for p in padded]))
                for k, _ in psig}
        batched = fn(self._catalog, slots, pvec)
        return [jax.tree_util.tree_map(lambda a, i=i: a[i], batched)
                for i in range(n)]
