"""jit'd public wrappers over the Pallas kernels with automatic fallback.

``use_pallas`` dispatch: on a real TPU backend the compiled kernels run;
on CPU (this container) the kernels execute in ``interpret=True`` mode for
correctness tests, while the *framework* call sites (models, engine) use
the jnp reference implementations by default so full-model smoke tests are
not slowed by the Python interpreter loop.  The dry-run lowers the jnp
path (identical math) — kernels are the TPU execution plan, refs are the
oracle and the CPU fallback.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import ref as _ref
from repro.configs import flags
from .decode_attn import decode_attention as _decode_pallas
from .segment_agg import fused_segment_agg as _fused_segagg
from .segment_agg import segment_agg as _segagg_pallas
from .ssd_scan import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def want_pallas(default: bool | None = None) -> bool:
    env = flags.value("REPRO_USE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "False")
    if default is not None:
        return default
    return _on_tpu()


def segment_agg(vals, segs, valid, num_segments: int, *,
                use_pallas: bool | None = None, block_rows: int = 256):
    if want_pallas(use_pallas):
        return _segagg_pallas(vals, segs, valid, num_segments,
                              block_rows=block_rows,
                              interpret=not _on_tpu())
    return _ref.segment_agg_ref(vals, segs, valid, num_segments)


def fused_segment_agg(vals, segs, valid, num_segments: int, *,
                      use_pallas: bool | None = None, block_rows: int = 256,
                      block_segs: int | None = None):
    """Multi-column fused segmented aggregation → (C, 4, num_segments).
    Kernel on TPU (interpret under test), jnp segment ops otherwise."""
    if want_pallas(use_pallas):
        backend = "pallas" if _on_tpu() else "interpret"
    else:
        backend = "jnp"
    return _fused_segagg(vals, segs, valid, num_segments,
                         block_rows=block_rows, block_segs=block_segs,
                         backend=backend)


def decode_attention(q, k, v, kv_len, *, use_pallas: bool | None = None,
                     chunk: int = 128):
    if want_pallas(use_pallas):
        return _decode_pallas(q, k, v, kv_len, chunk=chunk,
                              interpret=not _on_tpu())
    return _ref.decode_attention_ref(q, k, v, kv_len)


def ssd_scan(x, log_a, b, c, *, use_pallas: bool | None = None,
             chunk: int = 64):
    if want_pallas(use_pallas):
        return _ssd_pallas(x, log_a, b, c, chunk=chunk,
                           interpret=not _on_tpu())
    # chunked dual form (same math as the kernel) — NOT the sequential
    # oracle, which would lower to a T-step scan
    return _ref.ssd_scan_chunked(x, log_a, b, c, chunk=chunk)
