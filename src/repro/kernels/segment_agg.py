"""Pallas TPU kernel: fused grouped aggregation (the 𝒢_{AggΔ} hot path).

One pass over rows sorted by segment id computes SUM / COUNT / MIN / MAX
per segment simultaneously — the fused multi-aggregate the recognized
execution path of Aggify emits for grouped custom aggregates.  The kernel
accepts *multiple value columns per pass* (each with its own validity
mask, so differently-guarded recognized updates batch into one HBM
traversal) and tiles the *segment range* so the one-hot membership mask
always fits VMEM regardless of group cardinality.

TPU adaptation (vs a CUDA scatter-atomic formulation): atomics are not the
TPU model.  Instead each row-block materializes a one-hot membership mask
(rows × segment-tile) in VMEM and reduces with broadcast/select ops on the
VPU (8×128 lanes); partials accumulate into the output block, which stays
resident in VMEM across its whole visit run (output revisiting).  Rows are
pre-sorted by segment, so the mask is band-structured and the working set
is bounded by (BLOCK_ROWS × BLOCK_SEGS) — chosen by ``default_block_segs``
to respect a VMEM budget at a 128-lane-aligned tile width.

Band pruning (the default for the kernel backends): because rows are
sorted, row block *i* only intersects the contiguous band of segment tiles
``[min(segs_i) // BS, max(segs_i) // BS]``.  The grid is therefore NOT the
``(seg_tiles × row_blocks)`` cross product: a compact 1-D grid of
``row_blocks + seg_tiles - 1`` steps walks exactly the intersecting
``(row_block, seg_tile)`` pairs, carried into the kernel via
``pltpu.PrefetchScalarGridSpec`` step→block index maps (scalar prefetch,
so the index maps themselves read them).  Both the row-block index and the
segment-tile index are non-decreasing along the step sequence, so each
input block is fetched once and each output tile is written once — grid
cost O(row_blocks + seg_tiles) instead of O(row_blocks × seg_tiles).
``pruned_grid_steps`` reports the executed-step count so tests and
benchmarks can assert it.  Pruning requires the documented sorted-``segs``
precondition; see ``fused_segment_agg``.

``num_segments`` is the caller's static segment range: the grouped
executors pass a dense group bound (relational/group_bound.py) when one is
declared, which shrinks both the ``seg_tiles`` grid term
(``launched_grid_steps``) and the (C, 4, num_segments) output tensor
(``moment_tensor_bytes``) from row-capacity-sized to group-count-sized.

Grid (unpruned fallback, ``prune=False``): (num_seg_tiles, num_row_blocks)
with row blocks iterating fastest.  Block shapes in both layouts:
  vals  (BLOCK_ROWS, C)  f32          segs  (BLOCK_ROWS, 1) i32
  valid (BLOCK_ROWS, C)  i32
  out   (4*C, BLOCK_SEGS)  row layout [4*c + m] with m = sum,count,min,max

Execution backends (``fused_segment_agg``):
  * ``pallas``    — compiled kernel (real TPU).
  * ``interpret`` — the same kernel under the Pallas interpreter (CI/CPU
                    correctness; exercises the exact lowering).
  * ``jnp``       — pure ``jax.ops.segment_*`` fallback, identical math,
                    used on CPU/GPU where the interpreter loop would be
                    the bottleneck.
  * ``auto``      — pallas on TPU, jnp elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")
POS_INF = float("inf")

#: index of each fused value moment in the kernel output
MOMENTS = ("sum", "count", "min", "max")

#: optional *index* moments: the row index attaining the per-segment min
#: (row ``ARGMIN_ROW``) or max (row ``ARGMAX_ROW``), with the requesting
#: loop's tie order — ``*_first`` keeps the earliest attaining row (the
#: strict ``<``/``>`` comparison of a cursor loop never replaces an equal
#: key), ``*_last`` the latest (``<=``/``>=`` replaces on equality).  The
#: index accumulates as an f32 lexicographic (key, row) compare inside the
#: same band-pruned membership reduce, so it costs no extra grid steps;
#: exactness requires the (padded) row count below 2^24 (f32 integers).
INDEX_MOMENTS = ("argmin_first", "argmin_last", "argmax_first", "argmax_last")

#: moment-row offsets of the index rows (present only when a column
#: requests an index moment; the output then has 6 rows per column)
ARGMIN_ROW = 4
ARGMAX_ROW = 5

#: f32-exact row-index ceiling: above this the index moment is refused
INDEX_EXACT_ROWS = 1 << 24


def index_moment_ok(n: int, block_rows: int = 256) -> bool:
    """True when every row index the kernel can record — i.e. up to ``n``
    padded to a ``block_rows`` multiple — is exactly representable in the
    f32 accumulator.  The ONE gate shared by the kernel's own validation
    and the executors' use-index decision, so a row count just under the
    ceiling falls back to the legacy pick instead of tripping the
    kernel's raise."""
    return n + (-n) % block_rows < INDEX_EXACT_ROWS

#: TPU vector lane width — segment tiles are sized in multiples of it so
#: the membership-mask reduce never issues ragged lanes
LANE = 128


def default_block_segs(num_segments: int, block_rows: int = 256,
                       vmem_budget_elems: int = 1 << 19) -> int:
    """Largest 128-lane-aligned segment-tile width whose (block_rows × tile)
    membership mask stays under ``vmem_budget_elems`` f32 elements (default
    2 MB).  Invariants (asserted by tests): the result is a multiple of
    ``LANE``; it never exceeds the segment range rounded up to a lane
    multiple; and ``result * block_rows <= vmem_budget_elems`` whenever the
    budget admits at least one lane group (the floor is one 128-lane tile —
    narrower tiles would leave VPU lanes dead every cycle)."""
    budget = (vmem_budget_elems // max(block_rows, 1)) // LANE * LANE
    bs = max(LANE, budget)
    need = -(-num_segments // LANE) * LANE
    return int(min(need, bs))


# ---------------------------------------------------------------------------
# Moment normalization (shared by every backend and the sharded launcher)
# ---------------------------------------------------------------------------


def normalize_moments(moments, num_cols: int) -> tuple[tuple[str, ...], ...]:
    """Canonicalize ``moments`` to one validated tuple per column.

    Accepts either a flat tuple of moment names (applied to every column)
    or a per-column tuple of tuples.  Index moments imply their value
    extremum (``argmin_*`` adds ``min``, ``argmax_*`` adds ``max`` — the
    kernel's index merge reads the running extremum row).  A column may
    carry at most ONE tie order per extremum direction: ``argmin_first``
    and ``argmin_last`` share output row ``ARGMIN_ROW``, so requesting
    both on one column is a contract violation (callers split the column).
    Unknown moment names raise instead of being silently dropped."""
    known = MOMENTS + INDEX_MOMENTS
    if not moments or isinstance(moments[0], str):
        per_col = (tuple(moments),) * num_cols
    else:
        per_col = tuple(tuple(ms) for ms in moments)
    if len(per_col) != num_cols:
        raise ValueError(f"per-column moments: got {len(per_col)} entries "
                         f"for {num_cols} columns")
    out = []
    for ms in per_col:
        bad = [m for m in ms if m not in known]
        if bad:
            raise ValueError(f"unknown moment(s) {bad!r}; expected a subset "
                             f"of {known}")
        ms = set(ms)
        if "argmin_first" in ms and "argmin_last" in ms:
            raise ValueError("a column cannot carry both argmin_first and "
                             "argmin_last (one index row per extremum "
                             "direction) — use separate columns")
        if "argmax_first" in ms and "argmax_last" in ms:
            raise ValueError("a column cannot carry both argmax_first and "
                             "argmax_last (one index row per extremum "
                             "direction) — use separate columns")
        if "argmin_first" in ms or "argmin_last" in ms:
            ms.add("min")
        if "argmax_first" in ms or "argmax_last" in ms:
            ms.add("max")
        out.append(tuple(m for m in known if m in ms))
    return tuple(out)


def has_index_moments(moments: tuple[tuple[str, ...], ...]) -> bool:
    return any(m in INDEX_MOMENTS for ms in moments for m in ms)


def moment_rows(moments: tuple[tuple[str, ...], ...]) -> int:
    """Rows per column in the output tensor: 4 value rows, plus the two
    index rows when any column requests an index moment."""
    return 6 if has_index_moments(moments) else 4


def _index_tie(ms: tuple[str, ...], which: str):
    """Tie order of ``which`` ('argmin'/'argmax') for one column:
    True = first-attaining, False = last-attaining, None = not requested."""
    if which + "_first" in ms:
        return True
    if which + "_last" in ms:
        return False
    return None


def _row_fills(moments: tuple[tuple[str, ...], ...]) -> tuple[float, ...]:
    """Per-output-row init/identity values, column-major: [0, 0, +inf,
    -inf] for the value rows; the index rows hold the tie identity (+inf
    when the smallest attaining row wins, -inf when the largest does)."""
    nrows = moment_rows(moments)
    fills: list[float] = []
    for ms in moments:
        fills += [0.0, 0.0, POS_INF, NEG_INF]
        if nrows == 6:
            fills += [NEG_INF if _index_tie(ms, "argmin") is False
                      else POS_INF,
                      NEG_INF if _index_tie(ms, "argmax") is False
                      else POS_INF]
    return tuple(fills)


# ---------------------------------------------------------------------------
# Kernel bodies (shared between the pruned and unpruned grids)
# ---------------------------------------------------------------------------


def _init_out(out_ref, num_cols: int, block_segs: int,
              moments: tuple[tuple[str, ...], ...]) -> None:
    fills = _row_fills(moments)
    for r, f in enumerate(fills):
        out_ref[r, :] = jnp.full((block_segs,), f, out_ref.dtype)


def _extremum_with_index(out_ref, base: int, row: int, member, vbc, idxv,
                         block_val, tie_first: bool, minimize: bool) -> None:
    """Merge one row block's (key, row-index) pair into the resident
    extremum + index rows: the lexicographic compare of the index moment.
    ``block_val`` is the block's per-segment extremum; the attaining row
    within the block is the tie-ordered reduce over the rows matching it,
    and the merge with the resident tile compares keys first, indices on
    equality.  Must run before the extremum row is overwritten."""
    krow = base + (2 if minimize else 3)
    cur_k = out_ref[krow, :]
    cur_i = out_ref[base + row, :]
    hit = member & (vbc == block_val[None, :])
    if tie_first:
        bi = jnp.min(jnp.where(hit, idxv, POS_INF), axis=0)
        tie = jnp.minimum
    else:
        bi = jnp.max(jnp.where(hit, idxv, NEG_INF), axis=0)
        tie = jnp.maximum
    beats = block_val < cur_k if minimize else block_val > cur_k
    out_ref[base + row, :] = jnp.where(
        beats, bi, jnp.where(block_val == cur_k, tie(bi, cur_i), cur_i))


def _accum_rows(vals_ref, segs_ref, valid_ref, out_ref, seg_tile, row_base, *,
                block_segs: int, num_cols: int,
                moments: tuple[tuple[str, ...], ...]) -> None:
    """Accumulate one row block into the resident output tile ``seg_tile``
    (a traced i32 scalar on the pruned grid, a grid index otherwise).
    ``row_base`` is the global index of the block's first row — the index
    moments record ``row_base + local_row`` for the attaining row."""
    vals = vals_ref[...].astype(out_ref.dtype)          # (R, C)
    segs = segs_ref[...]                                # (R, 1) int32
    ok = valid_ref[...] != 0                            # (R, C)

    r = vals.shape[0]
    nrows = moment_rows(moments)
    local = segs - seg_tile * block_segs                # tile-relative ids
    seg_iota = lax.broadcasted_iota(jnp.int32, (r, block_segs), 1)
    in_tile = local == seg_iota                         # (R, BS) band mask
    idxv = None
    if nrows == 6:
        idxv = (row_base + lax.broadcasted_iota(
            jnp.int32, (r, block_segs), 0)).astype(out_ref.dtype)

    for c in range(num_cols):
        ms = moments[c]
        base = nrows * c
        member = in_tile & ok[:, c:c + 1]
        vbc = jnp.broadcast_to(vals[:, c:c + 1], (r, block_segs))
        if "sum" in ms:
            out_ref[base + 0, :] += jnp.sum(jnp.where(member, vbc, 0),
                                            axis=0)
        if "count" in ms:
            out_ref[base + 1, :] += jnp.sum(member.astype(out_ref.dtype),
                                            axis=0)
        amn = _index_tie(ms, "argmin")
        amx = _index_tie(ms, "argmax")
        if "min" in ms:
            bk = jnp.min(jnp.where(member, vbc, POS_INF), axis=0)
            if amn is not None:     # index merge reads the OLD extremum row
                _extremum_with_index(out_ref, base, ARGMIN_ROW, member, vbc,
                                     idxv, bk, tie_first=amn, minimize=True)
            out_ref[base + 2, :] = jnp.minimum(out_ref[base + 2, :], bk)
        if "max" in ms:
            bk = jnp.max(jnp.where(member, vbc, NEG_INF), axis=0)
            if amx is not None:
                _extremum_with_index(out_ref, base, ARGMAX_ROW, member, vbc,
                                     idxv, bk, tie_first=amx, minimize=False)
            out_ref[base + 3, :] = jnp.maximum(out_ref[base + 3, :], bk)


def _segment_agg_kernel(vals_ref, segs_ref, valid_ref, out_ref, *,
                        block_rows: int, block_segs: int, num_cols: int,
                        moments: tuple[tuple[str, ...], ...]):
    """Unpruned cross-product grid: (seg_tiles, row_blocks), rows fastest
    so the output tile stays VMEM-resident while every row block streams
    past it."""
    j = pl.program_id(0)          # segment tile (output stays resident)
    i = pl.program_id(1)          # row block   (streams past the tile)

    @pl.when(i == 0)
    def _():
        _init_out(out_ref, num_cols, block_segs, moments)

    _accum_rows(vals_ref, segs_ref, valid_ref, out_ref, j, i * block_rows,
                block_segs=block_segs, num_cols=num_cols, moments=moments)


def _segment_agg_kernel_pruned(rowm_ref, tilem_ref, nsteps_ref,
                               vals_ref, segs_ref, valid_ref, out_ref, *,
                               block_rows: int, block_segs: int,
                               num_cols: int,
                               moments: tuple[tuple[str, ...], ...]):
    """Band-pruned 1-D grid: step ``s`` works on row block ``rowm[s]`` and
    segment tile ``tilem[s]`` (scalar-prefetched maps; the BlockSpec index
    maps read the same arrays, so only intersecting blocks are fetched).
    Steps past ``nsteps`` are grid padding — they repeat the last real
    (row_block, seg_tile) pair so no new DMA is issued, and the accumulate
    is gated off."""
    s = pl.program_id(0)
    j = tilem_ref[s]
    prev_j = tilem_ref[jnp.maximum(s - 1, 0)]

    @pl.when((s == 0) | (j != prev_j))    # first visit of this output tile
    def _():
        _init_out(out_ref, num_cols, block_segs, moments)

    @pl.when(s < nsteps_ref[0])
    def _():
        _accum_rows(vals_ref, segs_ref, valid_ref, out_ref, j,
                    rowm_ref[s] * block_rows,
                    block_segs=block_segs, num_cols=num_cols,
                    moments=moments)


# ---------------------------------------------------------------------------
# Band computation (XLA-side, jit-safe) + host-side step accounting
# ---------------------------------------------------------------------------


def _band_maps(segs_flat: jax.Array, n_blocks: int, block_rows: int,
               block_segs: int, num_seg_tiles: int, grid_len: int):
    """Step→(row_block, seg_tile) maps for the pruned grid.

    Per-row-block tile bands [min_t, max_t] are flattened into one step
    sequence; for sorted input the bands are non-decreasing and overlap at
    most at endpoints, so the total real step count is bounded by
    ``n_blocks + num_seg_tiles - 1`` — the static ``grid_len``.  Steps
    beyond the real count clamp to the last real pair."""
    tiles = jnp.clip(segs_flat.reshape(n_blocks, block_rows) // block_segs,
                     0, num_seg_tiles - 1).astype(jnp.int32)
    min_t = jnp.min(tiles, axis=1)
    max_t = jnp.max(tiles, axis=1)
    spans = max_t - min_t + 1
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(spans, dtype=jnp.int32)])
    nsteps = offs[-1]
    steps = jnp.arange(grid_len, dtype=jnp.int32)
    blk = jnp.clip(jnp.searchsorted(offs, steps, side="right") - 1,
                   0, n_blocks - 1).astype(jnp.int32)
    tile = jnp.clip(min_t[blk] + steps - offs[blk], min_t[blk], max_t[blk])
    return blk, tile.astype(jnp.int32), nsteps.astype(jnp.int32)


def pruned_grid_steps(segs, num_segments: int, block_rows: int = 256,
                      block_segs: int | None = None,
                      vmem_budget_elems: int = 1 << 19) -> int:
    """Executed-step count of the band-pruned kernel for concrete ``segs``
    (host-side numpy): the sum over row blocks of each block's segment-tile
    band span.  For sorted input this is at most
    ``row_blocks + seg_tiles - 1`` (the static pruned grid length) — vs the
    ``row_blocks × seg_tiles`` cross product of the unpruned grid (see
    ``full_grid_steps``).  Tests and benchmarks assert against it."""
    s = np.asarray(segs)
    if block_segs is None:
        block_segs = default_block_segs(num_segments, block_rows,
                                        vmem_budget_elems)
    pad = (-s.shape[0]) % block_rows
    if pad:
        # mirror _pad_rows: repeat the last real segment id so the final
        # row block's band is not widened to the end of the range
        last = s[-1] if s.shape[0] else 0
        s = np.concatenate([s, np.full(pad, last, s.dtype)])
    num_seg_tiles = -(-num_segments // block_segs)
    tiles = np.clip(s.reshape(-1, block_rows) // block_segs,
                    0, num_seg_tiles - 1)
    return int(np.sum(tiles.max(axis=1) - tiles.min(axis=1) + 1))


def full_grid_steps(n: int, num_segments: int, block_rows: int = 256,
                    block_segs: int | None = None,
                    vmem_budget_elems: int = 1 << 19) -> int:
    """Step count of the unpruned (seg_tiles × row_blocks) grid."""
    if block_segs is None:
        block_segs = default_block_segs(num_segments, block_rows,
                                        vmem_budget_elems)
    n_blocks = -(-n // block_rows)
    return n_blocks * -(-num_segments // block_segs)


def launched_grid_steps(n: int, num_segments: int, block_rows: int = 256,
                        block_segs: int | None = None,
                        vmem_budget_elems: int = 1 << 19) -> int:
    """Static grid length ``fused_segment_agg`` actually launches for this
    shape: ``row_blocks`` when the segment range fits one tile (pruning is
    skipped — the row walk already is the whole grid), otherwise the
    band-pruned ``row_blocks + seg_tiles − 1`` (which includes the padding
    steps past ``pruned_grid_steps``; padding repeats the last real block
    pair with the accumulate gated off).  This is the number a dense
    group bound shrinks: ``seg_tiles`` is sized by ``num_segments``, so
    bounding it by the group count instead of the row capacity cuts the
    term — benchmarks/CI compare bounded vs capacity-sized launches."""
    if block_segs is None:
        block_segs = default_block_segs(num_segments, block_rows,
                                        vmem_budget_elems)
    n_blocks = -(-n // block_rows)
    num_seg_tiles = -(-num_segments // block_segs)
    return n_blocks if num_seg_tiles == 1 else n_blocks + num_seg_tiles - 1


def moment_tensor_bytes(num_cols: int, num_segments: int) -> int:
    """Bytes of the (C, 4, num_segments) f32 moment tensor — the kernel
    output and the sharded path's all-reduce payload.  Sized by the static
    segment range, so a dense group bound shrinks it proportionally."""
    return num_cols * len(MOMENTS) * num_segments * 4


def _validate_sorted(segs, prune: bool, assume_sorted: bool,
                     backend: str) -> bool:
    """Shared sorted-``segs`` precondition check for the band-pruned kernel
    paths (single-device and sharded).  Only kernel backends with pruning
    active care — the jnp fallback and the unpruned grid are
    order-independent.  Concrete unsorted input raises eagerly; returns
    True when the caller still needs the traced runtime guard (NaN
    poison), False when the precondition is established."""
    if not prune or assume_sorted or backend not in ("pallas", "interpret"):
        return False
    if isinstance(segs, jax.core.Tracer):
        return True
    s_np = np.asarray(segs)
    if s_np.size > 1 and np.any(s_np[1:] < s_np[:-1]):
        raise ValueError(
            "fused_segment_agg: band pruning requires `segs` sorted "
            "ascending — sort rows by segment (the grouped executors do) "
            "or pass prune=False")
    return False


def _pad_rows(vals, segs, valid, block: int):
    """Pad the row dimension to a multiple of ``block``.  Pad rows are
    invalid (they never contribute) and repeat the LAST real segment id,
    which keeps ``segs`` monotone without widening the final row block's
    tile band to the end of the segment range — padding with
    ``num_segments`` would make the pruned grid walk every trailing tile."""
    n = vals.shape[0]
    pad = (-n) % block
    if not pad:
        return vals, segs, valid
    vals = jnp.pad(vals, ((0, pad), (0, 0)))
    last = segs[-1] if n else jnp.zeros((), segs.dtype)
    segs = jnp.concatenate([segs, jnp.full((pad,), last, segs.dtype)])
    valid = jnp.pad(valid, ((0, pad), (0, 0)))
    return vals, segs, valid


def _normalize(vals: jax.Array, valid: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Lift (N,)/(N,C) vals and valid to matching (N, C)."""
    if vals.ndim == 1:
        vals = vals[:, None]
    if valid.ndim == 1:
        valid = valid[:, None]
    if valid.shape[1] == 1 and vals.shape[1] > 1:
        valid = jnp.broadcast_to(valid, vals.shape)
    return vals, valid


@functools.partial(jax.jit, static_argnames=("num_segments", "block_rows",
                                             "block_segs", "interpret",
                                             "moments", "prune",
                                             "check_sorted"))
def _segment_agg_pallas(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                        num_segments: int, block_rows: int,
                        block_segs: int, interpret: bool,
                        moments: tuple[str, ...] = MOMENTS,
                        prune: bool = True,
                        check_sorted: bool = True) -> jax.Array:
    """(N, C) vals/valid → (C, R, num_segments) f32 via the Pallas kernel
    (R = 4 value-moment rows, 6 when any column requests an index
    moment)."""
    n, num_cols = vals.shape
    nrows = moment_rows(moments)
    vals, segs, valid = _pad_rows(vals, segs, valid, block_rows)
    n_p = vals.shape[0]
    segs2 = segs.astype(jnp.int32).reshape(n_p, 1)
    valid2 = valid.astype(jnp.int32)
    vals2 = vals.astype(jnp.float32)

    num_seg_tiles = -(-num_segments // block_segs)
    s_pad = num_seg_tiles * block_segs
    n_blocks = n_p // block_rows
    if num_seg_tiles == 1:
        prune = False       # single tile: the cross product IS the row walk
    out_shape = jax.ShapeDtypeStruct((nrows * num_cols, s_pad), jnp.float32)

    if not prune:
        out = pl.pallas_call(
            functools.partial(_segment_agg_kernel, block_rows=block_rows,
                              block_segs=block_segs, num_cols=num_cols,
                              moments=moments),
            out_shape=out_shape,
            grid=(num_seg_tiles, n_blocks),
            in_specs=[
                pl.BlockSpec((block_rows, num_cols), lambda j, i: (i, 0)),
                pl.BlockSpec((block_rows, 1), lambda j, i: (i, 0)),
                pl.BlockSpec((block_rows, num_cols), lambda j, i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((nrows * num_cols, block_segs),
                                   lambda j, i: (0, j)),
            interpret=interpret,
        )(vals2, segs2, valid2)
        return out[:, :num_segments].reshape(num_cols, nrows, num_segments)

    grid_len = n_blocks + num_seg_tiles - 1
    rowm, tilem, nsteps = _band_maps(segs.astype(jnp.int32), n_blocks,
                                     block_rows, block_segs, num_seg_tiles,
                                     grid_len)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(grid_len,),
        in_specs=[
            pl.BlockSpec((block_rows, num_cols),
                         lambda s, rm, tm, ns: (rm[s], 0)),
            pl.BlockSpec((block_rows, 1),
                         lambda s, rm, tm, ns: (rm[s], 0)),
            pl.BlockSpec((block_rows, num_cols),
                         lambda s, rm, tm, ns: (rm[s], 0)),
        ],
        out_specs=pl.BlockSpec((nrows * num_cols, block_segs),
                               lambda s, rm, tm, ns: (0, tm[s])),
    )
    out = pl.pallas_call(
        functools.partial(_segment_agg_kernel_pruned, block_rows=block_rows,
                          block_segs=block_segs, num_cols=num_cols,
                          moments=moments),
        out_shape=out_shape,
        grid_spec=grid_spec,
        interpret=interpret,
    )(rowm, tilem, nsteps.reshape(1), vals2, segs2, valid2)

    # tiles no row-block band touches were never visited: their blocks hold
    # uninitialized memory, so fill them with the moment identities
    visited = jnp.zeros((num_seg_tiles,), bool).at[tilem].set(True)
    fill = jnp.array(_row_fills(moments), jnp.float32)
    out = jnp.where(jnp.repeat(visited, block_segs)[None, :], out,
                    fill[:, None])

    if check_sorted:
        # pruning is only meaning-preserving on sorted segs; poison (rather
        # than silently mis-aggregate) when the precondition is violated
        # under tracing, where the eager check could not run
        is_sorted = jnp.all(segs[1:] >= segs[:-1]) if n_p > 1 else True
        out = jnp.where(is_sorted, out, jnp.float32(jnp.nan))
    return out[:, :num_segments].reshape(num_cols, nrows, num_segments)


_MOMENT_ROW = {"sum": 0, "count": 1, "min": 2, "max": 3}
_MOMENT_FILL = {"sum": 0.0, "count": 0.0, "min": POS_INF, "max": NEG_INF}


def _segment_arg_index_unsorted(key: jax.Array, idx_cand: jax.Array,
                                seg: jax.Array, num_segments: int, *,
                                minimize: bool,
                                tie_first: bool) -> jax.Array:
    """Per-segment attaining row index for ARBITRARY (unsorted) segment
    ids — the ``layout='unsorted'`` jnp formulation.  The associative-scan
    trick of ``_segment_arg_index_scan`` needs segment-contiguous rows, so
    this uses the hit-detection form instead: one segment extremum, one
    row-sized ``best[seg]`` gather (the single row-sized gather of the
    whole sort-free jnp route — still far below the sort it replaces),
    and a tie-ordered index reduce.  Invalid rows carry the worst key and
    the tie-identity index, so an empty segment's ``best`` (reduce
    identity) only ever "hits" rows that resolve to the tie identity —
    matching the sorted formulation bit for bit."""
    segf = jax.ops.segment_min if minimize else jax.ops.segment_max
    best = segf(key, seg, num_segments=num_segments)
    hit = key == jnp.take(best, seg, mode="clip")
    ident = POS_INF if tie_first else NEG_INF
    cand = jnp.where(hit, idx_cand, jnp.float32(ident))
    redf = jax.ops.segment_min if tie_first else jax.ops.segment_max
    # empty segments reduce to the tie identity (the redf identity IS the
    # tie identity for each order), so no extra emptiness gate is needed
    return redf(cand, seg, num_segments=num_segments)


def _segment_arg_index_scan(key: jax.Array, idx_cand: jax.Array,
                            seg: jax.Array, num_segments: int, *,
                            minimize: bool, tie_first: bool) -> jax.Array:
    """Per-segment attaining row index WITHOUT any row-sized gather.

    The classic jnp formulation (``key == best[seg]`` hit detection)
    issues an N-sized gather; instead this runs a segmented lexicographic
    reduce as one ``lax.associative_scan`` over (key, idx, seg) triples —
    contiguous sorted segments make the segment-reset combine associative
    — and reads each segment's result at its last row (an
    S-sized take).  ``idx_cand`` carries the tie identity (±inf) for
    invalid rows, so a valid row always beats an invalid one on equal
    keys.  Returns the f32 index row (tie identity for empty segments)."""
    n = key.shape[0]

    def combine(a, b):          # b is the later contiguous range
        ak, ai, as_ = a
        bk, bi, bs = b
        better = (bk < ak) if minimize else (bk > ak)
        i_better = (bi < ai) if tie_first else (bi > ai)
        take_b = (bs != as_) | better | ((bk == ak) & i_better)
        return (jnp.where(take_b, bk, ak), jnp.where(take_b, bi, ai), bs)

    _, red_idx, _ = lax.associative_scan(
        combine, (key, idx_cand, seg.astype(jnp.int32)))
    last = jax.ops.segment_max(jnp.arange(n, dtype=jnp.int32), seg,
                               num_segments=num_segments)
    got = last >= 0                           # segments with any row at all
    picked = jnp.take(red_idx, jnp.clip(last, 0, n - 1))
    ident = POS_INF if tie_first else NEG_INF
    return jnp.where(got, picked, jnp.float32(ident))


def _segment_agg_jnp(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                     num_segments: int,
                     moments: tuple[tuple[str, ...], ...],
                     sorted_segs: bool = True) -> jax.Array:
    """Pure-JAX fallback, identical math: (N, C) → (C, R, num_segments).
    ``moments`` is per-column; moment rows a column does not request hold
    their init identity (0 / 0 / ±inf, tie identity for index rows).
    Unlike the kernel (where the fused pass makes extra moments nearly
    free), each jnp moment is a separate segment op, so it runs once per
    moment over exactly the columns that need it.  The value moments are
    order-independent (``jax.ops.segment_*`` scatter); only the index
    moments care about ``sorted_segs`` — contiguous sorted segments get
    the gather-free associative scan, arbitrary ids the hit-detection
    form."""
    v = vals.astype(jnp.float32)
    seg = segs.astype(jnp.int32)
    num_cols = vals.shape[1]
    nrows = moment_rows(moments)
    out = jnp.broadcast_to(
        jnp.asarray(_row_fills(moments),
                    jnp.float32).reshape(num_cols, nrows, 1),
        (num_cols, nrows, num_segments))
    for m in MOMENTS:
        idx = [c for c in range(num_cols) if m in moments[c]]
        if not idx:
            continue
        # static per-column slices, NOT v[:, idx] list-indexing: advanced
        # indexing lowers to an (N, len(idx)) gather, and this path is
        # spy-asserted to add no row-sized gathers beyond the group sort
        vi = jnp.stack([v[:, c] for c in idx], axis=1)
        gi = jnp.stack([valid[:, c] for c in idx], axis=1)
        if m == "sum":
            r = jax.ops.segment_sum(jnp.where(gi, vi, 0.0), seg,
                                    num_segments=num_segments)
        elif m == "count":
            r = jax.ops.segment_sum(gi.astype(jnp.float32), seg,
                                    num_segments=num_segments)
        elif m == "min":
            r = jax.ops.segment_min(jnp.where(gi, vi, POS_INF), seg,
                                    num_segments=num_segments)
        else:
            r = jax.ops.segment_max(jnp.where(gi, vi, NEG_INF), seg,
                                    num_segments=num_segments)
        out = out.at[jnp.asarray(idx), _MOMENT_ROW[m], :].set(r.T)
    if nrows == 6:
        n = vals.shape[0]
        rowidx = jnp.arange(n, dtype=jnp.float32)
        for c in range(num_cols):
            for which, row, minimize in (("argmin", ARGMIN_ROW, True),
                                         ("argmax", ARGMAX_ROW, False)):
                tie = _index_tie(moments[c], which)
                if tie is None:
                    continue
                worst = POS_INF if minimize else NEG_INF
                key = jnp.where(valid[:, c], v[:, c], worst)
                cand = jnp.where(valid[:, c], rowidx,
                                 POS_INF if tie else NEG_INF)
                argf = (_segment_arg_index_scan if sorted_segs
                        else _segment_arg_index_unsorted)
                r = argf(key, cand, seg, num_segments,
                         minimize=minimize, tie_first=tie)
                out = out.at[c, row, :].set(r)
    return out


def fused_segment_agg(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                      num_segments: int, *, block_rows: int = 256,
                      block_segs: int | None = None,
                      backend: str = "auto",
                      moments: tuple[str, ...] = MOMENTS,
                      prune: bool = True,
                      assume_sorted: bool = False,
                      layout: str = "sorted") -> jax.Array:
    """Fused multi-column segmented aggregation.

    ``vals``  (N,) or (N, C) — C value columns over the same row stream.
    ``segs``  (N,) int in [0, num_segments); sorted ascending under the
    default ``layout='sorted'``, arbitrary under ``layout='unsorted'``.
    ``valid`` (N,) or (N, C) bool — per-column row validity (guards).
    This guard input is also how whole-plan fusion (relational/fuse.py)
    reaches the kernel: pushed-down Filter predicates and the join's
    found mask arrive pre-ANDed into ``valid`` rather than as a
    compacted row stream, and the fused chain's probe output arrives as
    ``segs`` (right-row indices under ``layout='unsorted'``) — no
    plumbing here is fusion-specific; the chain reuses these two
    arguments as-is.
    ``moments`` restricts which of [sum, count, min, max] (plus the
    optional index moments ``argmin_first``/``argmin_last``/
    ``argmax_first``/``argmax_last`` — see ``INDEX_MOMENTS``) are
    computed — either one tuple of moment names applied to every column,
    or a per-column tuple of tuples.  Skipped rows hold their init
    identity.  Requesting an index moment grows the output to 6 rows per
    column: rows 4/5 carry the f32 row index attaining the column's
    min/max with the requested tie order (tie identity ±inf for empty
    segments), and the padded row count must stay below 2^24 so f32
    represents every index exactly.

    ``prune`` (kernel backends only) enables band pruning: the compact
    O(row_blocks + seg_tiles) grid over exactly the (row_block, seg_tile)
    pairs whose bands intersect, instead of the full cross product.
    Pruning relies on the sorted-``segs`` precondition, which is
    *validated*, not assumed: concrete unsorted input raises ``ValueError``
    eagerly; traced input gets an O(N) runtime monotonicity guard that
    poisons the output with NaN on violation.  Callers that establish the
    order by construction (the grouped executors sort first) pass
    ``assume_sorted=True`` to skip both checks.

    ``layout='unsorted'`` is the sort-free grouped route's accumulation
    mode: segment ids may arrive in ANY order (hash-slotted, see
    relational/keyslot.py), so band pruning is disabled — the kernel
    backends run the order-independent cross-product grid (whose one-hot
    membership reduce never assumed an order; with a dense group bound
    the segment range fits one tile and the "cross product" degenerates
    to the plain row walk), the sorted-``segs`` validation is skipped
    outright, and the jnp index moments switch from the contiguity-
    dependent associative scan to the hit-detection form.  Every moment
    — including the lexicographic (key, row) index merge — is a
    commutative monoid, so results match the sorted layout exactly up to
    f32 re-association of sums.

    Returns (C, R, num_segments) f32 with moment rows [sum, count, min,
    max(, argmin-index, argmax-index)]; empty segments read the
    identities [0, 0, +inf, -inf(, ±inf, ±inf)].
    """
    if layout not in ("sorted", "unsorted"):
        raise ValueError(f"unknown segment_agg layout {layout!r}; expected "
                         "'sorted' or 'unsorted'")
    if layout == "unsorted":
        prune = False            # band pruning is meaningless out of order
    vals, valid = _normalize(jnp.asarray(vals), jnp.asarray(valid))
    num_cols = vals.shape[1]
    moments = normalize_moments(moments, num_cols)
    if has_index_moments(moments) and not index_moment_ok(vals.shape[0],
                                                          block_rows):
        raise ValueError(
            f"index moments accumulate f32 row indices, exact only "
            f"below 2^24 (padded) rows; got {vals.shape[0]} — split the "
            f"input or use the exact jnp arg path")
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        return _segment_agg_jnp(vals, segs, valid, num_segments, moments,
                                sorted_segs=layout == "sorted")
    if backend not in ("pallas", "interpret"):
        raise ValueError(f"unknown segment_agg backend {backend!r}")
    if block_segs is None:
        block_segs = default_block_segs(num_segments, block_rows)
    check_sorted = (layout == "sorted"
                    and _validate_sorted(segs, prune, assume_sorted,
                                         backend))
    return _segment_agg_pallas(vals, jnp.asarray(segs), valid, num_segments,
                               block_rows, int(block_segs),
                               interpret=backend == "interpret",
                               moments=moments, prune=prune,
                               check_sorted=check_sorted)


def segment_agg(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                num_segments: int, block_rows: int = 256,
                interpret: bool = True,
                block_segs: int | None = None) -> jax.Array:
    """Single-column legacy entry point: (4, num_segments) f32 rows
    [sum, count, min, max].  See ``fused_segment_agg`` for the
    multi-column / backend-dispatching API."""
    out = fused_segment_agg(vals, segs, valid, num_segments,
                            block_rows=block_rows, block_segs=block_segs,
                            backend="interpret" if interpret else "pallas")
    return out[0]
