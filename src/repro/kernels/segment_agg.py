"""Pallas TPU kernel: fused grouped aggregation (the 𝒢_{AggΔ} hot path).

One pass over rows sorted by segment id computes SUM / COUNT / MIN / MAX
per segment simultaneously — the fused multi-aggregate the recognized
execution path of Aggify emits for grouped custom aggregates.  The kernel
accepts *multiple value columns per pass* (each with its own validity
mask, so differently-guarded recognized updates batch into one HBM
traversal) and tiles the *segment range* so the one-hot membership mask
always fits VMEM regardless of group cardinality.

TPU adaptation (vs a CUDA scatter-atomic formulation): atomics are not the
TPU model.  Instead each row-block materializes a one-hot membership mask
(rows × segment-tile) in VMEM and reduces with broadcast/select ops on the
VPU (8×128 lanes); partials accumulate into the output block, which stays
resident in VMEM across the whole row-block grid (output revisiting).
Rows are pre-sorted by segment, so the mask is band-structured and the
working set is bounded by (BLOCK_ROWS × BLOCK_SEGS) — chosen by
``default_block_segs`` to respect a VMEM budget.

Grid: (num_seg_tiles, num_row_blocks) — row blocks iterate fastest so the
output tile stays VMEM-resident while every row block streams past it.
Block shapes:
  vals  (BLOCK_ROWS, C)  f32          segs  (BLOCK_ROWS, 1) i32
  valid (BLOCK_ROWS, C)  i32
  out   (4*C, BLOCK_SEGS)  row layout [4*c + m] with m = sum,count,min,max

Execution backends (``fused_segment_agg``):
  * ``pallas``    — compiled kernel (real TPU).
  * ``interpret`` — the same kernel under the Pallas interpreter (CI/CPU
                    correctness; exercises the exact lowering).
  * ``jnp``       — pure ``jax.ops.segment_*`` fallback, identical math,
                    used on CPU/GPU where the interpreter loop would be
                    the bottleneck.
  * ``auto``      — pallas on TPU, jnp elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = float("-inf")
POS_INF = float("inf")

#: index of each fused moment in the kernel output
MOMENTS = ("sum", "count", "min", "max")


def default_block_segs(num_segments: int, block_rows: int = 256,
                       vmem_budget_elems: int = 1 << 19) -> int:
    """Largest segment-tile width whose (block_rows × tile) membership mask
    stays under ``vmem_budget_elems`` f32 elements (default 2 MB)."""
    bs = max(8, vmem_budget_elems // max(block_rows, 1))
    return int(min(num_segments, bs))


def _segment_agg_kernel(vals_ref, segs_ref, valid_ref, out_ref, *,
                        block_segs: int, num_cols: int,
                        moments: tuple[tuple[str, ...], ...]):
    j = pl.program_id(0)          # segment tile (output stays resident)
    i = pl.program_id(1)          # row block   (streams past the tile)

    @pl.when(i == 0)
    def _init():
        for c in range(num_cols):
            out_ref[4 * c + 0, :] = jnp.zeros((block_segs,), out_ref.dtype)
            out_ref[4 * c + 1, :] = jnp.zeros((block_segs,), out_ref.dtype)
            out_ref[4 * c + 2, :] = jnp.full((block_segs,), POS_INF,
                                             out_ref.dtype)
            out_ref[4 * c + 3, :] = jnp.full((block_segs,), NEG_INF,
                                             out_ref.dtype)

    vals = vals_ref[...].astype(out_ref.dtype)          # (R, C)
    segs = segs_ref[...]                                # (R, 1) int32
    ok = valid_ref[...] != 0                            # (R, C)

    r = vals.shape[0]
    local = segs - j * block_segs                       # tile-relative ids
    seg_iota = lax.broadcasted_iota(jnp.int32, (r, block_segs), 1)
    in_tile = local == seg_iota                         # (R, BS) band mask

    for c in range(num_cols):
        ms = moments[c]
        member = in_tile & ok[:, c:c + 1]
        vbc = jnp.broadcast_to(vals[:, c:c + 1], (r, block_segs))
        if "sum" in ms:
            out_ref[4 * c + 0, :] += jnp.sum(jnp.where(member, vbc, 0),
                                             axis=0)
        if "count" in ms:
            out_ref[4 * c + 1, :] += jnp.sum(member.astype(out_ref.dtype),
                                             axis=0)
        if "min" in ms:
            out_ref[4 * c + 2, :] = jnp.minimum(
                out_ref[4 * c + 2, :],
                jnp.min(jnp.where(member, vbc, POS_INF), axis=0))
        if "max" in ms:
            out_ref[4 * c + 3, :] = jnp.maximum(
                out_ref[4 * c + 3, :],
                jnp.max(jnp.where(member, vbc, NEG_INF), axis=0))


def _normalize(vals: jax.Array, valid: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Lift (N,)/(N,C) vals and valid to matching (N, C)."""
    if vals.ndim == 1:
        vals = vals[:, None]
    if valid.ndim == 1:
        valid = valid[:, None]
    if valid.shape[1] == 1 and vals.shape[1] > 1:
        valid = jnp.broadcast_to(valid, vals.shape)
    return vals, valid


@functools.partial(jax.jit, static_argnames=("num_segments", "block_rows",
                                             "block_segs", "interpret",
                                             "moments"))
def _segment_agg_pallas(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                        num_segments: int, block_rows: int,
                        block_segs: int, interpret: bool,
                        moments: tuple[str, ...] = MOMENTS) -> jax.Array:
    """(N, C) vals/valid → (C, 4, num_segments) f32 via the Pallas kernel."""
    n, num_cols = vals.shape
    pad = (-n) % block_rows
    if pad:
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        segs = jnp.pad(segs, (0, pad), constant_values=num_segments)
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
    n_p = n + pad
    segs2 = segs.astype(jnp.int32).reshape(n_p, 1)
    valid2 = valid.astype(jnp.int32)
    vals2 = vals.astype(jnp.float32)

    num_seg_tiles = -(-num_segments // block_segs)
    s_pad = num_seg_tiles * block_segs
    grid = (num_seg_tiles, n_p // block_rows)
    out = pl.pallas_call(
        functools.partial(_segment_agg_kernel, block_segs=block_segs,
                          num_cols=num_cols, moments=moments),
        out_shape=jax.ShapeDtypeStruct((4 * num_cols, s_pad), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, num_cols), lambda j, i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((block_rows, num_cols), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((4 * num_cols, block_segs),
                               lambda j, i: (0, j)),
        interpret=interpret,
    )(vals2, segs2, valid2)
    return out[:, :num_segments].reshape(num_cols, 4, num_segments)


_MOMENT_ROW = {"sum": 0, "count": 1, "min": 2, "max": 3}
_MOMENT_FILL = {"sum": 0.0, "count": 0.0, "min": POS_INF, "max": NEG_INF}


def _segment_agg_jnp(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                     num_segments: int,
                     moments: tuple[tuple[str, ...], ...]) -> jax.Array:
    """Pure-JAX fallback, identical math: (N, C) → (C, 4, num_segments).
    ``moments`` is per-column; moment rows a column does not request hold
    their init identity (0 / 0 / ±inf).  Unlike the kernel (where the
    fused pass makes extra moments nearly free), each jnp moment is a
    separate segment op, so it runs once per moment over exactly the
    columns that need it."""
    v = vals.astype(jnp.float32)
    seg = segs.astype(jnp.int32)
    num_cols = vals.shape[1]
    out = jnp.stack(
        [jnp.full((num_cols, num_segments), _MOMENT_FILL[m], jnp.float32)
         for m in MOMENTS], axis=1)
    for m in MOMENTS:
        idx = [c for c in range(num_cols) if m in moments[c]]
        if not idx:
            continue
        vi = v[:, idx]
        gi = valid[:, idx]
        if m == "sum":
            r = jax.ops.segment_sum(jnp.where(gi, vi, 0.0), seg,
                                    num_segments=num_segments)
        elif m == "count":
            r = jax.ops.segment_sum(gi.astype(jnp.float32), seg,
                                    num_segments=num_segments)
        elif m == "min":
            r = jax.ops.segment_min(jnp.where(gi, vi, POS_INF), seg,
                                    num_segments=num_segments)
        else:
            r = jax.ops.segment_max(jnp.where(gi, vi, NEG_INF), seg,
                                    num_segments=num_segments)
        out = out.at[jnp.asarray(idx), _MOMENT_ROW[m], :].set(r.T)
    return out


def fused_segment_agg(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                      num_segments: int, *, block_rows: int = 256,
                      block_segs: int | None = None,
                      backend: str = "auto",
                      moments: tuple[str, ...] = MOMENTS) -> jax.Array:
    """Fused multi-column segmented aggregation.

    ``vals``  (N,) or (N, C) — C value columns over the same row stream.
    ``segs``  (N,) int, sorted ascending, in [0, num_segments).
    ``valid`` (N,) or (N, C) bool — per-column row validity (guards).
    ``moments`` restricts which of [sum, count, min, max] are computed —
    either one tuple of moment names applied to every column, or a
    per-column tuple of tuples.  Skipped rows hold their init identity.

    Returns (C, 4, num_segments) f32 with moment rows [sum, count, min,
    max]; empty segments read [0, 0, +inf, -inf].
    """
    vals, valid = _normalize(jnp.asarray(vals), jnp.asarray(valid))
    num_cols = vals.shape[1]
    if not moments or isinstance(moments[0], str):
        moments = (tuple(m for m in MOMENTS if m in moments),) * num_cols
    else:
        moments = tuple(tuple(m for m in MOMENTS if m in ms)
                        for ms in moments)
    if len(moments) != num_cols:
        raise ValueError(f"per-column moments: got {len(moments)} entries "
                         f"for {num_cols} columns")
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        return _segment_agg_jnp(vals, segs, valid, num_segments, moments)
    if backend not in ("pallas", "interpret"):
        raise ValueError(f"unknown segment_agg backend {backend!r}")
    if block_segs is None:
        block_segs = default_block_segs(num_segments, block_rows)
    return _segment_agg_pallas(vals, jnp.asarray(segs), valid, num_segments,
                               block_rows, int(block_segs),
                               interpret=backend == "interpret",
                               moments=moments)


def segment_agg(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                num_segments: int, block_rows: int = 256,
                interpret: bool = True,
                block_segs: int | None = None) -> jax.Array:
    """Single-column legacy entry point: (4, num_segments) f32 rows
    [sum, count, min, max].  See ``fused_segment_agg`` for the
    multi-column / backend-dispatching API."""
    out = fused_segment_agg(vals, segs, valid, num_segments,
                            block_rows=block_rows, block_segs=block_segs,
                            backend="interpret" if interpret else "pallas")
    return out[0]
