"""Pallas TPU kernel: fused grouped aggregation (the 𝒢_{AggΔ} hot path).

One pass over rows sorted by segment id computes SUM / COUNT / MIN / MAX
per segment simultaneously — the fused multi-aggregate the recognized
execution path of Aggify emits for grouped custom aggregates.

TPU adaptation (vs a CUDA scatter-atomic formulation): atomics are not the
TPU model.  Instead each row-block materializes a one-hot membership mask
(rows × segments) in VMEM and reduces with broadcast/select ops on the VPU
(8×128 lanes); partials accumulate into the output block, which stays
resident in VMEM across the whole row-block grid (output revisiting).
Rows are pre-sorted by segment, so the mask is band-structured and the
working set is bounded by (BLOCK_ROWS × NUM_SEGS) — the caller tiles the
segment range so this fits VMEM.

Grid: (num_row_blocks,). Block shapes:
  vals  (BLOCK_ROWS, 1)  f32/bf16      segs (BLOCK_ROWS, 1) i32
  out   (4, NUM_SEGS)    rows = [sum, count, min, max]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = float("-inf")
POS_INF = float("inf")


def _segment_agg_kernel(vals_ref, segs_ref, valid_ref, out_ref, *,
                        num_segments: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[0, :] = jnp.zeros((num_segments,), out_ref.dtype)        # sum
        out_ref[1, :] = jnp.zeros((num_segments,), out_ref.dtype)        # count
        out_ref[2, :] = jnp.full((num_segments,), POS_INF, out_ref.dtype)  # min
        out_ref[3, :] = jnp.full((num_segments,), NEG_INF, out_ref.dtype)  # max

    vals = vals_ref[...].astype(out_ref.dtype)          # (R, 1)
    segs = segs_ref[...]                                # (R, 1) int32
    ok = valid_ref[...] != 0                            # (R, 1)

    r = vals.shape[0]
    seg_iota = lax.broadcasted_iota(jnp.int32, (r, num_segments), 1)
    member = (segs == seg_iota) & ok                    # (R, S) band mask

    vbc = jnp.broadcast_to(vals, (r, num_segments))
    out_ref[0, :] += jnp.sum(jnp.where(member, vbc, 0), axis=0)
    out_ref[1, :] += jnp.sum(member.astype(out_ref.dtype), axis=0)
    out_ref[2, :] = jnp.minimum(
        out_ref[2, :], jnp.min(jnp.where(member, vbc, POS_INF), axis=0))
    out_ref[3, :] = jnp.maximum(
        out_ref[3, :], jnp.max(jnp.where(member, vbc, NEG_INF), axis=0))


@functools.partial(jax.jit, static_argnames=("num_segments", "block_rows",
                                             "interpret"))
def segment_agg(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                num_segments: int, block_rows: int = 256,
                interpret: bool = True) -> jax.Array:
    """Returns (4, num_segments) f32: [sum, count, min, max] per segment.

    ``vals`` (N,) float, ``segs`` (N,) int32 sorted ascending, ``valid``
    (N,) bool.  N is padded to a multiple of ``block_rows``.
    """
    n = vals.shape[0]
    pad = (-n) % block_rows
    if pad:
        vals = jnp.pad(vals, (0, pad))
        segs = jnp.pad(segs, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    n_p = n + pad
    vals2 = vals.reshape(n_p, 1)
    segs2 = segs.astype(jnp.int32).reshape(n_p, 1)
    valid2 = valid.astype(jnp.int32).reshape(n_p, 1)

    grid = (n_p // block_rows,)
    out = pl.pallas_call(
        functools.partial(_segment_agg_kernel, num_segments=num_segments),
        out_shape=jax.ShapeDtypeStruct((4, num_segments), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((4, num_segments), lambda i: (0, 0)),
        interpret=interpret,
    )(vals2, segs2, valid2)
    return out
