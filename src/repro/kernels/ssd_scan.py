"""Pallas TPU kernel: chunked SSD (state-space duality) scan — the ordered
custom aggregate with an associative Merge, on the MXU.

The Mamba-2 recurrence per head (state N × channels P):

    h_t = a_t · h_{t-1} + B_t ⊗ x_t          (outer product update)
    y_t = C_t · h_t

is exactly an *ordered aggregate* in the paper's contract:

    Init:        h = 0
    Accumulate:  one timestep (the cursor-loop body)
    Merge:       (decayᵃ, stateᵃ) ∘ (decayᵇ, stateᵇ)
                 = (decayᵃ·decayᵇ, decayᵇ·stateᵃ + stateᵇ)   [associative]
    Terminate:   y projections

The chunked execution (this kernel) is Aggify's chunked executor on TPU:
within a chunk the quadratic dual form runs on the MXU (three matmuls),
across chunks the carried state h applies the Merge — sequential in the
grid, VMEM-resident scratch.

Grid: (BH, num_chunks).  Per-chunk math (chunk length C):
    la     = cumsum(log a)                       (C,)
    scores = (Cmat @ B^T) ⊙ M,  M[t,s] = e^{la_t − la_s}·[s ≤ t]
    y      = scores @ x  +  e^{la} ⊙ (Cmat @ h_prev)
    h_new  = e^{la_C} h_prev + (B ⊙ e^{la_C − la})^T @ x
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, loga_ref, b_ref, c_ref, y_ref, h_scr, *, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (C, P)
    loga = loga_ref[0].astype(jnp.float32)    # (C, 1)
    bmat = b_ref[0].astype(jnp.float32)       # (C, N)
    cmat = c_ref[0].astype(jnp.float32)       # (C, N)

    la = jnp.cumsum(loga, axis=0)             # (C, 1) inclusive
    # intra-chunk dual form: scores[t, s] = e^{la_t - la_s} (Cmat_t · B_s), s<=t
    rel = la - la.T                            # (C, C) = la_t - la_s
    t_idx = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = t_idx >= s_idx
    decay = jnp.where(causal, jnp.exp(rel), 0.0)
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * decay                    # (C, C)
    y_intra = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: contribution of carried state
    h_prev = h_scr[...]                        # (N, P)
    ch = jax.lax.dot_general(cmat, h_prev, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (C, P)
    y_cross = jnp.exp(la) * ch

    y_ref[0] = (y_intra + y_cross).astype(y_ref.dtype)

    # state update (the Merge): h_new = e^{la_C} h_prev + Σ_s e^{la_C-la_s} B_s x_s^T
    la_last = la[chunk - 1:chunk, :]           # (1, 1)
    w = jnp.exp(la_last - la)                  # (C, 1)
    bw = bmat * w                              # (C, N)
    outer = jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (N, P)
    h_scr[...] = jnp.exp(la_last[0, 0]) * h_prev + outer


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, log_a: jax.Array, b: jax.Array, c: jax.Array,
             chunk: int = 64, interpret: bool = True) -> jax.Array:
    """x (BH, T, P); log_a (BH, T); b,c (BH, T, N) → y (BH, T, P).

    BH folds batch × heads.  T must be a multiple of ``chunk`` (caller
    pads; padded steps should carry log_a=0, x=0 so the state is benign).
    """
    bh, t, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0, f"T={t} must be a multiple of chunk={chunk}"
    la2 = log_a.reshape(bh, t, 1)

    grid = (bh, t // chunk)
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        out_shape=jax.ShapeDtypeStruct((bh, t, p), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh_, j: (bh_, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh_, j: (bh_, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh_, j: (bh_, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh_, j: (bh_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda bh_, j: (bh_, j, 0)),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, la2, b, c)
    return y
