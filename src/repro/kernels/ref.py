"""Pure-jnp oracles for every Pallas kernel (the correctness contracts the
interpret-mode sweeps assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_agg_ref(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                    num_segments: int) -> jax.Array:
    """[sum, count, min, max] per segment, (4, num_segments) f32."""
    v = vals.astype(jnp.float32)
    s = jax.ops.segment_sum(jnp.where(valid, v, 0), segs,
                            num_segments=num_segments)
    c = jax.ops.segment_sum(valid.astype(jnp.float32), segs,
                            num_segments=num_segments)
    mn = jax.ops.segment_min(jnp.where(valid, v, jnp.inf), segs,
                             num_segments=num_segments)
    mx = jax.ops.segment_max(jnp.where(valid, v, -jnp.inf), segs,
                             num_segments=num_segments)
    return jnp.stack([s, c, mn, mx])


def fused_segment_agg_ref(vals: jax.Array, segs: jax.Array, valid: jax.Array,
                          num_segments: int) -> jax.Array:
    """Multi-column oracle: (N, C) vals, (N, C) per-column validity →
    (C, 4, num_segments) f32 with moment rows [sum, count, min, max]."""
    cols = [segment_agg_ref(vals[:, c], segs, valid[:, c], num_segments)
            for c in range(vals.shape[1])]
    return jnp.stack(cols, axis=0)


def segment_arg_index_ref(keys: jax.Array, segs: jax.Array,
                          valid: jax.Array, num_segments: int, *,
                          minimize: bool, tie_first: bool) -> jax.Array:
    """Oracle for the kernel's index moment: the row index attaining each
    segment's key extremum, first- or last-attaining on ties, valid rows
    only.  Deliberately the classic hit-detection formulation (segment
    extremum + equality scan + candidate reduce) — the very lowering the
    index moment replaces — so the kernel is pinned against independent
    math.  Returns int32 with the empty-segment sentinel ``n`` for
    first-attaining tie order, ``-1`` for last-attaining."""
    n = keys.shape[0]
    k = keys.astype(jnp.float32)
    worst = jnp.inf if minimize else -jnp.inf
    masked = jnp.where(valid, k, worst)
    segf = jax.ops.segment_min if minimize else jax.ops.segment_max
    best = segf(masked, segs, num_segments=num_segments)
    hit = valid & (masked == jnp.take(best, segs))
    idx = jnp.arange(n, dtype=jnp.int32)
    if tie_first:
        cand = jnp.where(hit, idx, n)
        r = jax.ops.segment_min(cand, segs, num_segments=num_segments)
        return jnp.minimum(r, n)      # rowless segments clamp to the sentinel
    cand = jnp.where(hit, idx, -1)
    r = jax.ops.segment_max(cand, segs, num_segments=num_segments)
    return jnp.maximum(r, -1)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array) -> jax.Array:
    """Masked softmax attention, fp32 accumulation.  q (BH,G,D);
    k,v (BH,S,D); kv_len (BH,) → (BH,G,D)."""
    bh, g, d = q.shape
    s = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bgd,bsd->bgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, None, :] < kv_len[:, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgs,bsd->bgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_chunked(x: jax.Array, log_a: jax.Array, b: jax.Array,
                     c: jax.Array, chunk: int = 64) -> jax.Array:
    """Chunked SSD in pure jnp — the SAME dual-form math as the Pallas
    kernel (matmul intra-chunk + carried-state merge), scanning over
    chunks instead of timesteps.  This is the lowering path on non-TPU
    backends: the sequential ref below is the semantic oracle but lowers
    to a T-step scan (T dynamic-update-slices of the state — catastrophic
    as an execution plan)."""
    bh, t, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0
    nc = t // chunk
    xc = x.reshape(bh, nc, chunk, p).astype(jnp.float32)
    lac = log_a.reshape(bh, nc, chunk, 1).astype(jnp.float32)
    bc = b.reshape(bh, nc, chunk, n).astype(jnp.float32)
    cc = c.reshape(bh, nc, chunk, n).astype(jnp.float32)

    la = jnp.cumsum(lac, axis=2)                         # (BH,NC,C,1)
    rel = la - jnp.swapaxes(la, 2, 3)                    # (BH,NC,C,C)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal, jnp.exp(rel), 0.0)
    scores = jnp.einsum("zgtn,zgsn->zgts", cc, bc) * decay
    y_intra = jnp.einsum("zgts,zgsp->zgtp", scores, xc)

    # carried state across chunks (the associative Merge)
    la_last = la[:, :, -1:, :]                           # (BH,NC,1,1)
    w = jnp.exp(la_last - la)                            # (BH,NC,C,1)
    chunk_state = jnp.einsum("zgsn,zgsp->zgnp", bc * w, xc)  # (BH,NC,N,P)
    chunk_decay = jnp.exp(la_last[:, :, 0, 0])           # (BH,NC)

    def step(h, inp):
        st, dec, cmat, lam = inp
        y_cross = jnp.einsum("ztn,znp->ztp", cmat, h) * jnp.exp(lam)
        h_new = dec[:, None, None] * h + st
        return h_new, y_cross

    h0 = jnp.zeros((bh, n, p), jnp.float32)
    _, y_cross = jax.lax.scan(
        step, h0,
        (chunk_state.swapaxes(0, 1), chunk_decay.swapaxes(0, 1),
         cc.swapaxes(0, 1), la.swapaxes(0, 1)))
    y = y_intra + y_cross.swapaxes(0, 1)
    return y.reshape(bh, t, p).astype(x.dtype)


def ssd_scan_ref(x: jax.Array, log_a: jax.Array, b: jax.Array,
                 c: jax.Array) -> jax.Array:
    """Sequential SSD recurrence: h_t = a_t h_{t-1} + B_t ⊗ x_t;
    y_t = C_t · h_t.  x (BH,T,P); log_a (BH,T); b,c (BH,T,N)."""
    bh, t, p = x.shape
    n = b.shape[-1]

    def per_bh(xb, lab, bb, cb):
        def step(h, inp):
            xt, lat, bt, ct = inp
            h = jnp.exp(lat) * h + jnp.outer(bt, xt)
            y = ct @ h
            return h, y
        h0 = jnp.zeros((n, p), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xb.astype(jnp.float32),
                                        lab.astype(jnp.float32),
                                        bb.astype(jnp.float32),
                                        cb.astype(jnp.float32)))
        return ys
    y = jax.vmap(per_bh)(x, log_a, b, c)
    return y.astype(x.dtype)
