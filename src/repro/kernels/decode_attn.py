"""Pallas TPU kernel: flash-decode attention as an online-softmax
*aggregate* (the paper's Init/Accumulate/Merge/Terminate contract on the
sequence axis).

One decode step attends a group of G query heads (the GQA group sharing a
KV head) against an S-long KV cache:

    Init:        m = -inf, l = 0, acc = 0
    Accumulate:  per KV chunk j —  s = q·K_j^T;  m' = max(m, max_j s)
                 p = exp(s - m'); acc = acc·e^{m-m'} + p·V_j; l = l·e^{m-m'}+Σp
    Merge:       same rescale-combine across *shards* of the KV cache
                 (repro.models.attention.softmax_aggregate, executed with
                 core.aggregate.shard_merge over the sequence-parallel axis)
    Terminate:   out = acc / l

TPU adaptation: the CUDA flash-decode formulation splits KV across SMs and
merges in shared memory; here the intra-chip split is the sequential grid
(chunk state lives in VMEM scratch across grid steps — the accumulate), and
the inter-chip split is the aggregate Merge over ICI.  MXU alignment: block
shapes are (G≥8, D multiple of 128) and KV chunks of 128/256 rows.

Grid: (BH, num_kv_chunks) — BH = batch × kv_heads; scratch persists per BH
row (re-initialized when the chunk index wraps to 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                        m_scr, l_scr, acc_scr, *, scale: float,
                        chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                       # (G, D)
    k = k_ref[0]                       # (C, D)
    v = v_ref[0]                       # (C, D)
    kv_len = len_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G, C)
    pos = j * chunk + lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < kv_len, s, NEG_INF)

    m_prev = m_scr[...]                # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # guard the all-masked chunk (exp(-inf - -inf))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(jnp.where(jnp.isfinite(s), s - m_safe, NEG_INF))   # (G, C)
    alpha = jnp.exp(jnp.where(jnp.isfinite(m_prev), m_prev - m_safe, NEG_INF))

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)    # (G, D)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, chunk: int = 128,
                     interpret: bool = True) -> jax.Array:
    """q (BH, G, D); k,v (BH, S, D); kv_len (BH,) int32 → out (BH, G, D).

    BH folds batch × kv_heads; G is the GQA query-group size; S is the
    (padded) cache capacity.
    """
    bh, g, d = q.shape
    s = k.shape[1]
    pad = (-s) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    s_p = s + pad
    scale = 1.0 / (d ** 0.5)
    lens = kv_len.astype(jnp.int32).reshape(bh, 1)

    grid = (bh, s_p // chunk)
    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, scale=scale, chunk=chunk),
        out_shape=jax.ShapeDtypeStruct((bh, g, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda b, j: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),   # m
            pltpu.VMEM((g, 1), jnp.float32),   # l
            pltpu.VMEM((g, d), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v, lens)
    return out
