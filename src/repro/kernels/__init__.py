"""repro.kernels — Pallas TPU kernels for the aggregation hot paths.

Each kernel ships with a pure-jnp oracle (ref.py) and a dispatching wrapper
(ops.py).  All kernels are instances of the paper's aggregation contract —
see the module docstrings."""
from . import ops, ref
from .decode_attn import decode_attention
from .segment_agg import fused_segment_agg, segment_agg
from .ssd_scan import ssd_scan

__all__ = ["ops", "ref", "decode_attention", "fused_segment_agg",
           "segment_agg", "ssd_scan"]
