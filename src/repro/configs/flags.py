"""One door for every ``REPRO_*`` environment switch.

Eight PRs accreted kill switches and mode selectors as ad-hoc
``os.environ.get`` reads scattered across keyslot, engine, executors,
fuse, serving, launch, and the fault registry — each with its own
parsing convention (``!= "off"`` here, ``in {...}`` there, truthy-string
elsewhere).  This module is the single accessor: every flag is declared
in ``KNOWN`` (so a typo'd name raises instead of silently defaulting),
and the three read shapes the codebase actually uses are provided as

* ``enabled(name)``   — kill-switch convention: on unless the env var is
  exactly ``"off"`` (every ``REPRO_*=off`` switch in the docs);
* ``value(name)``     — the raw string (or ``default``) for free-form
  flags like ``REPRO_FAULTS`` / ``REPRO_HLO_DIR``;
* ``choice(name, options)`` — mode selectors (``REPRO_SEGAGG_BACKEND``
  et al.): the value when it is one of ``options``, else ``None``.

Reads are deliberately **uncached**: tests monkeypatch ``os.environ``
around single calls, and several flags (faults, backends) are flipped
mid-process.  A read costs one dict lookup — caching would only buy
staleness.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

#: every REPRO_* flag the codebase reads, with a one-line contract.
#: Reading an undeclared name raises — the registry is the inventory
#: docs/serving.md and docs/execution-modes.md enumerate switches from.
KNOWN = {
    "REPRO_AGG_SERVE": "serving layer kill switch (off = uncached paths)",
    "REPRO_SERVE_GUARD": "serving fault-tolerance ladder kill switch",
    "REPRO_INCR_AGG": "incremental ingest kill switch (off = ingest "
                      "appends but every snapshot recomputes)",
    "REPRO_SERVE_CKPT": "durable checkpoint/restore kill switch (off = "
                        "checkpoint() is a no-op, restore() recomputes)",
    "REPRO_PLAN_FUSE": "whole-plan fusion pass kill switch",
    "REPRO_JOIN_HASH": "keyslot hash-join lowering kill switch",
    "REPRO_GROUPAGG_SORTFREE": "sort-free grouped route kill switch",
    "REPRO_KEYSLOT_ADAPTIVE": "sketch-driven probe-table sizing switch",
    "REPRO_GROUPAGG_FUSED": "fused grouped backend: pallas|interpret|"
                            "jnp|off",
    "REPRO_SEGAGG_BACKEND": "segment-agg backend: pallas|interpret|jnp",
    "REPRO_SEGAGG_PALLAS": "legacy truthy switch for the pallas backend",
    "REPRO_SEGAGG_SHARDED": "sharded segment-agg launch kill switch",
    "REPRO_USE_PALLAS": "global pallas-kernels kill switch",
    "REPRO_FAULTS": "comma list of armed fault-injection sites",
    "REPRO_HLO_DIR": "directory for dry-run HLO dumps",
}


def _check(name: str) -> None:
    if name not in KNOWN:
        raise KeyError(
            f"unknown repro flag {name!r} — declare it in "
            f"repro.configs.flags.KNOWN (known: {sorted(KNOWN)})")


def value(name: str, default: Optional[str] = None) -> Optional[str]:
    """The flag's raw environment value, or ``default`` when unset."""
    _check(name)
    return os.environ.get(name, default)


def enabled(name: str) -> bool:
    """Kill-switch read: True unless the env var is exactly ``"off"``."""
    _check(name)
    return os.environ.get(name) != "off"


def choice(name: str, options: Sequence[str]) -> Optional[str]:
    """Mode-selector read: the value when it names one of ``options``,
    else ``None`` (unset or unrecognized fall through to the default)."""
    _check(name)
    got = os.environ.get(name)
    return got if got in options else None


__all__ = ["KNOWN", "enabled", "value", "choice"]
