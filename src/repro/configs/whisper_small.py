"""whisper-small [audio] — encoder-decoder; conv frontend is a STUB
(input_specs supplies precomputed frame embeddings, 1500 frames).
[arXiv:2212.04356; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, d_head=64,
    enc_layers=12, enc_seq=1500, norm="ln", rope_theta=0.0,
    tie_embeddings=True,
    source="[arXiv:2212.04356; unverified]",
)
