"""repro.configs — one module per assigned architecture (exact public
configs) + the shape grid.  ``get_config(arch_id)`` resolves by public id;
``reduced`` variants drive the CPU smoke tests."""
from .base import SHAPES, ArchConfig, ShapeSpec, supports_shape

from . import (command_r_35b, h2o_danube_1_8b, hymba_1_5b,
               llama3_2_vision_90b, llama4_scout_17b_a16e, mamba2_2_7b,
               olmoe_1b_7b, qwen1_5_32b, qwen3_14b, whisper_small)

_MODULES = [qwen1_5_32b, qwen3_14b, h2o_danube_1_8b, command_r_35b,
            llama3_2_vision_90b, olmoe_1b_7b, llama4_scout_17b_a16e,
            mamba2_2_7b, hymba_1_5b, whisper_small]

CONFIGS = {m.CONFIG.arch_id: m.CONFIG for m in _MODULES}
ARCH_IDS = tuple(CONFIGS)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in CONFIGS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    return CONFIGS[arch_id]


__all__ = ["SHAPES", "ArchConfig", "ShapeSpec", "supports_shape",
           "CONFIGS", "ARCH_IDS", "get_config"]
