"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer, SWA
on the attention path, ssm_state=16.  [arXiv:2411.13676; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, d_head=64,
    sliding_window=1024,
    ssm_state=16, ssm_headdim=64, ssm_expand=2, conv_width=4,
    source="[arXiv:2411.13676; hf]",
)
