"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality),
ssm_state=128.  [arXiv:2405.21060; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, d_head=0,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, conv_width=4,
    source="[arXiv:2405.21060; unverified]",
)
