"""Architecture + shape configuration system."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 → d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0     # 0 = full attention
    rope_theta: float = 1e4
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    # VLM (cross-attention image layers)
    cross_attn_every: int = 0   # every Nth layer is a cross-attn layer
    n_img_tokens: int = 0
    # encoder-decoder (audio)
    enc_layers: int = 0
    enc_seq: int = 0            # stub frontend sequence (whisper: 1500 frames)
    norm: str = "rms"           # rms | ln
    tie_embeddings: bool = False
    source: str = ""            # provenance tag [source; verified-tier]

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def reduced(self) -> "ArchConfig":
        """Same-family smoke config: tiny widths/depths, preserved structure
        (GQA ratio, MoE routing, SSD shapes, cross-attn cadence)."""
        kv = max(1, min(self.n_kv_heads, 2))
        heads = kv * max(1, min(self.n_heads // max(self.n_kv_heads, 1), 2))
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.cross_attn_every else 2),
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            d_head=16,
            d_ff=96 if self.d_ff else 0,
            vocab=128,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            cross_attn_every=self.cross_attn_every and 2,
            n_img_tokens=min(self.n_img_tokens, 8) if self.n_img_tokens else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            enc_seq=min(self.enc_seq, 16) if self.enc_seq else 0,
        )

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        h, kv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * (h + 2 * kv) * dh + h * dh * d
        mlp = 3 * d * ff if ff else 0
        n = 0
        if self.family == "ssm":
            d_inner = self.ssm_expand * d
            nh = d_inner // self.ssm_headdim
            per = d * (2 * d_inner + 2 * self.ssm_state + nh) \
                + self.conv_width * (d_inner + 2 * self.ssm_state) \
                + d_inner * d + 2 * d
            n = self.n_layers * per
        elif self.family == "moe":
            per = attn + 3 * d * ff * self.n_experts + d * self.n_experts + 2 * d
            n = self.n_layers * per
        elif self.family == "hybrid":
            d_inner = self.ssm_expand * d
            nh = d_inner // self.ssm_headdim
            ssm = d * (2 * d_inner + 2 * self.ssm_state + nh) \
                + self.conv_width * (d_inner + 2 * self.ssm_state) + d_inner * d
            n = self.n_layers * (attn + ssm + mlp + 2 * d)
        elif self.family == "vlm":
            n_cross = self.n_layers // self.cross_attn_every
            n_self = self.n_layers - n_cross
            n = n_self * (attn + mlp + 2 * d) + n_cross * (attn + mlp + 2 * d)
        elif self.family == "audio":
            n = (self.enc_layers * (attn + mlp + 2 * d)
                 + self.n_layers * (2 * attn + mlp + 3 * d))
        else:
            n = self.n_layers * (attn + mlp + 2 * d)
        n += v * d * (1 if self.tie_embeddings else 2)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        h, kv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * (h + 2 * kv) * dh + h * dh * d
        per = attn + 3 * d * ff * self.top_k + d * self.n_experts + 2 * d
        return self.n_layers * per + self.vocab * d * (1 if self.tie_embeddings else 2)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def supports_shape(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention: run for SSM / hybrid /
    sliding-window archs, skip for pure full-attention archs (documented in
    DESIGN.md §5)."""
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid") or cfg.sliding_window:
            return True, ""
        return False, ("full attention: 500k decode KV exceeds the "
                       "sub-quadratic requirement; skipped per assignment")
    return True, ""
