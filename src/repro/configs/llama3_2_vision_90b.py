"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer;
patch-embedding frontend is a STUB (input_specs supplies image-token
embeddings).  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, d_head=128,
    cross_attn_every=5, n_img_tokens=1600, rope_theta=5e5,
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
)
