"""Columnar Table: structure-of-arrays with a validity mask.

XLA requires static shapes, so variable-cardinality relational results are
represented as fixed-capacity columns plus a boolean ``valid`` mask (invalid
rows are compacted to the tail by ``compress``).  This is the TPU-native
stand-in for a row-store result set; a cursor's "temp table" is simply a
materialized (concrete, block_until_ready) Table.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

#: monotonic source for Table.version — every constructed Table (including
#: every functional-update result) gets a fresh token, so "same version"
#: certifies "same rows" for host-side caches
_VERSIONS = itertools.count(1)


@jax.tree_util.register_pytree_node_class
@dataclass
class Table:
    columns: Dict[str, jax.Array]
    valid: Optional[jax.Array] = None  # bool (capacity,) ; None => all valid
    #: declared dense bound on the distinct-group count of this table's
    #: rows (``declare_group_bound``); static metadata the grouped
    #: executors use to size segment tensors — see
    #: relational/group_bound.py.  Row-preserving ops propagate it (they
    #: cannot create new key combinations); concat drops it.
    group_bound: Optional[int] = None
    #: host-side identity token: unique per constructed Table, never
    #: propagated by the functional update ops (each returns a NEW
    #: version) and excluded from the pytree — derived caches (the
    #: serving layer's slot tables) key on it so a mutation can never be
    #: served stale data.  Not part of traced state.
    version: int = field(default_factory=lambda: next(_VERSIONS),
                         compare=False)

    # -- pytree ---------------------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[n] for n in names) + (self.valid,)
        return children, (names, self.group_bound)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, group_bound = aux
        cols = dict(zip(names, children[:-1]))
        return cls(cols, children[-1], group_bound)

    # -- construction ---------------------------------------------------------
    @staticmethod
    def from_columns(**cols) -> "Table":
        cols = {k: jnp.asarray(v) for k, v in cols.items()}
        return Table(cols)

    # -- basic properties -----------------------------------------------------
    @property
    def capacity(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def column_names(self) -> tuple[str, ...]:
        return tuple(self.columns)

    def mask(self) -> jax.Array:
        if self.valid is None:
            return jnp.ones(self.capacity, dtype=bool)
        return self.valid

    def count(self) -> jax.Array:
        return jnp.sum(self.mask().astype(jnp.int32))

    # -- row ops ---------------------------------------------------------------
    def filter(self, mask: jax.Array) -> "Table":
        return Table(dict(self.columns), self.mask() & mask,
                     self.group_bound)

    def project(self, names: Iterable[str]) -> "Table":
        return Table({n: self.columns[n] for n in names}, self.valid,
                     self.group_bound)

    def with_column(self, name: str, values: jax.Array) -> "Table":
        cols = dict(self.columns)
        cols[name] = values
        # a new column may have more distinct values than the declared
        # group bound covers, so the declaration does not survive
        return Table(cols, self.valid)

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        cols = {mapping.get(k, k): v for k, v in self.columns.items()}
        return Table(cols, self.valid, self.group_bound)

    def take(self, idx: jax.Array, idx_valid: Optional[jax.Array] = None) -> "Table":
        cols = {k: jnp.take(v, idx, axis=0, mode="clip")
                for k, v in self.columns.items()}
        base = jnp.take(self.mask(), idx, mode="clip")
        v = base if idx_valid is None else base & idx_valid
        return Table(cols, v, self.group_bound)

    def compress(self) -> "Table":
        """Stable-compact valid rows to the front (fixed capacity)."""
        m = self.mask()
        order = jnp.argsort(~m, stable=True)
        t = self.take(order)
        n = jnp.sum(m.astype(jnp.int32))
        return Table(t.columns, jnp.arange(self.capacity) < n,
                     self.group_bound)

    def sort_by(self, keys: Iterable[str], descending: Iterable[bool] = ()) -> "Table":
        """Stable multi-key sort; invalid rows sort last.

        ONE variadic ``lax.sort``: the validity flag (invalid-last) leads,
        the transformed key columns follow in precedence order, and an
        iota operand rides along as the permutation payload — so a K-key
        sort costs a single fused sort instead of K stable argsorts plus
        2K row gathers (the pre-variadic formulation), and the only row
        gather left is the final ``take(order)``."""
        keys = list(keys)
        desc = list(descending) or [False] * len(keys)
        m = self.mask()
        ops = [(~m).astype(jnp.int8)]
        for k, d in zip(keys, desc):
            ops.append(_sort_key(self.columns[k], d, m))
        iota = lax.iota(jnp.int32, self.capacity)
        res = lax.sort(tuple(ops) + (iota,), dimension=0, is_stable=True,
                       num_keys=len(ops))
        return self.take(res[-1])

    def head(self, n: int) -> "Table":
        c = self.compress()
        cols = {k: v[:n] for k, v in c.columns.items()}
        return Table(cols, c.mask()[:n], self.group_bound)

    def declare_group_bound(self, max_groups: int) -> "Table":
        """Declare a dense bound on how many distinct groups this table's
        rows can form (any key set the caller intends to group by).  The
        grouped executors (``GroupAgg`` and grouped ``AggCall``) size
        their segment tensors, the band-pruned kernel grid, and the
        sharded all-reduce payload by the bound's power-of-two bucket
        instead of the row capacity — and *validate* it: a concrete input
        with more groups raises eagerly, a traced one NaN-poisons the
        outputs.  See relational/group_bound.py.

        The *bucket* (not the raw value) is stored: it rides in the
        pytree treedef, so tables declared with nearby bounds share one
        treedef and jitted callers don't retrace per distinct value."""
        from .group_bound import bucket_group_bound
        return Table(dict(self.columns), self.valid,
                     bucket_group_bound(max_groups))

    def shard_rows(self, mesh, axis: str = "data") -> "Table":
        """Commit every column (and the validity mask) to a row sharding —
        ``PartitionSpec(axis)`` on dim 0 — over ``mesh``.  The grouped
        fused-aggregation path (``GroupAgg`` and grouped ``AggCall``)
        detects the committed sharding and runs the segment-aggregate
        kernel per row shard with a cross-device moment merge
        (``launch/sharded_agg.py``) — no other caller changes needed."""
        from jax.sharding import NamedSharding, PartitionSpec
        sh = NamedSharding(mesh, PartitionSpec(axis))
        cols = {k: jax.device_put(v, sh) for k, v in self.columns.items()}
        return Table(cols, jax.device_put(self.mask(), sh),
                     self.group_bound)

    def materialize(self) -> "Table":
        """Force device materialization — models the cursor temp table."""
        cols = {k: jax.block_until_ready(jnp.asarray(v)) for k, v in self.columns.items()}
        v = None if self.valid is None else jax.block_until_ready(self.valid)
        return Table(cols, v, self.group_bound)

    def nbytes(self) -> int:
        tot = 0
        for v in self.columns.values():
            tot += int(np.prod(v.shape)) * v.dtype.itemsize
        return tot

    def to_numpy(self) -> dict[str, np.ndarray]:
        m = np.asarray(self.mask())
        return {k: np.asarray(v)[m] for k, v in self.columns.items()}


def _sort_key(col: jax.Array, descending: bool, valid: jax.Array) -> jax.Array:
    if col.dtype == jnp.bool_:
        col = col.astype(jnp.int32)
    key = -col if descending else col
    if jnp.issubdtype(key.dtype, jnp.floating):
        big = jnp.array(jnp.inf, dtype=key.dtype)
    else:
        big = jnp.array(jnp.iinfo(key.dtype).max, dtype=key.dtype)
    return jnp.where(valid, key, big)


def concat(a: Table, b: Table) -> Table:
    cols = {k: jnp.concatenate([a.columns[k], b.columns[k]], axis=0)
            for k in a.columns}
    return Table(cols, jnp.concatenate([a.mask(), b.mask()]))
