"""Plan execution in JAX over columnar Tables.

Every operator keeps the fixed-capacity + validity-mask representation, so
the whole plan compiles to one XLA program (no host round trips): this is
what realizes the paper's "single pipelined query execution" claim for the
rewritten form.  The cursor baseline, by contrast, calls ``materialize()``
between the query and the loop — the temp-table barrier.
"""
from __future__ import annotations

import os
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.loop_ir import eval_expr
from repro.configs import flags
from .plan import (AggCall, Filter, GroupAgg, IterSpace, Join, Limit, OrderBy,
                   Plan, Project, Scan)
from .table import Table

Catalog = Mapping[str, Table]
Env = Mapping[str, Any]


def execute(plan: Plan, catalog: Catalog, env: Optional[Env] = None) -> Table:
    env = dict(env or {})
    return _exec(plan, catalog, env)


def _col_env(t: Table, env: Env) -> dict[str, Any]:
    e = dict(env)
    e.update(t.columns)
    return e


def _exec(plan: Plan, catalog: Catalog, env: Env) -> Table:
    if isinstance(plan, Scan):
        return catalog[plan.table]

    if isinstance(plan, IterSpace):
        init = jnp.asarray(eval_expr(plan.init, env))
        bound = jnp.asarray(eval_expr(plan.bound, env))
        step = jnp.asarray(eval_expr(plan.step, env))
        idx = init + jnp.arange(plan.capacity, dtype=init.dtype) * step
        ok = (idx <= bound) if plan.inclusive else (idx < bound)
        # descending iteration (negative step)
        ok_desc = (idx >= bound) if plan.inclusive else (idx > bound)
        ok = jnp.where(step < 0, ok_desc, ok)
        return Table({plan.column: idx}, ok)

    if isinstance(plan, Filter):
        t = _exec(plan.child, catalog, env)
        mask = eval_expr(plan.pred, _col_env(t, env))
        return t.filter(jnp.asarray(mask, dtype=bool))

    if isinstance(plan, Project):
        t = _exec(plan.child, catalog, env)
        cenv = _col_env(t, env)
        cols = {}
        for name, e in plan.exprs:
            v = eval_expr(e, cenv)
            v = jnp.broadcast_to(jnp.asarray(v), (t.capacity,) + jnp.shape(jnp.asarray(v))[1:]) \
                if jnp.ndim(jnp.asarray(v)) == 0 else jnp.asarray(v)
            cols[name] = v
        # computed expressions can mint columns with more distinct values
        # than the declared group bound covers; only pure column renames
        # keep the declaration honest
        from repro.core.loop_ir import Col as _Col
        keep = t.group_bound if all(isinstance(e, _Col)
                                    for _, e in plan.exprs) else None
        return Table(cols, t.valid, keep)

    if isinstance(plan, Join):
        lt = _exec(plan.left, catalog, env)
        rt = _exec(plan.right, catalog, env)
        return _gather_join(lt, rt, plan.left_key, plan.right_key, plan.how)

    if isinstance(plan, OrderBy):
        t = _exec(plan.child, catalog, env)
        return t.sort_by(plan.keys, plan.descending)

    if isinstance(plan, Limit):
        # first-n valid rows by prefix sum of the validity mask — an
        # in-place mask intersection, never a compaction (the old
        # compress()-based lowering paid a row-sized stable sort + gather
        # just to drop a mask; see analysis/jaxpr_spy.limit_census)
        t = _exec(plan.child, catalog, env)
        keep = jnp.cumsum(t.mask().astype(jnp.int32)) <= plan.n
        return t.filter(keep)

    if isinstance(plan, GroupAgg):
        from . import fuse
        needed = plan.keys + _agg_cols(plan.aggs)
        res = fuse.fused_chain_result(plan.child, catalog, env,
                                      tuple(needed), _exec)
        if res is None:
            t = _exec(plan.child, catalog, env)
            return _group_agg(t, plan.keys, plan.aggs, plan.max_groups)
        slots = _probe_slot_mapping(res, plan.keys, plan.max_groups)
        if slots is None:
            return _group_agg(res.table, plan.keys, plan.aggs,
                              plan.max_groups)
        from .keyslot import provide_slots
        with provide_slots(slots):
            return _group_agg(res.table, plan.keys, plan.aggs,
                              plan.max_groups)

    if isinstance(plan, AggCall):
        # Import here: core.executors depends on this module.
        from repro.core.executors import execute_agg_call
        return execute_agg_call(plan, catalog, env)

    raise TypeError(f"unknown plan node {type(plan)}")


def _agg_cols(aggs) -> tuple[str, ...]:
    """Column names a GroupAgg aggs tuple reads (arg-extremum ops read a
    (key, payload) pair; count reads none)."""
    cols: list[str] = []
    for _out, _op, col in aggs:
        if col is None:
            continue
        if isinstance(col, tuple):
            cols.extend(col)
        else:
            cols.append(col)
    return tuple(cols)


def execute_for_agg(child: Plan, catalog: Catalog, env: Env,
                    needed: tuple) -> Table:
    """Execute an aggregate's child plan, fusing a
    ``Filter*/Project* → Join`` chain into the aggregate input when it
    matches (relational/fuse.py): the join runs as a lookup only,
    predicates fold into the validity mask the kernel sees as its guard,
    and only the ``needed`` columns materialize.  Anything unmatched
    falls back to per-node execution — identical results either way
    (the fusion parity gates pin this)."""
    from . import fuse
    t = fuse.fused_child_table(child, catalog, env, tuple(needed), _exec)
    if t is None:
        t = _exec(child, catalog, env)
    return t


def _probe_slot_mapping(res, keys: tuple[str, ...],
                        max_groups) -> dict | None:
    """Turn a fused chain's join-probe outputs into a keyslot slot table
    for the downstream GroupAgg — the "probe results feed the kernel"
    leg of whole-plan fusion.

    When the aggregate groups by exactly the join's left key (inner
    join), the probe already assigned every valid row a consistent
    segment id: ``ridx`` — equal keys hit the same build slot, distinct
    keys cannot share one (slot ownership is verified on exact canonical
    key words).  Providing ``(seg, owner, occupied, overflowed=0)`` via
    keyslot.provide_slots lets _group_agg's sort-free branch skip the
    whole slot build/claim/verify loop — the aggregation kernel launches
    straight off the probe outputs, with the chain's guard mask as row
    validity.  Segment ids are right-table row numbers here (not
    claim-densified), so the bound must cover the right capacity;
    ``owner`` holds the smallest matching LEFT row per segment, which is
    what sortfree_result gathers the representative key values from.

    Returns None — plain slotting proceeds — for multi-key or non-inner
    chains, keys that do not resolve to the left join key, an undeclared
    bound, or a bound smaller than the right table."""
    chain = res.chain
    if chain.join.how != "inner" or len(keys) != 1:
        return None
    try:
        if chain.resolve(keys[0]) != chain.join.left_key:
            return None
    except KeyError:
        return None
    from .group_bound import resolve_group_bound
    t = res.table
    declared = max_groups if max_groups is not None else t.group_bound
    _, bound = resolve_group_bound(declared, t.capacity)
    if bound is None or res.right_capacity > bound:
        return None
    cap = t.capacity
    tv = t.mask()
    seg = jnp.where(tv, res.ridx, bound).astype(jnp.int32)
    rows = jnp.arange(cap, dtype=jnp.int32)
    owner = jnp.full((bound,), cap, jnp.int32).at[seg].min(
        jnp.where(tv, rows, cap), mode="drop")
    occupied = owner < cap
    return {(tuple(keys), bound): (seg, owner, occupied, jnp.int32(0))}


# ---------------------------------------------------------------------------
# Join
# ---------------------------------------------------------------------------


def join_hash_enabled() -> bool:
    """Kill switch for the sort-free keyslot hash join (default: on).
    ``REPRO_JOIN_HASH=off`` restores the legacy stable-argsort +
    searchsorted lookup."""
    return flags.enabled("REPRO_JOIN_HASH")


def _common_key_cast(lk: jax.Array, rk: jax.Array):
    """Harmonize the two key columns onto one exact comparison dtype.

    Deliberately *numpy's* promotion lattice: ``np.promote_types(int32,
    float32)`` is float64 (exact for every int32), where JAX's own
    lattice would answer float32 and silently round keys above 2^24 —
    the historical ``lk.astype(rk.dtype)`` exactness bug.  Limitation:
    64-bit promotions need x64 enabled to take effect (JAX downgrades
    the cast otherwise), and int64 keys beyond 2^53 promoted against a
    float side are inexact in any float dtype.
    """
    if lk.dtype == rk.dtype:
        return lk, rk
    d = jnp.dtype(np.promote_types(lk.dtype, rk.dtype))
    return lk.astype(d), rk.astype(d)


def _sorted_lookup(lk: jax.Array, rk: jax.Array, rvalid: jax.Array,
                   ) -> tuple[jax.Array, jax.Array]:
    """Legacy lookup: sort right by key (invalid rows to +inf),
    binary-search each left key, verify equality + right validity."""
    rk_sortkey = _key_for_search(rk, rvalid)
    # stable, explicitly: searchsorted lands on the LEFTMOST equal sorted
    # key, so with a stable order a (contract-violating) duplicate right
    # key deterministically picks the smallest original row index —
    # matching sort_by's stability contract instead of whatever an
    # unstable sort happened to place first
    order = jnp.argsort(rk_sortkey, stable=True)
    rk_sorted = jnp.take(rk_sortkey, order)
    pos = jnp.searchsorted(rk_sorted, lk)
    pos = jnp.clip(pos, 0, rk.shape[0] - 1)
    ridx = jnp.take(order, pos)
    found = (jnp.take(rk, ridx) == lk) & jnp.take(rvalid, ridx)
    return ridx, found


def _hash_lookup(lk: jax.Array, rk: jax.Array, rvalid: jax.Array,
                 ) -> tuple[jax.Array, jax.Array]:
    """Sort-free lookup on the keyslot hash table: build on the right
    keys' canonical words, probe one walk per left row.  No row-sized
    sort or gather — the probe loop's per-round gathers are a handful of
    static equations regardless of row count."""
    from . import keyslot
    ridx, found = keyslot.build_probe(
        keyslot.key_words_for([rk]), rvalid, keyslot.key_words_for([lk]))
    if jnp.issubdtype(lk.dtype, jnp.floating):
        # canonical words equate NaN per bit pattern (grouping
        # semantics); join equality is VALUE equality, where NaN never
        # matches — mask it back out, mirroring the sorted route's
        # ``rk == lk`` verification
        found = found & (lk == lk)
    return ridx, found


def _join_lookup(lt: Table, rt: Table, lkey: str, rkey: str,
                 ) -> tuple[jax.Array, jax.Array]:
    """Resolve each left row against the unique-keyed right side.

    Returns ``(ridx, found)``: ``ridx`` (capacity,) int32 right-row
    indices (clip-safe sentinel where unmatched), ``found`` (capacity,)
    bool — left rows with a valid right match.  This is the whole join
    *lookup*; materializing joined columns (``_apply_join``) is separate
    so the fusion pass can consume the lookup directly.
    """
    lk, rk = _common_key_cast(lt.columns[lkey], rt.columns[rkey])
    if join_hash_enabled():
        return _hash_lookup(lk, rk, rt.mask())
    return _sorted_lookup(lk, rk, rt.mask())


def _apply_join(lt: Table, rt: Table, rkey: str, how: str,
                ridx: jax.Array, found: jax.Array) -> Table:
    """Materialize the joined Table from a ``_join_lookup`` result."""
    if how == "semi":
        return lt.filter(found)
    if how == "anti":
        return lt.filter(~found)

    gidx = jnp.clip(ridx, 0, rt.capacity - 1)
    cols = dict(lt.columns)
    for name, v in rt.columns.items():
        if name == rkey or name in cols:
            continue
        cols[name] = jnp.take(v, gidx, axis=0, mode="clip")
    if how == "inner":
        valid = lt.mask() & found
    elif how == "left":
        valid = lt.mask()
        # null out unmatched right columns (zeros)
        for name in rt.columns:
            if name == rkey or name in lt.columns:
                continue
            cols[name] = jnp.where(
                _bmask(found, cols[name]), cols[name],
                jnp.zeros_like(cols[name]))
    else:
        raise ValueError(f"unsupported join how={how}")
    # the join introduces right-side columns the left table's declared
    # bound never covered — grouping the result by one of them could have
    # arbitrarily many groups, so the declaration must not survive
    # (semi/anti joins returned earlier: they keep the left columns only)
    return Table(cols, valid)


def _gather_join(lt: Table, rt: Table, lkey: str, rkey: str, how: str) -> Table:
    """Join against a unique-keyed right side: hash lookup on the keyslot
    table by default (``_hash_lookup``), the legacy argsort +
    searchsorted route under ``REPRO_JOIN_HASH=off``."""
    ridx, found = _join_lookup(lt, rt, lkey, rkey)
    return _apply_join(lt, rt, rkey, how, ridx, found)


def _bmask(m: jax.Array, v: jax.Array) -> jax.Array:
    return m.reshape(m.shape + (1,) * (v.ndim - 1))


def _key_for_search(k: jax.Array, valid: jax.Array) -> jax.Array:
    if jnp.issubdtype(k.dtype, jnp.floating):
        return jnp.where(valid, k, jnp.inf).astype(k.dtype)
    big = jnp.iinfo(k.dtype).max
    return jnp.where(valid, k, big)


# ---------------------------------------------------------------------------
# Grouped built-in aggregation
# ---------------------------------------------------------------------------


def segment_ids_for(t: Table, keys: tuple[str, ...],
                    num_segments: Optional[int] = None
                    ) -> tuple[Table, jax.Array, jax.Array]:
    """Sort by group keys and derive segment ids.  Returns (sorted table,
    segment_ids, segment_starts_mask).  ``num_segments`` is the static
    segment range the ids must stay within (default: row capacity);
    invalid rows park in its last slot — the dedicated overflow segment
    when a dense group bound is declared (group_bound.resolve_group_bound
    reserves it), the legacy capacity-1 slot otherwise."""
    st = t.sort_by(keys)
    m = st.mask()
    same = jnp.ones(st.capacity, dtype=bool)
    for k in keys:
        c = st.columns[k]
        same = same & jnp.concatenate([jnp.array([False]), c[1:] == c[:-1]])
    starts = m & ~same
    seg = jnp.cumsum(starts.astype(jnp.int32)) - 1
    overflow = (st.capacity if num_segments is None else num_segments) - 1
    seg = jnp.where(m, seg, overflow)  # park invalid rows in the last seg
    return st, seg, starts


_SEG_OPS = {
    "sum": jax.ops.segment_sum,
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
    "prod": jax.ops.segment_prod,
}

#: ops the fused Pallas segment-aggregate kernel serves from its moment
#: rows (mean = sum/count; argmin/argmax = extremum + index moment)
_FUSED_OPS = ("sum", "min", "max", "count", "mean", "argmin", "argmax")

#: arg-extremum GroupAgg ops: col is a (key_col, payload_col) pair and the
#: output is the payload value of the FIRST row attaining the key extremum
#: within the group (strict-comparison tie order, matching a cursor
#: loop's ``If(key < best)``)
_ARG_OPS = ("argmin", "argmax")


def _groupagg_fused_backend() -> Optional[str]:
    """Backend for the fused GroupAgg path: None for per-op jnp segment
    ops, "off" for an explicit kill switch (also disables sharded
    routing).  Default: the compiled kernel on TPU (one HBM pass for all
    moments), per-op jnp elsewhere.  REPRO_GROUPAGG_FUSED ∈ {pallas,
    interpret, jnp, off} overrides (tests use 'interpret'); a
    thread-local ``reliability.degrade.force_backend`` scope beats both
    — the serving circuit breaker traces degraded executables under
    it."""
    from ..configs import flags
    from ..reliability.degrade import forced_backend
    forced = forced_backend()
    if forced is not None:
        return forced
    env = flags.choice("REPRO_GROUPAGG_FUSED",
                       ("pallas", "interpret", "jnp", "off"))
    if env is not None:
        return env
    return "pallas" if jax.default_backend() == "tpu" else None


def _group_agg(t: Table, keys: tuple[str, ...],
               aggs: tuple[tuple[str, str, Optional[str]], ...],
               max_groups: Optional[int] = None) -> Table:
    from .group_bound import (check_group_overflow, poison_overflow,
                              resolve_group_bound)
    from .keyslot import (overflow_extended, provided_slots,
                          slot_segment_ids, sortfree_enabled,
                          sortfree_result)
    backend = _groupagg_fused_backend()
    # dense segment range: plan-declared max_groups beats the table hint;
    # without either, the row capacity is the only static bound available
    declared = max_groups if max_groups is not None else t.group_bound
    nsegments, bound = resolve_group_bound(declared, t.capacity)
    cap = t.capacity
    # a row-sharded input table (Table.shard_rows) routes the fused pass
    # through the mesh — one kernel launch per row shard, moments
    # all-reduced; detect on the caller-committed columns, pre-sort.  A
    # provide_slots scope carrying this call's slot table overrides the
    # launcher: the cached assignment is GLOBAL (stable across calls), so
    # the segment ops run on it directly and GSPMD partitions the work.
    shard_route = None
    if backend != "off":
        from repro.launch.sharded_agg import row_sharded_mesh
        shard_route = row_sharded_mesh(*t.columns.values(), t.valid)
        if (shard_route is not None and bound is not None
                and provided_slots(keys, bound) is not None):
            shard_route = None
        if backend is None and shard_route is not None:
            backend = "auto"    # distributed beats per-op even off-TPU

    def _fusable(op, col):
        # kernel accumulates in f32: float64 columns keep the exact per-op
        # path, and counts (f32-exact only below 2^24) require the row
        # capacity to bound every segment count inside that range
        if op not in _FUSED_OPS:
            return False
        if op in ("count", "mean") and cap >= 1 << 24:
            return False
        if op in _ARG_OPS:
            # key compare + attaining-row index both run in f32: the key
            # column must embed exactly (≤32-bit float / ≤16-bit int) and
            # every (padded) row index must be f32-exact — the same gate
            # the kernel validates
            from repro.core.executors import _f32_exact_key_dtype
            from repro.kernels.segment_agg import index_moment_ok
            return (index_moment_ok(cap)
                    and _f32_exact_key_dtype(t.columns[col[0]].dtype))
        if col is None:
            return True
        d = t.columns[col].dtype
        return jnp.issubdtype(d, jnp.floating) and jnp.dtype(d).itemsize <= 4

    fused_aggs = [] if backend in (None, "off") else [
        (out, op, col) for out, op, col in aggs if _fusable(op, col)]
    rest_aggs = tuple(a for a in aggs if a not in fused_aggs)

    # SORT-FREE route: every GroupAgg op is an order-insensitive moment
    # (commutative merge algebra), so whenever a dense bound is declared
    # the hash-slotted segment assignment (relational/keyslot.py) replaces
    # the group sort outright.  Sharded inputs additionally need every op
    # on the fused pass — slots are assigned per shard inside the
    # launcher, so the per-op segment fallbacks have no global ids.
    sortfree = (bound is not None and sortfree_enabled()
                and not (shard_route is not None
                         and (rest_aggs or not fused_aggs)))

    cols: dict[str, jax.Array] = {}
    if sortfree and shard_route is not None:
        out, (rep, out_valid, unplaced) = _group_agg_fused(
            t, None, t.mask(), nsegments, fused_aggs, backend,
            shard_route=shard_route, sortfree_keys=keys)
        return sortfree_result(t, keys, rep, out_valid, unplaced, bound,
                               out)

    if sortfree:
        st, m = t, t.mask()
        seg, owner, occupied, unplaced = slot_segment_ids(t, keys, bound)
        # occupied is a dense CLAIM-order prefix (not key order); key
        # representatives, validation, and poisoning all happen in the
        # shared sortfree_result epilogue after the aggregates compute
        rep, out_valid = overflow_extended(owner, occupied, cap)
        layout = "unsorted"
    else:
        st, seg, starts = segment_ids_for(t, keys, num_segments=nsegments)
        m = st.mask()
        nseg = jnp.sum(starts.astype(jnp.int32))
        overflow_ok = check_group_overflow(nseg, bound)
        out_valid = jnp.arange(nsegments) < nseg
        # representative key values: first row of each segment
        first_idx = jnp.where(starts, jnp.arange(cap), cap)
        first_of_seg = jax.ops.segment_min(first_idx, seg,
                                           num_segments=nsegments)
        for k in keys:
            cols[k] = jnp.take(st.columns[k],
                               jnp.clip(first_of_seg, 0, cap - 1))
        layout = "sorted"

    if fused_aggs:
        cols.update(_group_agg_fused(st, seg, m, nsegments, fused_aggs,
                                     backend, shard_route=shard_route,
                                     layout=layout))
    aggs = rest_aggs

    for out, op, col in aggs:
        if op == "count":
            vals = m.astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32)
            cols[out] = jax.ops.segment_sum(vals, seg,
                                            num_segments=nsegments)
            continue
        if op in _ARG_OPS:
            # per-op fallback (wide key dtypes / fused off): hit-detection
            # formulation in the key column's own dtype — exact
            kc, pc = col
            kv, pv = st.columns[kc], st.columns[pc]
            fill = _identity_for("min" if op == "argmin" else "max",
                                 kv.dtype)
            masked = jnp.where(m, kv, fill)
            segf = jax.ops.segment_min if op == "argmin" \
                else jax.ops.segment_max
            best = segf(masked, seg, num_segments=nsegments)
            hit = m & (masked == jnp.take(best, seg))
            cand = jnp.where(hit, jnp.arange(cap), cap)
            pick = jax.ops.segment_min(cand, seg, num_segments=nsegments)
            got = pick < cap
            cols[out] = jnp.where(
                got, jnp.take(pv, jnp.clip(pick, 0, cap - 1)),
                jnp.zeros((), pv.dtype))
            continue
        v = st.columns[col]
        if op == "mean":
            s = jax.ops.segment_sum(jnp.where(m, v, 0).astype(jnp.float32), seg,
                                    num_segments=nsegments)
            c = jax.ops.segment_sum(m.astype(jnp.float32), seg,
                                    num_segments=nsegments)
            cols[out] = s / jnp.maximum(c, 1.0)
            continue
        if op in ("min", "max"):
            fill = _identity_for(op, v.dtype)
            v = jnp.where(m, v, fill)
        else:
            v = jnp.where(_bmask(m, v), v, jnp.zeros_like(v) if op == "sum" else jnp.ones_like(v))
        cols[out] = _SEG_OPS[op](v, seg, num_segments=nsegments)

    if sortfree:
        return sortfree_result(t, keys, rep, out_valid, unplaced, bound,
                               cols)
    return Table(poison_overflow(cols, overflow_ok), out_valid)


def _group_agg_fused(st: Table, seg: jax.Array, m: jax.Array,
                     num_segments: int, fused_aggs, backend: str,
                     shard_route=None, layout: str = "sorted",
                     sortfree_keys=None):
    """Serve sum/count/min/max/mean/argmin/argmax GroupAgg ops from ONE
    fused segment-aggregate pass: each distinct value (or arg-extremum
    key) column is one kernel column; all requested moments come back
    together, so e.g. (sum, count, mean, min) over one column costs a
    single HBM traversal.  Arg-extremum ops additionally request the
    kernel's index moment — the first-attaining row index arrives as
    output rows 4/5, and the payload is one num_segments-sized take (no
    row-capacity-sized gather).  ``num_segments`` is the static segment
    range — the dense group bound (+ overflow slot) when declared, the
    row capacity otherwise — and sizes the (C, R, num_segments) moment
    tensor.  ``shard_route`` = (mesh, axis): the pass runs per row shard
    with a cross-device moment merge, arg-extremum rows merged as
    lexicographic (key, global_row) collectives and payloads gathered
    shard-locally (launch/sharded_agg.py).

    ``layout='unsorted'`` runs the same pass on hash-slotted (unsorted)
    segment ids — the sort-free route.  ``sortfree_keys`` (the group-key
    names, sharded sort-free only) makes the launcher slot each shard's
    rows itself and merge key-aligned; ``seg`` is then unused and the
    return value becomes ``(cols, (rep_rows, out_valid, unplaced))`` so
    the caller can recover representatives/validity without global
    segment ids."""
    from repro.core.executors import _index_row_to_pick
    from repro.kernels.segment_agg import (ARGMAX_ROW, ARGMIN_ROW,
                                           fused_segment_agg)

    cap = st.capacity
    value_cols = list(dict.fromkeys(
        (col[0] if op in _ARG_OPS else col)
        for _, op, col in fused_aggs if col is not None))
    if not value_cols:        # count-only: any column works, mask does the job
        vals = jnp.zeros((cap, 1), jnp.float32)
        col_idx = {}
    else:
        vals = jnp.stack([st.columns[c].astype(jnp.float32)
                          for c in value_cols], axis=1)
        col_idx = {c: i for i, c in enumerate(value_cols)}
    moments = [set() for _ in range(max(1, len(value_cols)))]
    for _, op, col in fused_aggs:
        if op in _ARG_OPS:
            moments[col_idx[col[0]]].update(
                ("min", "argmin_first") if op == "argmin"
                else ("max", "argmax_first"))
            continue
        i = col_idx.get(col, 0)   # count (col=None) rides on column 0
        moments[i].update({"mean": ("sum", "count"),
                           "count": ("count",)}.get(op, (op,)))
    kernel_moments = tuple(tuple(sorted(ms)) for ms in moments)

    # sharded route: arg payloads are gathered shard-locally inside the
    # all-reduce, so hand the payload columns to the launcher
    payload_specs = []
    payload_slot = {}
    if shard_route is not None:
        for name, op, col in fused_aggs:
            if op in _ARG_OPS:
                payload_slot[name] = len(payload_specs)
                payload_specs.append((col_idx[col[0]], op == "argmin",
                                      (st.columns[col[1]],)))

    # sorted layout: segment_ids_for sorted the rows, so the band-pruned
    # kernel may assume the sorted-segs precondition; unsorted layout
    # (sort-free) disables pruning and the check outright
    payload_picks = ()
    sortfree_extras = None
    if sortfree_keys is not None:
        from repro.launch.sharded_agg import sharded_sortfree_segment_agg
        from .keyslot import key_words_for
        kw = key_words_for(st.columns[k] for k in sortfree_keys)
        bucket = num_segments - 1
        fused, payload_picks, rep, occupied, unplaced = \
            sharded_sortfree_segment_agg(
                vals, kw, m[:, None], m, num_segments, bucket,
                mesh=shard_route[0], axis=shard_route[1], backend=backend,
                moments=kernel_moments, payloads=tuple(payload_specs))
        sortfree_extras = (rep, occupied, unplaced)
    elif shard_route is not None:
        from repro.launch.sharded_agg import sharded_fused_segment_agg
        res = sharded_fused_segment_agg(
            vals, seg.astype(jnp.int32), m[:, None], num_segments,
            mesh=shard_route[0], axis=shard_route[1], backend=backend,
            moments=kernel_moments, assume_sorted=True,
            payloads=tuple(payload_specs))
        fused, payload_picks = res if payload_specs else (res, ())
    else:
        fused = fused_segment_agg(vals, seg.astype(jnp.int32), m[:, None],
                                  num_segments, backend=backend,
                                  moments=kernel_moments,
                                  assume_sorted=True, layout=layout)

    out: dict[str, jax.Array] = {}
    count = fused[0, 1]
    for name, op, col in fused_aggs:
        if op == "count":
            out[name] = count.astype(
                jnp.int64 if jax.config.jax_enable_x64 else jnp.int32)
            continue
        if op in _ARG_OPS:
            minimize = op == "argmin"
            i = col_idx[col[0]]
            pv = st.columns[col[1]]
            pick = _index_row_to_pick(
                fused[i, ARGMIN_ROW if minimize else ARGMAX_ROW], cap,
                tie_first=True)
            got = (pick >= 0) & (pick < cap)
            if name in payload_slot:
                pv_pick = payload_picks[payload_slot[name]][0].astype(
                    pv.dtype)
            else:
                pv_pick = jnp.take(pv, jnp.clip(pick, 0, cap - 1))
            out[name] = jnp.where(got, pv_pick, jnp.zeros((), pv.dtype))
            continue
        i = col_idx[col]
        d = st.columns[col].dtype
        if op == "sum":
            out[name] = fused[i, 0].astype(d)
        elif op == "mean":
            out[name] = fused[i, 0] / jnp.maximum(fused[i, 1], 1.0)
        elif op == "min":
            out[name] = fused[i, 2].astype(d)
        else:  # max
            out[name] = fused[i, 3].astype(d)
    if sortfree_extras is not None:
        return out, sortfree_extras
    return out


def _identity_for(op: str, dtype) -> jax.Array:
    if op == "min":
        return jnp.array(jnp.inf, dtype) if jnp.issubdtype(dtype, jnp.floating) \
            else jnp.array(jnp.iinfo(dtype).max, dtype)
    if op == "max":
        return jnp.array(-jnp.inf, dtype) if jnp.issubdtype(dtype, jnp.floating) \
            else jnp.array(jnp.iinfo(dtype).min, dtype)
    raise ValueError(op)
