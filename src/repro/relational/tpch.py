"""Synthetic TPC-H-shaped data generation (deterministic, seeded).

Cardality ratios follow the TPC-H spec at a configurable micro scale factor
(sf=1 ⇒ PART=200k, SUPP=10k, PARTSUPP=800k, CUSTOMER=150k, ORDERS=1.5M,
LINEITEM≈6M; we default to sf=0.001-ish for CPU benchmarks).  Column
domains mirror the spec where the workloads need them (supplycost,
quantity, prices, dates as integer days, etc.).
"""
from __future__ import annotations

import numpy as np

from .table import Table


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def gen_tpch(scale: float = 0.001, seed: int = 0) -> dict[str, Table]:
    r = _rng(seed)
    n_part = max(8, int(200_000 * scale))
    n_supp = max(4, int(10_000 * scale))
    n_psupp = n_part * 4                       # 4 suppliers per part
    n_cust = max(8, int(150_000 * scale))
    n_ord = max(16, int(1_500_000 * scale))
    n_li = n_ord * 4

    part = Table.from_columns(
        p_partkey=np.arange(n_part, dtype=np.int32),
        p_retailprice=(900 + (np.arange(n_part) % 1000)).astype(np.float32),
        p_type_promo=(r.random(n_part) < 0.2),
    )

    supplier = Table.from_columns(
        s_suppkey=np.arange(n_supp, dtype=np.int32),
        s_name=np.arange(n_supp, dtype=np.int32),  # dictionary-encoded name
        s_nationkey=r.integers(0, 25, n_supp).astype(np.int32),
        s_acctbal=r.uniform(-999, 9999, n_supp).astype(np.float32),
    )

    partsupp = Table.from_columns(
        ps_partkey=np.repeat(np.arange(n_part, dtype=np.int32), 4),
        ps_suppkey=r.integers(0, n_supp, n_psupp).astype(np.int32),
        ps_supplycost=r.uniform(1.0, 1000.0, n_psupp).astype(np.float32),
        ps_availqty=r.integers(1, 10_000, n_psupp).astype(np.int32),
    )

    customer = Table.from_columns(
        c_custkey=np.arange(n_cust, dtype=np.int32),
        c_mktsegment=r.integers(0, 5, n_cust).astype(np.int32),
    )

    orders = Table.from_columns(
        o_orderkey=np.arange(n_ord, dtype=np.int32),
        o_custkey=r.integers(0, n_cust, n_ord).astype(np.int32),
        o_orderdate=r.integers(0, 2556, n_ord).astype(np.int32),  # days
        o_totalprice=r.uniform(800, 500_000, n_ord).astype(np.float32),
        o_comment_special=(r.random(n_ord) < 0.01),  # "special requests"
    )

    lineitem = Table.from_columns(
        l_orderkey=np.repeat(np.arange(n_ord, dtype=np.int32), 4),
        l_partkey=r.integers(0, n_part, n_li).astype(np.int32),
        l_suppkey=r.integers(0, n_supp, n_li).astype(np.int32),
        l_quantity=r.integers(1, 51, n_li).astype(np.float32),
        l_extendedprice=r.uniform(900, 100_000, n_li).astype(np.float32),
        l_discount=(r.integers(0, 11, n_li) / 100).astype(np.float32),
        l_shipdate=r.integers(0, 2556, n_li).astype(np.int32),
        l_receiptdate=r.integers(0, 2556, n_li).astype(np.int32),
        l_commitdate=r.integers(0, 2556, n_li).astype(np.int32),
        l_returnflag=r.integers(0, 3, n_li).astype(np.int32),
    )

    return {
        "PART": part, "SUPPLIER": supplier, "PARTSUPP": partsupp,
        "CUSTOMER": customer, "ORDERS": orders, "LINEITEM": lineitem,
    }


SCHEMAS = {
    "PART": ("p_partkey", "p_retailprice", "p_type_promo"),
    "SUPPLIER": ("s_suppkey", "s_name", "s_nationkey", "s_acctbal"),
    "PARTSUPP": ("ps_partkey", "ps_suppkey", "ps_supplycost", "ps_availqty"),
    "CUSTOMER": ("c_custkey", "c_mktsegment"),
    "ORDERS": ("o_orderkey", "o_custkey", "o_orderdate", "o_totalprice",
               "o_comment_special"),
    "LINEITEM": ("l_orderkey", "l_partkey", "l_suppkey", "l_quantity",
                 "l_extendedprice", "l_discount", "l_shipdate",
                 "l_receiptdate", "l_commitdate", "l_returnflag"),
}


def scan(table: str):
    from .plan import Scan
    return Scan(table, SCHEMAS[table])
