"""Whole-plan fusion: Filter/Project/Join chains collapse into one
aggregate input — no intermediate Table between the join probe and the
kernel launch.

The per-node executor (engine._exec) materializes every operator: a
``Join → Filter → GroupAgg`` chain builds a full joined Table (one
row-sized gather per right column, all of them), then filters it, then
aggregates.  But the aggregate consumes only (a) a validity mask and
(b) the handful of columns it actually reads — which is exactly what
the fused chain produces directly:

* the join lowers to its *lookup* only (``engine._join_lookup``: keyslot
  hash build/probe — no row-sized sort, no gather), yielding a
  right-row index + found mask;
* Filter predicates never filter a Table — they evaluate against a lazy
  column resolver and AND into the validity mask, which reaches the
  kernel as the per-column guard mask (the PR-1 guard machinery);
* pure-Col Projects fold into a name → source-column mapping (zero
  data movement);
* only the columns the aggregate names (``needed``) materialize: left
  columns pass through by reference, right columns cost one clipped
  take each — strictly fewer gathers than the materialized join, which
  gathered every right column whether read or not.

The pass is a *pattern match*, not a planner: ``match_chain`` walks
Filter*/pure-Col-Project* down to an inner/left equi-Join and bails to
the materialized path on anything else (semi/anti joins are already
materialization-free filters; computed projections can mint columns the
chain cannot guard; OrderBy/Limit pin physical row semantics).  Parity
is gated seam-by-seam in tests/test_join_fuse.py: fused vs unfused
plans bit-for-bit on jnp AND interpret backends, plus a subprocess
8-way-mesh sharded case (the probe runs on per-shard-local rows; the
gathered right columns are re-committed to the left table's row
sharding so the O(num_segments) merge route still engages).

Kill switch: ``REPRO_PLAN_FUSE=off`` restores per-node materialization
(the bench "materialized" arm pins it).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.configs import flags
from repro.core.loop_ir import (BinOp, Call, Col, Expr, UnOp, Where,
                                eval_expr)
from .plan import Filter, Join, Plan, Project
from .table import Table

__all__ = ["fuse_enabled", "match_chain", "execute_chain",
           "fused_child_table", "fused_chain_result", "FusedChain",
           "ChainResult"]


def fuse_enabled() -> bool:
    """Kill switch for the whole-plan fusion pass (default: on).
    ``REPRO_PLAN_FUSE=off`` restores per-node Table materialization."""
    return flags.enabled("REPRO_PLAN_FUSE")


@dataclass(frozen=True)
class ChainResult:
    """A fused chain's execution product: the thin aggregate-input Table
    plus the raw probe outputs, so a grouping consumer keyed on the join
    key can feed ``ridx`` directly as segment ids (engine GroupAgg's
    provide_slots bridge) instead of re-slotting the key column."""
    table: Table
    chain: "FusedChain"
    ridx: jax.Array
    found: jax.Array
    right_capacity: int


@dataclass(frozen=True)
class FusedChain:
    """A matched ``Filter*/Project* → Join`` chain, normalized to the
    join-output namespace: ``preds`` are the chain's Filter predicates
    rewritten through every intervening Project; ``src_of`` maps each
    chain-output column name to its join-output source column (None =
    identity, no Project in the chain)."""
    join: Join
    preds: tuple[Expr, ...]
    src_of: Optional[Mapping[str, str]]

    def resolve(self, name: str) -> str:
        if self.src_of is None:
            return name
        src = self.src_of.get(name)
        if src is None:
            raise KeyError(name)
        return src


def _rename_cols(e: Expr, mapping: Mapping[str, str]) -> Expr:
    """Rewrite every ``Col(out)`` to ``Col(mapping[out])`` — the Project
    fold.  (loop_ir.substitute replaces Var only, so the Col walk lives
    here.)  Raises KeyError when the expression names a column the
    Project does not produce — the caller bails to materialization,
    preserving the unfused path's error."""
    if isinstance(e, Col):
        return Col(mapping[e.name])
    if isinstance(e, BinOp):
        return BinOp(e.op, _rename_cols(e.lhs, mapping),
                     _rename_cols(e.rhs, mapping))
    if isinstance(e, UnOp):
        return UnOp(e.op, _rename_cols(e.operand, mapping))
    if isinstance(e, Where):
        return Where(_rename_cols(e.cond, mapping),
                     _rename_cols(e.t, mapping),
                     _rename_cols(e.f, mapping))
    if isinstance(e, Call):
        return Call(e.name, e.fn,
                    tuple(_rename_cols(a, mapping) for a in e.args))
    return e                                  # Const / Var


def match_chain(plan: Plan) -> Optional[FusedChain]:
    """Pattern-match a fusable ``Filter*/Project* → Join(inner|left)``
    chain; None means execute per-node.  Projects must be pure column
    selections (every expr a Col) — computed projections mint values the
    lazy resolver cannot guard and fall back."""
    preds: list[Expr] = []
    src_of: Optional[dict[str, str]] = None
    node = plan
    while True:
        if isinstance(node, Filter):
            # a Filter renames nothing: its pred is already in the same
            # namespace as everything collected so far
            preds.append(node.pred)
            node = node.child
            continue
        if isinstance(node, Project):
            if not all(isinstance(e, Col) for _, e in node.exprs):
                return None
            proj = {out: e.name for out, e in node.exprs}
            try:
                preds = [_rename_cols(p, proj) for p in preds]
                if src_of is None:
                    src_of = dict(proj)
                else:
                    src_of = {top: proj[cur]
                              for top, cur in src_of.items()}
            except KeyError:
                return None
            node = node.child
            continue
        if isinstance(node, Join) and node.how in ("inner", "left"):
            return FusedChain(node, tuple(preds), src_of)
        return None


class _ChainEnv(Mapping):
    """Mapping view the chain's predicates evaluate under: column names
    resolve lazily through the join lookup (left by reference, right by
    one memoized gather), everything else falls back to the scalar
    environment — the same shadowing order as engine._col_env (columns
    win)."""

    def __init__(self, resolver: Callable[[str], Any],
                 names: frozenset, env: Mapping[str, Any]):
        self._resolver = resolver
        self._names = names
        self._env = env

    def __getitem__(self, name):
        if name in self._names:
            return self._resolver(name)
        return self._env[name]

    def __iter__(self):
        return iter(self._names | set(self._env))

    def __len__(self):
        return len(self._names | set(self._env))


def _recommit_rows(arrays: list, template: Table) -> list:
    """Gathered right-side columns lose the left table's committed row
    sharding (the gather output lands wherever XLA puts it) — put them
    back on the left rows' NamedSharding so ``row_sharded_mesh`` still
    detects the distributed aggregate route downstream."""
    from repro.launch.sharded_agg import row_sharded_mesh
    route = row_sharded_mesh(*template.columns.values(), template.valid)
    if route is None:
        return arrays
    mesh, axis = route
    from jax.sharding import NamedSharding, PartitionSpec
    s = NamedSharding(mesh, PartitionSpec(axis))
    return [jax.device_put(a, s) for a in arrays]


def execute_chain(chain: FusedChain, catalog, env: Mapping[str, Any],
                  needed: tuple, _exec) -> Optional[ChainResult]:
    """Run a matched chain: join *lookup* (no materialized join),
    predicates folded into the validity mask (the kernel guard), and
    only the ``needed`` columns realized.  Returns None — fall back to
    per-node execution — when a needed/predicate column is not served
    by the join output (the unfused path then raises its own error)."""
    from .engine import _bmask, _join_lookup

    join = chain.join
    lt = _exec(join.left, catalog, env)
    rt = _exec(join.right, catalog, env)
    ridx, found = _join_lookup(lt, rt, join.left_key, join.right_key)
    is_left = join.how == "left"
    gidx = jnp.clip(ridx, 0, rt.capacity - 1)

    gathered: dict[str, jax.Array] = {}

    def col(name: str) -> jax.Array:
        # join-output namespace: left wins collisions; the right key
        # column never survives the join (engine._apply_join contract)
        if name in lt.columns:
            return lt.columns[name]
        if name in gathered:
            return gathered[name]
        if name == join.right_key or name not in rt.columns:
            raise KeyError(name)
        v = jnp.take(rt.columns[name], gidx, axis=0, mode="clip")
        if is_left:
            v = jnp.where(_bmask(found, v), v, jnp.zeros_like(v))
        v, = _recommit_rows([v], lt)
        gathered[name] = v
        return v

    names = frozenset(lt.columns) | (frozenset(rt.columns)
                                     - {join.right_key})
    cenv = _ChainEnv(col, names, env)

    valid = lt.mask() if is_left else lt.mask() & found
    try:
        for p in chain.preds:
            valid = valid & jnp.asarray(eval_expr(p, cenv), bool)
        cols: dict[str, jax.Array] = {}
        from_left = True
        for name in dict.fromkeys(needed):
            src = chain.resolve(name)
            cols[name] = col(src)
            from_left = from_left and src in lt.columns
    except KeyError:
        return None

    # the fused chain's rows are a subset of the LEFT table's rows, so
    # when every realized column is a left column the left bound still
    # covers every group the result can produce (exactly the
    # Filter/semi-join preservation rule); any gathered right column
    # voids it, as in the materialized join
    bound = lt.group_bound if from_left else None
    return ChainResult(Table(cols, valid, bound), chain, ridx, found,
                       rt.capacity)


def fused_chain_result(child: Plan, catalog, env: Mapping[str, Any],
                       needed: tuple, _exec) -> Optional[ChainResult]:
    """Match + execute, keeping the probe outputs so the caller can feed
    them as segment ids (engine._probe_slot_mapping); None when the
    chain does not fuse (caller materializes per-node)."""
    if not fuse_enabled():
        return None
    chain = match_chain(child)
    if chain is None:
        return None
    return execute_chain(chain, catalog, env, needed, _exec)


def fused_child_table(child: Plan, catalog, env: Mapping[str, Any],
                      needed: tuple, _exec) -> Optional[Table]:
    """The one-call entry the aggregate executors use: match + execute,
    None when the chain does not fuse (caller materializes per-node)."""
    res = fused_chain_result(child, catalog, env, needed, _exec)
    return None if res is None else res.table
