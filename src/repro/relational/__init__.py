"""repro.relational — columnar tables, logical plans, and the JAX query
engine (the substrate the paper's cursor loops iterate over)."""
from .engine import execute
from .plan import (AggCall, Filter, GroupAgg, IterSpace, Join, Limit,
                   OrderBy, Plan, Project, Scan, push_filter, strip_order)
from .table import Table, concat

__all__ = ["execute", "AggCall", "Filter", "GroupAgg", "IterSpace", "Join",
           "Limit", "OrderBy", "Plan", "Project", "Scan", "push_filter",
           "strip_order", "Table", "concat"]
