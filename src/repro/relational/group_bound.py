"""Dense segment-id bound for grouped aggregation.

The grouped executors historically sized every segment tensor by the *row
capacity* of the input table — the only group-count bound XLA's static
shapes could get for free.  On the default bench shape (50k rows, ~2k
groups) that makes the fused kernel's (C, 4, S) moment tensor, the
band-pruned grid's ``seg_tiles`` term, and the sharded all-reduce payload
~25× larger than the actual group count.  Both PL/SQL-compilation lines of
work (Duta et al.; Ramachandra et al.) stress that the rewritten form must
hand the optimizer *tight* static shapes — this module is that bound for
the XLA/Pallas backend.

A caller declares ``max_groups`` on a ``GroupAgg`` / ``AggCall`` plan node
(or on the input table via ``Table.declare_group_bound``).  The declared
value is **bucketed** — rounded up to the next power-of-two multiple of
the 128-lane tile width — so nearby bounds share one compiled program and
recompilation stays bounded (at most log2(capacity/128) distinct shapes).
The segment range becomes ``bucket + 1``: real groups occupy
``[0, bucket)`` and the extra slot is a dedicated **overflow segment**
where invalid rows park (they previously parked in ``capacity - 1``, which
a dense range no longer contains).

The bound is *validated, not assumed* — mirroring the sorted-``segs``
precondition of the band-pruned kernel: a concrete group count above the
bucket raises eagerly; under tracing (where the count is a tracer) the
outputs are poisoned — NaN for floating columns, the dtype minimum for
integer columns — instead of silently aliasing overflowing groups into the
overflow slot.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

#: TPU vector lane width — kept equal to ``kernels.segment_agg.LANE``
#: (asserted by tests) without importing the Pallas toolchain here.
LANE = 128


class GroupBoundOverflow(ValueError):
    """A concrete group count (or slot-overflow count) exceeded the
    declared dense bound.  Subclasses ValueError so existing eager-raise
    contracts hold; the serving layer re-raises it as the structured
    ``serve.guard.BoundOverflow`` on the request's future."""


def poison_sentinel(dtype):
    """The poison value ``poison_overflow`` writes for ``dtype`` — NaN
    for floats, the dtype minimum for signed ints, the maximum for
    unsigned ints (whose minimum is 0, indistinguishable from a real
    aggregate), False for bools; None for dtypes poisoning cannot mark.
    ONE definition shared by the poisoner, the serving layer's detector
    (serve/guard.py), and the round-trip contract tests — the detector
    is only as good as the sentinels, so they cannot be allowed to
    drift."""
    d = jnp.dtype(dtype)
    if jnp.issubdtype(d, jnp.floating):
        return jnp.array(jnp.nan, d)
    if d == jnp.bool_:
        return jnp.array(False)
    if jnp.issubdtype(d, jnp.unsignedinteger):
        return jnp.array(jnp.iinfo(d).max, d)
    if jnp.issubdtype(d, jnp.integer):
        return jnp.array(jnp.iinfo(d).min, d)
    return None


def bucket_group_bound(max_groups: int) -> int:
    """Round a declared group bound up to its recompilation bucket: the
    next power of two, floored at one 128-lane tile.  Every bucket is a
    multiple of ``LANE`` (so the kernel's segment tiles stay lane-aligned)
    and a power of two (so distinct compiled shapes grow logarithmically
    in the declared bound)."""
    mg = int(max_groups)
    if mg <= 0:
        raise ValueError(f"max_groups must be positive, got {max_groups}")
    if mg <= LANE:
        return LANE
    return 1 << (mg - 1).bit_length()


def resolve_group_bound(max_groups: Optional[int],
                        capacity: int) -> tuple[int, Optional[int]]:
    """Resolve a declared bound into ``(num_segments, validated_bound)``.

    ``num_segments`` is the static segment range every grouped tensor is
    sized by: ``bucket(max_groups) + 1`` (the +1 is the overflow slot for
    invalid rows) when a useful bound is declared, the row ``capacity``
    otherwise.  ``validated_bound`` is the bucket the group count must stay
    within (``None`` means nothing to validate — the capacity already
    bounds the count).  A declared bound whose bucket reaches the capacity
    is a no-op: the dense range would not be smaller than the legacy one.
    """
    if max_groups is None:
        return capacity, None
    bucket = bucket_group_bound(max_groups)
    if bucket + 1 >= capacity:
        return capacity, None
    return bucket + 1, bucket


def check_group_overflow(nseg, bound: Optional[int]):
    """Validate the measured group count against the dense bound.

    Returns the traced ``ok`` guard (``nseg <= bound``) when validation
    must happen at runtime, or ``None`` when there is nothing left to
    check.  Concrete counts above the bound raise eagerly."""
    if bound is None:
        return None
    if isinstance(nseg, jax.core.Tracer):
        return nseg <= bound
    if int(nseg) > bound:
        raise GroupBoundOverflow(
            f"grouped aggregation: input has {int(nseg)} groups but the "
            f"declared dense bound admits at most {bound} (max_groups "
            f"bucketed to the next power-of-two lane multiple) — raise "
            f"max_groups or drop the declaration")
    return None


#: auxiliary stamp column ``poison_overflow`` adds when NO output column
#: carries a strong sentinel (every column bool or unmarkable): False is
#: an everyday bool value, so an all-bool result would otherwise be
#: undetectably poisoned.  The stamp is 0.0 on a clean result and NaN on
#: a poisoned one — a strong float column the serving detector
#: (``serve.guard.is_poisoned``) reads like any other; the serving layer
#: strips it before handing the result out.
STAMP_COL = "__poison_stamp__"


def _any_strong(cols: dict) -> bool:
    """True when some column can carry a strong (non-bool) sentinel."""
    for v in cols.values():
        d = jnp.dtype(v.dtype)
        if d != jnp.bool_ and poison_sentinel(d) is not None:
            return True
    return False


def poison_overflow(cols: dict, ok) -> dict:
    """Poison every output column where the traced overflow guard failed:
    NaN for floating columns; for integers — which cannot hold NaN — the
    dtype minimum if signed, the dtype maximum if unsigned (whose minimum
    is 0, indistinguishable from a real aggregate); False for booleans.
    ``ok=None`` (no runtime guard) is the identity.

    When no column can carry a strong sentinel (every output bool), an
    auxiliary f32 ``STAMP_COL`` is added — 0.0 clean, NaN poisoned — so
    the detector's all-or-none scan still has one strong column to read
    (the bool-only blind spot fix; the serving layer strips the stamp
    after its scan)."""
    if ok is None:
        return cols
    out = {}
    for k, v in cols.items():
        bad = poison_sentinel(v.dtype)
        out[k] = v if bad is None else jnp.where(ok, v, bad)
    if cols and not _any_strong(cols):
        shape = next(iter(cols.values())).shape
        out[STAMP_COL] = jnp.where(ok, jnp.zeros(shape, jnp.float32),
                                   jnp.full(shape, jnp.nan, jnp.float32))
    return out
