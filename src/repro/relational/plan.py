"""Logical query plans.

A plan is an introspectable tree (needed by Aggify: the rewrite composes the
cursor query as a subquery under an aggregation node — Eq. 5/6 — and acyclic
code motion pushes predicates into it).  Plans are deliberately small: Scan,
Filter, Project, Join (PK-FK gather + semi/anti), OrderBy, GroupAgg, Limit,
and AggCall (the 𝒢_{AggΔ} operator produced by the rewrite).

Expressions in plans use the shared AST of ``repro.core.loop_ir``: ``Col``
references name columns of the child; ``Var`` references enclosing program
variables (correlation parameters), bound at execution time from the scalar
environment — mirroring how the paper's cursor query references UDF
parameters (e.g. ``@pkey``).

Plans execute per-node (engine._exec) EXCEPT one pattern the engine
rewrites before execution: a ``Filter*/Project* → Join(inner|left)``
chain feeding a grouped aggregate fuses into a single aggregate input
(relational/fuse.py) — the Join runs as a hash lookup only, Filter
predicates become the kernel's guard mask, pure-Col Projects fold into
column selection, and (when the aggregate groups by the join key) the
probe output itself serves as the segment-id tensor.  Nodes stay
logical either way; the fusion is an execution-time pattern match, not
a plan transform, so plan trees remain introspectable by Aggify.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Sequence

from repro.core.loop_ir import BinOp, Col, Expr, wrap


@dataclass(frozen=True)
class Plan:
    def filter(self, pred: Expr) -> "Filter":
        return Filter(self, pred)

    def project(self, **exprs: Any) -> "Project":
        return Project(self, tuple((k, wrap(v)) for k, v in exprs.items()))

    def select(self, *names: str) -> "Project":
        return Project(self, tuple((n, Col(n)) for n in names))

    def order_by_(self, keys: Sequence[str], descending: Sequence[bool] = ()) -> "OrderBy":
        return OrderBy(self, tuple(keys), tuple(descending) or (False,) * len(keys))

    def limit(self, n: int) -> "Limit":
        return Limit(self, n)

    # -- protocol used by Aggify ------------------------------------------
    @property
    def order_by(self) -> tuple[str, ...]:
        """Sort keys the result is guaranteed to carry (empty = unordered)."""
        return ()

    @property
    def columns(self) -> tuple[str, ...]:
        raise NotImplementedError


@dataclass(frozen=True)
class Scan(Plan):
    table: str
    schema: tuple[str, ...] = ()

    @property
    def columns(self) -> tuple[str, ...]:
        return self.schema


@dataclass(frozen=True)
class IterSpace(Plan):
    """Iteration-space relation for FOR-loop rewriting (paper §8.2's
    recursive-CTE analogue).  init/bound/step are expressions over program
    variables, evaluated from the scalar environment at execution time."""
    init: Expr
    bound: Expr
    step: Expr
    inclusive: bool
    capacity: int
    column: str

    @property
    def columns(self) -> tuple[str, ...]:
        return (self.column,)


@dataclass(frozen=True)
class Filter(Plan):
    child: Plan
    pred: Expr

    @property
    def columns(self) -> tuple[str, ...]:
        return self.child.columns

    @property
    def order_by(self) -> tuple[str, ...]:
        return self.child.order_by


@dataclass(frozen=True)
class Project(Plan):
    child: Plan
    exprs: tuple[tuple[str, Expr], ...]

    @property
    def columns(self) -> tuple[str, ...]:
        return tuple(n for n, _ in self.exprs)

    @property
    def order_by(self) -> tuple[str, ...]:
        return self.child.order_by


@dataclass(frozen=True)
class Join(Plan):
    """Lookup join: ``right`` must be unique on ``right_key`` (PK).  Each left
    row picks up the matching right row (inner: unmatched dropped; left:
    unmatched keep nulls=0).  ``how`` in {'inner','left','semi','anti'}.
    Lowered as a keyslot hash build/probe (engine._hash_lookup; the
    legacy stable-argsort + searchsorted lookup survives behind
    ``REPRO_JOIN_HASH=off``)."""
    left: Plan
    right: Plan
    left_key: str
    right_key: str
    how: str = "inner"

    @property
    def columns(self) -> tuple[str, ...]:
        if self.how in ("semi", "anti"):
            return self.left.columns
        return tuple(dict.fromkeys(self.left.columns + self.right.columns))

    @property
    def order_by(self) -> tuple[str, ...]:
        return self.left.order_by


@dataclass(frozen=True)
class OrderBy(Plan):
    child: Plan
    keys: tuple[str, ...]
    descending: tuple[bool, ...] = ()

    @property
    def columns(self) -> tuple[str, ...]:
        return self.child.columns

    @property
    def order_by(self) -> tuple[str, ...]:
        return self.keys


@dataclass(frozen=True)
class Limit(Plan):
    child: Plan
    n: int

    @property
    def columns(self) -> tuple[str, ...]:
        return self.child.columns

    @property
    def order_by(self) -> tuple[str, ...]:
        return self.child.order_by


@dataclass(frozen=True)
class GroupAgg(Plan):
    """Built-in grouped aggregation: aggs = ((out, op, col), ...) with op in
    {sum,min,max,count,mean,prod,argmin,argmax}.  For the arg-extremum
    ops ``col`` is a ``(key_col, payload_col)`` pair: the output is the
    payload value of the FIRST row attaining the group's key extremum
    (strict-comparison tie order — the cursor loop's ``If(key < best)``).
    ``max_groups`` declares a dense bound on the group count (see
    relational/group_bound.py): segment tensors are sized by its
    power-of-two bucket plus an overflow slot instead of the input row
    capacity, and the bound is validated (concrete overflow raises;
    traced overflow NaN-poisons the outputs)."""
    child: Plan
    keys: tuple[str, ...]
    aggs: tuple[tuple[str, str, Optional[str]], ...]
    max_groups: Optional[int] = None

    @property
    def columns(self) -> tuple[str, ...]:
        return self.keys + tuple(a[0] for a in self.aggs)


@dataclass(frozen=True)
class AggCall(Plan):
    """𝒢_{AggΔ(P_accum)}(child) — the operator introduced by the Aggify
    rewrite (Eq. 5).  ``param_binding`` maps each Accumulate parameter to a
    Col of the child (fetch-derived) or a Var/Const of the enclosing program
    (outer-derived).  ``ordered`` + ``sort_keys`` encode Eq. 6.  ``group_keys``
    optionally turns it into a grouped invocation (decorrelation)."""
    child: Plan
    aggregate: Any                      # core.aggify.CustomAggregate
    param_binding: tuple[tuple[str, Expr], ...]
    ordered: bool = False
    sort_keys: tuple[str, ...] = ()
    sort_desc: tuple[bool, ...] = ()
    group_keys: tuple[str, ...] = ()
    mode: str = "auto"                  # auto|stream|chunked|recognized|fused
    #: dense group-count bound for the grouped invocation (bucketed +
    #: validated; see relational/group_bound.py); None = row capacity
    max_groups: Optional[int] = None

    @property
    def columns(self) -> tuple[str, ...]:
        return self.group_keys + tuple(self.aggregate.terminate_vars)


def is_unordered(plan: Plan) -> bool:
    return not plan.order_by


def strip_order(plan: Plan) -> tuple[Plan, tuple[str, ...], tuple[bool, ...]]:
    """Split Q_s into (Q, s) per Eq. 6 — peel the topmost OrderBy."""
    if isinstance(plan, OrderBy):
        return plan.child, plan.keys, plan.descending or (False,) * len(plan.keys)
    return plan, (), ()


def push_filter(plan: Plan, pred: Expr) -> Plan:
    """Conjoin ``pred`` into the plan (used by acyclic code motion, §8.1).
    The predicate references child columns, so it composes on top of Q —
    the engine's filter is pipelined, matching the paper's 'merge into the
    cursor query WHERE clause'."""
    if isinstance(plan, OrderBy):
        return OrderBy(push_filter(plan.child, pred), plan.keys, plan.descending)
    return Filter(plan, pred)
