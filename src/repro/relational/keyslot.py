"""Sort-free dense group slotting: hash-slotted segment ids.

The grouped executors historically derived segment ids by *sorting* the
input on the group keys (``Table.sort_by`` + adjacent-difference,
``engine.segment_ids_for``) — an O(N log N) materializing step the
order-insensitive moment aggregates (sum/count/min/max and the
arg-extremum index moment, all commutative merge algebras) never need.
For those, grouping only requires a key → dense-segment *assignment*, not
a total order.  This module is that assignment: a static-capacity,
power-of-two, quadratic-probe hash table built entirely from XLA
primitives (scatter-min claims + gathers inside one ``lax.while_loop``).
The probe table is over-provisioned (``EXPAND ×`` the dense group bound
of relational/group_bound.py, so the load factor is bounded at 1/EXPAND
and probing terminates in a couple of O(N) rounds even at a full
bucket); occupied probe slots then renumber densely into ``[0, bucket)``
by one prefix sum, so everything segment-sized stays bucket-sized.

Contract, mirroring the sorted route:

* every valid row with the same group-key tuple gets the same slot in
  ``[0, bucket)``; distinct tuples get distinct slots (hash collisions
  are *resolved* by probing on full key equality, never assumed away);
* invalid rows park in the dedicated overflow slot (``bucket`` — the
  ``num_segments - 1`` slot ``resolve_group_bound`` reserves);
* the bound is *validated, not assumed* (the ``check_group_overflow``
  pattern): when the input carries more distinct keys than the bucket has
  slots, probing exhausts the table and the unplaced rows are counted —
  a concrete count raises eagerly, a traced one hands back a guard the
  caller uses to poison its outputs.

Unlike the sorted route, slot numbers are *probe-table order* (the
order the keys' winning probe slots happen to sit in the table), not
key order: the ``occupied`` mask is still a dense ``[0, #groups)``
prefix — the densifying prefix sum guarantees it — but which group owns
which slot is hash-determined, and the representative row of each group
comes from the ``owner`` table rather than from segment starts.  Key
equality is *bitwise on canonical words*:
floats compare after a −0.0 → +0.0 normalization (so ±0 share a group,
as value equality would), and NaN keys — which value equality would
splinter into one group per row — share a group per bit pattern, the
SQL-flavored choice.

Probing cost: all rows of one key share one hash, so they probe in
lockstep — the loop runs for the *maximum probe length over keys*, each
round a handful of O(N) elementwise ops plus one table-sized
scatter-min.  Quadratic probing (triangular increments, which visit
every slot of a power-of-two table) plus the 1/EXPAND load bound keeps
that maximum at a couple of rounds on real key sets; the bench shape
(50k rows, a full 512-slot bucket) slots in well under the variadic
sort it replaces.
"""
from __future__ import annotations

import math
import threading
from contextlib import contextmanager
from functools import partial
from typing import Iterable, Mapping, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs import flags
from ..reliability import faults
from .group_bound import GroupBoundOverflow

__all__ = [
    "canonical_key_words", "key_words_for", "slot_ids_from_words",
    "build_probe",
    "slot_segment_ids", "check_slot_overflow", "overflow_extended",
    "sortfree_enabled", "sortfree_result", "provide_slots",
    "provided_slots", "slot_build_count", "distinct_count_sketch",
    "adaptive_expand", "adaptive_enabled", "probe_rounds",
    "SlotState", "fresh_slot_state", "slot_ids_extend",
    "slot_state_build", "slot_extend_count",
]


def sortfree_enabled() -> bool:
    """Kill switch for the sort-free grouped route (default: on).  The
    route additionally requires a declared dense group bound and an
    order-insensitive call — this only gates the dispatch.
    ``REPRO_GROUPAGG_SORTFREE=off`` forces every grouped call back onto
    the sorted route."""
    return flags.enabled("REPRO_GROUPAGG_SORTFREE")


# ---------------------------------------------------------------------------
# Canonical key words: every key column becomes 1–2 uint32 words whose
# bitwise equality coincides with group equality
# ---------------------------------------------------------------------------


def canonical_key_words(col: jax.Array) -> tuple[jax.Array, ...]:
    """Lower one key column to uint32 words with group-equality semantics:
    equal keys ⇒ equal words, distinct keys ⇒ distinct words (exactly —
    no narrowing cast is ever taken, so wide-int/f64 keys slot exactly
    where the f32 kernel arg path cannot).  Floats normalize −0.0 to
    +0.0 first; 64-bit dtypes split into (hi, lo) words."""
    col = jnp.asarray(col)
    d = jnp.dtype(col.dtype)
    if d == jnp.bool_:
        return (col.astype(jnp.uint32),)
    if jnp.issubdtype(d, jnp.unsignedinteger):
        if d.itemsize <= 4:
            return (col.astype(jnp.uint32),)
        return ((col >> 32).astype(jnp.uint32), col.astype(jnp.uint32))
    if jnp.issubdtype(d, jnp.integer):
        if d.itemsize <= 4:
            return (lax.bitcast_convert_type(col.astype(jnp.int32),
                                             jnp.uint32),)
        u = lax.bitcast_convert_type(col, jnp.uint64)
        return ((u >> jnp.uint64(32)).astype(jnp.uint32),
                u.astype(jnp.uint32))
    if jnp.issubdtype(d, jnp.floating):
        if d.itemsize <= 4:
            f = col.astype(jnp.float32)          # f16/bf16 embed exactly
            f = jnp.where(f == 0, jnp.float32(0.0), f)
            return (lax.bitcast_convert_type(f, jnp.uint32),)
        f = jnp.where(col == 0, jnp.zeros((), d), col)
        u = lax.bitcast_convert_type(f, jnp.uint64)
        return ((u >> jnp.uint64(32)).astype(jnp.uint32),
                u.astype(jnp.uint32))
    raise TypeError(f"unhashable group-key dtype {d} (expected bool, "
                    "integer, or floating)")


def key_words_for(columns: Iterable[jax.Array]) -> jax.Array:
    """Stack the canonical words of every key column into one (N, K)
    uint32 matrix — the unit the slotting, the hash, and the sharded
    key-table exchange all operate on."""
    words: list[jax.Array] = []
    for c in columns:
        words.extend(canonical_key_words(c))
    return jnp.stack(words, axis=1)


# ---------------------------------------------------------------------------
# Hash + probe loop
# ---------------------------------------------------------------------------


def _rotl(x: jax.Array, r: int) -> jax.Array:
    return (x << r) | (x >> (32 - r))


def _hash_words(words: jax.Array) -> jax.Array:
    """murmur3-style mix of the (N, K) word matrix into one uint32 hash
    per row (uint32 arithmetic wraps in XLA, which is the point)."""
    h = jnp.full(words.shape[:1], 0x9E3779B9, jnp.uint32)
    for k in range(words.shape[1]):
        w = words[:, k] * jnp.uint32(0xCC9E2D51)
        w = _rotl(w, 15) * jnp.uint32(0x1B873593)
        h = _rotl(h ^ w, 13) * jnp.uint32(5) + jnp.uint32(0xE6546B64)
    h ^= jnp.uint32(words.shape[1])
    h = (h ^ (h >> 16)) * jnp.uint32(0x85EBCA6B)
    h = (h ^ (h >> 13)) * jnp.uint32(0xC2B2AE35)
    return h ^ (h >> 16)


#: probe-table expansion: the hash table has ``EXPAND × bucket`` slots,
#: bounding the load factor at 1/EXPAND by construction — probing stays a
#: couple of rounds even when the key set fills the declared bucket
#: exactly (a full table would otherwise probe O(√bucket) rounds, each an
#: O(N) scatter).  The table is scratch: occupied probe slots densify to
#: ``[0, bucket)`` by prefix-sum before anything segment-sized is built,
#: so the moment tensors never see the expansion.  This is the *ceiling*:
#: eager builds shrink it adaptively from the distinct-count sketch
#: (``adaptive_expand``) — the estimated key count, not the worst case,
#: sizes the scatter table each probe round touches.
EXPAND = 16

#: adaptive sizing targets this load factor: estimated distinct keys /
#: probe-table slots ≤ 1/8, so probing still terminates in a couple of
#: rounds even when the sketch undershoots by 2×
_TARGET_LOAD_INV = 8

#: floor on the adaptive expansion: the sketch is noisy and the probe
#: table must stay comfortably larger than the true key set (correctness
#: never depends on it — probing is exhaustive over the table and the
#: dense renumbering validates the bucket — but load > 1/2 costs rounds)
_MIN_EXPAND = 4


def adaptive_enabled() -> bool:
    """Kill switch for sketch-driven probe-table sizing (default: on).
    ``REPRO_KEYSLOT_ADAPTIVE=off`` pins the fixed ``EXPAND`` ceiling."""
    return flags.enabled("REPRO_KEYSLOT_ADAPTIVE")


def adaptive_expand(est_distinct: int, bucket: int) -> int:
    """Probe-table expansion factor from a distinct-count estimate: the
    smallest power of two keeping the estimated load factor at or below
    ``1/_TARGET_LOAD_INV``, clamped to ``[_MIN_EXPAND, EXPAND]``.  With
    the fixed ceiling a 128-slot key set probing a 4096-bucket table paid
    a 65536-slot scatter per round; the sketch sizes that table by the
    keys actually present instead (ROADMAP carried item)."""
    need = _TARGET_LOAD_INV * max(1, int(est_distinct))
    e = 1
    while e * bucket < need and e < EXPAND:
        e <<= 1
    return max(_MIN_EXPAND, min(EXPAND, e))


def slot_ids_from_words(words: jax.Array, valid: jax.Array,
                        bucket: int, expand: int = EXPAND,
                        ) -> tuple[jax.Array, jax.Array,
                                   jax.Array, jax.Array]:
    """Assign each valid row a dense slot in ``[0, bucket)`` keyed by its
    canonical word tuple.  Returns ``(seg, owner, occupied, overflowed)``:

    * ``seg``        (N,)      int32 — the slot; invalid rows AND rows
                     whose key exceeded the bucket (more distinct keys
                     than slots) hold ``bucket``, the overflow slot;
    * ``owner``      (bucket,) int32 — the representative row index that
                     claimed each slot (``N`` where the slot is empty);
    * ``occupied``   (bucket,) bool  — which slots hold a real group (a
                     dense prefix: slot numbers are claim-order);
    * ``overflowed`` ()        int32 — valid rows parked in the overflow
                     slot; nonzero means the key set overflowed the
                     bucket (``check_slot_overflow`` validates it).

    Probe round ``p`` of a row with hash ``h`` tries probe-table slot
    ``(h + p(p+1)/2) mod M`` (``M = EXPAND × bucket``; triangular
    increments visit every slot of a power-of-two table, so ``M`` rounds
    are exhaustive): empty slots are claimed by the smallest contending
    row index (scatter-min), then every prober compares its key words
    against the slot owner's — equal places, different probes on.  A
    claim winner always places on its own claim, so every non-empty slot
    is owned by a row of the key that lives there; hash collisions cost
    extra rounds, never wrong slots.  The sparse probe slots then
    renumber densely by a prefix sum over the occupancy mask — keys
    beyond the first ``bucket`` (overflow) park with the invalid rows.
    """
    if bucket & (bucket - 1) or bucket <= 0:
        raise ValueError(f"bucket must be a positive power of two, got "
                         f"{bucket}")
    if expand & (expand - 1) or expand <= 0:
        raise ValueError(f"expand must be a positive power of two, got "
                         f"{expand}")
    words = jnp.asarray(words)
    n = words.shape[0]
    m = bucket * expand
    h = _hash_words(words)
    idx = jnp.arange(n, dtype=jnp.int32)
    mask = jnp.uint32(m - 1)
    valid = jnp.asarray(valid, bool)

    def cond(st):
        _tbl, _slot, active, rnd = st
        return (rnd < m) & jnp.any(active)

    def body(st):
        # every still-active row has probed exactly `rnd` times, so the
        # probe counter IS the round counter — no per-row carry needed
        tbl, slot, active, rnd = st
        p = rnd.astype(jnp.uint32)
        cand = ((h + (p * (p + 1)) // 2) & mask).astype(jnp.int32)
        claim = jnp.full((m,), n, jnp.int32).at[cand].min(
            jnp.where(active, idx, n), mode="promise_in_bounds")
        tbl = jnp.where(tbl == n, claim, tbl)
        own = jnp.take(tbl, cand, mode="clip")
        ow = jnp.take(words, jnp.clip(own, 0, max(n - 1, 0)), axis=0,
                      mode="clip")
        eq = (own < n) & jnp.all(ow == words, axis=1)
        slot = jnp.where(active & eq, cand, slot)
        active = active & ~eq
        return tbl, slot, active, rnd + 1

    st0 = (jnp.full((m,), n, jnp.int32),
           jnp.full((n,), m, jnp.int32), valid, jnp.int32(0))
    tbl, slot, active, _rnd = lax.while_loop(cond, body, st0)
    if not isinstance(_rnd, jax.core.Tracer):
        global _LAST_ROUNDS
        _LAST_ROUNDS = int(_rnd)

    # densify: occupied probe slots renumber to [0, #groups) in slot
    # order; groups past the bucket (and probe-exhausted rows, possible
    # only when distinct keys exceed M ≥ bucket) overflow
    occ_m = tbl < n
    dense = jnp.cumsum(occ_m.astype(jnp.int32)) - 1
    d = jnp.take(dense, jnp.clip(slot, 0, m - 1), mode="clip")
    placed = ~active & valid & (d < bucket)
    seg = jnp.where(placed, d, bucket).astype(jnp.int32)
    owner = jnp.full((bucket,), n, jnp.int32).at[
        jnp.where(occ_m & (dense < bucket), dense, bucket)].set(
        tbl, mode="drop")
    occupied = jnp.arange(bucket) < jnp.minimum(dense[-1] + 1, bucket)
    overflowed = jnp.sum((valid & (seg == bucket)).astype(jnp.int32))
    return seg, owner, occupied, overflowed


# ---------------------------------------------------------------------------
# Incremental slotting: extend a resident assignment with a micro-batch.
#
# ``slot_ids_from_words`` is one-shot — its probe table is scratch, so a
# serving layer folding micro-batches would re-probe *history* on every
# arrival.  The stateful variant below keeps the probe table and a dense
# key table resident: ``fresh_slot_state`` allocates them,
# ``slot_ids_extend`` slots ONE batch against them (O(batch) work — the
# loop's scatters are table-sized but the per-round elementwise work is
# batch-sized, and history rows are never touched), and the returned
# state carries the union key set for the next batch.  Dense ids are
# *claim order across calls*: resident keys keep their ids forever
# (appends never renumber), new keys take the next ids.
# ---------------------------------------------------------------------------


class SlotState:
    """Resident slotting state: ``tbl`` (bucket×expand,) int32 maps probe
    slots to dense ids (−1 empty), ``ktab`` (bucket, K) uint32 holds each
    dense id's canonical key words, ``cnt`` is the number of dense ids
    assigned.  Treat as immutable — ``slot_ids_extend`` returns a new
    one.  A state whose extend reported ``overflowed > 0`` is NOT
    reusable for further extends: overflow keys' scratch claims are
    scrubbed to holes that sit on other keys' probe paths — the caller
    must grow the bucket and rebuild (the serving layer's
    double-and-retry does exactly this)."""

    __slots__ = ("tbl", "ktab", "cnt", "bucket", "expand")

    def __init__(self, tbl, ktab, cnt, bucket: int, expand: int):
        self.tbl = tbl
        self.ktab = ktab
        self.cnt = cnt
        self.bucket = int(bucket)
        self.expand = int(expand)


def fresh_slot_state(num_words: int, bucket: int,
                     expand: int = EXPAND) -> SlotState:
    """An empty resident slotting state for ``num_words``-word keys over a
    ``bucket``-slot dense range (same power-of-two constraints as
    ``slot_ids_from_words``)."""
    if bucket & (bucket - 1) or bucket <= 0:
        raise ValueError(f"bucket must be a positive power of two, got "
                         f"{bucket}")
    if expand & (expand - 1) or expand <= 0:
        raise ValueError(f"expand must be a positive power of two, got "
                         f"{expand}")
    m = bucket * expand
    return SlotState(jnp.full((m,), -1, jnp.int32),
                     jnp.zeros((bucket, num_words), jnp.uint32),
                     jnp.int32(0), bucket, expand)


def slot_ids_extend(words: jax.Array, valid: jax.Array,
                    state: SlotState,
                    ) -> tuple[jax.Array, jax.Array, jax.Array, SlotState]:
    """Slot one micro-batch against a resident assignment.  Returns
    ``(seg, new_owner, overflowed, new_state)``:

    * ``seg``        (N,)      int32 — dense slot per batch row (resident
                     keys resolve to their existing id, new keys claim the
                     next ids); invalid and overflowed rows hold
                     ``bucket``;
    * ``new_owner``  (bucket,) int32 — the *batch-local* row index that
                     claimed each newly assigned slot this call (``N``
                     everywhere else, including slots owned by earlier
                     calls) — the caller globalizes it with the batch
                     rows' table positions and merges into its resident
                     representative table;
    * ``overflowed`` ()        int32 — valid batch rows whose key found
                     no dense slot (the union key set outgrew the
                     bucket); nonzero also poisons ``new_state`` (see
                     ``SlotState``);
    * ``new_state``  — the state extended with this batch's keys.

    The probe loop is ``slot_ids_from_words``'s claim/verify round with
    the densifying prefix sum replaced by direct dense-id claims: a
    winner writes ``cnt + rank`` (rank = its order among this round's
    winners) into the probe table and its key words into the key table,
    so every later prober — this round or next month's batch — resolves
    by key-word equality against the id's recorded words.  A winner
    always places on its own claim, so every probe slot a placed key
    stepped over is occupied at call end: probe paths stay consistent
    across calls (absent overflow).
    """
    bucket, expand = state.bucket, state.expand
    words = jnp.asarray(words)
    if state.ktab.shape[1] != words.shape[1]:
        raise ValueError(
            f"key-word arity changed: state has {state.ktab.shape[1]} "
            f"words, batch has {words.shape[1]}")
    seg, new_owner, overflowed, tbl, ktab, cnt = _extend_probe(
        words, jnp.asarray(valid, bool), jnp.asarray(state.tbl),
        jnp.asarray(state.ktab), jnp.asarray(state.cnt, jnp.int32),
        bucket=bucket, expand=expand)
    return seg, new_owner, overflowed, SlotState(tbl, ktab, cnt,
                                                 bucket, expand)


@partial(jax.jit, static_argnames=("bucket", "expand"))
def _extend_probe(words, valid, state_tbl, state_ktab, state_cnt, *,
                  bucket: int, expand: int):
    # jitted per (batch shape, bucket, expand): the probe while_loop is
    # traced once per shape instead of on every eager call — sustained
    # ingest folds hit this thousands of times
    m = bucket * expand
    n, k = words.shape
    h = _hash_words(words)
    idx = jnp.arange(n, dtype=jnp.int32)
    mask = jnp.uint32(m - 1)
    scratch_rows = bucket + n          # overflow claims park past bucket
    ktab_s = jnp.concatenate(
        [state_ktab, jnp.zeros((n, k), jnp.uint32)], axis=0)

    def cond(st):
        _t, _k, _o, _c, _s, active, rnd = st
        return (rnd < m) & jnp.any(active)

    def body(st):
        tbl, ktab, own_arr, cnt, slot, active, rnd = st
        p = rnd.astype(jnp.uint32)
        cand = ((h + (p * (p + 1)) // 2) & mask).astype(jnp.int32)
        empty = jnp.take(tbl, cand, mode="clip") < 0
        claim = jnp.full((m,), n, jnp.int32).at[cand].min(
            jnp.where(active & empty, idx, n), mode="promise_in_bounds")
        winner = active & empty & (jnp.take(claim, cand,
                                            mode="clip") == idx)
        rank = jnp.cumsum(winner.astype(jnp.int32)) - 1
        newid = cnt + rank
        tbl = tbl.at[jnp.where(winner, cand, m)].set(newid, mode="drop")
        ktab = ktab.at[jnp.where(winner, newid, scratch_rows)].set(
            words, mode="drop")
        own_arr = own_arr.at[jnp.where(winner, newid, bucket)].set(
            idx, mode="drop")
        cnt = cnt + jnp.sum(winner.astype(jnp.int32))
        own = jnp.take(tbl, cand, mode="clip")
        ow = jnp.take(ktab, jnp.clip(own, 0, scratch_rows - 1), axis=0,
                      mode="clip")
        eq = (own >= 0) & jnp.all(ow == words, axis=1)
        slot = jnp.where(active & eq, own, slot)
        active = active & ~eq
        return tbl, ktab, own_arr, cnt, slot, active, rnd + 1

    st0 = (state_tbl, ktab_s,
           jnp.full((bucket,), n, jnp.int32),
           state_cnt,
           jnp.full((n,), scratch_rows, jnp.int32), valid, jnp.int32(0))
    tbl, ktab_s, new_owner, cnt, slot, active, _rnd = lax.while_loop(
        cond, body, st0)

    placed = ~active & valid & (slot < bucket)
    seg = jnp.where(placed, slot, bucket).astype(jnp.int32)
    overflowed = jnp.sum((valid & (seg == bucket)).astype(jnp.int32))
    # overflow keys claimed scratch ids ≥ bucket; scrub those probe slots
    # (holes — hence the no-extend-after-overflow contract above)
    tbl = jnp.where(tbl >= bucket, jnp.int32(-1), tbl)
    return (seg, new_owner, overflowed, tbl, ktab_s[:bucket],
            jnp.minimum(cnt, bucket))


def slot_state_build(table, keys: Iterable[str], bucket: int,
                     expand: Optional[int] = None):
    """Full stateful build: slot every row of ``table`` from a fresh
    state — the seeding counterpart of ``slot_segment_ids`` for callers
    that will keep extending (the serving layer's append path).  Counts
    as a slot *build* (bumps the build counter, sized adaptively from
    the distinct sketch like the one-shot path); subsequent
    ``slot_ids_extend`` calls bump the *extend* counter instead — the
    acceptance spies diff both.  Returns ``(seg, owner, overflowed,
    state)`` with ``owner`` already table-global (a fresh build's batch
    IS the table)."""
    keys = tuple(keys)
    global _SLOT_BUILDS
    _SLOT_BUILDS += 1
    words = key_words_for(table.columns[k] for k in keys)
    mask = table.mask()
    if expand is None:
        expand = EXPAND
        if (adaptive_enabled()
                and not isinstance(words, jax.core.Tracer)
                and not isinstance(mask, jax.core.Tracer)):
            expand = adaptive_expand(distinct_count_sketch(table, keys),
                                     bucket)
    state = fresh_slot_state(words.shape[1], bucket, expand)
    seg, owner, overflowed, state = slot_ids_extend(words, mask, state)
    return seg, owner, overflowed, state


_SLOT_EXTENDS = 0


def slot_extend_count() -> int:
    """Number of incremental ``slot_ids_extend`` calls made on behalf of
    a Table append (the serving layer bumps it) since import — the
    acceptance test asserts appends extend instead of rebuilding by
    diffing this against ``slot_build_count``."""
    return _SLOT_EXTENDS


def note_slot_extend() -> None:
    """Bump the extend counter (serving-layer append path)."""
    global _SLOT_EXTENDS
    _SLOT_EXTENDS += 1


#: build-side probe-table expansion for ``build_probe``: the table holds
#: the next power of two ≥ 4 × build rows, bounding the load factor at
#: 1/4 — and since slots ≥ rows ≥ distinct keys, every build key is
#: guaranteed a slot (no overflow state, unlike the bucket-bounded
#: ``slot_ids_from_words``)
_JOIN_EXPAND = 4


def _probe_table_size(n_build: int) -> int:
    need = max(8, _JOIN_EXPAND * max(1, n_build))
    return 1 << (need - 1).bit_length()


def build_probe(build_words: jax.Array, build_valid: jax.Array,
                probe_words: jax.Array,
                probe_valid: Optional[jax.Array] = None,
                ) -> tuple[jax.Array, jax.Array]:
    """Hash-join lookup on canonical key words: build an open-addressing
    table over the build-side rows, then resolve each probe row to the
    matching build row with one lockstep probe walk.  Returns
    ``(ridx, found)``:

    * ``ridx``  (Np,) int32 — build-row index whose key words equal the
      probe row's (``Nb``, the build row count, where no match exists —
      a clip-safe sentinel);
    * ``found`` (Np,) bool  — probe rows with a valid-build-row match.

    The build loop is ``slot_ids_from_words``'s claim/verify round
    (scatter-min claims, full key-word equality verification) minus the
    densifying renumber — the raw probe table IS the product here.
    Duplicate build keys probe in lockstep (equal words ⇒ equal hash), so
    the scatter-min deterministically awards their shared slot to the
    *smallest* valid build-row index — exactly the stable pick the
    sorted-route join made via ``argsort(stable=True)`` + leftmost
    ``searchsorted``.  The probe walk stops at key equality or at the
    first *empty* slot: any slot a placed build key stepped over was
    contended that round (the key's own rows were active claimants), so
    it is occupied at build end — first-empty is a sound miss proof.
    Probing terminates within ``M`` rounds unconditionally (triangular
    increments are exhaustive on a power-of-two table); the ≤ 1/4 load
    bound keeps real walks to a couple of rounds.

    Equality is bitwise on canonical words (NaN matches NaN per bit
    pattern, −0.0 matches +0.0): *join* routes that need SQL value
    equality mask NaN keys out of ``found`` at the call site.
    """
    build_words = jnp.asarray(build_words)
    probe_words = jnp.asarray(probe_words)
    nb = build_words.shape[0]
    npr = probe_words.shape[0]
    pvalid = (jnp.ones((npr,), bool) if probe_valid is None
              else jnp.asarray(probe_valid, bool))
    if nb == 0:
        return (jnp.zeros((npr,), jnp.int32),
                jnp.zeros((npr,), bool))
    m = _probe_table_size(nb)
    mask = jnp.uint32(m - 1)
    bvalid = jnp.asarray(build_valid, bool)
    hb = _hash_words(build_words)
    idx = jnp.arange(nb, dtype=jnp.int32)

    def bcond(st):
        _tbl, active, rnd = st
        return (rnd < m) & jnp.any(active)

    def bbody(st):
        tbl, active, rnd = st
        p = rnd.astype(jnp.uint32)
        cand = ((hb + (p * (p + 1)) // 2) & mask).astype(jnp.int32)
        claim = jnp.full((m,), nb, jnp.int32).at[cand].min(
            jnp.where(active, idx, nb), mode="promise_in_bounds")
        tbl = jnp.where(tbl == nb, claim, tbl)
        own = jnp.take(tbl, cand, mode="clip")
        ow = jnp.take(build_words, jnp.clip(own, 0, nb - 1), axis=0,
                      mode="clip")
        eq = (own < nb) & jnp.all(ow == build_words, axis=1)
        active = active & ~eq
        return tbl, active, rnd + 1

    tbl, _active, _rnd = lax.while_loop(
        bcond, bbody,
        (jnp.full((m,), nb, jnp.int32), bvalid, jnp.int32(0)))

    hp = _hash_words(probe_words)

    def pcond(st):
        _ridx, _found, active, rnd = st
        return (rnd < m) & jnp.any(active)

    def pbody(st):
        ridx, found, active, rnd = st
        p = rnd.astype(jnp.uint32)
        cand = ((hp + (p * (p + 1)) // 2) & mask).astype(jnp.int32)
        own = jnp.take(tbl, cand, mode="clip")
        empty = own >= nb
        ow = jnp.take(build_words, jnp.clip(own, 0, nb - 1), axis=0,
                      mode="clip")
        eq = ~empty & jnp.all(ow == probe_words, axis=1)
        hit = active & eq
        ridx = jnp.where(hit, own, ridx)
        found = found | hit
        active = active & ~eq & ~empty
        return ridx, found, active, rnd + 1

    ridx, found, _a, _r = lax.while_loop(
        pcond, pbody,
        (jnp.full((npr,), nb, jnp.int32), jnp.zeros((npr,), bool),
         pvalid, jnp.int32(0)))
    return ridx, found


# ---------------------------------------------------------------------------
# Slot-table reuse: a serving layer (or any caller that amortizes the
# probe loop across repeated calls) can compute the four slot arrays once
# per (table version, key set, bucket) and *provide* them for the scope of
# an execution — ``slot_segment_ids`` then returns the provided arrays
# instead of re-probing.  The override is thread-local (concurrent server
# executions don't see each other's tables) and keyed by
# ``(key-name tuple, bucket)``; the provider owns the harder invariant
# that the arrays were built from the table being executed (the serving
# layer keys its cache by ``Table.version`` for exactly this).  Builds
# that actually run the probe loop bump a module counter — the spy tests
# and the serving bench use it to assert slotting amortized to zero.
# ---------------------------------------------------------------------------

_SLOT_BUILDS = 0
_LAST_ROUNDS = None
_PROVIDED = threading.local()


def slot_build_count() -> int:
    """Number of times the probe loop was actually built (eager call or
    jit trace) since import — provided slots don't count.  Monotonic;
    callers diff it around a region to assert slotting was cached."""
    return _SLOT_BUILDS


def probe_rounds():
    """Probe rounds the most recent *eager* ``slot_ids_from_words`` ran
    (None before any eager build; traced builds don't record — the count
    is a tracer there).  The adaptive-sizing regression test pins this:
    shrinking the probe table must not send the round count past a
    handful even at the sketch's target load factor."""
    return _LAST_ROUNDS


def provided_slots(keys, bucket: int):
    """The slot arrays provided for ``(keys, bucket)`` by an enclosing
    ``provide_slots`` scope, or None."""
    stack = getattr(_PROVIDED, "stack", None)
    if not stack:
        return None
    k = (tuple(keys), int(bucket))
    for mapping in reversed(stack):
        got = mapping.get(k)
        if got is not None:
            return got
    return None


@contextmanager
def provide_slots(mapping: Mapping):
    """Provide precomputed slot arrays for the dynamic extent of the
    context: ``mapping`` maps ``(key-name tuple, bucket)`` to the
    ``(seg, owner, occupied, overflowed)`` tuple ``slot_ids_from_words``
    returned for the table about to be executed.  Nested scopes stack;
    inner providers win."""
    norm = {(tuple(k), int(b)): tuple(v) for (k, b), v in mapping.items()}
    stack = getattr(_PROVIDED, "stack", None)
    if stack is None:
        stack = _PROVIDED.stack = []
    stack.append(norm)
    try:
        yield
    finally:
        stack.pop()


def slot_segment_ids(table, keys: Iterable[str], bucket: int):
    """``slot_ids_from_words`` over a Table's group-key columns and row
    mask — the sort-free counterpart of ``engine.segment_ids_for`` (same
    overflow-parking convention; representative rows come from ``owner``
    instead of segment starts, validity from ``occupied`` instead of a
    dense prefix).  An enclosing ``provide_slots`` scope short-circuits
    the probe loop with its cached arrays."""
    keys = tuple(keys)
    pre = provided_slots(keys, bucket)
    if pre is not None:
        return pre
    global _SLOT_BUILDS
    _SLOT_BUILDS += 1
    words = key_words_for(table.columns[k] for k in keys)
    mask = table.mask()
    expand = EXPAND
    if (adaptive_enabled()
            and not isinstance(words, jax.core.Tracer)
            and not isinstance(mask, jax.core.Tracer)):
        # eager build: size the probe table by the keys actually present
        # (sketch ~ one O(N) pass) instead of the worst-case ceiling.
        # Correctness never rides on the estimate — any key set within
        # the bucket fits (the table keeps ≥ _MIN_EXPAND × bucket slots)
        # and the dense renumbering still validates the bucket itself.
        expand = adaptive_expand(distinct_count_sketch(table, keys),
                                 bucket)
    return slot_ids_from_words(words, mask, bucket, expand)


def distinct_count_sketch(table, keys: Iterable[str],
                          m: int = 4096) -> int:
    """Linear-counting estimate of the table's distinct group-key tuples —
    the sketch the serving layer uses to infer ``max_groups`` when no
    dense bound was declared (ROADMAP carried item).  One O(N) pass: the
    canonical key words hash (the same murmur-mix slotting probes with)
    into an ``m``-bucket occupancy bitmap; ``d̂ = -m·ln(1 - b/m)`` for
    ``b`` occupied buckets.  Concrete (blocks on the device value);
    clamped to ``[1, #valid rows]``, and a saturated bitmap degrades to
    the valid-row count — an over-, never under-, estimate there.  The
    estimate itself can undershoot by its sampling error, so callers pad
    it and *validate* the resulting bound (the slot build raises on
    overflow) rather than trusting it."""
    words = key_words_for(table.columns[k] for k in keys)
    valid = jnp.asarray(table.mask(), bool)
    nvalid = int(jnp.sum(valid.astype(jnp.int32)))
    if nvalid == 0:
        return 1
    h = (_hash_words(words) & jnp.uint32(m - 1)).astype(jnp.int32)
    occ = jnp.zeros((m,), jnp.int32).at[
        jnp.where(valid, h, m)].max(1, mode="drop")
    b = int(jnp.sum(occ))
    if b >= m:
        est = nvalid
    else:
        est = max(1, min(nvalid, int(math.ceil(-m * math.log(1.0 - b / m)))))
    if faults.fire("sketch_undershoot"):
        est = max(1, est // 8)
    return est


def overflow_extended(owner: jax.Array, occupied: jax.Array,
                      capacity: int) -> tuple[jax.Array, jax.Array]:
    """Extend the (bucket,)-sized ``owner``/``occupied`` tables with the
    overflow slot, giving the ``num_segments``-sized representative-row
    and output-validity arrays the grouped executors build their result
    Table from: the overflow slot is never a real group (valid False)
    and its representative parks at ``capacity`` (callers clip before
    gathering key values).  One place owns this convention so the
    engine's GroupAgg and the executors' grouped AggCall cannot
    diverge."""
    rep = jnp.concatenate([owner, jnp.full((1,), capacity, jnp.int32)])
    out_valid = jnp.concatenate([occupied, jnp.zeros((1,), bool)])
    return rep, out_valid


def sortfree_result(table, keys: Iterable[str], rep: jax.Array,
                    out_valid: jax.Array, unplaced, bucket: int,
                    agg_cols: dict):
    """Assemble the sort-free grouped result Table — the ONE epilogue
    both grouped executors (engine ``GroupAgg`` and the executors'
    grouped ``AggCall``) share, so the overflow/representative
    convention cannot diverge between them: validate the overflow count
    (concrete raise / traced poison guard), gather one representative
    row of key values per slot (``rep`` already carries the overflow
    sentinel; clipped before the take), and stamp the claim-order
    validity mask."""
    from .group_bound import poison_overflow
    from .table import Table
    overflow_ok = check_slot_overflow(unplaced, bucket)
    cap = table.capacity
    safe_rep = jnp.clip(rep, 0, cap - 1)
    cols = {k: jnp.take(table.columns[k], safe_rep) for k in keys}
    cols.update(agg_cols)
    return Table(poison_overflow(cols, overflow_ok), out_valid)


def check_slot_overflow(unplaced, bucket: int):
    """Validate that every valid row found a real slot — the sort-free
    face of the dense-bound validation
    (``group_bound.check_group_overflow``): valid rows land in the
    overflow slot exactly when the input carries more distinct keys than
    the declared bucket.  Concrete counts raise eagerly; traced counts
    return the ``ok`` guard the caller feeds to ``poison_overflow``;
    ``None`` means the bound held."""
    if isinstance(unplaced, jax.core.Tracer):
        return unplaced == 0
    if int(unplaced) > 0:
        raise GroupBoundOverflow(
            f"sort-free grouped aggregation: {int(unplaced)} rows carry "
            f"group keys beyond the declared dense bound ({bucket} slots; "
            f"max_groups bucketed to the next power-of-two lane multiple) "
            f"— raise max_groups or drop the declaration")
    return None
