"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` visits each ``while`` body ONCE — a scanned
64-layer transformer reports ~1/64 of its true FLOPs (verified empirically;
see EXPERIMENTS.md §Dry-run).  Since the whole framework scans over layers,
we parse the optimized per-device HLO text and account costs per
computation, multiplying ``while`` bodies by their trip count (recovered
from the loop-condition constant).

Accounted:
  * flops            — dot ops: 2 × |result| × |contracting dims| (plus the
                       same inside fusions/called computations);
  * traffic_bytes    — HBM-traffic proxy: operand+result bytes of
                       materializing ops (fusion, dot, copy, collectives,
                       dynamic-update-slice, …): post-fusion boundaries are
                       what actually hits memory;
  * collective_bytes — per collective kind, result-shape bytes (the data a
                       chip must move for its shard).

All values are per-device (the compiled module is the per-device SPMD
program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# Ops whose operand/result bytes approximate TPU HBM traffic.  Two earlier
# iterations over-counted by orders of magnitude (recorded in EXPERIMENTS.md
# §Perf methodology): (v1) counting broadcast/reshape/iota — those fuse on
# TPU; (v2) counting every CPU-backend fusion's I/O — the CPU backend
# fragments into many tiny fusions re-reading the same tensors.  The stable
# proxy: tensor-contraction and data-movement ops only — dots (weights +
# activations), gathers/scatters (embedding, MoE dispatch), sorts (MoE
# routing), cache updates, convolutions, and collectives.  Elementwise
# chains fuse into these on TPU and are free at first order.
_TRAFFIC_OPS = _COLLECTIVES + (
    "dot", "dynamic-update-slice", "dynamic-slice", "convolution",
    "scatter", "gather", "sort", "select-and-scatter",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(tok: str) -> tuple[str, list[int]]:
    m = _SHAPE_RE.search(tok)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclass
class CompCosts:
    flops: float = 0.0
    traffic: float = 0.0
    collectives: dict = field(default_factory=dict)
    whiles: list = field(default_factory=list)    # (cond, body, trip|None)
    calls: list = field(default_factory=list)     # called computation names


@dataclass
class HloCosts:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: dict = field(default_factory=dict)

    @property
    def collective_total(self) -> float:
        return float(sum(self.collective_bytes.values()))


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")
_CALLEE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"\b[su]32\[\]\s+constant\((\d+)\)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def parse_hlo(text: str) -> tuple[dict[str, CompCosts], str, dict[str, int]]:
    comps: dict[str, CompCosts] = {}
    consts: dict[str, list[int]] = {}
    shapes: dict[str, dict[str, str]] = {}
    entry = ""
    cur = None
    for line in text.splitlines():
        if not line.startswith((" ", "\t")) and line.rstrip().endswith("{") \
                and "->" in line:
            hdr = _COMP_HDR.match(line)
            if hdr:
                cur = hdr.group(1)
                comps[cur] = CompCosts()
                consts[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
                continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        for cm in _CONST_INT.finditer(rhs):
            consts[cur].append(int(cm.group(1)))
        # split "TYPE opcode(operands...), attrs"
        op_m = re.search(r"\)?\s*([a-z][\w\-]*)\(", rhs)
        if not op_m:
            continue
        opcode = op_m.group(1)
        result_part = rhs[:op_m.start()]
        operand_part = rhs[op_m.end():]
        # operand list ends at the first unmatched ')'
        depth = 0
        end = len(operand_part)
        for i, ch in enumerate(operand_part):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        operand_str = operand_part[:end]
        # symbol table: scheduled HLO references operands by %name only
        shapes.setdefault(cur, {})[name] = result_part
        operand_shapes = [shapes[cur].get(nm, "")
                          for nm in _OPERAND_NAME.findall(operand_str)]
        c = comps[cur]

        if opcode == "while":
            cond = _COND.search(rhs)
            body = _BODY.search(rhs)
            trip_m = _TRIP.search(rhs)
            if cond and body:
                c.whiles.append((cond.group(1), body.group(1),
                                 int(trip_m.group(1)) if trip_m else None))
            continue
        if opcode in ("call", "fusion", "map", "conditional", "custom-call",
                      "reduce", "sort", "scatter", "select-and-scatter",
                      "reduce-window", "reduce-scatter", "all-reduce"):
            callee = _CALLEE.search(rhs)
            if callee and opcode in ("call", "conditional"):
                c.calls.append(callee.group(1))
            if opcode == "fusion" and callee:
                c.calls.append(callee.group(1))

        if opcode == "dot":
            _, res_dims = _shape_dims(result_part)
            contract = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            res_n = 1
            for d in res_dims:
                res_n *= d
            k = 1
            lhs_shape = operand_shapes[0] if operand_shapes else ""
            _, lhs_dims = _shape_dims(lhs_shape)
            if contract and lhs_dims:
                for idx in (contract.group(1).split(",")
                            if contract.group(1) else []):
                    k *= lhs_dims[int(idx)]
            c.flops += 2.0 * res_n * k

        if opcode in _COLLECTIVES:
            b = _shape_bytes(result_part)
            c.collectives[opcode] = c.collectives.get(opcode, 0.0) + b

        if opcode in _TRAFFIC_OPS:
            if opcode in ("dynamic-slice", "gather"):
                # reads only the sliced region (NOT the whole operand —
                # counting the full stacked-layer params per scan slice
                # overstated traffic ~16×), then writes the result
                c.traffic += 2 * _shape_bytes(result_part)
            elif opcode == "dynamic-update-slice":
                upd = operand_shapes[1] if len(operand_shapes) > 1 \
                    else result_part
                c.traffic += 2 * _shape_bytes(upd)
            elif opcode == "scatter":
                upd = operand_shapes[2] if len(operand_shapes) > 2 \
                    else result_part
                c.traffic += 2 * _shape_bytes(upd)
            else:
                c.traffic += _shape_bytes(result_part) \
                    + sum(_shape_bytes(s) for s in operand_shapes)

    trip_consts = {name: (max(v) if v else 1) for name, v in consts.items()}
    return comps, entry, trip_consts


def analyze_hlo(text: str) -> HloCosts:
    comps, entry, consts = parse_hlo(text)

    memo: dict[str, HloCosts] = {}

    def walk(name: str, depth=0) -> HloCosts:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 64:
            return HloCosts()
        memo[name] = HloCosts()          # break cycles
        c = comps[name]
        out = HloCosts(flops=c.flops, traffic_bytes=c.traffic,
                       collective_bytes=dict(c.collectives))
        for callee in c.calls:
            sub = walk(callee, depth + 1)
            out.flops += sub.flops
            out.traffic_bytes += sub.traffic_bytes
            for k, v in sub.collective_bytes.items():
                out.collective_bytes[k] = out.collective_bytes.get(k, 0) + v
        for cond, body, trip_known in c.whiles:
            trip = trip_known if trip_known is not None else consts.get(cond, 1)
            sub = walk(body, depth + 1)
            out.flops += trip * sub.flops
            out.traffic_bytes += trip * sub.traffic_bytes
            for k, v in sub.collective_bytes.items():
                out.collective_bytes[k] = out.collective_bytes.get(k, 0) \
                    + trip * v
        memo[name] = out
        return out

    return walk(entry)
