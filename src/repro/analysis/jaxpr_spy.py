"""Structural jaxpr spies: assert properties of a traced program that
timing cannot (and unit values will not) catch.

The first client is the fused arg-extremum acceptance bound: the grouped
argmin/argmax lowering must issue NO row-capacity-sized gather — the
kernel's index moment replaced the ``take(best, seg)`` hit-detection scan
and the full-row candidate reduce, and the jnp fallback computes the index
with a segmented ``associative_scan`` (slices, not gathers).  The group
sort itself legitimately gathers full rows, so the spy compares against a
no-arg baseline program rather than demanding zero: the arg-extremum must
add nothing row-sized (``benchmarks/arg_gather_spy.py``, a tier-1 test,
and a dedicated CI step all assert it).

The second client is the SORT census of the sort-free grouped route
(hash-slotted segment ids, relational/keyslot.py): its acceptance bound
is that the traced program contains ZERO row-capacity-sized ``sort``
equations — the group sort, its per-key argsorts, and ``compress`` all
lower to the ``sort`` primitive, so ``count_row_sized_sorts`` pins "the
sort stays deleted" structurally (``benchmarks/sortfree_spy.py``, a
tier-1 test, and a CI step).

Counting is done on the CLOSED jaxpr, pre-optimization: every ``jnp.take``
/ advanced-index lowers to the ``gather`` primitive there, every
``jnp.argsort`` / ``lax.sort`` to the ``sort`` primitive, the counts are
deterministic (no backend fusion heuristics), and sub-jaxprs — jit calls,
scan bodies, while bodies, shard_map bodies, and interpret-mode
``pallas_call`` kernels — are walked recursively, so nothing hides inside
a call boundary.
"""
from __future__ import annotations

import math
from typing import Iterator

from jax.extend import core as _core


def _sub_jaxprs(params) -> Iterator["_core.Jaxpr"]:
    for v in params.values():
        yield from _as_jaxprs(v)


def _as_jaxprs(v) -> Iterator["_core.Jaxpr"]:
    if isinstance(v, _core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, _core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _as_jaxprs(x)


def iter_eqns(jaxpr) -> Iterator:
    """Every equation of ``jaxpr`` and, recursively, of every sub-jaxpr
    carried in equation params (pjit, scan, while, shard_map, pallas_call,
    custom_* wrappers, ...)."""
    if isinstance(jaxpr, _core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def gather_output_sizes(jaxpr) -> list[int]:
    """Flattened output element count of every ``gather`` equation in the
    (closed) jaxpr, recursing through call boundaries."""
    sizes = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name == "gather":
            shape = getattr(eqn.outvars[0].aval, "shape", ())
            sizes.append(int(math.prod(shape)))
    return sizes


def sort_output_sizes(jaxpr) -> list[int]:
    """Largest flattened output element count of every ``sort`` equation
    in the (closed) jaxpr, recursing through call boundaries.  A variadic
    sort (``lax.sort`` with several operands, e.g. ``Table.sort_by``'s
    keys + iota permutation) is ONE equation — its widest output is the
    size that matters, and fusing K argsorts into one variadic sort is
    visible as K equations collapsing to one."""
    sizes = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name == "sort":
            sizes.append(max(
                int(math.prod(getattr(v.aval, "shape", ())))
                for v in eqn.outvars))
    return sizes


def count_row_sized_sorts(jaxpr, n: int) -> int:
    """Number of sort equations whose output is at least row-set-sized —
    the acceptance metric of the sort-free grouped route: hash-slotted
    segment assignment must leave ZERO of these in the traced program
    (segment-sized sorts, should any appear, are legal — O(num_segments)
    work was never the problem)."""
    return sum(1 for s in sort_output_sizes(jaxpr) if s >= n)


def count_row_sized_gathers(jaxpr, n: int) -> int:
    """Number of gather equations whose OUTPUT is at least row-set-sized.

    This is the acceptance metric of the fused arg-extremum path: a
    ``take(best, seg)`` hit-detection scan materializes an (N,)-sized
    gather output, while the index-moment lowering's payload take outputs
    only (num_segments,) elements.  Gathers *reading* a row-sized operand
    but emitting a segment-sized result are intentionally not counted —
    output size is what the collective/memory cost scales with."""
    return sum(1 for s in gather_output_sizes(jaxpr) if s >= n)


def row_census(jaxpr, n: int) -> dict[str, int]:
    """Row-sized sort AND gather counts in one walk — the combined
    acceptance census of the whole-plan-fusion clients: the hash-join /
    fused-chain lowering must show zero row-sized sorts (the legacy
    join's stable argsort, ``compress``'s permutation sort, and the
    group sort all register here) and no more row-sized gathers than the
    materialized plan it replaced.  ``Limit`` is covered by the same
    counters: its old ``compress()`` lowering costs one row-sized sort
    plus per-column row-sized gathers, while the prefix-sum rewrite
    (engine) is a cumsum + compare — nothing registers."""
    return {"sorts": count_row_sized_sorts(jaxpr, n),
            "gathers": count_row_sized_gathers(jaxpr, n)}
