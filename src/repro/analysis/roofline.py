"""Roofline model: compute / memory / collective terms per (arch × shape ×
mesh), derived from the dry-run artifacts.

Hardware constants (TPU v5e-like, per chip):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI               : ~50 GB/s per link

Terms (seconds per step, per the assignment's definition):
    compute    = HLO_FLOPs / (chips × peak)        [= per-device flops/peak]
    memory     = HLO_bytes / (chips × HBM bw)
    collective = collective_bytes / (chips × link bw)

Our per-device numbers come from the trip-count-corrected HLO analysis
(analysis/hlo.py) — ``compiled.cost_analysis()`` visits each scan body once
and undercounts a 64-layer model by ~64× (both raw and corrected values are
recorded in the artifacts).

MODEL_FLOPS convention: 6·N·D for training (D = tokens), 2·N·D for
inference; MoE uses N_active.  The usefulness ratio MODEL_FLOPS/HLO_FLOPs
exposes remat/recompute waste (flash backward recompute, causal masking
waste, dead padding).
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Optional

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops_per_dev: float = 0.0
    useful_ratio: float = 0.0
    hbm_gb: float = 0.0
    reason: str = ""

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step bound that is *useful* model compute —
        (model_flops/peak) / max(term): 1.0 = perfectly compute-bound with
        zero overhead."""
        if self.bound_s <= 0:
            return 0.0
        return (self.model_flops_per_dev / PEAK_FLOPS) / self.bound_s


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        total = 6.0 * n * d
    elif shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        total = 2.0 * n * d
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
    return total / chips


def row_from_artifact(rec: dict) -> RooflineRow:
    mesh = "2x16x16" if rec.get("multi_pod") else "16x16"
    if rec.get("status") != "OK":
        return RooflineRow(rec["arch"], rec["shape"], mesh,
                           rec.get("status", "FAIL"),
                           reason=rec.get("reason", rec.get("error", "")))
    chips = rec["chips"]
    hlo = rec["hlo_per_device"]
    compute_s = hlo["flops"] / PEAK_FLOPS
    memory_s = hlo["traffic_bytes"] / HBM_BW
    collective_s = hlo["collective_total"] / ICI_BW
    mf = model_flops_per_device(rec["arch"], rec["shape"], chips)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    hbm = (rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
           + rec["memory"]["output_bytes"]
           - rec["memory"]["alias_bytes"]) / 1e9
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=mesh, status="OK",
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops_per_dev=mf,
        useful_ratio=mf / hlo["flops"] if hlo["flops"] else 0.0,
        hbm_gb=hbm)


def load_rows(art_dir: str) -> list[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            rows.append(row_from_artifact(json.load(f)))
    return rows


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def render_table(rows: list[RooflineRow], mesh: Optional[str] = "16x16") -> str:
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "useful | roofline frac | HBM GB | status |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if mesh and r.mesh != mesh:
            continue
        if r.status != "OK":
            out.append(f"| {r.arch} | {r.shape} | | | | | | | | "
                       f"{r.status}: {r.reason[:60]} |")
            continue
        out.append(
            f"| {r.arch} | {r.shape} | {_fmt_s(r.compute_s)} | "
            f"{_fmt_s(r.memory_s)} | {_fmt_s(r.collective_s)} | "
            f"{r.dominant} | {r.useful_ratio:.2f} | "
            f"{r.roofline_fraction:.3f} | {r.hbm_gb:.1f} | OK |")
    return "\n".join(out)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load_rows(args.art)
    print(render_table(rows, None if args.mesh == "all" else args.mesh))


if __name__ == "__main__":
    main()
