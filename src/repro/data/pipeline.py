"""Deterministic, stateless, host-sharded synthetic token pipeline.

Every (step, host) pair maps to a unique slice of a counter-based PRNG
stream, so:
  * any host can (re)compute any shard — elastic scaling and straggler
    replacement need no data-state handoff;
  * restart-after-failure resumes mid-epoch bit-identically from the step
    index alone (no iterator state in checkpoints);
  * a double-buffered prefetch thread overlaps host data generation with
    device compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Iterator, Optional

import numpy as np

PyTree = Any


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def _rng_for(cfg: DataConfig, step: int, host: int) -> np.random.Generator:
    # counter-based: independent stream per (seed, step, host)
    ss = np.random.SeedSequence([cfg.seed, step, host])
    return np.random.default_rng(ss)


def host_batch(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """The slice of the global batch owned by this host at ``step``."""
    assert cfg.global_batch % cfg.n_hosts == 0
    per_host = cfg.global_batch // cfg.n_hosts
    rng = _rng_for(cfg, step, cfg.host_id)
    tokens = rng.integers(0, cfg.vocab, (per_host, cfg.seq_len + 1),
                          dtype=np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class Prefetcher:
    """Double-buffered background prefetch (compute/IO overlap)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = host_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> tuple[int, dict[str, np.ndarray]]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
