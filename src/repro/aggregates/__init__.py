"""repro.aggregates — built-in aggregate library (paper §3.1) as Aggregate
contract instances."""
from .builtin import (BUILTINS, argmin_agg, avg_agg, count_agg, max_agg,
                      min_agg, sum_agg, var_agg)

__all__ = ["BUILTINS", "argmin_agg", "avg_agg", "count_agg", "max_agg",
           "min_agg", "sum_agg", "var_agg"]
