"""Built-in aggregates as first-class Aggregate instances (paper §3.1:
"min, max, sum, avg and count are provided by DBMSs as built-in aggregate
functions") — all deterministic, all with Merge, so every executor
(streaming / chunked / tree / shard-merge) applies.

These are also the targets the recognizer lowers synthesized aggregates
onto; having them as explicit contract instances lets tests cross-check
the recognizer output against a hand-written reference for each algebra.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.aggregate import Aggregate

F32 = jnp.float32


def sum_agg(dtype=F32) -> Aggregate:
    def init():
        return {"s": jnp.zeros((), dtype)}
    return Aggregate(
        "sum", init,
        lambda st, row: {"s": st["s"] + row["x"].astype(dtype)},
        lambda st: st["s"],
        merge=lambda a, b: {"s": a["s"] + b["s"]},
        identity=init)


def count_agg() -> Aggregate:
    def init():
        return {"n": jnp.zeros((), jnp.int32)}
    return Aggregate(
        "count", init,
        lambda st, row: {"n": st["n"] + 1},
        lambda st: st["n"],
        merge=lambda a, b: {"n": a["n"] + b["n"]},
        identity=init)


def min_agg(dtype=F32) -> Aggregate:
    def identity():
        return {"m": jnp.array(jnp.inf, dtype)}
    return Aggregate(
        "min", identity,
        lambda st, row: {"m": jnp.minimum(st["m"], row["x"].astype(dtype))},
        lambda st: st["m"],
        merge=lambda a, b: {"m": jnp.minimum(a["m"], b["m"])},
        identity=identity)


def max_agg(dtype=F32) -> Aggregate:
    def identity():
        return {"m": jnp.array(-jnp.inf, dtype)}
    return Aggregate(
        "max", identity,
        lambda st, row: {"m": jnp.maximum(st["m"], row["x"].astype(dtype))},
        lambda st: st["m"],
        merge=lambda a, b: {"m": jnp.maximum(a["m"], b["m"])},
        identity=identity)


def avg_agg(dtype=F32) -> Aggregate:
    """Average via (sum, count) state — the canonical 'merge needs more
    state than terminate returns' example."""
    def init():
        return {"s": jnp.zeros((), dtype), "n": jnp.zeros((), dtype)}
    return Aggregate(
        "avg", init,
        lambda st, row: {"s": st["s"] + row["x"].astype(dtype),
                         "n": st["n"] + 1},
        lambda st: st["s"] / jnp.maximum(st["n"], 1),
        merge=lambda a, b: {"s": a["s"] + b["s"], "n": a["n"] + b["n"]},
        identity=init)


def argmin_agg(dtype=F32) -> Aggregate:
    """argmin with payload — the minCostSupp algebra (strict <: first
    attaining row wins, earlier chunk wins on merge ties)."""
    def identity():
        return {"k": jnp.array(jnp.inf, dtype),
                "p": jnp.zeros((), jnp.int32)}
    def accumulate(st, row):
        better = row["key"].astype(dtype) < st["k"]
        return {"k": jnp.where(better, row["key"].astype(dtype), st["k"]),
                "p": jnp.where(better, row["payload"], st["p"])}
    def merge(a, b):
        take_b = b["k"] < a["k"]
        return {"k": jnp.where(take_b, b["k"], a["k"]),
                "p": jnp.where(take_b, b["p"], a["p"])}
    return Aggregate("argmin", identity, accumulate, lambda st: st["p"],
                     merge=merge, identity=identity)


def argmax_agg(dtype=F32) -> Aggregate:
    """argmax with payload — the mirror of ``argmin_agg`` (strict >:
    first attaining row wins, earlier chunk wins on merge ties).  The
    algebra the engine's GroupAgg ``argmax`` op and the fused kernel's
    ``argmax_first`` index moment both lower."""
    def identity():
        return {"k": jnp.array(-jnp.inf, dtype),
                "p": jnp.zeros((), jnp.int32)}
    def accumulate(st, row):
        better = row["key"].astype(dtype) > st["k"]
        return {"k": jnp.where(better, row["key"].astype(dtype), st["k"]),
                "p": jnp.where(better, row["payload"], st["p"])}
    def merge(a, b):
        take_b = b["k"] > a["k"]
        return {"k": jnp.where(take_b, b["k"], a["k"]),
                "p": jnp.where(take_b, b["p"], a["p"])}
    return Aggregate("argmax", identity, accumulate, lambda st: st["p"],
                     merge=merge, identity=identity)


def var_agg(dtype=F32) -> Aggregate:
    """Welford/Chan parallel variance — a nontrivial Merge (the class of
    aggregate the paper's streaming-only engine cannot parallelize but the
    contract's Merge can)."""
    def init():
        return {"n": jnp.zeros((), dtype), "mean": jnp.zeros((), dtype),
                "m2": jnp.zeros((), dtype)}
    def accumulate(st, row):
        n = st["n"] + 1
        d = row["x"].astype(dtype) - st["mean"]
        mean = st["mean"] + d / n
        return {"n": n, "mean": mean,
                "m2": st["m2"] + d * (row["x"].astype(dtype) - mean)}
    def merge(a, b):
        n = a["n"] + b["n"]
        safe = jnp.maximum(n, 1)
        d = b["mean"] - a["mean"]
        mean = (a["n"] * a["mean"] + b["n"] * b["mean"]) / safe
        m2 = a["m2"] + b["m2"] + d * d * a["n"] * b["n"] / safe
        return {"n": n, "mean": mean, "m2": m2}
    return Aggregate("var", init, accumulate,
                     lambda st: st["m2"] / jnp.maximum(st["n"], 1),
                     merge=merge, identity=init)


BUILTINS = {
    "sum": sum_agg, "count": count_agg, "min": min_agg, "max": max_agg,
    "avg": avg_agg, "argmin": argmin_agg, "argmax": argmax_agg,
    "var": var_agg,
}
