"""Activation-sharding context: lets model code place
``with_sharding_constraint`` anchors without owning a mesh.

The launcher (dryrun/train) sets the context before tracing; unset, every
constraint is a no-op, so tests and single-device runs are unaffected.
Axis aliases: "dp" → the composed data axes (("pod","data") or ("data",)),
"tp" → "model".
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Any, Optional

import jax

_CTX: dict[str, Any] = {"mesh": None, "dp": None, "tp": True}


def set_ctx(mesh, dp_axes, tp: bool = True) -> None:
    _CTX["mesh"] = mesh
    _CTX["dp"] = tuple(dp_axes)
    _CTX["tp"] = tp


def clear_ctx() -> None:
    _CTX["mesh"] = None
    _CTX["dp"] = None
    _CTX["tp"] = True


@contextmanager
def ctx(mesh, dp_axes, tp: bool = True):
    set_ctx(mesh, dp_axes, tp)
    try:
        yield
    finally:
        clear_ctx()


def constrain(x: jax.Array, *dims: Optional[str]) -> jax.Array:
    """dims: one of "dp", "tp", None per array dim (may be shorter than
    x.ndim; missing dims are unconstrained).  Divisibility-checked: a dim
    that doesn't divide is left unconstrained."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    entries = []
    for i, d in enumerate(dims):
        if d is None or (d == "tp" and not _CTX["tp"]):
            entries.append(None)
            continue
        axes = _CTX["dp"] if d == "dp" else ("model",)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if x.shape[i] % size == 0 and x.shape[i] > 0:
            entries.append(tuple(axes) if len(axes) > 1 else axes[0])
        else:
            entries.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
