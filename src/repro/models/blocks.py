"""Per-family transformer blocks (full-sequence + decode variants), built
from the attention/ssm/moe sublayers.  All blocks are pure functions of
(stacked-layer) param dicts — scanned over layers by models/model.py."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig

from .attention import (attention_layer, decode_cross_attention,
                        decode_step_attention, init_attention,
                        project_cross_kv)
from .layers import (F32, gated_mlp, gelu_mlp, init_embed, init_gated_mlp,
                     init_gelu_mlp, init_rms_norm, layer_norm, rms_norm)
from .moe import init_moe, moe_layer
from .ssm import decode_step_ssm, init_ssm, init_ssm_cache, ssm_layer

PyTree = Any


def _norm(cfg: ArchConfig, params, x, which: str):
    if cfg.norm == "ln":
        return layer_norm(x, params[which]["scale"], params[which]["bias"])
    return rms_norm(x, params[which])


def init_norm(cfg: ArchConfig, dtype=jnp.bfloat16):
    if cfg.norm == "ln":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return init_rms_norm(cfg.d_model, dtype)


# --------------------------------------------------------------------------
# Block init
# --------------------------------------------------------------------------


def init_block(key, cfg: ArchConfig, kind: str, dtype=jnp.bfloat16) -> PyTree:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict[str, Any] = {}
    if kind in ("dense", "moe", "hybrid", "enc", "dec", "cross"):
        p["norm1"] = init_norm(cfg, dtype)
    if kind in ("dense", "moe", "hybrid", "enc", "dec", "cross"):
        p["norm2"] = init_norm(cfg, dtype)
    if kind == "dense" or kind == "enc":
        p["attn"] = init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, cfg.qkv_bias, cfg.qk_norm,
                                   dtype)
        p["mlp"] = (init_gelu_mlp(ks[1], d, cfg.d_ff, dtype)
                    if cfg.norm == "ln" else
                    init_gated_mlp(ks[1], d, cfg.d_ff, dtype))
    elif kind == "moe":
        p["attn"] = init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, cfg.qkv_bias, cfg.qk_norm,
                                   dtype)
        p["moe"] = init_moe(ks[1], d, cfg.d_ff, cfg.n_experts, dtype)
    elif kind == "ssm":
        p["norm1"] = init_norm(cfg, dtype)
        p["ssm"] = init_ssm(ks[0], d, state=cfg.ssm_state,
                            headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
                            conv_width=cfg.conv_width, dtype=dtype)
    elif kind == "hybrid":
        p["attn"] = init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, cfg.qkv_bias, cfg.qk_norm,
                                   dtype)
        p["ssm"] = init_ssm(ks[1], d, state=cfg.ssm_state,
                            headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
                            conv_width=cfg.conv_width, dtype=dtype)
        p["mlp"] = init_gated_mlp(ks[2], d, cfg.d_ff, dtype)
    elif kind == "cross":
        p["attn"] = init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, cfg.qkv_bias, cfg.qk_norm,
                                   dtype)
        p["mlp"] = (init_gelu_mlp(ks[1], d, cfg.d_ff, dtype)
                    if cfg.norm == "ln" else
                    init_gated_mlp(ks[1], d, cfg.d_ff, dtype))
        p["gate"] = jnp.zeros((), F32)   # tanh-gated cross-attn (llama-vision)
    elif kind == "dec":
        p["attn"] = init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, cfg.qkv_bias, cfg.qk_norm,
                                   dtype)
        p["xattn"] = init_attention(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim, cfg.qkv_bias, cfg.qk_norm,
                                    dtype)
        p["norm3"] = init_norm(cfg, dtype)
        p["mlp"] = init_gelu_mlp(ks[2], d, cfg.d_ff, dtype)
    else:
        raise ValueError(kind)
    return p


# --------------------------------------------------------------------------
# Full-sequence (train / prefill) blocks.  Each returns (x, cache_entry).
# --------------------------------------------------------------------------


def fwd_dense(params, x, positions, cfg: ArchConfig, *, q_chunk, kv_chunk,
              causal=True):
    h, kv = attention_layer(params["attn"], _norm(cfg, params, x, "norm1"),
                            positions, n_heads=cfg.n_heads,
                            rope_theta=cfg.rope_theta,
                            window=cfg.sliding_window,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    x = x + checkpoint_name(h, "sublayer_out")
    mlp = gelu_mlp if cfg.norm == "ln" else gated_mlp
    x = x + checkpoint_name(mlp(params["mlp"], _norm(cfg, params, x, "norm2")), "sublayer_out")
    return x, kv


def fwd_moe(params, x, positions, cfg: ArchConfig, *, q_chunk, kv_chunk):
    h, kv = attention_layer(params["attn"], _norm(cfg, params, x, "norm1"),
                            positions, n_heads=cfg.n_heads,
                            rope_theta=cfg.rope_theta,
                            window=cfg.sliding_window,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    x = x + checkpoint_name(h, "sublayer_out")
    y, aux = moe_layer(params["moe"], _norm(cfg, params, x, "norm2"),
                       n_experts=cfg.n_experts, top_k=cfg.top_k)
    return x + checkpoint_name(y, "sublayer_out"), (kv, aux)


def fwd_ssm(params, x, cfg: ArchConfig, *, ssd_chunk, use_pallas=None):
    h = ssm_layer(params["ssm"], _norm(cfg, params, x, "norm1"),
                  state=cfg.ssm_state, headdim=cfg.ssm_headdim,
                  expand=cfg.ssm_expand, chunk=ssd_chunk,
                  use_pallas=use_pallas)
    return x + checkpoint_name(h, "sublayer_out")


def fwd_hybrid(params, x, positions, cfg: ArchConfig, *, q_chunk, kv_chunk,
               ssd_chunk, use_pallas=None):
    xn = _norm(cfg, params, x, "norm1")
    ha, kv = attention_layer(params["attn"], xn, positions,
                             n_heads=cfg.n_heads, rope_theta=cfg.rope_theta,
                             window=cfg.sliding_window,
                             q_chunk=q_chunk, kv_chunk=kv_chunk)
    hs = ssm_layer(params["ssm"], xn, state=cfg.ssm_state,
                   headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
                   chunk=ssd_chunk, use_pallas=use_pallas)
    x = x + checkpoint_name(0.5 * (ha + hs), "sublayer_out")
    x = x + checkpoint_name(gated_mlp(params["mlp"], _norm(cfg, params, x, "norm2")), "sublayer_out")
    return x, kv


def fwd_cross(params, x, img_kv, cfg: ArchConfig, *, q_chunk, kv_chunk):
    h, _ = attention_layer(params["attn"], _norm(cfg, params, x, "norm1"),
                           positions=None, n_heads=cfg.n_heads,
                           rope_theta=0.0, q_chunk=q_chunk,
                           kv_chunk=kv_chunk, cross_kv=img_kv)
    x = x + jnp.tanh(params["gate"]).astype(x.dtype) * h
    mlp = gelu_mlp if cfg.norm == "ln" else gated_mlp
    x = x + mlp(params["mlp"], _norm(cfg, params, x, "norm2"))
    return x


def fwd_dec(params, x, positions, enc_kv, cfg: ArchConfig, *, q_chunk,
            kv_chunk):
    h, kv = attention_layer(params["attn"], _norm(cfg, params, x, "norm1"),
                            positions, n_heads=cfg.n_heads,
                            rope_theta=cfg.rope_theta,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    x = x + h
    h, _ = attention_layer(params["xattn"], _norm(cfg, params, x, "norm2"),
                           positions=None, n_heads=cfg.n_heads,
                           rope_theta=0.0, q_chunk=q_chunk,
                           kv_chunk=kv_chunk, cross_kv=enc_kv)
    x = x + h
    x = x + gelu_mlp(params["mlp"], _norm(cfg, params, x, "norm3"))
    return x, kv


# --------------------------------------------------------------------------
# Decode blocks (one token).  Each returns (x, new_cache_entry).
# --------------------------------------------------------------------------


def dec_dense(params, x, cache, cfg: ArchConfig):
    h, new_cache = decode_step_attention(
        params["attn"], _norm(cfg, params, x, "norm1"), cache,
        n_heads=cfg.n_heads, rope_theta=cfg.rope_theta,
        window=cfg.sliding_window)
    x = x + h
    mlp = gelu_mlp if cfg.norm == "ln" else gated_mlp
    x = x + mlp(params["mlp"], _norm(cfg, params, x, "norm2"))
    return x, new_cache


def dec_moe(params, x, cache, cfg: ArchConfig):
    h, new_cache = decode_step_attention(
        params["attn"], _norm(cfg, params, x, "norm1"), cache,
        n_heads=cfg.n_heads, rope_theta=cfg.rope_theta,
        window=cfg.sliding_window)
    x = x + h
    y, _ = moe_layer(params["moe"], _norm(cfg, params, x, "norm2"),
                     n_experts=cfg.n_experts, top_k=cfg.top_k)
    return x + y, new_cache


def dec_ssm(params, x, cache, cfg: ArchConfig):
    h, new_cache = decode_step_ssm(
        params["ssm"], _norm(cfg, params, x, "norm1"), cache,
        state=cfg.ssm_state, headdim=cfg.ssm_headdim, expand=cfg.ssm_expand)
    return x + h, new_cache


def dec_hybrid(params, x, cache, cfg: ArchConfig):
    xn = _norm(cfg, params, x, "norm1")
    ha, attn_cache = decode_step_attention(
        params["attn"], xn, cache["attn"], n_heads=cfg.n_heads,
        rope_theta=cfg.rope_theta, window=cfg.sliding_window)
    hs, ssm_cache = decode_step_ssm(
        params["ssm"], xn, cache["ssm"], state=cfg.ssm_state,
        headdim=cfg.ssm_headdim, expand=cfg.ssm_expand)
    x = x + 0.5 * (ha + hs)
    x = x + gated_mlp(params["mlp"], _norm(cfg, params, x, "norm2"))
    return x, {"attn": attn_cache, "ssm": ssm_cache}


def dec_cross(params, x, img_cache, cfg: ArchConfig):
    h = decode_cross_attention(params["attn"],
                               _norm(cfg, params, x, "norm1"), img_cache)
    x = x + jnp.tanh(params["gate"]).astype(x.dtype) * h
    mlp = gelu_mlp if cfg.norm == "ln" else gated_mlp
    x = x + mlp(params["mlp"], _norm(cfg, params, x, "norm2"))
    return x


def dec_dec(params, x, cache, enc_cache, cfg: ArchConfig):
    h, new_cache = decode_step_attention(
        params["attn"], _norm(cfg, params, x, "norm1"), cache,
        n_heads=cfg.n_heads, rope_theta=cfg.rope_theta)
    x = x + h
    h = decode_cross_attention(params["xattn"],
                               _norm(cfg, params, x, "norm2"), enc_cache)
    x = x + h
    x = x + gelu_mlp(params["mlp"], _norm(cfg, params, x, "norm3"))
    return x, new_cache
