"""Mixture-of-Experts FFN — expert dispatch/combine as *grouped
aggregation* (the paper's 𝒢_{AggΔ} over the expert key), shardable over the
``model`` axis (EP).

Sort-based capacity dispatch (static shapes):
  1. router scores → top-k experts per token;
  2. (token, expert) assignments sorted by expert — exactly the
     sort-before-segment step of the grouped executor;
  3. rank-within-expert positions scatter tokens into an (E, C) grid
     (capacity C, overflow dropped — standard GShard/Switch semantics);
  4. per-expert FFN batched einsum over (E, C, d) with E sharded (EP);
  5. combine = weighted segment-sum back to token order.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .layers import F32

PyTree = Any


def init_moe(key, d: int, ff: int, n_experts: int,
             dtype=jnp.bfloat16) -> PyTree:
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_ff = 1.0 / math.sqrt(ff)
    return {
        "router": (jax.random.normal(ks[0], (d, n_experts), F32) * s_in).astype(F32),
        "w_gate": (jax.random.normal(ks[1], (n_experts, d, ff), F32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (n_experts, d, ff), F32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (n_experts, ff, d), F32) * s_ff).astype(dtype),
    }


def moe_layer(params: PyTree, x: jax.Array, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25) -> tuple[jax.Array, jax.Array]:
    """x (B,S,d) → (y (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(F32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (T,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)            # (T,k)
    if top_k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                 # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], n_experts, dtype=F32), axis=0)
    aux = n_experts * jnp.sum(me * ce)

    # ---- grouped-aggregation dispatch (sort by expert) --------------------
    a = t * top_k
    flat_expert = gate_idx.reshape(a)                            # (A,)
    flat_token = jnp.repeat(jnp.arange(t), top_k)
    flat_gate = gate_vals.reshape(a)

    order = jnp.argsort(flat_expert)
    se, stok, sg = (jnp.take(flat_expert, order), jnp.take(flat_token, order),
                    jnp.take(flat_gate, order))

    # rank within expert group
    same = jnp.concatenate([jnp.array([False]), se[1:] == se[:-1]])
    seg_start = jnp.where(~same, jnp.arange(a), 0)
    start_of = jax.ops.segment_max(seg_start, se, num_segments=n_experts)
    rank = jnp.arange(a) - jnp.take(start_of, se)

    capacity = max(1, int(capacity_factor * a / n_experts))
    keep = rank < capacity
    slot = se * capacity + rank                                  # (A,)
    slot = jnp.where(keep, slot, n_experts * capacity)           # overflow bin

    # scatter token ids / gates into the (E*C [+1]) grid
    grid_tok = jnp.full((n_experts * capacity + 1,), t, jnp.int32) \
        .at[slot].set(stok.astype(jnp.int32), mode="drop")
    grid_gate = jnp.zeros((n_experts * capacity + 1,), F32) \
        .at[slot].set(sg, mode="drop")
    grid_tok = grid_tok[:-1].reshape(n_experts, capacity)
    grid_gate = grid_gate[:-1].reshape(n_experts, capacity)
    grid_ok = grid_tok < t

    # gather tokens: (E, C, d) — E sharded over "model" (EP)
    xe = jnp.take(xt, jnp.clip(grid_tok, 0, t - 1), axis=0)
    xe = jnp.where(grid_ok[..., None], xe, 0)

    # per-expert gated FFN (batched over E)
    h = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"],
                   preferred_element_type=F32)
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"],
                   preferred_element_type=F32)
    act = (jax.nn.silu(h) * u).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", act, params["w_down"],
                    preferred_element_type=F32)                  # (E,C,d) f32

    # ---- combine: weighted segment-sum back to tokens ----------------------
    ye = ye * grid_gate[..., None]
    flat_out_tok = jnp.where(grid_ok, grid_tok, t).reshape(-1)
    y = jax.ops.segment_sum(ye.reshape(-1, d), flat_out_tok,
                            num_segments=t + 1)[:t]
    return y.reshape(b, s, d).astype(x.dtype), aux
