"""Blockwise (flash-style) attention in pure JAX with a custom VJP.

Forward: online-softmax accumulation over KV chunks (the Aggregate of the
paper's contract, on the sequence axis).  Saves only (out, m, l) per
position — O(S·D) residuals instead of O(S²) logits.

Backward: the standard two-pass recompute —
  pass A: per q-block, rescan KV to rebuild p and accumulate dq;
  pass B: per kv-block, rescan Q to accumulate dk, dv.

GQA-aware: q (B,S,H,D) groups over kv (B,S,Hkv,D) without materializing the
H-expanded KV.  Sliding-window masking composes with the causal mask.

This is the TRAIN/PREFILL execution plan that the dry-run lowers; on real
TPUs the inner block math maps 1:1 onto an MXU kernel (and the decode-side
twin IS a Pallas kernel: kernels/decode_attn.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def _block_mask(q_pos, kv_pos, causal: bool, window: int, s_kv: int):
    mask = (kv_pos < s_kv)[None, :]
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
        if window:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 1024):
    """q (B,S,H,D); k,v (B,Skv,Hkv,D) → out (B,S,H,D)."""
    out, _ = _flash_fwd(q, k, v, causal, window, q_chunk, kv_chunk)
    return out


def _pad_blocks(x, chunk, axis=1):
    s = x.shape[axis]
    n = -(-s // chunk)
    pad = n * chunk - s
    if pad:
        cfg = [(0, 0)] * x.ndim
        cfg[axis] = (0, pad)
        x = jnp.pad(x, cfg)
    return x, n


def _flash_fwd(q, k, v, causal, window, q_chunk, kv_chunk):
    b, s, h, d = q.shape
    s_kv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s_kv)

    qp, nq = _pad_blocks(q, q_chunk)
    kp, nkv = _pad_blocks(k, kv_chunk)
    vp, _ = _pad_blocks(v, kv_chunk)

    qb = qp.reshape(b, nq, q_chunk, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kb = kp.reshape(b, nkv, kv_chunk, hkv, d).transpose(1, 0, 3, 2, 4)
    vb = vp.reshape(b, nkv, kv_chunk, hkv, d).transpose(1, 0, 3, 2, 4)
    # qb (nq, B, Hkv, G, qc, D); kb/vb (nkv, B, Hkv, kc, D)

    q_pos = jnp.arange(nq * q_chunk).reshape(nq, q_chunk)
    kv_pos = jnp.arange(nkv * kv_chunk).reshape(nkv, kv_chunk)

    def q_block(qi, q_posi):
        def kv_step(carry, inp):
            m, l, acc = carry
            ki, vi, kv_posi = inp
            logits = jnp.einsum("bhgqd,bhkd->bhgqk", qi, ki,
                                preferred_element_type=F32) * scale
            mask = _block_mask(q_posi, kv_posi, causal, window, s_kv)
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vi.dtype), vi,
                            preferred_element_type=F32)
            return (m_new, l_new, acc * alpha[..., None] + pv), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, F32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), F32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, d), F32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, kv_pos))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return o.astype(q.dtype), m + jnp.log(jnp.maximum(l, 1e-30))

    ob, lse_b = jax.lax.map(lambda args: q_block(*args), (qb, q_pos))
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * q_chunk, h, d)[:, :s]
    # lse (nq, B, Hkv, G, qc) — saved for backward
    return out, (q, k, v, out, lse_b)


def _flash_bwd(causal, window, q_chunk, kv_chunk, res, dout):
    q, k, v, out, lse_b = res
    b, s, h, d = q.shape
    s_kv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s_kv)

    qp, nq = _pad_blocks(q, q_chunk)
    kp, nkv = _pad_blocks(k, kv_chunk)
    vp, _ = _pad_blocks(v, kv_chunk)
    dop, _ = _pad_blocks(dout, q_chunk)
    outp, _ = _pad_blocks(out, q_chunk)

    qb = qp.reshape(b, nq, q_chunk, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kb = kp.reshape(b, nkv, kv_chunk, hkv, d).transpose(1, 0, 3, 2, 4)
    vb = vp.reshape(b, nkv, kv_chunk, hkv, d).transpose(1, 0, 3, 2, 4)
    dob = dop.reshape(b, nq, q_chunk, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    outb = outp.reshape(b, nq, q_chunk, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)

    q_pos = jnp.arange(nq * q_chunk).reshape(nq, q_chunk)
    kv_pos = jnp.arange(nkv * kv_chunk).reshape(nkv, kv_chunk)

    # D_i = rowsum(dout * out)  (per query position)
    delta = jnp.sum(dob.astype(F32) * outb.astype(F32), axis=-1)  # (nq,B,Hkv,G,qc)

    def p_block(qi, ki, lse, q_posi, kv_posi):
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qi, ki,
                            preferred_element_type=F32) * scale
        mask = _block_mask(q_posi, kv_posi, causal, window, s_kv)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        return jnp.exp(logits - lse[..., None])          # (B,Hkv,G,qc,kc)

    # ---- pass A: dq -------------------------------------------------------
    def dq_block(args):
        qi, doi, lse, dlt, q_posi = args

        def kv_step(dq_acc, inp):
            ki, vi, kv_posi = inp
            p = p_block(qi, ki, lse, q_posi, kv_posi)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", doi.astype(F32),
                            vi.astype(F32), preferred_element_type=F32)
            ds = p * (dp - dlt[..., None]) * scale
            dq_acc += jnp.einsum("bhgqk,bhkd->bhgqd", ds, ki.astype(F32),
                                 preferred_element_type=F32)
            return dq_acc, None

        dq0 = jnp.zeros((b, hkv, g, q_chunk, d), F32)
        dq, _ = jax.lax.scan(kv_step, dq0, (kb, vb, kv_pos))
        return dq

    dqb = jax.lax.map(dq_block, (qb, dob, lse_b, delta, q_pos))
    dq = dqb.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * q_chunk, h, d)[:, :s]

    # ---- pass B: dk, dv ---------------------------------------------------
    def dkv_block(args):
        ki, vi, kv_posi = args

        def q_step(carry, inp):
            dk_acc, dv_acc = carry
            qi, doi, lse, dlt, q_posi = inp
            p = p_block(qi, ki, lse, q_posi, kv_posi)
            dv_acc += jnp.einsum("bhgqk,bhgqd->bhkd", p, doi.astype(F32),
                                 preferred_element_type=F32)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", doi.astype(F32),
                            vi.astype(F32), preferred_element_type=F32)
            ds = p * (dp - dlt[..., None]) * scale
            dk_acc += jnp.einsum("bhgqk,bhgqd->bhkd", ds, qi.astype(F32),
                                 preferred_element_type=F32)
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, hkv, kv_chunk, d), F32)
        (dk, dv), _ = jax.lax.scan(q_step, (z, z),
                                   (qb, dob, lse_b, delta, q_pos))
        return dk, dv

    dkb, dvb = jax.lax.map(dkv_block, (kb, vb, kv_pos))
    dk = dkb.transpose(1, 0, 3, 2, 4).reshape(b, nkv * kv_chunk, hkv, d)[:, :s_kv]
    dv = dvb.transpose(1, 0, 3, 2, 4).reshape(b, nkv * kv_chunk, hkv, d)[:, :s_kv]

    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention.defvjp(
    lambda q, k, v, causal, window, qc, kc: _flash_fwd(q, k, v, causal,
                                                       window, qc, kc),
    _flash_bwd)
