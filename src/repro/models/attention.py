"""GQA attention — flash (blockwise) causal/SWA prefill and
aggregate-contract decode.

The online-softmax state (m, l, acc) is a paper-contract ``Aggregate``
(``softmax_aggregate``): prefill accumulates over KV chunks (models/flash.py)
and sequence-parallel decode merges per-shard partials with its Merge —
Aggify's chunked/sharded execution on the sequence axis.  The Pallas twin of
the decode path is ``repro.kernels.decode_attn``.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.aggregate import Aggregate

from .flash import flash_attention
from .layers import F32, apply_rope, rms_norm

PyTree = Any
NEG_INF = -1e30


def init_attention(key, d: int, n_heads: int, n_kv: int, d_head: int,
                   qkv_bias: bool, qk_norm: bool, dtype=jnp.bfloat16) -> PyTree:
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(n_heads * d_head)
    p = {
        "wq": (jax.random.normal(ks[0], (d, n_heads, d_head), F32) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, n_kv, d_head), F32) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, n_kv, d_head), F32) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads, d_head, d), F32) * so).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, d_head), dtype)
        p["bk"] = jnp.zeros((n_kv, d_head), dtype)
        p["bv"] = jnp.zeros((n_kv, d_head), dtype)
    if qk_norm:
        p["q_norm"] = jnp.ones((d_head,), dtype)
        p["k_norm"] = jnp.ones((d_head,), dtype)
    return p


def project_qkv(params, x, positions, rope_theta):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"],
                   preferred_element_type=F32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"],
                   preferred_element_type=F32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"],
                   preferred_element_type=F32).astype(x.dtype)
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def attention_layer(params: PyTree, x: jax.Array, positions: jax.Array, *,
                    n_heads: int, rope_theta: float = 1e4, window: int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    cross_kv: Optional[tuple] = None, causal: bool = True,
                    ) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Full-sequence attention (train / prefill).  Returns (y, (k, v))."""
    if cross_kv is None:
        q, k, v = project_qkv(params, x, positions, rope_theta)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"],
                       preferred_element_type=F32).astype(x.dtype)
        if "q_norm" in params:
            q = rms_norm(q, params["q_norm"])
        k, v = cross_kv
        causal = False
    out = flash_attention(q, k, v, causal, window, q_chunk, kv_chunk)
    y = jnp.einsum("bshd,hdo->bso", out, params["wo"],
                   preferred_element_type=F32).astype(x.dtype)
    return y, (k, v)


def project_cross_kv(params: PyTree, ctx: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Precompute the K/V of a cross-attention context (encoder output or
    image embeddings)."""
    k = jnp.einsum("bsd,dhk->bshk", ctx, params["wk"],
                   preferred_element_type=F32).astype(ctx.dtype)
    v = jnp.einsum("bsd,dhk->bshk", ctx, params["wv"],
                   preferred_element_type=F32).astype(ctx.dtype)
    if "k_norm" in params:
        k = rms_norm(k, params["k_norm"])
    return k, v


# --------------------------------------------------------------------------
# Decode — the aggregate path
# --------------------------------------------------------------------------


def softmax_aggregate(d_head: int) -> Aggregate:
    """Online-softmax as the paper's Init/Accumulate/Merge/Terminate; used
    by tests and by sequence-parallel shard merges."""
    def init():
        return {"m": jnp.full((), NEG_INF, F32), "l": jnp.zeros((), F32),
                "acc": jnp.zeros((d_head,), F32)}

    def accumulate(state, row):
        m_new = jnp.maximum(state["m"], row["s"])
        alpha = jnp.exp(state["m"] - m_new)
        p = jnp.exp(row["s"] - m_new)
        return {"m": m_new,
                "l": state["l"] * alpha + p,
                "acc": state["acc"] * alpha + p * row["v"].astype(F32)}

    def merge(a, b):
        m = jnp.maximum(a["m"], b["m"])
        aa, ab = jnp.exp(a["m"] - m), jnp.exp(b["m"] - m)
        return {"m": m, "l": a["l"] * aa + b["l"] * ab,
                "acc": a["acc"] * aa + b["acc"] * ab}

    def terminate(state):
        return state["acc"] / jnp.maximum(state["l"], 1e-30)

    return Aggregate("online_softmax", init, accumulate, terminate,
                     merge=merge, identity=init)


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    b, s, hkv, d = k.shape
    g = n_heads // hkv
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, g, d)) \
        .reshape(b, s, n_heads, d)


def decode_attention_jnp(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         kv_len: jax.Array) -> jax.Array:
    """q (B,H,D); caches (B,S,Hkv,D); kv_len (B,) → (B,H,D).

    Flash-decode in jnp (fp32 softmax); with the cache S axis sharded, the
    partitioner emits the partial-softmax combine over ICI — the aggregate
    Merge.  Pallas twin: kernels/decode_attn.py."""
    b, h, d = q.shape
    s = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                        preferred_element_type=F32) / math.sqrt(d)
    ok = jnp.arange(s)[None, None, None, :] < kv_len[:, None, None, None]
    logits = jnp.where(ok, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    w = (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v_cache,
                     preferred_element_type=F32)
    return out.reshape(b, h, d).astype(q.dtype)


def decode_step_attention(params: PyTree, x: jax.Array, cache: PyTree, *,
                          n_heads: int, rope_theta: float = 1e4,
                          window: int = 0) -> tuple[jax.Array, PyTree]:
    """One-token decode.  x (B,1,d).  cache {"k","v" (B,S,Hkv,D),
    "len" (B,)} — S == window for SWA archs (ring buffer, absolute-RoPE
    keys stored)."""
    pos = cache["len"][:, None]
    q, k, v = project_qkv(params, x, pos, rope_theta)
    cap = cache["k"].shape[1]
    slot = cache["len"] % cap if window else jnp.minimum(cache["len"], cap - 1)
    kc = _scatter_rows(cache["k"], slot, k)
    vc = _scatter_rows(cache["v"], slot, v)
    new_len = cache["len"] + 1
    eff = jnp.minimum(new_len, cap)
    out = decode_attention_jnp(q[:, 0], kc, vc, eff)
    y = jnp.einsum("bhd,hdo->bo", out, params["wo"],
                   preferred_element_type=F32).astype(x.dtype)
    return y[:, None, :], {"k": kc, "v": vc, "len": new_len}


def decode_cross_attention(params: PyTree, x: jax.Array,
                           cross_cache: PyTree) -> jax.Array:
    """Cross-attention during decode: static encoder KV, no cache update."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"],
                   preferred_element_type=F32).astype(x.dtype)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"])
    out = decode_attention_jnp(q[:, 0], cross_cache["k"], cross_cache["v"],
                               cross_cache["len"])
    y = jnp.einsum("bhd,hdo->bo", out, params["wo"],
                   preferred_element_type=F32).astype(x.dtype)
    return y[:, None, :]


def _scatter_rows(cache: jax.Array, slot: jax.Array, new: jax.Array) -> jax.Array:
    """cache (B,S,H,D); slot (B,); new (B,1,H,D)."""
    s = cache.shape[1]
    onehot = jax.nn.one_hot(slot, s, dtype=cache.dtype)          # (B,S)
    return cache * (1 - onehot)[:, :, None, None] + \
        onehot[:, :, None, None] * new
