"""Foundational model layers as pure functions over parameter pytrees.

Conventions:
  * params are nested dicts of jnp arrays (bf16 storage by default);
  * matmuls accumulate in fp32 (``preferred_element_type``);
  * every layer ships an ``init_*`` returning concrete arrays — the dry-run
    obtains shapes via ``jax.eval_shape`` so no memory is allocated.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any
F32 = jnp.float32


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w, preferred_element_type=F32)
    if b is not None:
        y = y + b
    return y.astype(x.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(F32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(F32)).astype(x.dtype)


def init_rms_norm(d: int, dtype=jnp.bfloat16) -> jax.Array:
    return jnp.ones((d,), dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(F32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(F32) + bias.astype(F32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float = 1e4) -> jax.Array:
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e4) -> jax.Array:
    """x (..., S, H, D); positions (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    ang = positions[..., None].astype(F32) * freqs          # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP (gated SiLU — llama family) and GeLU (whisper)
# --------------------------------------------------------------------------


def gated_mlp(params: PyTree, x: jax.Array) -> jax.Array:
    h = dense(x, params["w_gate"])
    g = jax.nn.silu(h.astype(F32)).astype(x.dtype)
    u = dense(x, params["w_up"])
    return dense(g * u, params["w_down"])


def init_gated_mlp(key, d: int, ff: int, dtype=jnp.bfloat16) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_ff = 1.0 / math.sqrt(ff)
    return {
        "w_gate": (jax.random.normal(k1, (d, ff), F32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, ff), F32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (ff, d), F32) * s_ff).astype(dtype),
    }


def gelu_mlp(params: PyTree, x: jax.Array) -> jax.Array:
    h = dense(x, params["w_in"], params.get("b_in"))
    g = jax.nn.gelu(h.astype(F32)).astype(x.dtype)
    return dense(g, params["w_out"], params.get("b_out"))


def init_gelu_mlp(key, d: int, ff: int, dtype=jnp.bfloat16) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": (jax.random.normal(k1, (d, ff), F32) / math.sqrt(d)).astype(dtype),
        "b_in": jnp.zeros((ff,), dtype),
        "w_out": (jax.random.normal(k2, (ff, d), F32) / math.sqrt(ff)).astype(dtype),
        "b_out": jnp.zeros((d,), dtype),
    }


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------


def embed(params: PyTree, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params: PyTree, x: jax.Array) -> jax.Array:
    """Returns fp32 logits."""
    w = params.get("unembedding", params["embedding"])
    return jnp.einsum("...d,vd->...v", x, w, preferred_element_type=F32)


def init_embed(key, vocab: int, d: int, tie: bool,
               dtype=jnp.bfloat16) -> PyTree:
    k1, k2 = jax.random.split(key)
    p = {"embedding": (jax.random.normal(k1, (vocab, d), F32) * 0.01).astype(dtype)}
    if not tie:
        p["unembedding"] = (jax.random.normal(k2, (vocab, d), F32) * 0.01).astype(dtype)
    return p
