"""The unified LM: config-driven assembly of the per-family blocks, with
scan-over-layers (stacked params), per-layer remat, train/prefill/decode
entry points, and modality-frontend stubs (``[audio]``/``[vlm]`` configs
receive precomputed frame/patch embeddings per the assignment)."""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig

from . import blocks as B
from .attention import project_cross_kv
from .layers import F32, embed, init_embed, layer_norm, rms_norm, unembed

PyTree = Any


def _sincos_positions(s: int, d: int, offset=0) -> jax.Array:
    pos = (jnp.arange(s) + offset)[:, None].astype(F32)
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=F32) / half)
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


@dataclass
class LM:
    cfg: ArchConfig
    q_chunk: int = 1024
    kv_chunk: int = 1024
    ssd_chunk: int = 64
    remat: bool = True
    use_pallas: Optional[bool] = None
    moe_aux_coef: float = 0.01
    dtype: Any = jnp.bfloat16
    vocab_pad_multiple: int = 128   # pad vocab so TP can shard it (Megatron
                                    # convention); padded logits are masked
                                    # to -inf in loss/decode.
    pad_heads_multiple: int = 0     # pad attention heads so TP can shard
                                    # them (zero-weight pad heads — exact
                                    # function preservation; §Perf).
    remat_policy: str = "full"      # full | save_sublayer.  save_sublayer
                                    # keeps each sublayer's post-all-reduce
                                    # output: backward skips re-running the
                                    # forward TP collectives (≈1/3 of the
                                    # per-layer AR traffic) for ~2 residual-
                                    # stream activations per layer of HBM.

    def __post_init__(self):
        import dataclasses as _dc
        self.logical_cfg = self.cfg
        self._head_pad = None
        m = self.pad_heads_multiple
        cfg = self.cfg
        if m and cfg.n_heads and cfg.n_heads % m:
            g = cfg.n_heads // max(cfg.n_kv_heads, 1)
            hp = cfg.n_heads
            while hp % m or hp % g:
                hp += 1
            self._head_pad = (cfg.n_heads, cfg.n_kv_heads)
            self.cfg = _dc.replace(cfg, n_heads=hp, n_kv_heads=hp // g)

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.cfg.vocab // m) * m

    def _mask_pad_logits(self, logits: jax.Array) -> jax.Array:
        v = self.cfg.vocab
        if self.vocab_padded == v:
            return logits
        keep = jnp.arange(self.vocab_padded) < v
        return jnp.where(keep, logits, -1e30)

    # ---------------------------------------------------------------------
    # init
    # ---------------------------------------------------------------------

    def _block_kind(self) -> str:
        return {"dense": "dense", "moe": "moe", "ssm": "ssm",
                "hybrid": "hybrid"}.get(self.cfg.family, "")

    def init(self, key) -> PyTree:
        cfg = self.cfg
        k_emb, k_blocks, k_final = jax.random.split(key, 3)
        params: dict[str, Any] = {
            "embed": init_embed(k_emb, self.vocab_padded, cfg.d_model,
                                cfg.tie_embeddings, self.dtype),
            "final_norm": B.init_norm(cfg, self.dtype),
        }
        if cfg.family == "vlm":
            n_groups = cfg.n_layers // cfg.cross_attn_every
            n_self = cfg.cross_attn_every - 1
            kg = jax.random.split(k_blocks, n_groups)

            def group(k):
                k1, k2 = jax.random.split(k)
                selfs = jax.vmap(lambda kk: B.init_block(kk, cfg, "dense",
                                                         self.dtype))(
                    jax.random.split(k1, n_self))
                cross = B.init_block(k2, cfg, "cross", self.dtype)
                return {"selfs": selfs, "cross": cross}

            params["groups"] = jax.vmap(group)(kg)
        elif cfg.family == "audio":
            ke, kd = jax.random.split(k_blocks)
            params["enc_blocks"] = jax.vmap(
                lambda kk: B.init_block(kk, cfg, "enc", self.dtype))(
                jax.random.split(ke, cfg.enc_layers))
            params["dec_blocks"] = jax.vmap(
                lambda kk: B.init_block(kk, cfg, "dec", self.dtype))(
                jax.random.split(kd, cfg.n_layers))
            params["enc_final_norm"] = B.init_norm(cfg, self.dtype)
        else:
            kind = self._block_kind()
            params["blocks"] = jax.vmap(
                lambda kk: B.init_block(kk, cfg, kind, self.dtype))(
                jax.random.split(k_blocks, cfg.n_layers))
        if self._head_pad:
            params = self._zero_pad_heads(params)
        return params

    def _zero_pad_heads(self, params: PyTree) -> PyTree:
        """Zero the padded head slices so the padded model computes the
        EXACT same function: wq/bq pad columns → q ≡ 0 in pad heads; wo
        pad rows → their output contribution ≡ 0."""
        h0, kv0 = self._head_pad

        def zero_from(arr, axis, start):
            n = arr.shape[axis]
            if start >= n:
                return arr
            keep = (jnp.arange(n) < start)
            shape = [1] * arr.ndim
            shape[axis] = n
            return arr * keep.reshape(shape).astype(arr.dtype)

        def visit(path, leaf):
            key = str(getattr(path[-1], "key", ""))
            if key in ("wq", "bq"):
                return zero_from(leaf, leaf.ndim - 2, h0)
            if key in ("wk", "wv", "bk", "bv"):
                return zero_from(leaf, leaf.ndim - 2, kv0)
            if key == "wo":
                return zero_from(leaf, leaf.ndim - 3, h0)
            return leaf

        return jax.tree_util.tree_map_with_path(visit, params)

    # ---------------------------------------------------------------------
    # forward (train / prefill body)
    # ---------------------------------------------------------------------

    def _maybe_remat(self, fn):
        if not self.remat:
            return fn
        if self.remat_policy == "save_sublayer":
            policy = jax.checkpoint_policies.save_only_these_names(
                "sublayer_out")
            return jax.checkpoint(fn, policy=policy)
        return jax.checkpoint(fn)

    def forward(self, params: PyTree, tokens: jax.Array, *,
                img_ctx: Optional[jax.Array] = None,
                frames: Optional[jax.Array] = None,
                collect_cache: bool = False):
        """tokens (B,S) → (logits (B,S,V) f32, aux, caches|None)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens).astype(self.dtype)
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.rope_theta == 0.0:  # absolute sinusoidal (whisper)
            x = x + _sincos_positions(s, cfg.d_model).astype(x.dtype)[None]
        aux = jnp.zeros((), F32)
        caches = None

        if cfg.family == "vlm":
            x = self._vlm_stack(params, x, positions, img_ctx)
        elif cfg.family == "audio":
            enc_out = self._audio_encoder(params, frames)
            x, caches = self._audio_decoder(params, x, positions, enc_out,
                                            collect_cache)
        else:
            x, aux, caches = self._uniform_stack(params, x, positions,
                                                 collect_cache)

        x = (layer_norm(x, params["final_norm"]["scale"],
                        params["final_norm"]["bias"])
             if cfg.norm == "ln" else rms_norm(x, params["final_norm"]))
        logits = unembed(params["embed"], x)
        return logits, aux, caches

    def _uniform_stack(self, params, x, positions, collect_cache):
        cfg = self.cfg
        kind = self._block_kind()

        def body(carry, layer_params):
            x, aux = carry
            if kind == "dense":
                x, kv = B.fwd_dense(layer_params, x, positions, cfg,
                                    q_chunk=self.q_chunk,
                                    kv_chunk=self.kv_chunk)
                out = kv if collect_cache else None
            elif kind == "moe":
                x, (kv, a) = B.fwd_moe(layer_params, x, positions, cfg,
                                       q_chunk=self.q_chunk,
                                       kv_chunk=self.kv_chunk)
                aux = aux + a
                out = kv if collect_cache else None
            elif kind == "ssm":
                x = B.fwd_ssm(layer_params, x, cfg, ssd_chunk=self.ssd_chunk,
                              use_pallas=self.use_pallas)
                out = None
            else:  # hybrid
                x, kv = B.fwd_hybrid(layer_params, x, positions, cfg,
                                     q_chunk=self.q_chunk,
                                     kv_chunk=self.kv_chunk,
                                     ssd_chunk=self.ssd_chunk,
                                     use_pallas=self.use_pallas)
                out = kv if collect_cache else None
            return (x, aux), out

        (x, aux), caches = lax.scan(self._maybe_remat(body),
                                    (x, jnp.zeros((), F32)),
                                    params["blocks"])
        return x, aux, caches

    def _vlm_stack(self, params, x, positions, img_ctx):
        cfg = self.cfg

        def group(x, gp):
            def self_body(x, lp):
                x, _ = B.fwd_dense(lp, x, positions, cfg,
                                   q_chunk=self.q_chunk,
                                   kv_chunk=self.kv_chunk)
                return x, None
            x, _ = lax.scan(self._maybe_remat(self_body), x, gp["selfs"])
            img_kv = project_cross_kv(gp["cross"]["attn"],
                                      img_ctx.astype(x.dtype))
            x = B.fwd_cross(gp["cross"], x, img_kv, cfg,
                            q_chunk=self.q_chunk, kv_chunk=self.kv_chunk)
            return x, None

        x, _ = lax.scan(self._maybe_remat(group), x, params["groups"])
        return x

    def _audio_encoder(self, params, frames):
        cfg = self.cfg
        x = frames.astype(self.dtype)
        x = x + _sincos_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def body(x, lp):
            h, _ = B.attention_layer(
                lp["attn"], B._norm(cfg, lp, x, "norm1"), positions,
                n_heads=cfg.n_heads, rope_theta=0.0, q_chunk=self.q_chunk,
                kv_chunk=self.kv_chunk, causal=False)
            x = x + h
            x = x + B.gelu_mlp(lp["mlp"], B._norm(cfg, lp, x, "norm2"))
            return x, None

        x, _ = lax.scan(self._maybe_remat(body), x, params["enc_blocks"])
        return (layer_norm(x, params["enc_final_norm"]["scale"],
                           params["enc_final_norm"]["bias"])
                if cfg.norm == "ln"
                else rms_norm(x, params["enc_final_norm"]))

    def _audio_decoder(self, params, x, positions, enc_out, collect_cache):
        cfg = self.cfg

        def body(x, lp):
            enc_kv = project_cross_kv(lp["xattn"], enc_out)
            x, kv = B.fwd_dec(lp, x, positions, enc_kv, cfg,
                              q_chunk=self.q_chunk, kv_chunk=self.kv_chunk)
            return x, kv if collect_cache else None

        x, caches = lax.scan(self._maybe_remat(body), x,
                             params["dec_blocks"])
        return x, caches

    # ---------------------------------------------------------------------
    # loss / train objective
    # ---------------------------------------------------------------------

    def loss(self, params: PyTree, batch: PyTree) -> jax.Array:
        logits, aux, _ = self.forward(
            params, batch["tokens"],
            img_ctx=batch.get("img_ctx"), frames=batch.get("frames"))
        logits = self._mask_pad_logits(logits)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(F32)
        ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + self.moe_aux_coef * aux

    # ---------------------------------------------------------------------
    # decode
    # ---------------------------------------------------------------------

    def init_cache(self, batch: int, cache_len: int, *,
                   img_ctx: Optional[jax.Array] = None,
                   enc_out: Optional[jax.Array] = None,
                   params: Optional[PyTree] = None,
                   start_len=None) -> PyTree:
        """Empty (or pre-aged) caches.  ``start_len`` (B,) models 'a cache
        of seq_len' for the decode dry-run shapes.  SWA archs allocate a
        ring buffer of size window."""
        cfg = self.cfg
        kv, dh = cfg.n_kv_heads, cfg.head_dim
        cap = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
            else cache_len
        ln = (jnp.zeros((batch,), jnp.int32) if start_len is None
              else jnp.broadcast_to(jnp.asarray(start_len, jnp.int32),
                                    (batch,)))

        def attn_cache(n_layers):
            return {"k": jnp.zeros((n_layers, batch, cap, kv, dh), self.dtype),
                    "v": jnp.zeros((n_layers, batch, cap, kv, dh), self.dtype),
                    "len": jnp.broadcast_to(ln[None], (n_layers, batch))}

        def ssm_cache(n_layers):
            d_inner = cfg.ssm_expand * cfg.d_model
            nh = d_inner // cfg.ssm_headdim
            return {"conv": jnp.zeros((n_layers, batch, cfg.conv_width - 1,
                                       d_inner + 2 * cfg.ssm_state), self.dtype),
                    "h": jnp.zeros((n_layers, batch, nh, cfg.ssm_state,
                                    cfg.ssm_headdim), F32)}

        if cfg.family in ("dense", "moe"):
            return {"layers": attn_cache(cfg.n_layers)}
        if cfg.family == "ssm":
            return {"layers": ssm_cache(cfg.n_layers)}
        if cfg.family == "hybrid":
            return {"layers": {"attn": attn_cache(cfg.n_layers),
                               "ssm": ssm_cache(cfg.n_layers)}}
        if cfg.family == "vlm":
            n_groups = cfg.n_layers // cfg.cross_attn_every
            n_self = cfg.cross_attn_every - 1
            img_cache = None
            if img_ctx is not None and params is not None:
                def per_group(gp):
                    k, v = project_cross_kv(gp["cross"]["attn"],
                                            img_ctx.astype(self.dtype))
                    return {"k": k, "v": v,
                            "len": jnp.full((batch,), img_ctx.shape[1],
                                            jnp.int32)}
                img_cache = jax.vmap(per_group)(params["groups"])
            else:
                n_img = cfg.n_img_tokens
                img_cache = {"k": jnp.zeros((n_groups, batch, n_img, kv, dh),
                                            self.dtype),
                             "v": jnp.zeros((n_groups, batch, n_img, kv, dh),
                                            self.dtype),
                             "len": jnp.full((n_groups, batch), n_img,
                                             jnp.int32)}
            selfs = {"k": jnp.zeros((n_groups, n_self, batch, cap, kv, dh),
                                    self.dtype),
                     "v": jnp.zeros((n_groups, n_self, batch, cap, kv, dh),
                                    self.dtype),
                     "len": jnp.broadcast_to(ln[None, None],
                                             (n_groups, n_self, batch))}
            return {"selfs": selfs, "img": img_cache}
        if cfg.family == "audio":
            if enc_out is not None and params is not None:
                def per_layer(lp):
                    k, v = project_cross_kv(lp["xattn"], enc_out)
                    return {"k": k, "v": v,
                            "len": jnp.full((batch,), enc_out.shape[1],
                                            jnp.int32)}
                enc_cache = jax.vmap(per_layer)(params["dec_blocks"])
            else:
                enc_cache = {"k": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq,
                                             kv, dh), self.dtype),
                             "v": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq,
                                             kv, dh), self.dtype),
                             "len": jnp.full((cfg.n_layers, batch),
                                             cfg.enc_seq, jnp.int32)}
            return {"layers": attn_cache(cfg.n_layers), "enc": enc_cache}
        raise ValueError(cfg.family)

    def decode_step(self, params: PyTree, cache: PyTree,
                    tokens: jax.Array) -> tuple[jax.Array, PyTree]:
        """tokens (B,1) → (logits (B,V) f32, new cache)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens).astype(self.dtype)
        if cfg.rope_theta == 0.0:
            if cfg.family == "audio":
                pos0 = cache["layers"]["len"][0]
            else:
                pos0 = cache["layers"]["len"][0]
            x = x + jax.vmap(
                lambda p: _sincos_positions(1, cfg.d_model, p)[0])(
                pos0).astype(x.dtype)[:, None]

        if cfg.family in ("dense", "moe"):
            fn = B.dec_dense if cfg.family == "dense" else B.dec_moe

            def body(x, inp):
                lp, lc = inp
                x, nc = fn(lp, x, lc, cfg)
                return x, nc

            x, new_layers = lax.scan(body, x,
                                     (params["blocks"], cache["layers"]))
            new_cache = {"layers": new_layers}
        elif cfg.family == "ssm":
            def body(x, inp):
                lp, lc = inp
                x, nc = B.dec_ssm(lp, x, lc, cfg)
                return x, nc
            x, new_layers = lax.scan(body, x,
                                     (params["blocks"], cache["layers"]))
            new_cache = {"layers": new_layers}
        elif cfg.family == "hybrid":
            def body(x, inp):
                lp, lc = inp
                x, nc = B.dec_hybrid(lp, x, lc, cfg)
                return x, nc
            x, new_layers = lax.scan(body, x,
                                     (params["blocks"], cache["layers"]))
            new_cache = {"layers": new_layers}
        elif cfg.family == "vlm":
            def group(x, inp):
                gp, sc, ic = inp

                def self_body(x, inp2):
                    lp, lc = inp2
                    x, nc = B.dec_dense(lp, x, lc, cfg)
                    return x, nc

                x, new_sc = lax.scan(self_body, x, (gp["selfs"], sc))
                x = B.dec_cross(gp["cross"], x, ic, cfg)
                return x, new_sc

            x, new_selfs = lax.scan(group, x,
                                    (params["groups"], cache["selfs"],
                                     cache["img"]))
            new_cache = {"selfs": new_selfs, "img": cache["img"]}
        elif cfg.family == "audio":
            def body(x, inp):
                lp, lc, ec = inp
                x, nc = B.dec_dec(lp, x, lc, ec, cfg)
                return x, nc
            x, new_layers = lax.scan(body, x,
                                     (params["dec_blocks"], cache["layers"],
                                      cache["enc"]))
            new_cache = {"layers": new_layers, "enc": cache["enc"]}
        else:
            raise ValueError(cfg.family)

        x = (layer_norm(x, params["final_norm"]["scale"],
                        params["final_norm"]["bias"])
             if cfg.norm == "ln" else rms_norm(x, params["final_norm"]))
        logits = self._mask_pad_logits(unembed(params["embed"], x))[:, 0]
        return logits, new_cache

    def prefill(self, params: PyTree, tokens: jax.Array, *,
                img_ctx=None, frames=None):
        """Prefill: full forward; returns (last-position logits, nothing-
        cached marker).  Cache assembly from prefill outputs is family-
        specific and exercised by the serving example; the dry-run lowers
        this step for the prefill_32k shape."""
        logits, aux, _ = self.forward(params, tokens, img_ctx=img_ctx,
                                      frames=frames, collect_cache=False)
        return logits[:, -1], aux
