"""Mamba-2 (SSD) layer — the paper's *ordered aggregate with associative
Merge*, executed chunked (kernels/ssd_scan.py is the Pallas twin of the
jnp chunked path here).

Layer structure (Mamba-2):
    in_proj -> [z | x | B | C | dt]      (single fused projection)
    conv1d(x)  (causal depthwise, width 4)
    SSD scan over heads: h_t = exp(-softplus(dt_t)·A) h_{t-1} + dt·B_t⊗x_t
    y = C_t·h_t + D·x_t ;  out = out_proj( y * silu(z) )

Decode keeps (conv window, SSD state) as the cache — O(1) per token, the
reason this family RUNS the long_500k shape.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

from .layers import F32, rms_norm
from .shard_ctx import constrain

PyTree = Any


def _ssd_chunked_4d(xh: jax.Array, log_decay: jax.Array, bmat: jax.Array,
                    cmat: jax.Array, chunk: int) -> jax.Array:
    """Chunked SSD keeping (B, S, H, P) layout — B/C projections shared
    across heads (Mamba-2's MQA-style sharing), heads shardable over the
    TP axis.  Folding (B·H) into one dim (the kernel layout) interleaves
    the batch-sharded and head axes and forces the partitioner to reshard
    every SSD tensor (observed: 2.2 TB/device of all-gathers on hymba
    train).  Math identical to kernels/ssd_scan.py.

    xh (B,S,H,P) — dt-folded input; log_decay (B,S,H); bmat/cmat (B,S,N).
    """
    b_sz, s_len, n_heads, p = xh.shape
    n = bmat.shape[-1]
    pad = (-s_len) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    t = s_len + pad
    nc = t // chunk

    xc = xh.reshape(b_sz, nc, chunk, n_heads, p).astype(F32)
    xc = constrain(xc, "dp", None, None, "tp", None)
    lac = log_decay.reshape(b_sz, nc, chunk, n_heads).astype(F32)
    bc = bmat.reshape(b_sz, nc, chunk, n).astype(F32)
    cc = cmat.reshape(b_sz, nc, chunk, n).astype(F32)

    la = jnp.cumsum(lac, axis=2)                      # (B,NC,C,H)
    scores = jnp.einsum("bgtn,bgsn->bgts", cc, bc)    # shared across heads
    rel = la[:, :, :, None, :] - la[:, :, None, :, :]  # (B,NC,C,C,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    decay = jnp.where(causal, jnp.exp(rel), 0.0)
    y_intra = jnp.einsum("bgtsh,bgshp->bgthp",
                         scores[:, :, :, :, None] * decay, xc)

    la_last = la[:, :, -1:, :]                        # (B,NC,1,H)
    w = jnp.exp(la_last - la)                         # (B,NC,C,H)
    chunk_state = jnp.einsum("bgcn,bgch,bgchp->bghnp", bc, w, xc)
    chunk_decay = jnp.exp(la_last[:, :, 0, :])        # (B,NC,H)

    def step(h, inp):
        st, dec, cg, lag = inp
        # h (B,H,N,P); cg (B,C,N); lag (B,C,H)
        y_cross = jnp.einsum("bcn,bhnp->bchp", cg, h) * jnp.exp(lag)[..., None]
        h_new = dec[:, :, None, None] * h + st
        return h_new, y_cross

    h0 = constrain(jnp.zeros((b_sz, n_heads, n, p), F32),
                   "dp", "tp", None, None)
    _, y_cross = jax.lax.scan(
        step, h0,
        (chunk_state.swapaxes(0, 1), chunk_decay.swapaxes(0, 1),
         cc.swapaxes(0, 1), la.swapaxes(0, 1)))
    y = y_intra + y_cross.swapaxes(0, 1)              # (B,NC,C,H,P)
    y = y.reshape(b_sz, t, n_heads, p)[:, :s_len]
    return y


def init_ssm(key, d: int, *, state: int, headdim: int, expand: int,
             conv_width: int, dtype=jnp.bfloat16) -> PyTree:
    """SHARD-ALIGNED projection layout (§Perf iteration 2): z/x/B/C/dt are
    separate weights rather than one fused in_proj.  Slicing a fused
    (d, 2·d_inner+2N+H) projection whose output dim is TP-sharded cuts
    across shard boundaries (boundaries at d_inner etc. are not multiples
    of d_in_proj/16) and forced the partitioner to reshard every SSD input
    (observed: ~230 GB/device of collective-permute+all-reduce per train
    step on mamba2).  Separate weights shard each output dim cleanly; the
    math (a single matmul vs five) is identical up to concatenation."""
    d_inner = expand * d
    n_heads = d_inner // headdim
    ks = jax.random.split(key, 6)  # (indices stable for seeded tests)
    s = 1.0 / math.sqrt(d)
    return {
        # z|x fused INTERLEAVED as (d, 2, d_inner): both halves share
        # the d_inner@model shard layout, so the z/x split is a local
        # slice of an UNSHARDED dim (a flat (d, 2·d_inner) fusion parks z
        # on shards 0..7 and x on 8..15 — observed 77 GB/device of
        # collective-permute).  One backward dx all-reduce for both.
        # w_bc / w_dt are tiny and REPLICATED: no backward dx all-reduce.
        "w_zx": (jax.random.normal(ks[0], (d, 2, d_inner), F32) * s).astype(dtype),
        "w_bc": (jax.random.normal(ks[2], (d, 2 * state), F32) * s).astype(dtype),
        "w_dt": (jax.random.normal(ks[3], (d, n_heads), F32) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[4], (conv_width, d_inner + 2 * state),
                                     F32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((d_inner + 2 * state,), dtype),
        "a_log": jnp.zeros((n_heads,), F32),          # A = -exp(a_log)
        "dt_bias": jnp.zeros((n_heads,), F32),
        "d_skip": jnp.ones((n_heads,), F32),
        "norm": jnp.ones((d_inner,), dtype),
        "w_out": (jax.random.normal(ks[5], (d_inner, d), F32)
                  / math.sqrt(d_inner)).astype(dtype),
    }


def _project_in(params, x_in):
    zx = jnp.einsum("bsd,dkf->bskf", x_in, params["w_zx"],
                    preferred_element_type=F32).astype(x_in.dtype)
    z, x = zx[..., 0, :], zx[..., 1, :]   # slice of the UNSHARDED dim
    bc = jnp.einsum("bsd,df->bsf", x_in, params["w_bc"],
                    preferred_element_type=F32).astype(x_in.dtype)
    dt = jnp.einsum("bsd,df->bsf", x_in, params["w_dt"],
                    preferred_element_type=F32)
    return z, x, bc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time: xbc (B,S,C); w (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=F32)
    for i in range(width):
        out = out + pad[:, i:i + xbc.shape[1], :].astype(F32) * w[i].astype(F32)
    return jax.nn.silu(out + b.astype(F32)).astype(xbc.dtype)


def ssm_layer(params: PyTree, x_in: jax.Array, *, state: int, headdim: int,
              expand: int, chunk: int = 64,
              use_pallas: bool | None = None) -> jax.Array:
    """Full-sequence SSD (train / prefill).  x_in (B,S,d)."""
    b_sz, s_len, d = x_in.shape
    d_inner = expand * d
    n_heads = d_inner // headdim

    z, x, bc, dt = _project_in(params, x_in)

    # depthwise causal conv applied per tensor (shard-aligned; depthwise
    # conv commutes with the concat the reference formulation uses)
    x = _causal_conv(x, params["conv_w"][:, :d_inner],
                     params["conv_b"][:d_inner])
    bc = _causal_conv(bc, params["conv_w"][:, d_inner:],
                      params["conv_b"][d_inner:])
    bmat, cmat = bc[..., :state], bc[..., state:]

    dt = jax.nn.softplus(dt.astype(F32) + params["dt_bias"])     # (B,S,H)
    a = -jnp.exp(params["a_log"])                                # (H,)
    log_decay = dt * a                                           # (B,S,H) ≤ 0

    xh = x.reshape(b_sz, s_len, n_heads, headdim)
    # fold dt into the input contribution (standard SSD discretization)
    xh_dt = (xh.astype(F32) * dt[..., None]).astype(x.dtype)

    if kops.want_pallas(use_pallas):
        # kernel layout: fold (B·H) into the grid dim
        xs = xh_dt.transpose(0, 2, 1, 3).reshape(b_sz * n_heads, s_len,
                                                 headdim)
        las = log_decay.transpose(0, 2, 1).reshape(b_sz * n_heads, s_len)
        bb = jnp.broadcast_to(bmat[:, None], (b_sz, n_heads, s_len, state)) \
            .reshape(b_sz * n_heads, s_len, state)
        ccb = jnp.broadcast_to(cmat[:, None], (b_sz, n_heads, s_len, state)) \
            .reshape(b_sz * n_heads, s_len, state)
        pad = (-s_len) % chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
            las = jnp.pad(las, ((0, 0), (0, pad)))
            bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0)))
            ccb = jnp.pad(ccb, ((0, 0), (0, pad), (0, 0)))
        y = kops.ssd_scan(xs, las, bb, ccb, chunk=chunk,
                          use_pallas=use_pallas)
        y = y[:, :s_len].reshape(b_sz, n_heads, s_len, headdim) \
            .transpose(0, 2, 1, 3)
    else:
        # SPMD layout: keep (B, S, H, P) — heads shard over TP, batch
        # over DP; B/C stay shared across heads (no H-fold broadcast)
        y = _ssd_chunked_4d(xh_dt, log_decay, bmat, cmat, chunk)
    y = y + xh.astype(F32) * params["d_skip"][None, None, :, None]
    y = y.reshape(b_sz, s_len, d_inner).astype(x_in.dtype)

    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(y.dtype), params["norm"])
    return jnp.einsum("bsf,fd->bsd", y, params["w_out"],
                      preferred_element_type=F32).astype(x_in.dtype)


def init_ssm_cache(batch: int, d: int, *, state: int, headdim: int,
                   expand: int, conv_width: int, dtype=jnp.bfloat16) -> PyTree:
    d_inner = expand * d
    n_heads = d_inner // headdim
    return {
        "conv": jnp.zeros((batch, conv_width - 1, d_inner + 2 * state), dtype),
        "h": jnp.zeros((batch, n_heads, state, headdim), F32),
    }


def decode_step_ssm(params: PyTree, x_in: jax.Array, cache: PyTree, *,
                    state: int, headdim: int, expand: int
                    ) -> tuple[jax.Array, PyTree]:
    """One-token decode.  x_in (B,1,d)."""
    b_sz, _, d = x_in.shape
    d_inner = expand * d
    n_heads = d_inner // headdim

    z, x, bc, dt = _project_in(params, x_in)
    xbc_new = jnp.concatenate([x, bc], axis=-1)                 # (B,1,C)

    # conv window update
    win = jnp.concatenate([cache["conv"], xbc_new], axis=1)     # (B,W,C)
    w = params["conv_w"]
    conv_out = jnp.sum(win.astype(F32) * w.astype(F32)[None], axis=1) \
        + params["conv_b"].astype(F32)                           # (B,C)
    xbc = jax.nn.silu(conv_out).astype(x_in.dtype)
    x1, b1, c1 = (xbc[:, :d_inner], xbc[:, d_inner:d_inner + state],
                  xbc[:, d_inner + state:])

    dt1 = jax.nn.softplus(dt[:, 0].astype(F32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt1 * a)                                     # (B,H)

    xh = x1.reshape(b_sz, n_heads, headdim).astype(F32)
    upd = jnp.einsum("bn,bhp->bhnp", b1.astype(F32), xh * dt1[..., None])
    h = cache["h"] * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", c1.astype(F32), h)
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(b_sz, d_inner)

    y = rms_norm((y * jax.nn.silu(z[:, 0].astype(F32))).astype(x_in.dtype),
                 params["norm"])
    out = jnp.einsum("bf,fd->bd", y, params["w_out"],
                     preferred_element_type=F32).astype(x_in.dtype)
    return out[:, None, :], {"conv": win[:, 1:], "h": h}
