"""repro.models — config-driven model zoo: dense GQA, MoE, SSD (Mamba-2),
hybrid (Hymba), cross-attn VLM, enc-dec audio.  Decode attention and the
SSD scan implement the paper's aggregation contract."""
from .model import LM

__all__ = ["LM"]
