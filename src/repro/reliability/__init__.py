"""Reliability toolkit for the serving path.

``faults``  — the deterministic fault-injection registry (``REPRO_FAULTS``
env hooks + the ``inject`` context manager) that the chaos battery
(tests/test_serving_faults.py) drives.  ``degrade`` — the thread-local
kernel-backend override the serving circuit breaker uses to trip an
executable onto the exact jnp path.  Both are dependency-free leaves so
every layer (relational, core, launch, serve) can hook them without
import cycles.
"""
from . import degrade, faults

__all__ = ["faults", "degrade"]
