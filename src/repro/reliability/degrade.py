"""Degradation ladder plumbing: a thread-local kernel-backend override.

Froid keeps the un-optimized UDF as a semantic fallback whenever its
rewrite cannot apply; this module is the runtime half of that principle
for the fused grouped-aggregation path.  The serving circuit breaker
(serve/guard.py) builds a *degraded* executable by tracing the same plan
under ``force_backend("jnp")`` — every kernel-backend resolution
(``core.executors._segagg_backend``, the engine's
``_groupagg_fused_backend``) consults the override first, so the traced
program lowers to the exact ``jax.ops.segment_*`` path that always
exists and that CPU CI bit-verifies against the kernel.

Thread-local on purpose: jit tracing happens on the calling thread, so
the override scopes to exactly one trace even while other server threads
trace primary executables concurrently.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

__all__ = ["force_backend", "forced_backend"]

_TL = threading.local()


def forced_backend() -> Optional[str]:
    """The backend forced by an enclosing ``force_backend`` scope, or
    None.  Backend resolvers check this before every other source."""
    stack = getattr(_TL, "stack", None)
    return stack[-1] if stack else None


@contextmanager
def force_backend(backend: str):
    """Force every kernel-backend resolution in this thread to
    ``backend`` for the dynamic extent (nested scopes stack; inner
    wins).  ``'jnp'`` is the degradation ladder's always-correct rung."""
    if backend not in ("pallas", "interpret", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")
    stack = getattr(_TL, "stack", None)
    if stack is None:
        stack = _TL.stack = []
    stack.append(backend)
    try:
        yield
    finally:
        stack.pop()
