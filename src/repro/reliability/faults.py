"""Deterministic fault injection for the serving path.

A *site* is a named point in the code (``SITES``) where a failure mode
the reliability layer must survive can be forced: the bound sketch
undershooting, the slot-table cache going stale, the kernel backend
throwing, the dispatcher thread dying.  Sites fire a bounded number of
times (shot counts, no randomness), so every chaos test is exactly
reproducible: ``inject("backend_exc:3")`` makes the next three passes
through the backend-launch site raise, and nothing else.

Configuration sources, later wins:

* the ``REPRO_FAULTS`` environment variable at import (the CI chaos step
  sets it, proving the env hook is live end-to-end) — comma-separated
  ``site[:shots]`` specs; a bare ``site`` fires every time, ``site:N``
  fires the first N passes;
* the ``inject(spec)`` context manager (what the tests use): *replaces*
  the active table for the dynamic extent, restores on exit.

The hot-path cost when no fault is configured is one module-global
boolean check (``_ENABLED``), so production code can leave the hooks in
place unconditionally.
"""
from __future__ import annotations

import os
import threading
from contextlib import contextmanager
from typing import Dict, Optional

__all__ = ["SITES", "FaultInjected", "configure", "inject", "fire",
           "fail", "fired", "active_spec", "reset_counters"]

#: every named injection point, and where it lives — ``configure``
#: rejects unknown names so a typo cannot silently disarm a chaos test
SITES = (
    "sketch_undershoot",   # keyslot.distinct_count_sketch: estimate //= 8
    "bound_unvalidated",   # agg_server._slot_table: skip the concrete
                           #   overflow validation once (models the
                           #   build/launch race the version key prevents)
    "slot_stale",          # agg_server._slot_table: a cache hit claims a
                           #   dead Table.version
    "backend_exc",         # agg_server launch: the primary executable
                           #   raises (kernel-backend failure)
    "kernel_launch",       # core.executors._grouped_fused: raise at the
                           #   fused kernel call site (trace-time)
    "shard_launch",        # launch.sharded_agg: raise entering a sharded
                           #   launcher
    "ingest_fold",         # agg_server.ingest: raise entering the
                           #   micro-batch moment fold (the chaos battery
                           #   proves a failed fold never corrupts the
                           #   resident state)
    "dispatcher_die",      # agg_server dispatcher loop: kill the thread
    "dispatcher_stall",    # agg_server dispatcher loop: sleep 0.25s once
                           #   (lets deadline/queue tests win races
                           #   deterministically)
    "fold_publish",        # incremental.ResidentAgg: crash between
                           #   building the successor epoch and the
                           #   atomic reference swap — the published
                           #   epoch must stay the pre-fold one
    "checkpoint_write",    # serve.checkpoint: truncate the payload file
                           #   after writing (torn write; the manifest
                           #   checksum must catch it at restore)
    "restore_corrupt",     # serve.checkpoint: flip a byte of the payload
                           #   as it is read back (bit rot; checksum
                           #   verification must refuse the restore)
    "selftest",            # consumed only by the chaos battery's
                           #   env-config liveness test
)


class FaultInjected(RuntimeError):
    """The exception a firing ``fail`` site raises; carries the site name
    so tests can assert exactly which injection surfaced."""

    def __init__(self, site: str):
        super().__init__(f"injected fault at site {site!r}")
        self.site = site


_LOCK = threading.Lock()
_SHOTS: Dict[str, int] = {}    # site -> remaining shots (-1 = unlimited)
_FIRED: Dict[str, int] = {}    # site -> total times fired
_SPEC: Optional[str] = None
_ENABLED = False               # fast-path flag: no lock when no faults


def _parse(spec: Optional[str]) -> Dict[str, int]:
    table: Dict[str, int] = {}
    for part in (spec or "").split(","):
        part = part.strip()
        if not part:
            continue
        name, _, shots = part.partition(":")
        if name not in SITES:
            raise ValueError(f"unknown fault site {name!r} (expected one "
                             f"of {', '.join(SITES)})")
        table[name] = int(shots) if shots else -1
    return table


def configure(spec: Optional[str]) -> None:
    """Install a fault table from a ``site[:shots]`` csv spec (None or
    empty disarms everything).  Counters survive reconfiguration."""
    global _SHOTS, _SPEC, _ENABLED
    table = _parse(spec)
    with _LOCK:
        _SHOTS = table
        _SPEC = spec or None
        _ENABLED = bool(table)


@contextmanager
def inject(spec: str):
    """Arm ``spec`` for the dynamic extent, then restore whatever was
    configured before (the env table, usually).  Process-global — chaos
    tests that use it must not run concurrently with each other."""
    global _SHOTS, _SPEC, _ENABLED
    with _LOCK:
        prev_shots, prev_spec, prev_enabled = dict(_SHOTS), _SPEC, _ENABLED
    configure(spec)
    try:
        yield
    finally:
        with _LOCK:
            _SHOTS, _SPEC, _ENABLED = prev_shots, prev_spec, prev_enabled


def fire(site: str) -> bool:
    """True when ``site`` is armed and a shot remains; consumes one shot.
    The disarmed fast path is one boolean read — no lock."""
    if not _ENABLED:
        return False
    with _LOCK:
        left = _SHOTS.get(site)
        if left is None or left == 0:
            return False
        if left > 0:
            _SHOTS[site] = left - 1
        _FIRED[site] = _FIRED.get(site, 0) + 1
        return True


def fail(site: str) -> None:
    """Raise ``FaultInjected(site)`` when the site fires; no-op otherwise."""
    if fire(site):
        raise FaultInjected(site)


def fired(site: str) -> int:
    """Total times ``site`` has fired since import (or ``reset_counters``)."""
    with _LOCK:
        return _FIRED.get(site, 0)


def reset_counters() -> None:
    with _LOCK:
        _FIRED.clear()


def active_spec() -> Optional[str]:
    """The spec currently armed (None when disarmed) — the chaos
    battery's env liveness test reads it."""
    return _SPEC


# arm from the environment at import: the CI chaos step exports
# REPRO_FAULTS and the battery asserts the hook came live
from repro.configs import flags as _flags  # noqa: E402  (import-time arming)

configure(_flags.value("REPRO_FAULTS"))
