"""The aggregation contract (paper §3.1) as a first-class JAX object, plus
the execution combinators that realize its parallelism:

  * ``streaming``      — sequential ``lax.scan`` over rows (the *Streaming
                         Aggregate* physical operator of Eq. 6).
  * ``chunked``        — rows split into C chunks; per-chunk sequential
                         ``accumulate`` runs in parallel (vmap), partials
                         combined with ``merge``.  Because chunks partition
                         the input *in order* and merge respects chunk
                         order, this is valid for ordered aggregates too —
                         the Merge-based intra-query parallelism of §3.1
                         extended beyond the paper's streaming-only engine.
  * ``tree_reduce``    — log-depth merge tree of per-row states (for cheap
                         accumulate; fully vectorized lift).
  * ``shard_merge``    — cross-device partial aggregation: local accumulate
                         on each shard + ICI merge (used by flash-decode /
                         sequence-parallel attention and by grouped EP
                         aggregation).

State is any pytree.  ``merge`` is optional, exactly as in the paper: a
merge-less aggregate can only execute as a streaming aggregate.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


@dataclass(frozen=True)
class Aggregate:
    """init/accumulate/merge/terminate — the custom-aggregate contract.

    init:       (init_args) -> state
    accumulate: (state, row) -> state          (row: pytree of per-row values)
    merge:      (state, state) -> state | None  (optional; None => stream-only)
    terminate:  (state) -> result
    identity:   optional () -> state that is a left/right identity of merge;
                required by tree_reduce / shard_merge when padding exists.
    """
    name: str
    init: Callable[..., PyTree]
    accumulate: Callable[[PyTree, PyTree], PyTree]
    terminate: Callable[[PyTree], PyTree]
    merge: Optional[Callable[[PyTree, PyTree], PyTree]] = None
    identity: Optional[Callable[[], PyTree]] = None

    @property
    def mergeable(self) -> bool:
        return self.merge is not None


# ---------------------------------------------------------------------------
# Execution combinators
# ---------------------------------------------------------------------------


def streaming(agg: Aggregate, rows: PyTree, valid: Optional[jax.Array] = None,
              *init_args) -> PyTree:
    """Sequential fold over the leading axis of ``rows`` (Eq. 6 semantics).
    ``valid`` masks padded rows (skipped: state passes through)."""
    state0 = agg.init(*init_args)

    def step(state, xs):
        if valid is None:
            row = xs
            new = agg.accumulate(state, row)
        else:
            row, ok = xs
            new = agg.accumulate(state, row)
            new = jax.tree.map(lambda a, b: jnp.where(ok, a, b), new, state)
        return new, None

    xs = rows if valid is None else (rows, valid)
    state, _ = lax.scan(step, state0, xs)
    return agg.terminate(state)


def chunked(agg: Aggregate, rows: PyTree, valid: Optional[jax.Array] = None,
            *init_args, num_chunks: int = 8) -> PyTree:
    """Parallel partial aggregation: C per-chunk streaming folds (vmapped)
    + an ordered merge of the C partial states.

    Chunk 0 starts from ``init(*init_args)``; chunks 1..C-1 start from the
    merge identity, so ``merge(p0, p1, ..., p_{C-1})`` (left fold, in chunk
    order) equals the sequential fold.  Requires ``merge`` + ``identity``.
    """
    if agg.merge is None or agg.identity is None:
        raise ValueError(f"aggregate {agg.name!r} is not mergeable; "
                         "only streaming execution is available")
    leaves = jax.tree.leaves(rows)
    n = leaves[0].shape[0] if leaves else valid.shape[0]
    num_chunks = max(1, min(num_chunks, n))
    pad = (-n) % num_chunks
    if pad:
        def _pad(x):
            cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
            return jnp.pad(x, cfg)
        rows = jax.tree.map(_pad, rows)
        v = jnp.arange(n + pad) < n
        valid = v if valid is None else jnp.concatenate([valid, jnp.zeros(pad, bool)]) & v
    elif valid is None:
        valid = jnp.ones(n, dtype=bool)
    m = (n + pad) // num_chunks
    rows_c = jax.tree.map(lambda x: x.reshape((num_chunks, m) + x.shape[1:]), rows)
    valid_c = valid.reshape(num_chunks, m)

    ident = agg.identity()

    def fold_chunk(chunk_rows, chunk_valid):
        def step(state, xs):
            row, ok = xs
            new = agg.accumulate(state, row)
            return jax.tree.map(lambda a, b: jnp.where(ok, a, b), new, state), None
        state, _ = lax.scan(step, ident, (chunk_rows, chunk_valid))
        return state

    partials = jax.vmap(fold_chunk)(rows_c, valid_c)

    # ordered left-fold merge of the C partials, seeded with init state
    state0 = agg.init(*init_args)

    def merge_step(acc, part):
        return agg.merge(acc, part), None

    state, _ = lax.scan(merge_step, state0,
                        jax.tree.map(lambda x: x, partials))
    return agg.terminate(state)


def tree_reduce(agg: Aggregate, rows: PyTree, valid: Optional[jax.Array] = None,
                *init_args) -> PyTree:
    """Fully vectorized lift: per-row singleton states merged in a log-depth
    tree.  Valid only for *commutative-enough* merges or order-respecting
    reductions (the tree preserves left-to-right order)."""
    if agg.merge is None or agg.identity is None:
        raise ValueError(f"aggregate {agg.name!r} is not mergeable")
    ident = agg.identity()

    def lift(row, ok):
        st = agg.accumulate(ident, row)
        return jax.tree.map(lambda a, b: jnp.where(ok, a, b), st, ident)

    leaves = jax.tree.leaves(rows)
    n = leaves[0].shape[0] if leaves else valid.shape[0]
    v = jnp.ones(n, dtype=bool) if valid is None else valid
    states = jax.vmap(lift)(rows, v)

    # pad to a power of two with identities, then log-depth pairwise merge
    size = 1
    while size < n:
        size *= 2
    pad = size - n
    if pad:
        states = jax.tree.map(
            lambda x, i: jnp.concatenate(
                [x, jnp.broadcast_to(jnp.asarray(i)[None], (pad,) + jnp.asarray(i).shape)], 0),
            states, ident)
    while size > 1:
        half = size // 2
        a = jax.tree.map(lambda x: x[0:2 * half:2], states)
        b = jax.tree.map(lambda x: x[1:2 * half:2], states)
        states = jax.vmap(agg.merge)(a, b)
        size = half
    final = jax.tree.map(lambda x: x[0], states)
    state0 = agg.init(*init_args)
    final = agg.merge(state0, final)
    return agg.terminate(final)


def associative_scan(agg: Aggregate, rows: PyTree,
                     *init_args) -> PyTree:
    """All-prefix aggregation (returns terminate() of every prefix state).
    Requires an associative merge.  Used by SSD-style ordered aggregates."""
    if agg.merge is None or agg.identity is None:
        raise ValueError(f"aggregate {agg.name!r} is not mergeable")
    ident = agg.identity()
    states = jax.vmap(lambda r: agg.accumulate(ident, r))(rows)
    prefix = lax.associative_scan(jax.vmap(agg.merge), states)
    state0 = agg.init(*init_args)
    prefix = jax.vmap(lambda p: agg.merge(state0, p))(prefix)
    return jax.vmap(agg.terminate)(prefix)


def fold_moments(a: jax.Array, b: jax.Array, moments=None) -> jax.Array:
    """Merge two (C, R, S) fused-moment tensors OUTSIDE ``shard_map`` —
    the public face of the cross-shard collective algebra for callers
    that hold both operands on one host (the serving layer's incremental
    ingest folds each micro-batch's moments into its resident tensor with
    exactly this).  Sum and count rows add, min/max extremize; with
    R = 6 the index rows merge as the lexicographic (key, global_row)
    extremum of ``launch.sharded_agg._merge_index_rows`` — each operand's
    index row enters only where its key row attains the merged extremum,
    reduced by min (first-attaining tie order) or max (last-attaining).
    Both operands' index rows must already be in ONE global row numbering
    (the caller globalizes batch-local indices before folding — the
    serving layer uses table positions).  ``moments`` follows
    ``kernels.segment_agg.normalize_moments`` (default: the four value
    moments, i.e. R = 4); for R = 4 the fold is pinned bit-for-bit equal
    to ``moment_merge_aggregate(...).merge`` by tests.  Commutative and
    associative (f32 sum rounding aside), with the identity tensor given
    by ``_row_fills`` — fold order across micro-batches does not change
    which row wins an arg-extremum."""
    from repro.kernels.segment_agg import (ARGMAX_ROW, ARGMIN_ROW, MOMENTS,
                                           NEG_INF, POS_INF, _index_tie,
                                           moment_rows, normalize_moments)
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    if a.shape != b.shape or a.ndim != 3:
        raise ValueError(f"fold_moments: operands must share one "
                         f"(C, R, S) shape, got {a.shape} vs {b.shape}")
    num_cols = a.shape[0]
    norm = normalize_moments(MOMENTS if moments is None else moments,
                             num_cols)
    nrows = moment_rows(norm)
    if a.shape[1] != nrows:
        raise ValueError(f"fold_moments: moments spec implies {nrows} "
                         f"rows per column, operands have {a.shape[1]}")
    mn = jnp.minimum(a[:, 2], b[:, 2])
    mx = jnp.maximum(a[:, 3], b[:, 3])
    merged = [a[:, 0] + b[:, 0], a[:, 1] + b[:, 1], mn, mx]
    if nrows == 6:
        idx_cols = []
        for c in range(num_cols):
            rows = []
            for which, row, gkey in (("argmin", ARGMIN_ROW, mn[c]),
                                     ("argmax", ARGMAX_ROW, mx[c])):
                tie_first = _index_tie(norm[c], which)
                if tie_first is None:
                    rows.append(jnp.full_like(gkey, POS_INF))
                    continue
                ident = POS_INF if tie_first else NEG_INF
                key_row = 2 if which == "argmin" else 3
                ca = jnp.where(a[c, key_row] == gkey, a[c, row], ident)
                cb = jnp.where(b[c, key_row] == gkey, b[c, row], ident)
                rows.append(jnp.minimum(ca, cb) if tie_first
                            else jnp.maximum(ca, cb))
            idx_cols.append(jnp.stack(rows))
        merged.append(jnp.stack(idx_cols)[:, 0])
        merged.append(jnp.stack(idx_cols)[:, 1])
    return jnp.stack(merged, axis=1)


def shard_merge(agg: Aggregate, local_state: PyTree, axis_name: str) -> PyTree:
    """Cross-device partial aggregation: all-gather the per-shard partial
    states over ``axis_name`` and left-fold ``merge`` in shard order.
    Called inside shard_map.  For order-insensitive merges XLA will pattern
    this into an all-reduce-shaped schedule."""
    if agg.merge is None:
        raise ValueError(f"aggregate {agg.name!r} is not mergeable")
    gathered = jax.tree.map(
        lambda x: lax.all_gather(x, axis_name, axis=0), local_state)
    size = lax.psum(1, axis_name)

    def body(i, acc):
        part = jax.tree.map(lambda g: g[i], gathered)
        return agg.merge(acc, part)

    first = jax.tree.map(lambda g: g[0], gathered)
    return lax.fori_loop(1, size, body, first)
