"""Data flow analysis (paper §3.2): reaching definitions, live variables,
UD/DU chains — the textbook iterative fixpoint formulations [Aho et al.;
Khedker et al.], operating on the per-statement CFG of ``cfg.py``.

These are the *inputs* to Algorithm 1 (``A(L, R, UD, DU)`` in the paper).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet

from .cfg import CFG

Def = tuple[int, str]  # (node id, variable)


@dataclass
class DataflowResult:
    cfg: CFG
    reach_in: list[frozenset[Def]]
    reach_out: list[frozenset[Def]]
    live_in: list[frozenset[str]]
    live_out: list[frozenset[str]]
    ud: dict[tuple[int, str], frozenset[int]]   # (use node, var) -> def nodes
    du: dict[tuple[int, str], frozenset[int]]   # (def node, var) -> use nodes

    # -- queries used by Algorithm 1 ---------------------------------------

    def defs_reaching_use(self, node: int, var: str) -> frozenset[int]:
        return self.ud.get((node, var), frozenset())

    def live_at(self, node: int) -> frozenset[str]:
        """Variables live at the entry of ``node`` (a program point)."""
        return self.live_in[node]


def analyze(cfg: CFG) -> DataflowResult:
    n = len(cfg.nodes)

    # ---- reaching definitions (forward, union) ----------------------------
    gen: list[set[Def]] = [set() for _ in range(n)]
    kill_vars: list[frozenset[str]] = [frozenset() for _ in range(n)]
    for node in cfg.nodes:
        gen[node.nid] = {(node.nid, v) for v in node.defs}
        kill_vars[node.nid] = node.defs

    reach_in: list[set[Def]] = [set() for _ in range(n)]
    reach_out: list[set[Def]] = [set(gen[i]) for i in range(n)]
    changed = True
    while changed:
        changed = False
        for node in cfg.nodes:
            i = node.nid
            rin: set[Def] = set()
            for p in node.preds:
                rin |= reach_out[p]
            rout = gen[i] | {d for d in rin if d[1] not in kill_vars[i]}
            if rin != reach_in[i] or rout != reach_out[i]:
                reach_in[i], reach_out[i] = rin, rout
                changed = True

    # ---- liveness (backward, union) ---------------------------------------
    live_in: list[set[str]] = [set() for _ in range(n)]
    live_out: list[set[str]] = [set() for _ in range(n)]
    changed = True
    while changed:
        changed = False
        for node in reversed(cfg.nodes):
            i = node.nid
            lout: set[str] = set()
            for s in node.succs:
                lout |= live_in[s]
            lin = set(node.uses) | (lout - set(node.defs))
            if lin != live_in[i] or lout != live_out[i]:
                live_in[i], live_out[i] = lin, lout
                changed = True

    # ---- UD / DU chains ----------------------------------------------------
    ud: dict[tuple[int, str], frozenset[int]] = {}
    du_acc: dict[tuple[int, str], set[int]] = {}
    for node in cfg.nodes:
        for v in node.uses:
            defs = frozenset(d for (d, dv) in reach_in[node.nid] if dv == v)
            ud[(node.nid, v)] = defs
            for d in defs:
                du_acc.setdefault((d, v), set()).add(node.nid)
    du = {k: frozenset(v) for k, v in du_acc.items()}

    return DataflowResult(
        cfg=cfg,
        reach_in=[frozenset(s) for s in reach_in],
        reach_out=[frozenset(s) for s in reach_out],
        live_in=[frozenset(s) for s in live_in],
        live_out=[frozenset(s) for s in live_out],
        ud=ud,
        du=du,
    )
