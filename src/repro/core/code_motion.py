"""§8.1 — Acyclic code motion.

Beyond classical loop-invariant code motion, the paper hoists *loop-variant*
expressions out of the loop body into the cursor query Q, provided the
expression involves no variable written in the loop body ("acyclic").  Two
transformations are implemented:

1. **Guard-to-WHERE**: when the loop body is a single guarded update
   ``If(c1 ∧ c2 ∧ …, S)``, every conjunct whose variables are all acyclic
   (fetch vars or loop-invariant program vars) moves into Q's WHERE clause —
   the paper's own example hoists ``@pCost > @lb`` out of Figure 1.  Fetch
   variables become column references; invariant vars remain Var references
   bound from the enclosing program (the engine's correlated-parameter
   mechanism).

2. **Expression-to-projection**: maximal acyclic subexpressions of body
   assignments that reference at least one fetch variable are computed in Q
   as projected columns; the body reads the precomputed column.  This
   exposes the arithmetic to the set-oriented engine (vector units) and
   shrinks Accumulate — the paper's "expose more operations to the query
   optimizer".
"""
from __future__ import annotations

from dataclasses import replace
from typing import Optional

from repro.relational.plan import Project, push_filter, strip_order

from .loop_ir import (Assign, BinOp, Col, CursorLoop, Expr, If, Program, Stmt,
                      UnOp, Var, Where, assigned_vars, expr_cols, expr_vars,
                      vars_to_cols)
from .recognize import split_conjuncts, _conjoin


def apply_acyclic_code_motion(prog: Program,
                              hoist_guards: bool = True,
                              hoist_exprs: bool = True) -> Program:
    loop = prog.loop
    if not isinstance(loop, CursorLoop):
        return prog
    body = list(loop.body)
    q = loop.query
    fetch_map = dict(loop.fetch)          # var -> column
    written = assigned_vars(body)
    acyclic_vars = set(fetch_map)         # fetch vars are per-row (column) refs

    def is_acyclic(e: Expr) -> bool:
        return not (expr_vars(e) & written)

    # ---- 1. guard-to-WHERE -------------------------------------------------
    if hoist_guards and len(body) == 1 and isinstance(body[0], If) \
            and not body[0].orelse:
        guard = body[0]
        conjs = split_conjuncts(guard.cond)
        hoisted = [c for c in conjs if is_acyclic(c)]
        kept = [c for c in conjs if not is_acyclic(c)]
        if hoisted:
            pred = _conjoin([_to_query_expr(c, fetch_map) for c in hoisted])
            child, keys, desc = strip_order(q)
            child = push_filter(child, pred)
            q = _reorder(child, keys, desc)
            if kept:
                body = [If(_conjoin(kept), guard.then)]
            else:
                body = list(guard.then)

    # ---- 2. expression-to-projection ---------------------------------------
    if hoist_exprs:
        proj: dict[str, Expr] = {}
        counter = [0]

        def hoist(e: Expr) -> Expr:
            if _worth_hoisting(e, is_acyclic, set(fetch_map)):
                name = f"__acm_{counter[0]}"
                counter[0] += 1
                proj[name] = _to_query_expr(e, fetch_map)
                return Var(name)   # bound per-row via the extended FETCH
            if isinstance(e, BinOp):
                return BinOp(e.op, hoist(e.lhs), hoist(e.rhs))
            if isinstance(e, UnOp):
                return UnOp(e.op, hoist(e.operand))
            if isinstance(e, Where):
                return Where(hoist(e.cond), hoist(e.t), hoist(e.f))
            return e

        new_body = [_map_exprs(s, hoist) for s in body]
        if proj:
            child, keys, desc = strip_order(q)
            passthrough = {c: Col(c) for c in child.columns}
            passthrough.update(proj)
            child = Project(child, tuple(passthrough.items()))
            q = _reorder(child, keys, desc)
            body = new_body
            # extend the fetch binding with the precomputed columns
            fetch = tuple(loop.fetch) + tuple(
                (name, name) for name in proj)
            new_loop = CursorLoop(q, fetch, body)
            return Program(prog.name, prog.params, prog.pre, new_loop,
                           prog.post, prog.returns, prog.var_dtypes,
                           prog.local_tables)

    new_loop = CursorLoop(q, loop.fetch, body)
    return Program(prog.name, prog.params, prog.pre, new_loop, prog.post,
                   prog.returns, prog.var_dtypes, prog.local_tables)


def _reorder(child, keys, desc):
    if not keys:
        return child
    from repro.relational.plan import OrderBy
    return OrderBy(child, keys, desc)


def _to_query_expr(e: Expr, fetch_map: dict[str, str]) -> Expr:
    """Var(v in fetch) -> Col(column); other Vars stay (correlated params)."""
    from .loop_ir import substitute
    return substitute(e, {v: Col(c) for v, c in fetch_map.items()})


def _worth_hoisting(e: Expr, is_acyclic, fetch_vars: set[str]) -> bool:
    """Hoist maximal acyclic *compound* expressions that touch ≥1 fetch var
    (pure-invariant expressions are loop-invariant code motion and are left
    to the scalar env — they're already computed once)."""
    if not isinstance(e, (BinOp, UnOp, Where)):
        return False
    if not is_acyclic(e):
        return False
    vs = expr_vars(e)
    return bool(vs & fetch_vars)


def _map_exprs(s: Stmt, fn) -> Stmt:
    if isinstance(s, Assign):
        return Assign(s.var, fn(s.expr))
    if isinstance(s, If):
        return If(fn(s.cond), tuple(_map_exprs(x, fn) for x in s.then),
                  tuple(_map_exprs(x, fn) for x in s.orelse))
    from .loop_ir import InsertLocal
    if isinstance(s, InsertLocal):
        return InsertLocal(s.table_var, tuple(fn(e) for e in s.values))
    raise TypeError(type(s))
