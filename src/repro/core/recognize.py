"""Aggregate recognition & Merge synthesis.

The paper's §3.1 contract includes an *optional* ``Merge`` used for
intra-query parallelism, but (a) SQL Server never derives one
automatically, and (b) the paper's engine executes user-defined aggregates
only as streaming aggregates.  This module is the beyond-paper step that
makes the technique TPU-native:

1. **Merge synthesis** — pattern-match the loop body Δ and derive a merge
   operator + merge identity ("no rows seen" state).  With these, the
   chunked / tree / shard executors in ``aggregate.py`` parallelize the
   loop within a chip (VPU lanes) and across chips (ICI) while preserving
   the sequential semantics (chunks partition the input in order; ties in
   extremal updates resolve toward the earlier chunk, matching the strict-
   comparison first-writer-wins of the loop).

2. **Closed-form recognition** — when every state update matches a known
   algebra, emit a fully set-oriented evaluation (vectorized jnp / Pallas
   segment kernels) with *no scan at all*: the "optimizer visibility" the
   paper argues for in §8.1, taken to its limit.

Recognized field-update algebras:

    sum      f = f + e            (count is sum with e = 1)
    prod     f = f * e
    min/max  f = min/max(f, e)   or   If(e < f, f = e)
    argmin/argmax group:
             If(e ⊲ f_key [and acyclic-guard], f_key = e; payload_i = p_i)
             with ⊲ ∈ {<, <=, >, >=}
    last     f = e               (e acyclic; order-sensitive)

where every contribution ``e``/``p_i``/guard is *acyclic*: it reads only
fetch variables, outer parameters, and constants — never a state field.
Bodies mixing recognized updates are recognized field-by-field; any
unrecognized statement makes the whole body unrecognized (stream-only,
exactly the paper's execution model).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from .loop_ir import (Assign, BinOp, Const, Expr, If, Stmt, UnOp, Var, Where,
                      expr_vars, wrap)


@dataclass(frozen=True)
class FieldUpdate:
    kind: str                       # sum|prod|min|max|arg_group|last
    fields: tuple[str, ...]         # updated fields (1 for scalars; key+payloads for arg_group)
    exprs: tuple[Expr, ...]         # contribution per field (key expr first for arg_group)
    guard: Optional[Expr] = None    # acyclic guard (None = always)
    op: str = ""                    # for arg_group: the comparison < <= > >=


# ---------------------------------------------------------------------------
# Recognition
# ---------------------------------------------------------------------------


def recognize(body: Sequence[Stmt], fetch_vars: set[str], fields: set[str],
              outer_params: set[str]) -> Optional[tuple[FieldUpdate, ...]]:
    """``fields`` must be the set of fields *written* in the body: a field
    that is only read (e.g. the @lb lower bound of the paper's Figure 1) is
    loop-constant and therefore acyclic — it participates in contributions
    and guards like any outer parameter."""
    updates: list[FieldUpdate] = []
    written: set[str] = set()

    def is_acyclic(e: Expr) -> bool:
        return not (expr_vars(e) & fields)

    for s in body:
        u = _match_stmt(s, fields, is_acyclic)
        if u is None:
            return None
        # each field may be target of exactly one recognized update, and a
        # contribution may not read a field written earlier in the body
        for f in u.fields:
            if f in written:
                return None
            written.add(f)
        updates.append(u)
    return tuple(updates)


def _match_stmt(s: Stmt, fields: set[str], is_acyclic) -> Optional[FieldUpdate]:
    if isinstance(s, Assign):
        return _match_assign(s, fields, is_acyclic)
    if isinstance(s, If) and not s.orelse:
        return _match_guarded(s, fields, is_acyclic)
    return None


def _match_assign(s: Assign, fields: set[str], is_acyclic) -> Optional[FieldUpdate]:
    f, e = s.var, s.expr
    if f not in fields:
        return None
    # f = f + e   /  f = e + f
    if isinstance(e, BinOp) and e.op in ("+", "*", "min", "max"):
        for self_side, other in ((e.lhs, e.rhs), (e.rhs, e.lhs)):
            if isinstance(self_side, Var) and self_side.name == f and is_acyclic(other):
                kind = {"+": "sum", "*": "prod", "min": "min", "max": "max"}[e.op]
                return FieldUpdate(kind, (f,), (other,))
    # f = f - e  (sum of negated contribution)
    if isinstance(e, BinOp) and e.op == "-":
        if isinstance(e.lhs, Var) and e.lhs.name == f and is_acyclic(e.rhs):
            return FieldUpdate("sum", (f,), (UnOp("neg", e.rhs),))
    # f = e (acyclic) — last value
    if is_acyclic(e):
        return FieldUpdate("last", (f,), (e,))
    return None


_CMP_FLIP = {"<": ">", "<=": ">=", ">": "<", ">=": "<="}


def _match_guarded(s: If, fields: set[str], is_acyclic) -> Optional[FieldUpdate]:
    """If(conj ∧ (e ⊲ f_key) ∧ conj, f_key = e; payload...) — argmin/argmax
    with optional acyclic guard conjuncts."""
    conjs = split_conjuncts(s.cond)
    assigns: list[Assign] = []
    for b in s.then:
        if not isinstance(b, Assign):
            return None
        assigns.append(b)
    targets = {a.var for a in assigns}
    if not targets <= fields:
        return None

    # find the single cyclic comparison conjunct
    key_cmp = None
    guard_conjs: list[Expr] = []
    for c in conjs:
        if is_acyclic(c):
            guard_conjs.append(c)
            continue
        if key_cmp is not None:
            return None
        key_cmp = c
    guard = _conjoin(guard_conjs)

    if key_cmp is None:
        # uniformly guarded recognized update: If(acyclic, f = f + e)
        if len(assigns) != 1:
            return None
        u = _match_assign(assigns[0], fields, is_acyclic)
        if u is None:
            return None
        return FieldUpdate(u.kind, u.fields, u.exprs, guard=guard)

    # key comparison: e ⊲ key_field, with key_field ∈ fields and e acyclic
    if not isinstance(key_cmp, BinOp) or key_cmp.op not in ("<", "<=", ">", ">="):
        return None
    lhs, rhs, op = key_cmp.lhs, key_cmp.rhs, key_cmp.op
    if isinstance(rhs, Var) and rhs.name in fields and is_acyclic(lhs):
        key_field, key_expr = rhs.name, lhs
    elif isinstance(lhs, Var) and lhs.name in fields and is_acyclic(rhs):
        key_field, key_expr, op = lhs.name, rhs, _CMP_FLIP[op]
    else:
        return None
    # now semantics: update when  key_expr ⟨op⟩ current_key

    # the branch must assign key_field = key_expr and acyclic payloads
    key_assigned = False
    payload_fields: list[str] = []
    payload_exprs: list[Expr] = []
    for a in assigns:
        if a.var == key_field:
            if a.expr != key_expr:
                return None
            key_assigned = True
        else:
            if not is_acyclic(a.expr):
                return None
            payload_fields.append(a.var)
            payload_exprs.append(a.expr)
    if not key_assigned:
        return None
    return FieldUpdate("arg_group",
                       (key_field,) + tuple(payload_fields),
                       (key_expr,) + tuple(payload_exprs),
                       guard=guard, op=op)


def split_conjuncts(e: Expr) -> list[Expr]:
    if isinstance(e, BinOp) and e.op == "and":
        return split_conjuncts(e.lhs) + split_conjuncts(e.rhs)
    return [e]


def _conjoin(es: Sequence[Expr]) -> Optional[Expr]:
    if not es:
        return None
    out = es[0]
    for e in es[1:]:
        out = BinOp("and", out, e)
    return out


# ---------------------------------------------------------------------------
# Merge synthesis
# ---------------------------------------------------------------------------


_MINMAX_ID = {
    "min": lambda d: jnp.array(jnp.inf, d) if jnp.issubdtype(d, jnp.floating)
    else jnp.array(jnp.iinfo(d).max, d),
    "max": lambda d: jnp.array(-jnp.inf, d) if jnp.issubdtype(d, jnp.floating)
    else jnp.array(jnp.iinfo(d).min, d),
}


def set_flag(field: str) -> str:
    """State key of the 'this last-value field has been written' flag."""
    return f"{field}__set"


def make_identity(updates: tuple[FieldUpdate, ...],
                  outer_state: Mapping[str, Any]):
    """The 'no rows seen' state: sum→0, prod→1, min→+∞, max→−∞,
    arg_group→(worst key, zero payload), last→zero + set-flag.  Fields not
    written by any update (loop-constant reads) keep their P_0 value so the
    state structure matches Accumulate's output."""
    def identity():
        st: dict[str, Any] = {f: jnp.asarray(v) for f, v in outer_state.items()}
        for u in updates:
            if u.kind == "sum":
                st[u.fields[0]] = jnp.zeros_like(outer_state[u.fields[0]])
            elif u.kind == "prod":
                st[u.fields[0]] = jnp.ones_like(outer_state[u.fields[0]])
            elif u.kind in ("min", "max"):
                d = jnp.asarray(outer_state[u.fields[0]]).dtype
                st[u.fields[0]] = _MINMAX_ID[u.kind](d)
            elif u.kind == "arg_group":
                kf = u.fields[0]
                d = jnp.asarray(outer_state[kf]).dtype
                worst = _MINMAX_ID["min" if u.op in ("<", "<=") else "max"](d)
                st[kf] = worst
                for p in u.fields[1:]:
                    st[p] = jnp.zeros_like(outer_state[p])
            elif u.kind == "last":
                st[u.fields[0]] = jnp.zeros_like(outer_state[u.fields[0]])
                st[set_flag(u.fields[0])] = jnp.array(False)
            else:  # pragma: no cover
                raise ValueError(u.kind)
        return st
    return identity


def bookkeeping(updates: tuple[FieldUpdate, ...]):
    """Post-body state maintenance executed by the aggregate wrapper (the
    compiled Δ knows nothing of merge bookkeeping): raise the set-flag of
    each 'last' field whose (optional) guard passed for this row."""
    from .loop_ir import eval_expr

    lasts = [u for u in updates if u.kind == "last"]

    def update(state: dict[str, Any], row_env: Mapping[str, Any]) -> dict[str, Any]:
        for u in lasts:
            fired = (jnp.asarray(True) if u.guard is None
                     else jnp.asarray(eval_expr(u.guard, row_env), bool))
            k = set_flag(u.fields[0])
            state[k] = jnp.logical_or(state.get(k, jnp.array(False)), fired)
        return state

    return update, tuple(set_flag(u.fields[0]) for u in lasts)


def make_merge(updates: tuple[FieldUpdate, ...]):
    """Ordered merge: ``a`` is the earlier chunk.  Exactness w.r.t. the
    sequential loop follows chunk-locality of each algebra (see module
    docstring)."""
    def merge(a, b):
        out: dict[str, Any] = dict(a)   # loop-constant fields pass through
        for u in updates:
            if u.kind == "sum":
                f = u.fields[0]
                out[f] = a[f] + b[f]
            elif u.kind == "prod":
                f = u.fields[0]
                out[f] = a[f] * b[f]
            elif u.kind == "min":
                f = u.fields[0]
                out[f] = jnp.minimum(a[f], b[f])
            elif u.kind == "max":
                f = u.fields[0]
                out[f] = jnp.maximum(a[f], b[f])
            elif u.kind == "arg_group":
                kf = u.fields[0]
                cmp = {"<": lambda x, y: x < y, "<=": lambda x, y: x <= y,
                       ">": lambda x, y: x > y, ">=": lambda x, y: x >= y}[u.op]
                # does b's champion beat a's?  strict ops keep the earlier
                # chunk on ties (first-writer-wins); non-strict keep later.
                take_b = cmp(b[kf], a[kf])
                for f in u.fields:
                    out[f] = jnp.where(take_b, b[f], a[f])
            elif u.kind == "last":
                f = u.fields[0]
                k = set_flag(f)
                out[f] = jnp.where(b[k], b[f], a[f])
                out[k] = jnp.logical_or(a[k], b[k])
            else:  # pragma: no cover
                raise ValueError(u.kind)
        return out
    return merge


# ---------------------------------------------------------------------------
# Closed-form (fully vectorized) evaluation
# ---------------------------------------------------------------------------


def vectorized_eval(updates: tuple[FieldUpdate, ...],
                    col_env: Mapping[str, Any],
                    valid: jax.Array,
                    outer_state: Mapping[str, Any]) -> dict[str, Any]:
    """Evaluate all recognized updates set-orientedly over whole columns.

    ``col_env`` binds fetch params to columns and outer params to scalars.
    Tie order matches the sequential loop (first/last attaining row for
    strict/non-strict comparisons; 'last' takes the final valid row).
    """
    from .loop_ir import eval_expr

    n = valid.shape[0]
    out: dict[str, Any] = {}
    for u in updates:
        g = valid
        if u.guard is not None:
            g = g & jnp.asarray(eval_expr(u.guard, col_env), bool)
        if u.kind in ("sum", "prod", "min", "max"):
            f = u.fields[0]
            e = jnp.broadcast_to(
                jnp.asarray(eval_expr(u.exprs[0], col_env),
                            jnp.asarray(outer_state[f]).dtype), (n,))
            if u.kind == "sum":
                out[f] = outer_state[f] + jnp.sum(jnp.where(g, e, 0))
            elif u.kind == "prod":
                out[f] = outer_state[f] * jnp.prod(jnp.where(g, e, 1))
            elif u.kind == "min":
                out[f] = jnp.minimum(outer_state[f],
                                     jnp.min(jnp.where(g, e, _MINMAX_ID["min"](e.dtype))))
            else:
                out[f] = jnp.maximum(outer_state[f],
                                     jnp.max(jnp.where(g, e, _MINMAX_ID["max"](e.dtype))))
        elif u.kind == "arg_group":
            kf = u.fields[0]
            kd = jnp.asarray(outer_state[kf]).dtype
            key = jnp.broadcast_to(jnp.asarray(eval_expr(u.exprs[0], col_env), kd), (n,))
            minimize = u.op in ("<", "<=")
            worst = _MINMAX_ID["min" if minimize else "max"](kd)
            masked = jnp.where(g, key, worst)
            if u.op == "<":
                idx = jnp.argmin(masked)                      # first min
            elif u.op == "<=":
                idx = n - 1 - jnp.argmin(masked[::-1])        # last min
            elif u.op == ">":
                idx = jnp.argmax(masked)
            else:
                idx = n - 1 - jnp.argmax(masked[::-1])
            best = masked[idx]
            cmp = {"<": best < outer_state[kf], "<=": best <= outer_state[kf],
                   ">": best > outer_state[kf], ">=": best >= outer_state[kf]}[u.op]
            beat = cmp & g[idx]
            out[kf] = jnp.where(beat, best, outer_state[kf])
            for f, pe in zip(u.fields[1:], u.exprs[1:]):
                pv = jnp.broadcast_to(
                    jnp.asarray(eval_expr(pe, col_env),
                                jnp.asarray(outer_state[f]).dtype), (n,))
                out[f] = jnp.where(beat, pv[idx], outer_state[f])
        elif u.kind == "last":
            f = u.fields[0]
            e = jnp.broadcast_to(
                jnp.asarray(eval_expr(u.exprs[0], col_env),
                            jnp.asarray(outer_state[f]).dtype), (n,))
            any_valid = jnp.any(g)
            last_idx = n - 1 - jnp.argmax(g[::-1])
            out[f] = jnp.where(any_valid, e[last_idx], outer_state[f])
        else:  # pragma: no cover
            raise ValueError(u.kind)
    return out
