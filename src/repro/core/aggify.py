"""Algorithm 1 — Aggify(G, Q, Δ): custom-aggregate construction (paper §5)
and the loop-elimination rewrite (paper §6).

Faithful implementation of the paper's equations:

    V_F      = (V_Δ − (V_fetch ∪ V_local)) ∪ {isInitialized}      (Eq. 1)
    R(v)     = 1 iff some use of v in the loop has a reaching
               definition outside the loop                          (Eq. 2)
    P_accum  = { v ∈ V_use | R(v) = 1 }                            (Eq. 3)
    V_init   = P_accum − V_fetch                                   (Eq. 4)
    V_term   = fields of V_F live at the end of the loop           (§5.4)

    Loop(Q, Δ)   ⇒  𝒢_{AggΔ(P_accum)}(Q)                           (Eq. 5)
    Loop(Q_s, Δ) ⇒  𝒢_{StreamAggΔ(P_accum)}(Sort_s(Q))             (Eq. 6)

The generated aggregate follows the Init/Accumulate/Terminate(/Merge)
contract of §3.1.  ``deferred_init=True`` reproduces the paper's deferred
field initialization (Init takes no arguments in SQL; fields are set from
Accumulate parameters under an ``isInitialized`` flag — §5.2).  In JAX the
aggregate is a closure, so eager initialization from the enclosing program
state is available and provably equivalent (the V_init parameters are
loop-constant); both paths are implemented and tested equal.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Any, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from . import recognize as _recognize
from .aggregate import Aggregate
from .cfg import CFG, FETCH_STATUS
from .dataflow import DataflowResult, analyze
from .loop_ir import (Assign, Col, CursorLoop, Expr, If, InsertLocal, Program,
                      Stmt, Var, assigned_vars, body_vars, eval_expr, flatten,
                      stmt_uses, wrap)


# ---------------------------------------------------------------------------
# Analysis record (exactly the sets the paper derives; asserted in tests
# against the paper's own Figure-1/Figure-2 illustrations)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AggifyAnalysis:
    v_delta: frozenset[str]
    v_fetch: frozenset[str]
    v_local: frozenset[str]
    v_fields: frozenset[str]      # V_F without the isInitialized bookkeeping
    p_accum: tuple[str, ...]      # ordered: fetch params (FETCH order), then
                                  # outer params (first-use order)
    v_init: frozenset[str]
    v_term: tuple[str, ...]


@dataclass(frozen=True)
class CustomAggregate:
    """The generated aggregate AggΔ (paper Figure 4 template)."""
    name: str
    fields: tuple[str, ...]            # V_F
    fetch_params: tuple[str, ...]      # per-row Accumulate params (from Q)
    outer_params: tuple[str, ...]      # loop-constant Accumulate params
    init_fields: tuple[str, ...]       # V_init
    terminate_vars: tuple[str, ...]    # V_term
    body: tuple[Stmt, ...]             # Δ — placed verbatim in Accumulate
    analysis: AggifyAnalysis = None
    local_tables: Mapping[str, Any] = dc_field(default_factory=dict)
    recognized: Optional[tuple] = None  # recognize.FieldUpdate list, if any
    #: Program.var_dtypes carried along so executors can resolve the dtype
    #: of fields absent from the caller environment (the engine's AggCall
    #: path has no other channel for it)
    var_dtypes: Mapping[str, Any] = dc_field(default_factory=dict)

    @property
    def accum_params(self) -> tuple[str, ...]:
        return self.fetch_params + self.outer_params

    @property
    def mergeable(self) -> bool:
        return self.recognized is not None and not self.local_tables

    # -- compile to the JAX aggregate contract ------------------------------

    def as_jax_aggregate(self, outer_values: Mapping[str, Any],
                         deferred_init: bool = False,
                         dtype_env: Optional[Mapping[str, Any]] = None) -> Aggregate:
        """Instantiate the Init/Accumulate/Merge/Terminate contract.

        ``outer_values`` supplies the current values of every field at the
        program point just before the loop (this is P_0 of §7) plus the
        outer Accumulate parameters.
        """
        fields = self.fields
        outer_state = {f: _as_val(outer_values[f], dtype_env, f)
                       for f in fields}
        outer_params = {p: _as_val(outer_values[p], dtype_env, p)
                        for p in self.outer_params}
        consts = dict(outer_params)

        if deferred_init:
            # Faithful §5.2: fields start at type-default; first Accumulate
            # copies V_init params into fields under isInitialized.
            def init():
                st = {f: jnp.zeros_like(outer_state[f]) for f in fields}
                st["isInitialized"] = jnp.array(False)
                return st

            def accumulate(state, row):
                st = dict(state)
                init_now = ~st["isInitialized"]
                for f in self.init_fields:
                    st[f] = jnp.where(init_now, consts[f], st[f])
                # non-V_init fields keep default until written; their value
                # is never read before a write (else they'd be in V_init),
                # except by Terminate on an empty input — handled by the
                # rewrite falling back to pre-loop values (see run paths).
                st["isInitialized"] = jnp.array(True)
                env = dict(consts)
                env.update({k: v for k, v in st.items() if k != "isInitialized"})
                env.update(row)
                env = exec_stmts(self.body, env)
                new = {f: env[f] for f in fields}
                new["isInitialized"] = st["isInitialized"]
                return new

            def terminate(state):
                return tuple(
                    jnp.where(state["isInitialized"], state[v], outer_state[v])
                    for v in self.terminate_vars)

            return Aggregate(self.name, init, accumulate, terminate)

        # Eager (JAX-native) initialization: state starts at P_0.
        book = flag_keys = None
        merge = identity = None
        if self.mergeable:
            identity = _recognize.make_identity(self.recognized, outer_state)
            merge = _recognize.make_merge(self.recognized)
            book, flag_keys = _recognize.bookkeeping(self.recognized)

        def init():
            st = dict(outer_state)
            if flag_keys:
                # P_0 'last' fields hold well-defined pre-loop values
                for k in flag_keys:
                    st[k] = jnp.array(True)
            return st

        def accumulate(state, row):
            env = dict(consts)
            env.update({k: v for k, v in state.items()
                        if not k.endswith("__set")})
            env.update(row)
            env2 = exec_stmts(self.body, dict(env))
            new = {f: env2[f] for f in fields}
            if book is not None:
                for k in flag_keys or ():
                    new[k] = state.get(k, jnp.array(False))
                env.update(row)
                new = book(new, env)
            return new

        def terminate(state):
            return tuple(state[v] for v in self.terminate_vars)

        return Aggregate(self.name, init, accumulate, terminate,
                         merge=merge, identity=identity)


def _as_val(v, dtype_env, name):
    if dtype_env and name in dtype_env:
        return jnp.asarray(v, dtype=dtype_env[name])
    return jax.tree.map(jnp.asarray, v)   # pytree states (local tables) too


# ---------------------------------------------------------------------------
# Statement execution with select semantics (used by Accumulate and by the
# cursor baseline; identical code ⇒ semantics preserved by construction)
# ---------------------------------------------------------------------------


def exec_stmts(stmts: Sequence[Stmt], env: dict[str, Any]) -> dict[str, Any]:
    for s in stmts:
        if isinstance(s, Assign):
            env[s.var] = eval_expr(s.expr, env)
        elif isinstance(s, If):
            c = eval_expr(s.cond, env)
            t_env = exec_stmts(s.then, dict(env))
            e_env = exec_stmts(s.orelse, dict(env))
            changed = assigned_vars(s.then) | assigned_vars(s.orelse)
            for v in changed:
                tv, ev = t_env.get(v), e_env.get(v)
                if tv is None and ev is None:
                    continue
                # A var defined on only one branch and absent before the If
                # is branch-local; its post-If value is never legitimately
                # read (it would be in V_init otherwise), so mirror the
                # defined side.
                tv = ev if tv is None else tv
                ev = tv if ev is None else ev
                env[v] = jax.tree.map(
                    lambda a, b: jnp.where(c, a, b), tv, ev)
        elif isinstance(s, InsertLocal):
            buf, cnt = env[s.table_var]
            vals = tuple(eval_expr(e, env) for e in s.values)
            new_buf = tuple(
                jnp.asarray(b).at[jnp.clip(cnt, 0, b.shape[0] - 1)].set(
                    jnp.asarray(v, dtype=b.dtype))
                for b, v in zip(buf, vals))
            env[s.table_var] = (new_buf, cnt + 1)
        else:
            raise TypeError(type(s))
    return env


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------


def analyze_loop(prog: Program) -> tuple[AggifyAnalysis, DataflowResult, CFG]:
    """Run the dataflow pass A(L, R, UD, DU) and compute the Aggify sets."""
    if not isinstance(prog.loop, CursorLoop):
        raise TypeError("analyze_loop expects a CursorLoop (rewrite FOR "
                        "loops via repro.core.for_loops first)")
    cfg = CFG.of_program(prog)
    dfa = analyze(cfg)
    loop = prog.loop

    v_fetch = frozenset(loop.fetch_vars)
    v_delta = frozenset(body_vars(loop.body))

    # V_local: declared (first defined) inside the body and dead at loop
    # end.  Local table variables are declared (initialized empty) before
    # the loop and accumulate ACROSS iterations, so they are never
    # body-local even when dead afterwards.
    defined_before = set(prog.params) | assigned_vars(prog.pre) \
        | set(v_fetch) | set(prog.local_tables)
    assigned_in_body = assigned_vars(loop.body)
    live_at_exit = dfa.live_in[cfg.loop_exit_point]
    v_local = frozenset(v for v in assigned_in_body
                        if v not in defined_before and v not in live_at_exit)

    v_fields = frozenset(v_delta - (v_fetch | v_local))

    # P_accum per Eq. 2/3, via UD chains over the per-statement CFG.
    body_nodes = cfg.body_nodes
    outside = lambda d: d not in body_nodes
    use_order: list[str] = []
    p_accum_set: set[str] = set()
    for nid in sorted(body_nodes):
        node = cfg.nodes[nid]
        for v in sorted(node.uses):
            if v == FETCH_STATUS or v in prog.local_tables:
                continue
            defs = dfa.defs_reaching_use(nid, v)
            if any(outside(d) for d in defs):
                if v not in p_accum_set:
                    p_accum_set.add(v)
                    use_order.append(v)

    fetch_params = tuple(v for v in loop.fetch_vars if v in p_accum_set)
    outer_params = tuple(v for v in use_order if v not in v_fetch)
    p_accum = fetch_params + outer_params

    v_init = frozenset(p_accum_set - set(v_fetch))

    # V_term: fields live at the end of the loop, deterministic order.
    v_term = tuple(sorted(v for v in v_fields if v in live_at_exit))

    ana = AggifyAnalysis(v_delta=v_delta, v_fetch=v_fetch, v_local=v_local,
                         v_fields=v_fields, p_accum=p_accum, v_init=v_init,
                         v_term=v_term)
    return ana, dfa, cfg


def build_aggregate(prog: Program, name: Optional[str] = None) -> CustomAggregate:
    """§5: construct AggΔ from the loop (the first half of Algorithm 1)."""
    check_applicability(prog)
    ana, _, _ = analyze_loop(prog)
    loop = prog.loop
    fields = tuple(sorted(ana.v_fields))
    local_tables = {k: v for k, v in prog.local_tables.items()
                    if k in ana.v_fields}
    recognized = None
    if not local_tables:
        written = assigned_vars(loop.body) & set(fields)
        recognized = _recognize.recognize(
            loop.body, fetch_vars=set(loop.fetch_vars),
            fields=written, outer_params=set(p for p in ana.p_accum
                                             if p not in ana.v_fetch))
    return CustomAggregate(
        name=name or f"{prog.name}_agg",
        fields=fields,
        fetch_params=tuple(v for v in ana.p_accum if v in ana.v_fetch),
        outer_params=tuple(v for v in ana.p_accum if v not in ana.v_fetch),
        init_fields=tuple(sorted(ana.v_init)),
        terminate_vars=ana.v_term,
        body=loop.body,
        analysis=ana,
        local_tables=local_tables,
        recognized=recognized,
        var_dtypes=dict(prog.var_dtypes),
    )


# ---------------------------------------------------------------------------
# Applicability (Theorem 4.2 preconditions, §4.2)
# ---------------------------------------------------------------------------


class NotAggifyable(Exception):
    pass


def check_applicability(prog: Program) -> None:
    """Theorem 4.2: any cursor loop that does not modify persistent database
    state can be rewritten.  Our IR admits persistent-state mutation only
    via InsertLocal targeting a table NOT declared in ``local_tables`` —
    reject that; everything else (assignments, branching, local-table DML,
    pure function calls) is supported."""
    if not isinstance(prog.loop, CursorLoop):
        raise NotAggifyable("not a cursor loop (use for_loops.rewrite_for)")
    for s in flatten(prog.loop.body):
        if isinstance(s, InsertLocal) and s.table_var not in prog.local_tables:
            raise NotAggifyable(
                f"loop mutates persistent table {s.table_var!r}; aggregates "
                "cannot modify database state (paper §4.1)")


def is_aggifyable(prog: Program) -> bool:
    try:
        check_applicability(prog)
        return True
    except NotAggifyable:
        return False


# ---------------------------------------------------------------------------
# Rewrite (Eq. 5 / Eq. 6) — second half of Algorithm 1
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RewrittenProgram:
    """The loop-free output: pre statements (dead code eliminated), one
    AggCall query, bindings of its result tuple to the V_term variables,
    then the post statements."""
    name: str
    params: tuple[str, ...]
    pre: tuple[Stmt, ...]
    agg_call: Any                      # relational.plan.AggCall
    bind: tuple[str, ...]              # V_term, in result-tuple order
    post: tuple[Stmt, ...]
    returns: tuple[str, ...]
    aggregate: CustomAggregate = None
    var_dtypes: Mapping[str, Any] = dc_field(default_factory=dict)


def aggify(prog: Program, mode: str = "auto",
           group_keys: Sequence[str] = ()) -> RewrittenProgram:
    """Full Algorithm 1: build AggΔ, then replace the loop with
    𝒢_{AggΔ(P_accum)}(Q) (Eq. 5) or the order-enforced variant (Eq. 6)."""
    from repro.relational.plan import AggCall, strip_order

    agg = build_aggregate(prog)
    loop = prog.loop
    fetch_map = dict(loop.fetch)   # var -> column

    q = loop.query
    child, sort_keys, sort_desc = strip_order(q)
    ordered = bool(sort_keys)

    binding: list[tuple[str, Expr]] = []
    for p in agg.fetch_params:
        binding.append((p, Col(fetch_map[p])))
    for p in agg.outer_params:
        binding.append((p, Var(p)))

    call = AggCall(child=child, aggregate=agg,
                   param_binding=tuple(binding),
                   ordered=ordered, sort_keys=sort_keys, sort_desc=sort_desc,
                   group_keys=tuple(group_keys), mode=mode)

    pre = _dead_code_eliminate(prog, agg)
    return RewrittenProgram(
        name=prog.name, params=prog.params, pre=pre, agg_call=call,
        bind=agg.terminate_vars, post=prog.post, returns=prog.returns,
        aggregate=agg, var_dtypes=prog.var_dtypes)


def _dead_code_eliminate(prog: Program, agg: CustomAggregate) -> tuple[Stmt, ...]:
    """§6.2: 'This transformation may render some variables as dead' —
    backward sweep over the pre statements keeping only definitions that
    feed the rewritten query (fields P_0, outer params), the post
    statements, or the returns."""
    needed: set[str] = set(agg.fields) | set(agg.outer_params) | set(prog.returns)
    for s in flatten(prog.post):
        needed |= stmt_uses(s)
    kept: list[Stmt] = []
    for s in reversed(prog.pre):
        if isinstance(s, Assign):
            if s.var in needed:
                kept.append(s)
                needed |= stmt_uses(s)
            # else: dead — dropped (e.g. @pCost/@sName decls in Figure 7)
        elif isinstance(s, If):
            defs = assigned_vars([s])
            if defs & needed:
                kept.append(s)
                needed |= set().union(*(stmt_uses(x) for x in flatten([s])))
        else:
            kept.append(s)
    return tuple(reversed(kept))
