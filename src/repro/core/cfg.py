"""Control Flow Graph construction (paper §3.2, Figure 3).

Each statement is its own basic block, exactly as in the paper's Figure 3.
The cursor-loop skeleton is modeled faithfully:

    entry -> pre... -> FETCH0 -> WHILE hdr -> body... -> FETCHn -> WHILE hdr
                                      |(false)
                                      v
                                    post... -> exit

The FETCH nodes *define* the fetch variables; the WHILE header *uses* the
implicit ``@@FETCH_STATUS``.  Parameters are defined at the entry node so
that reaching-definitions distinguishes outer definitions from in-loop
definitions (Eq. 2/3 of the paper).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from .loop_ir import (Assign, CursorLoop, If, InsertLocal, Program, Stmt,
                      expr_vars, stmt_defs, stmt_uses)

FETCH_STATUS = "@@FETCH_STATUS"


@dataclass
class Node:
    nid: int
    kind: str               # entry|exit|assign|if|insert|fetch|while
    stmt: Optional[Stmt]
    defs: frozenset[str]
    uses: frozenset[str]
    in_loop_body: bool = False
    succs: list[int] = field(default_factory=list)
    preds: list[int] = field(default_factory=list)

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"<{self.nid}:{self.kind} defs={sorted(self.defs)} uses={sorted(self.uses)}>"


class CFG:
    def __init__(self) -> None:
        self.nodes: list[Node] = []
        self.entry: int = -1
        self.exit: int = -1
        # program points of interest for Aggify:
        self.loop_header: int = -1
        self.loop_exit_point: int = -1   # first node after the loop (post/exit)
        self.body_nodes: set[int] = set()
        self.fetch_nodes: set[int] = set()

    def add(self, kind: str, stmt: Optional[Stmt] = None,
            defs: Sequence[str] = (), uses: Sequence[str] = (),
            in_loop_body: bool = False) -> int:
        n = Node(len(self.nodes), kind, stmt, frozenset(defs), frozenset(uses),
                 in_loop_body)
        self.nodes.append(n)
        return n.nid

    def edge(self, a: int, b: int) -> None:
        if b not in self.nodes[a].succs:
            self.nodes[a].succs.append(b)
            self.nodes[b].preds.append(a)

    # -- construction -------------------------------------------------------

    @staticmethod
    def of_program(prog: Program) -> "CFG":
        if not isinstance(prog.loop, CursorLoop):
            raise TypeError("CFG.of_program expects a Program with a CursorLoop; "
                            "rewrite ForLoop via repro.core.for_loops first")
        g = CFG()
        # Entry defines the parameters (their values reach every use).
        g.entry = g.add("entry", defs=prog.params)
        frontier = [g.entry]

        def chain(stmts: Sequence[Stmt], frontier: list[int],
                  in_body: bool) -> list[int]:
            for s in stmts:
                frontier = _emit(g, s, frontier, in_body)
            return frontier

        frontier = chain(prog.pre, frontier, False)

        loop = prog.loop
        fvars = set(loop.fetch_vars) | {FETCH_STATUS}
        f0 = g.add("fetch", defs=fvars, uses=())
        g.fetch_nodes.add(f0)
        for p in frontier:
            g.edge(p, f0)

        hdr = g.add("while", uses=[FETCH_STATUS])
        g.loop_header = hdr
        g.edge(f0, hdr)

        body_start = len(g.nodes)
        body_frontier = chain(loop.body, [hdr], True)
        fn = g.add("fetch", defs=fvars, uses=(), in_loop_body=True)
        g.fetch_nodes.add(fn)
        for p in body_frontier:
            g.edge(p, fn)
        g.edge(fn, hdr)          # back edge
        g.body_nodes = set(range(body_start, len(g.nodes)))

        # loop exit -> post -> exit
        post_frontier = chain(prog.post, [hdr], False)
        g.exit = g.add("exit", uses=prog.returns)
        for p in post_frontier:
            g.edge(p, g.exit)
        # first node after the header on the false edge:
        g.loop_exit_point = g.nodes[hdr].succs[-1] if prog.post else g.exit
        return g


def _emit(g: CFG, s: Stmt, frontier: list[int], in_body: bool) -> list[int]:
    if isinstance(s, Assign):
        n = g.add("assign", s, defs=stmt_defs(s), uses=stmt_uses(s),
                  in_loop_body=in_body)
        for p in frontier:
            g.edge(p, n)
        return [n]
    if isinstance(s, InsertLocal):
        n = g.add("insert", s, defs=stmt_defs(s), uses=stmt_uses(s),
                  in_loop_body=in_body)
        for p in frontier:
            g.edge(p, n)
        return [n]
    if isinstance(s, If):
        c = g.add("if", s, uses=expr_vars(s.cond), in_loop_body=in_body)
        for p in frontier:
            g.edge(p, c)
        t_frontier = [c]
        for ts in s.then:
            t_frontier = _emit(g, ts, t_frontier, in_body)
        e_frontier = [c]
        for es in s.orelse:
            e_frontier = _emit(g, es, e_frontier, in_body)
        # merge point is implicit: both frontiers feed the next statement.
        # (when orelse is empty, e_frontier == [c]: the false edge.)
        return t_frontier + e_frontier
    raise TypeError(type(s))
