"""§8.2 — Optimizing iterative FOR loops.

A FOR loop with a fixed iteration structure is rewritten as a cursor loop
over an *iteration-space relation* (the paper uses a recursive CTE; our
engine's equivalent is the ``IterSpace`` leaf plan, which generates the
space from the loop's init/bound/step expressions at execution time — the
values need not be statically determinable, exactly as §8.2 requires).

Once rewritten, the loop is a standard cursor loop and Algorithm 1 applies.
XLA's static-shape discipline requires a capacity (maximum trip count);
rows beyond the dynamic bound are masked invalid.
"""
from __future__ import annotations

from repro.relational.plan import IterSpace

from .loop_ir import CursorLoop, ForLoop, Program


def rewrite_for(prog: Program, capacity: int) -> Program:
    """Program-with-ForLoop -> Program-with-CursorLoop over IterSpace."""
    loop = prog.loop
    if isinstance(loop, CursorLoop):
        return prog
    if not isinstance(loop, ForLoop):
        raise TypeError(type(loop))
    col = f"__iter_{loop.var}"
    q = IterSpace(init=loop.init, bound=loop.bound, step=loop.step,
                  inclusive=loop.inclusive, capacity=capacity, column=col)
    cl = CursorLoop(query=q, fetch=((loop.var, col),), body=loop.body)
    return Program(prog.name, prog.params, prog.pre, cl, prog.post,
                   prog.returns, prog.var_dtypes, prog.local_tables)
