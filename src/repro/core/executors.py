"""Executors: the faithful cursor baselines and the Aggify execution paths.

Baselines (paper §2.3 — what Aggify eliminates):
  * ``run_cursor(interpreted=True)``  — host-driven row-at-a-time evaluation
    (the client/JDBC or interpreted T-SQL model: per-row dispatch overhead).
  * ``run_cursor()``                  — in-engine sequential loop: the cursor
    query is **materialized** (temp table barrier), then folded row-by-row
    with ``lax.scan``.

Aggify paths (§5/§6 + our beyond-paper parallel modes):
  * ``mode='stream'``     — Eq. 6 streaming aggregate (sequential, pipelined,
                            no temp table).  Always available.
  * ``mode='chunked'``    — Merge-parallel partial aggregation (synthesized
                            merge; see recognize.py).
  * ``mode='recognized'`` — fully set-oriented closed form (no scan at all).
  * ``mode='fused'``      — grouped: recognized updates lowered onto the
                            fused Pallas segment-aggregate kernel
                            (kernels/segment_agg.py) — one VMEM-resident
                            pass computes every sum/count/min/max moment
                            AND the arg-extremum attaining-row index (the
                            kernel's index moment, tie-ordered) for every
                            recognized column; payload selection is then a
                            num_segments-sized take, and the remaining
                            update kinds (last/prod, wide-dtype fields)
                            stay on jnp segment ops in the same XLA
                            program.  Ungrouped, the closed form is
                            already one fused pass, so 'fused' coincides
                            with 'recognized'.
  * ``mode='auto'``       — fused > recognized > chunked > stream.

Grouped invocation (``AggCall.group_keys``) decorrelates per-group loops
(the paper's Q2/minCostSupp-per-part pattern) into a single pass — fused
(Pallas kernel), segment-vectorized (recognized), or one segmented scan
(generic).  Kernel backend selection: compiled on TPU, ``jax.ops.segment_*``
fallback on CPU/GPU; ``REPRO_SEGAGG_BACKEND`` ∈ {pallas, interpret, jnp}
overrides, and the legacy ``REPRO_SEGAGG_PALLAS=1`` forces the kernel
(interpret mode off-TPU).
"""
from __future__ import annotations

from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.relational import engine as _engine
from repro.relational.plan import AggCall
from repro.relational.table import Table

from . import recognize as _recognize
from .aggregate import fold_moments  # noqa: F401  (public re-export: the
#   incremental serving layer folds micro-batch moments through the same
#   door the grouped executors launch them from)
from .aggify import CustomAggregate, RewrittenProgram, aggify, exec_stmts
from .loop_ir import (Assign, Col, CursorLoop, Program, Var, assigned_vars,
                      eval_expr, expr_cols)


# ---------------------------------------------------------------------------
# Environment setup
# ---------------------------------------------------------------------------


def _default_for(prog, name):
    dt = prog.var_dtypes.get(name, jnp.float32)
    return jnp.zeros((), dtype=dt)


def _default_missing_fields(agg, env, outer_vals, var_dtypes) -> None:
    """Fill ``outer_vals`` defaults for aggregate fields absent from the
    caller environment.  Dtype resolution (shared by the grouped and
    ungrouped paths so they cannot diverge): the explicit ``var_dtypes``
    param wins, then the mapping the aggregate carried from
    ``Program.var_dtypes`` (the engine's plan-execution path has no way
    to pass the param), else float32."""
    dtypes = var_dtypes if var_dtypes is not None \
        else getattr(agg, "var_dtypes", None)
    for f in agg.fields:
        if f in env:
            outer_vals.setdefault(f, env[f])
        else:
            dt = (dtypes or {}).get(f, jnp.float32)
            outer_vals.setdefault(f, jnp.zeros((), dtype=dt))


def build_env(prog, catalog, params: Optional[Mapping[str, Any]] = None) -> dict:
    env: dict[str, Any] = {}
    for p in prog.params:
        if params is None or p not in params:
            raise ValueError(f"missing parameter {p!r}")
        env[p] = jnp.asarray(params[p])
    for tv, (dtypes, cap) in prog.local_tables.items():
        bufs = tuple(jnp.zeros((cap,), dtype=d) for d in dtypes)
        env[tv] = (bufs, jnp.array(0, jnp.int32))
    env = exec_stmts(prog.pre, env)
    return env


# ---------------------------------------------------------------------------
# Cursor baselines
# ---------------------------------------------------------------------------


def run_cursor(prog: Program, catalog, params=None, interpreted: bool = False):
    """Reference semantics: materialize Q, iterate Δ row-by-row."""
    env = build_env(prog, catalog, params)
    loop = prog.loop
    assert isinstance(loop, CursorLoop)
    t = _engine.execute(loop.query, catalog, env)
    t = t.compress().materialize()       # the temp-table barrier (§2.3)

    rows = {v: t.columns[c] for v, c in loop.fetch}
    valid = t.mask()
    state_vars = sorted(assigned_vars(loop.body))
    state0 = {v: env[v] if v in env else _default_for(prog, v)
              for v in state_vars}

    if interpreted:
        import numpy as np
        n = int(np.asarray(jnp.sum(valid)))
        st = dict(state0)
        for i in range(n):
            e = dict(env); e.update(st)
            e.update({v: jax.tree.map(lambda a: a[i], c)
                      for v, c in rows.items()})
            e2 = exec_stmts(loop.body, e)
            st = {v: e2[v] for v in state_vars}
        env.update(st)
    else:
        def step(state, xs):
            row, ok = xs
            e = dict(env); e.update(state); e.update(row)
            e2 = exec_stmts(loop.body, dict(e))
            new = {v: e2[v] for v in state_vars}
            new = jax.tree.map(lambda a, b: jnp.where(ok, a, b), new, state)
            return new, None

        final, _ = lax.scan(step, state0, (rows, valid))
        env.update(final)

    env = exec_stmts(prog.post, env)
    return {r: env[r] for r in prog.returns}


# ---------------------------------------------------------------------------
# Rewritten execution
# ---------------------------------------------------------------------------


def run_rewritten(rp: RewrittenProgram, catalog, params=None,
                  mode: Optional[str] = None, deferred_init: bool = False,
                  num_chunks: int = 8):
    env: dict[str, Any] = {}
    for p in rp.params:
        if params is None or p not in params:
            raise ValueError(f"missing parameter {p!r}")
        env[p] = jnp.asarray(params[p])
    agg = rp.aggregate
    for tv, (dtypes, cap) in agg.local_tables.items():
        bufs = tuple(jnp.zeros((cap,), dtype=d) for d in dtypes)
        env[tv] = (bufs, jnp.array(0, jnp.int32))
    env = exec_stmts(rp.pre, env)

    call = rp.agg_call if mode is None else AggCall(
        rp.agg_call.child, rp.agg_call.aggregate, rp.agg_call.param_binding,
        rp.agg_call.ordered, rp.agg_call.sort_keys, rp.agg_call.sort_desc,
        rp.agg_call.group_keys, mode, rp.agg_call.max_groups)
    vals = agg_call_values(call, catalog, env, deferred_init=deferred_init,
                           num_chunks=num_chunks, var_dtypes=rp.var_dtypes)
    env.update(vals)
    env = exec_stmts(rp.post, env)
    return {r: env[r] for r in rp.returns}


def run_aggify(prog: Program, catalog, params=None, mode: str = "auto",
               deferred_init: bool = False, num_chunks: int = 8):
    """Convenience: Algorithm 1 + execute."""
    rp = aggify(prog, mode=mode)
    return run_rewritten(rp, catalog, params, deferred_init=deferred_init,
                         num_chunks=num_chunks)


# ---------------------------------------------------------------------------
# AggCall evaluation
# ---------------------------------------------------------------------------


def fused_eligible(agg: CustomAggregate) -> bool:
    """True when the accumulator decomposes into moments the fused Pallas
    segment-aggregate kernel computes: at least one recognized sum/min/max
    update (counts are sums of 1; means are sum/count) or an argmin/argmax
    group, whose key extremum AND attaining-row index both come from the
    kernel (the index moment) — payload selection is then a single
    num_segments-sized take in the same XLA program."""
    return (agg.recognized is not None and not agg.local_tables
            and any(u.kind in ("sum", "min", "max", "arg_group")
                    for u in agg.recognized))


def _resolve_mode(call: AggCall, agg: CustomAggregate,
                  deferred_init: bool) -> str:
    mode = call.mode
    if deferred_init:
        # deferred V_init (paper §5.2) only exists on the streaming fold;
        # an explicit request for a parallel/closed-form mode cannot be
        # honored, so refuse it rather than silently running 'stream'
        if mode not in ("auto", "stream"):
            raise ValueError(
                f"deferred_init=True requires streaming execution; "
                f"incompatible with explicit mode={mode!r}")
        return "stream"
    if mode == "auto":
        if agg.recognized is not None and not agg.local_tables:
            return "recognized"
        if agg.mergeable:
            return "chunked"
        return "stream"
    if mode == "fused":
        # ungrouped: the closed form already is one fused pass
        if agg.recognized is None:
            raise ValueError(f"aggregate {agg.name!r} not recognized; cannot "
                             "run in fused mode")
        return "recognized"
    if mode == "recognized" and agg.recognized is None:
        raise ValueError(f"aggregate {agg.name!r} not recognized; cannot "
                         "run in recognized mode")
    if mode == "chunked" and not agg.mergeable:
        raise ValueError(f"aggregate {agg.name!r} has no merge")
    return mode


def _agg_call_needed(call: AggCall) -> tuple[str, ...]:
    """Columns an AggCall reads from its child: group/sort keys plus
    every Col its parameter bindings reference — the ``needed`` set the
    whole-plan fusion pass (relational/fuse.py) materializes."""
    need = list(call.group_keys) + list(call.sort_keys)
    for _name, e in call.param_binding:
        need.extend(sorted(expr_cols(e)))
    return tuple(need)


def agg_call_values(call: AggCall, catalog, env, deferred_init=False,
                    num_chunks: int = 8, var_dtypes=None) -> dict[str, Any]:
    """Evaluate 𝒢_{AggΔ}(Q) (ungrouped) → {V_term var: value}."""
    if call.group_keys:
        raise ValueError("grouped AggCall: use execute_agg_call / engine")
    agg: CustomAggregate = call.aggregate
    t = _engine.execute_for_agg(call.child, catalog, env,
                                _agg_call_needed(call))
    if call.ordered:
        t = t.sort_by(call.sort_keys, call.sort_desc)

    rows: dict[str, jax.Array] = {}
    outer_vals: dict[str, Any] = {}
    for name, e in call.param_binding:
        if isinstance(e, Col):
            rows[name] = t.columns[e.name]
        else:
            outer_vals[name] = eval_expr(e, env)
    _default_missing_fields(agg, env, outer_vals, var_dtypes)

    valid = t.mask()
    mode = _resolve_mode(call, agg, deferred_init)

    if mode == "recognized":
        col_env = dict(outer_vals)
        col_env.update(rows)
        outer_state = {f: jnp.asarray(outer_vals[f]) for f in agg.fields}
        out = _recognize.vectorized_eval(agg.recognized, col_env, valid,
                                         outer_state)
        return {v: out.get(v, outer_state[v]) for v in agg.terminate_vars}

    jagg = agg.as_jax_aggregate(outer_vals, deferred_init=deferred_init)
    from .aggregate import chunked, streaming
    if mode == "chunked":
        res = chunked(jagg, rows, valid, num_chunks=num_chunks)
    else:
        res = streaming(jagg, rows, valid)
    return dict(zip(agg.terminate_vars, res))


def execute_agg_call(call: AggCall, catalog, env,
                     var_dtypes=None) -> Table:
    """Engine entry point: returns a Table (1 row, or one row per group).
    ``var_dtypes`` (Program.var_dtypes) resolves the dtype of aggregate
    fields absent from ``env`` — without it they default to float32."""
    if call.group_keys:
        return grouped_agg_call(call, catalog, env, var_dtypes=var_dtypes)
    vals = agg_call_values(call, catalog, env, var_dtypes=var_dtypes)
    cols = {}
    for k, v in vals.items():
        a = jnp.asarray(v)
        cols[k] = a[None] if a.ndim == 0 else a[None, ...]
    return Table(cols, jnp.ones(1, dtype=bool))


# ---------------------------------------------------------------------------
# Grouped invocation (decorrelation)
# ---------------------------------------------------------------------------


#: recognized update kinds whose merge algebra is commutative — the
#: sort-free grouped route only fires when every update is one of these
#: ('last' is positional over the *iteration* order, so it stays sorted)
_ORDER_INSENSITIVE_KINDS = ("sum", "prod", "min", "max", "arg_group")


def _sortfree_eligible(call: AggCall, agg: CustomAggregate, mode: str,
                       bound) -> bool:
    """True when the grouped call may skip the group sort entirely: a
    dense bound is declared (the hash slot table is bucket-sized), the
    call is order-insensitive (no Eq.-6 ordering, no sort keys), the
    physical mode is set-oriented (the segmented scan IS sequential
    semantics), and every recognized update folds with a commutative
    merge."""
    from repro.relational.keyslot import sortfree_enabled
    return (bound is not None and sortfree_enabled()
            and not call.ordered and not call.sort_keys
            and mode in ("fused", "recognized")
            and agg.recognized is not None
            and all(u.kind in _ORDER_INSENSITIVE_KINDS
                    for u in agg.recognized))


def sortfree_call_route(call: AggCall, bound) -> bool:
    """Would this grouped AggCall take the (global-slot) sort-free route
    for the given validated bound?  Serving-layer entry point: the
    dispatcher below makes the same decision inline; a cache that wants
    to pre-build the slot table must predict it without executing."""
    if not call.group_keys:
        return False
    agg: CustomAggregate = call.aggregate
    try:
        mode = _resolve_grouped_mode(call, agg)
    except ValueError:
        return False
    return _sortfree_eligible(call, agg, mode, bound)


def grouped_agg_call(call: AggCall, catalog, env,
                     var_dtypes=None) -> Table:
    agg: CustomAggregate = call.aggregate
    t = _engine.execute_for_agg(call.child, catalog, env,
                                _agg_call_needed(call))
    # row-sharded input (Table.shard_rows): the fused path runs the kernel
    # per shard and all-reduces moments; detect BEFORE the sort, on the
    # columns the caller committed
    from repro.launch.sharded_agg import row_sharded_mesh
    shard_route = row_sharded_mesh(*t.columns.values(), t.valid)
    from repro.relational.engine import segment_ids_for
    from repro.relational.group_bound import (check_group_overflow,
                                              poison_overflow,
                                              resolve_group_bound)
    from repro.relational.keyslot import (overflow_extended,
                                          provided_slots,
                                          slot_segment_ids,
                                          sortfree_result)
    # dense segment range: AggCall-declared max_groups beats the table
    # hint; every segment tensor below (and the kernel / all-reduce
    # payload) is sized by it instead of the row capacity
    declared = call.max_groups if call.max_groups is not None \
        else t.group_bound
    nsegments, bound = resolve_group_bound(declared, t.capacity)
    # a provide_slots scope carrying this call's slot table beats the
    # per-shard launcher: the cached assignment is global and stable
    # across calls, so the segment ops use it directly under GSPMD
    if (shard_route is not None and bound is not None
            and provided_slots(tuple(call.group_keys), bound) is not None):
        shard_route = None
    cap = t.capacity
    mode = _resolve_grouped_mode(call, agg)

    # bind params against the unsorted table first: routing only consults
    # dtypes, and the sort-free route consumes these bindings as-is
    rows: dict[str, jax.Array] = {}
    outer_vals: dict[str, Any] = {}
    for name, e in call.param_binding:
        if isinstance(e, Col):
            rows[name] = t.columns[e.name]
        else:
            outer_vals[name] = eval_expr(e, env)
    _default_missing_fields(agg, env, outer_vals, var_dtypes)

    sortfree = _sortfree_eligible(call, agg, mode, bound)
    updates_split = None
    if sortfree and shard_route is not None:
        # sharded sort-free assigns slots per shard inside the launcher —
        # only viable when the WHOLE aggregate lowers to the kernel pass
        # (jnp-routed leftovers would need global segment ids), arg
        # updates included: past the f32-exact index ceiling their
        # legacy select tail needs global ids too
        from repro.kernels.segment_agg import index_moment_ok
        col_env = dict(outer_vals)
        col_env.update(rows)
        kernel_updates, rest = _split_kernel_updates(agg, outer_vals,
                                                     col_env)
        if (mode != "fused" or rest or not kernel_updates
                or (any(u.kind == "arg_group" for u in kernel_updates)
                    and not index_moment_ok(cap))):
            sortfree = False
        else:
            updates_split = (kernel_updates, rest)

    cols: dict[str, jax.Array] = {}
    if sortfree:
        st, m = t, t.mask()
        if shard_route is not None:
            out, (rep, out_valid, unplaced) = _grouped_fused(
                agg, rows, outer_vals, m, None, nsegments,
                backend=_segagg_backend(),
                require_kernel=call.mode == "fused",
                shard_route=shard_route,
                sortfree_keys=tuple(call.group_keys), table=st,
                updates_split=updates_split)
        else:
            seg, owner, occupied, unplaced = slot_segment_ids(
                t, call.group_keys, bound)
            rep, out_valid = overflow_extended(owner, occupied, cap)
            if mode == "fused":
                out = _grouped_fused(agg, rows, outer_vals, m, seg,
                                     nsegments, backend=_segagg_backend(),
                                     require_kernel=call.mode == "fused",
                                     layout="unsorted")
            else:
                out = _grouped_recognized(agg, rows, outer_vals, m, seg,
                                          nsegments)
        return sortfree_result(st, call.group_keys, rep, out_valid,
                               unplaced, bound,
                               {v: out[v] for v in agg.terminate_vars})

    sort_keys = tuple(call.group_keys) + tuple(call.sort_keys)
    sort_desc = (False,) * len(call.group_keys) + tuple(
        call.sort_desc or (False,) * len(call.sort_keys))
    st, seg, starts = segment_ids_for(
        t.sort_by(sort_keys, sort_desc), call.group_keys,
        num_segments=nsegments)
    # note: sort_by in segment_ids_for re-sorts by group keys only (stable),
    # preserving the intra-group order established above.
    m = st.mask()
    nseg = jnp.sum(starts.astype(jnp.int32))
    overflow_ok = check_group_overflow(nseg, bound)
    out_valid = jnp.arange(nsegments) < nseg

    # re-bind fetch-derived params against the SORTED rows
    for name, e in call.param_binding:
        if isinstance(e, Col):
            rows[name] = st.columns[e.name]

    first_idx = jnp.where(starts, jnp.arange(cap), cap)
    first_of_seg = jax.ops.segment_min(first_idx, seg,
                                       num_segments=nsegments)
    safe_first = jnp.clip(first_of_seg, 0, cap - 1)
    for k in call.group_keys:
        cols[k] = jnp.take(st.columns[k], safe_first)

    if mode == "fused":
        out = _grouped_fused(agg, rows, outer_vals, m, seg, nsegments,
                             backend=_segagg_backend(),
                             require_kernel=call.mode == "fused",
                             shard_route=shard_route)
    elif mode == "recognized":
        out = _grouped_recognized(agg, rows, outer_vals, m, seg, nsegments)
    else:
        out = _grouped_scan(agg, rows, outer_vals, m, starts, seg,
                            nsegments)
    for v in agg.terminate_vars:
        cols[v] = out[v]
    return Table(poison_overflow(cols, overflow_ok), out_valid)


def _resolve_grouped_mode(call: AggCall, agg: CustomAggregate) -> str:
    """Grouped physical-mode selection: fused > recognized > scan.
    'stream' and 'chunked' both lower to the generic segmented scan (the
    per-group sequential semantics; chunk-parallelism within a segment is
    an open item)."""
    mode = call.mode
    recognized = agg.recognized is not None and not agg.local_tables
    if mode == "auto":
        if fused_eligible(agg):
            return "fused"
        return "recognized" if recognized else "scan"
    if mode == "fused":
        if not fused_eligible(agg):
            raise ValueError(
                f"aggregate {agg.name!r} has no fused-eligible recognized "
                "updates (sum/min/max/argmin/argmax); cannot run in fused "
                "mode")
        return "fused"
    if mode == "recognized":
        if not recognized:
            raise ValueError(f"aggregate {agg.name!r} not recognized; cannot "
                             "run in recognized mode")
        return "recognized"
    if mode == "chunked" and not agg.mergeable:
        raise ValueError(f"aggregate {agg.name!r} has no merge")
    return "scan"


def _segagg_backend() -> str:
    """Kernel backend for the fused grouped path: compiled on TPU, pure-JAX
    segment ops on CPU/GPU (the interpreter loop is test-only).  A
    thread-local ``reliability.degrade.force_backend`` scope wins over
    everything — the serving circuit breaker traces its degraded
    executable under it.  Env overrides: REPRO_SEGAGG_BACKEND, or legacy
    REPRO_SEGAGG_PALLAS=1."""
    from repro.configs import flags
    from repro.reliability.degrade import forced_backend
    forced = forced_backend()
    if forced is not None:
        return forced
    env = flags.choice("REPRO_SEGAGG_BACKEND", ("pallas", "interpret", "jnp"))
    if env is not None:
        return env
    on_tpu = jax.default_backend() == "tpu"
    if flags.value("REPRO_SEGAGG_PALLAS") == "1":
        return "pallas" if on_tpu else "interpret"
    return "pallas" if on_tpu else "jnp"


def _f32_exact_key_dtype(dt) -> bool:
    """True when every value of ``dt`` survives the cast to the kernel's
    f32 accumulator exactly: ≤32-bit floats (f16/bf16 embed exactly),
    bools, and ≤16-bit ints.  Wide ints and float64 can collide after the
    cast, which would mis-pick the attaining row of an arg-extremum — key
    expressions of those dtypes route to the exact jnp path (mirroring
    the f32-exactness gating of the count/mean built-ins)."""
    d = jnp.dtype(dt)
    if jnp.issubdtype(d, jnp.floating):
        return d.itemsize <= 4
    if d == jnp.bool_:
        return True
    if jnp.issubdtype(d, jnp.integer):
        return d.itemsize <= 2
    return False


def _split_kernel_updates(agg, outer_vals, col_env):
    """Partition the recognized updates into (kernel_updates, rest): the
    fused kernel accumulates in f32, so only sum/min/max/arg_group
    updates over ≤32-bit floating fields — with f32-exactly-embeddable
    arg keys — take the kernel pass; everything else stays on the jnp
    segment ops (in the same XLA program)."""
    kernel_updates = []
    rest = []
    for u in agg.recognized:
        d = jnp.asarray(outer_vals[u.fields[0]]).dtype
        # the kernel accumulates in f32: float64 fields would silently
        # lose precision, so they stay on the jnp path in their own dtype
        ok = (u.kind in ("sum", "min", "max", "arg_group")
              and jnp.issubdtype(d, jnp.floating)
              and jnp.dtype(d).itemsize <= 4)
        if ok and u.kind == "arg_group":
            # ... and so would wide-int/f64 KEY EXPRESSIONS (not just
            # fields): distinct keys that collide in f32 would mis-pick
            # the attaining row, so those route to the exact path too
            # (eval_shape: the dtype probe must not evaluate the N-row
            # expression a second time under eager execution)
            ok = _f32_exact_key_dtype(
                jax.eval_shape(lambda u=u: jnp.asarray(
                    eval_expr(u.exprs[0], col_env))).dtype)
        (kernel_updates if ok else rest).append(u)
    return kernel_updates, rest


def _grouped_fused(agg, rows, outer_vals, valid, seg, num_segments, backend="auto",
                   require_kernel=False, shard_route=None,
                   layout="sorted", sortfree_keys=None, table=None,
                   updates_split=None):
    """Fused grouped aggregation: every recognized sum/min/max/arg-extremum
    update over a ≤32-bit floating field is batched into ONE fused
    segment-aggregate pass (each column carries its own guard mask, so
    differently-guarded updates still share the traversal); remaining
    updates (prod/last, float64/integer fields, wide-int/f64 arg-extremum
    keys) run on the jnp segment path in the same XLA program.

    Arg-extremum updates additionally request the kernel's INDEX MOMENT:
    the attaining row index comes back as output rows 4/5 with the loop's
    tie order, so the whole update is consumed with a num_segments-sized
    payload take — no hit-detection equality scan, no full-row candidate
    reduce, no row-capacity-sized gather (``_arg_select_from_index``).

    ``require_kernel`` (an explicit ``mode='fused'`` request) raises
    instead of silently running a kernel-free pass when every update is
    dtype-routed to jnp.  ``shard_route`` = (mesh, axis) routes the kernel
    pass through ``launch.sharded_agg.sharded_fused_segment_agg`` — one
    kernel launch per row shard, moments all-reduced over the mesh axis,
    arg-extremum payloads gathered shard-locally and merged as
    O(num_segments) collectives (never O(rows)).

    SORT-FREE variants: ``layout='unsorted'`` runs the identical pass on
    hash-slotted segment ids (no pre-sort happened).  ``sortfree_keys``
    (+ ``table``, sharded only) hands slotting to the launcher itself —
    each shard slots its own rows and the merge is key-aligned; ``seg``
    is unused and the return value becomes ``(out, (rep_rows, out_valid,
    unplaced))`` so the caller recovers representatives and validity
    without global segment ids."""
    from repro.kernels.segment_agg import (ARGMAX_ROW, ARGMIN_ROW,
                                           fused_segment_agg,
                                           index_moment_ok)

    col_env = dict(outer_vals)
    col_env.update(rows)
    n = valid.shape[0]
    # f32 row indices are exact below 2^24 PADDED rows (the same gate the
    # kernel validates); beyond that the arg-extremum keeps the kernel
    # key extremum but falls back to the legacy jnp pick
    use_index = index_moment_ok(n)

    kernel_updates, rest = (updates_split if updates_split is not None
                            else _split_kernel_updates(agg, outer_vals,
                                                       col_env))
    if require_kernel and not kernel_updates:
        raise ValueError(
            f"aggregate {agg.name!r}: no recognized update targets a ≤32-bit "
            "floating field (the kernel accumulates in f32), so mode='fused' "
            "would run no kernel work — use mode='recognized' or 'auto'")

    out: dict[str, jax.Array] = {}
    if kernel_updates:
        cols = []
        masks = []
        moments: list[set] = []    # per kernel column
        col_of: dict = {}          # (expr, guard[, tie]) -> column index
        upd_col = []
        upd_mname = []             # index-moment name per update (or None)
        for u in kernel_updates:
            ck = (u.exprs[0], u.guard)
            mname = None
            if u.kind == "arg_group" and use_index:
                minimize = u.op in ("<", "<=")
                tie_first = u.op in ("<", ">")
                mname = (("argmin" if minimize else "argmax")
                         + ("_first" if tie_first else "_last"))
                conflict = (("argmin" if minimize else "argmax")
                            + ("_last" if tie_first else "_first"))
                if ck in col_of and conflict in moments[col_of[ck]]:
                    # one index row per extremum direction: an update with
                    # the opposite tie order gets its own column
                    ck = ck + (mname,)
            if ck not in col_of:    # min+max over one column share a pass
                g = valid
                if u.guard is not None:
                    g = g & jnp.asarray(eval_expr(u.guard, col_env), bool)
                e = jnp.broadcast_to(
                    jnp.asarray(eval_expr(u.exprs[0], col_env), jnp.float32),
                    (n,))
                col_of[ck] = len(cols)
                cols.append(e)
                masks.append(g)
                moments.append(set())
            c = col_of[ck]
            upd_col.append(c)
            upd_mname.append(mname)
            if u.kind == "arg_group":
                moments[c].add("min" if u.op in ("<", "<=") else "max")
                if mname is not None:
                    moments[c].add(mname)
            else:
                moments[c].add(u.kind)
        kernel_moments = tuple(tuple(sorted(ms)) for ms in moments)

        # sharded route: payload candidates are gathered SHARD-LOCALLY and
        # merged inside the all-reduce, so evaluate them up front
        payload_specs = []
        payload_slot = {}          # update position -> slot in the result
        if shard_route is not None:
            for j, (u, c, mname) in enumerate(zip(kernel_updates, upd_col,
                                                  upd_mname)):
                if mname is None:
                    continue
                pvals = tuple(
                    jnp.broadcast_to(
                        jnp.asarray(eval_expr(pe, col_env),
                                    jnp.asarray(outer_vals[f]).dtype), (n,))
                    for f, pe in zip(u.fields[1:], u.exprs[1:]))
                payload_slot[j] = len(payload_specs)
                payload_specs.append((c, u.op in ("<", "<="), pvals))

        # sorted layout: the grouped sort established the sorted-segs
        # precondition by construction, so the band-pruned kernel skips
        # its guard; unsorted layout (sort-free) never had an order
        payload_picks = ()
        sortfree_extras = None
        if sortfree_keys is not None:
            from repro.launch.sharded_agg import \
                sharded_sortfree_segment_agg
            from repro.relational.keyslot import key_words_for
            kw = key_words_for(table.columns[k] for k in sortfree_keys)
            fused, payload_picks, rep, occupied, unplaced = \
                sharded_sortfree_segment_agg(
                    jnp.stack(cols, axis=1), kw, jnp.stack(masks, axis=1),
                    valid, num_segments, num_segments - 1,
                    mesh=shard_route[0], axis=shard_route[1],
                    backend=backend, moments=kernel_moments,
                    payloads=tuple(payload_specs))
            sortfree_extras = (rep, occupied, unplaced)
        elif shard_route is not None:
            from repro.launch.sharded_agg import sharded_fused_segment_agg
            res = sharded_fused_segment_agg(
                jnp.stack(cols, axis=1), seg.astype(jnp.int32),
                jnp.stack(masks, axis=1), num_segments, mesh=shard_route[0],
                axis=shard_route[1], backend=backend,
                moments=kernel_moments, assume_sorted=True,
                payloads=tuple(payload_specs))
            fused, payload_picks = res if payload_specs else (res, ())
        else:
            from repro.reliability import faults as _faults
            _faults.fail("kernel_launch")
            fused = fused_segment_agg(
                jnp.stack(cols, axis=1), seg.astype(jnp.int32),
                jnp.stack(masks, axis=1), num_segments, backend=backend,
                moments=kernel_moments, assume_sorted=True, layout=layout)
        for j, (u, c) in enumerate(zip(kernel_updates, upd_col)):
            f = u.fields[0]
            d = jnp.asarray(outer_vals[f]).dtype
            g, key = masks[c], cols[c]
            if u.kind == "arg_group":
                minimize = u.op in ("<", "<=")
                best = fused[c, 2 if minimize else 3].astype(d)
                if upd_mname[j] is not None:
                    pick = _index_row_to_pick(
                        fused[c, ARGMIN_ROW if minimize else ARGMAX_ROW],
                        n, tie_first=u.op in ("<", ">"))
                    pre = (payload_picks[payload_slot[j]]
                           if j in payload_slot else None)
                    _arg_select_from_index(u, outer_vals, col_env, best,
                                           pick, n, out, payloads=pre)
                else:
                    worst = _recognize._MINMAX_ID[
                        "min" if minimize else "max"](d)
                    masked = jnp.where(g, key.astype(d), worst)
                    _arg_group_select(u, outer_vals, col_env, g, masked,
                                      best, seg, num_segments, out)
                continue
            r = fused[c, {"sum": 0, "min": 2, "max": 3}[u.kind]].astype(d)
            if u.kind == "sum":
                out[f] = outer_vals[f] + r
            elif u.kind == "min":
                out[f] = jnp.minimum(outer_vals[f], r)
            else:
                out[f] = jnp.maximum(outer_vals[f], r)
    if rest:
        out.update(_grouped_recognized(agg, rows, outer_vals, valid, seg,
                                       num_segments, updates=tuple(rest)))
    if sortfree_keys is not None:
        # the caller pre-checked rest == [] and kernel_updates != [], so
        # sortfree_extras was always produced on this path
        return out, sortfree_extras
    return out


def _index_row_to_pick(idx_row: jax.Array, n: int,
                       tie_first: bool) -> jax.Array:
    """Convert a kernel index-moment row (f32, tie identity ±inf for empty
    segments) to the int32 pick convention of the select tails: ``n`` is
    the empty sentinel for first-attaining tie order, ``-1`` for
    last-attaining.  The ±inf → sentinel mapping happens in f32, BEFORE
    the int cast (casting inf to int is undefined)."""
    if tie_first:
        return jnp.where(idx_row < n, idx_row, n).astype(jnp.int32)
    return jnp.where(idx_row >= 0, idx_row, -1).astype(jnp.int32)


def _arg_select_from_index(u, outer_vals, col_env, best, pick, n, out,
                           payloads=None) -> None:
    """Arg-extremum tail on the kernel's index moment: the attaining row
    arrives directly from the fused pass (tie order already applied), so
    the legacy hit-detection equality scan, the full-row candidate reduce,
    and the row-set-sized ``take(best, seg)`` all disappear — the only
    remaining data movement is ONE num_segments-sized payload take per
    payload column.  ``payloads`` (the sharded path) are per-segment
    candidates already gathered shard-locally; then no local take runs at
    all.  The beat-compare against the pre-loop state is unchanged."""
    kf = u.fields[0]
    got = (pick >= 0) & (pick < n)
    cmp = {"<": best < outer_vals[kf], "<=": best <= outer_vals[kf],
           ">": best > outer_vals[kf], ">=": best >= outer_vals[kf]}[u.op]
    beat = cmp & got
    out[kf] = jnp.where(beat, best, outer_vals[kf])
    safe = jnp.clip(pick, 0, n - 1)
    for i, (f, pe) in enumerate(zip(u.fields[1:], u.exprs[1:])):
        pd = jnp.asarray(outer_vals[f]).dtype
        if payloads is not None:
            pv_pick = payloads[i].astype(pd)
        else:
            pv = jnp.broadcast_to(jnp.asarray(eval_expr(pe, col_env), pd),
                                  (n,))
            pv_pick = jnp.take(pv, safe)
        out[f] = jnp.where(beat, pv_pick, outer_vals[f])


def _arg_group_select(u, outer_vals, col_env, g, masked, best, seg, num_segments,
                      out) -> None:
    """Legacy tail of the grouped argmin/argmax lowering (the jnp
    recognized path and the >2^24-row kernel fallback): given the
    per-segment key extremum ``best``, pick the attaining row with a
    hit-detection equality scan (first for strict comparisons, last for
    non-strict — matching the sequential loop's tie order), gather the
    payload columns, and beat-compare against the pre-loop state.  The
    fused path replaces this with ``_arg_select_from_index`` (the kernel's
    index moment), which issues no row-capacity-sized gather."""
    n = masked.shape[0]
    idx = jnp.arange(n)
    hit = g & (masked == jnp.take(best, seg))
    cand = jnp.where(hit, idx, (n if u.op in ("<", ">") else -1))
    pickfn = jax.ops.segment_min if u.op in ("<", ">") else jax.ops.segment_max
    pick = pickfn(cand, seg, num_segments=num_segments)
    _arg_select_from_index(u, outer_vals, col_env, best, pick, n, out)


def _grouped_recognized(agg, rows, outer_vals, valid, seg, num_segments,
                        updates=None):
    """Segment-vectorized recognized aggregation on ``jax.ops.segment_*``
    (``updates`` restricts to a subset — used by the fused path for the
    kinds the kernel does not cover)."""
    col_env = dict(outer_vals)
    col_env.update(rows)
    out: dict[str, jax.Array] = {}
    n = valid.shape[0]
    idx = jnp.arange(n)
    for u in (agg.recognized if updates is None else updates):
        g = valid
        if u.guard is not None:
            g = g & jnp.asarray(eval_expr(u.guard, col_env), bool)
        if u.kind in ("sum", "prod", "min", "max"):
            f = u.fields[0]
            d = jnp.asarray(outer_vals[f]).dtype
            e = jnp.broadcast_to(jnp.asarray(eval_expr(u.exprs[0], col_env), d), (n,))
            if u.kind == "sum":
                out[f] = outer_vals[f] + jax.ops.segment_sum(
                    jnp.where(g, e, 0), seg, num_segments=num_segments)
            elif u.kind == "prod":
                out[f] = outer_vals[f] * jax.ops.segment_prod(
                    jnp.where(g, e, 1), seg, num_segments=num_segments)
            elif u.kind == "min":
                r = jax.ops.segment_min(
                    jnp.where(g, e, _recognize._MINMAX_ID["min"](d)), seg,
                    num_segments=num_segments)
                out[f] = jnp.minimum(outer_vals[f], r)
            else:
                r = jax.ops.segment_max(
                    jnp.where(g, e, _recognize._MINMAX_ID["max"](d)), seg,
                    num_segments=num_segments)
                out[f] = jnp.maximum(outer_vals[f], r)
        elif u.kind == "arg_group":
            kf = u.fields[0]
            kd = jnp.asarray(outer_vals[kf]).dtype
            key = jnp.broadcast_to(jnp.asarray(eval_expr(u.exprs[0], col_env), kd), (n,))
            minimize = u.op in ("<", "<=")
            worst = _recognize._MINMAX_ID["min" if minimize else "max"](kd)
            masked = jnp.where(g, key, worst)
            segfn = jax.ops.segment_min if minimize else jax.ops.segment_max
            best = segfn(masked, seg, num_segments=num_segments)
            _arg_group_select(u, outer_vals, col_env, g, masked, best,
                              seg, num_segments, out)
        elif u.kind == "last":
            f = u.fields[0]
            pd = jnp.asarray(outer_vals[f]).dtype
            e = jnp.broadcast_to(jnp.asarray(eval_expr(u.exprs[0], col_env), pd), (n,))
            cand = jnp.where(g, idx, -1)
            pick = jax.ops.segment_max(cand, seg, num_segments=num_segments)
            got = pick >= 0
            out[f] = jnp.where(got, jnp.take(e, jnp.clip(pick, 0, n - 1)),
                               outer_vals[f])
        else:  # pragma: no cover
            raise ValueError(u.kind)
    return out


def _grouped_scan(agg, rows, outer_vals, valid, starts, seg, num_segments):
    """Generic grouped custom aggregate: ONE segmented scan pass — state
    resets at segment starts; per-segment final states gathered at segment
    ends and terminated."""
    jagg = agg.as_jax_aggregate(outer_vals, deferred_init=False)
    init_state = jagg.init()

    def step(state, xs):
        row, ok, is_start = xs
        st = jax.tree.map(lambda i, s: jnp.where(is_start, i, s),
                          init_state, state)
        new = jagg.accumulate(st, row)
        new = jax.tree.map(lambda a, b: jnp.where(ok, a, b), new, st)
        return new, new

    n = valid.shape[0]
    state0 = jax.tree.map(lambda x: x, init_state)
    _, states = lax.scan(step, state0, (rows, valid, starts))

    # last row index of each segment
    idx = jnp.arange(n)
    cand = jnp.where(valid, idx, -1)
    last = jax.ops.segment_max(cand, seg, num_segments=num_segments)
    safe = jnp.clip(last, 0, n - 1)
    seg_states = jax.tree.map(lambda s: jnp.take(s, safe, axis=0), states)
    terms = jax.vmap(jagg.terminate)(seg_states)
    out = dict(zip(agg.terminate_vars, terms))
    # empty segments fall back to pre-loop values
    got = last >= 0
    for v in agg.terminate_vars:
        out[v] = jnp.where(got, out[v], outer_vals.get(v, jnp.zeros_like(out[v])))
    return out
