"""Loop IR — the language model of Section 4.2 of the paper.

A cursor loop is ``CL(Q, Δ)``: a query ``Q`` plus a program fragment ``Δ``
evaluated once per result row (Definition 4.1).  This module defines the
typed AST for ``Δ`` and the enclosing program, plus expression evaluation.

The same expression AST is reused by the relational layer for vectorized
predicate/projection evaluation (a column environment instead of a scalar
one), which is what makes *acyclic code motion* (paper §8.1) a pure IR
transplant: an expression hoisted out of the loop body becomes a WHERE
predicate with identical semantics.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Expressions
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Expr:
    def __add__(self, o): return BinOp("+", self, wrap(o))
    def __radd__(self, o): return BinOp("+", wrap(o), self)
    def __sub__(self, o): return BinOp("-", self, wrap(o))
    def __rsub__(self, o): return BinOp("-", wrap(o), self)
    def __mul__(self, o): return BinOp("*", self, wrap(o))
    def __rmul__(self, o): return BinOp("*", wrap(o), self)
    def __truediv__(self, o): return BinOp("/", self, wrap(o))
    def __rtruediv__(self, o): return BinOp("/", wrap(o), self)
    def __mod__(self, o): return BinOp("%", self, wrap(o))
    def __lt__(self, o): return BinOp("<", self, wrap(o))
    def __le__(self, o): return BinOp("<=", self, wrap(o))
    def __gt__(self, o): return BinOp(">", self, wrap(o))
    def __ge__(self, o): return BinOp(">=", self, wrap(o))
    def eq(self, o): return BinOp("==", self, wrap(o))
    def ne(self, o): return BinOp("!=", self, wrap(o))
    def and_(self, o): return BinOp("and", self, wrap(o))
    def or_(self, o): return BinOp("or", self, wrap(o))
    def __neg__(self): return UnOp("neg", self)


@dataclass(frozen=True)
class Const(Expr):
    value: Any
    dtype: Optional[str] = None


@dataclass(frozen=True)
class Var(Expr):
    """A program (scalar) variable reference."""
    name: str


@dataclass(frozen=True)
class Col(Expr):
    """A cursor-column reference (an attribute of the current row of Q)."""
    name: str


@dataclass(frozen=True)
class BinOp(Expr):
    op: str
    lhs: Expr
    rhs: Expr


@dataclass(frozen=True)
class UnOp(Expr):
    op: str
    operand: Expr


@dataclass(frozen=True)
class Where(Expr):
    """Ternary select ``cond ? t : f`` (pure expression-level branch)."""
    cond: Expr
    t: Expr
    f: Expr


@dataclass(frozen=True)
class Call(Expr):
    """Pure scalar function invocation (e.g. the ``getLowerBound`` UDF in
    the paper's Figure 1).  ``fn`` must be a pure jnp-compatible callable."""
    name: str
    fn: Callable[..., Any]
    args: tuple[Expr, ...]


def wrap(x: Any) -> Expr:
    if isinstance(x, Expr):
        return x
    return Const(x)


_BINOPS: dict[str, Callable[[Any, Any], Any]] = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "%": lambda a, b: a % b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "and": jnp.logical_and,
    "or": jnp.logical_or,
    "min": jnp.minimum,
    "max": jnp.maximum,
    "pow": lambda a, b: a ** b,
}

_UNOPS: dict[str, Callable[[Any], Any]] = {
    "neg": lambda a: -a,
    "not": jnp.logical_not,
    "abs": jnp.abs,
    "log": jnp.log,
    "exp": jnp.exp,
    "sqrt": jnp.sqrt,
    "float": lambda a: a.astype(jnp.float32) if hasattr(a, "astype") else float(a),
}


def eval_expr(e: Expr, env: Mapping[str, Any]) -> Any:
    """Evaluate an expression under ``env`` (vars and cols share the
    namespace; columns are bound by the executor).  Works identically for
    scalar (per-row) and vectorized (whole-column) environments."""
    if isinstance(e, Const):
        v = e.value
        if e.dtype is not None:
            return jnp.asarray(v, dtype=e.dtype)
        return v
    if isinstance(e, Var):
        return env[e.name]
    if isinstance(e, Col):
        return env[e.name]
    if isinstance(e, BinOp):
        return _BINOPS[e.op](eval_expr(e.lhs, env), eval_expr(e.rhs, env))
    if isinstance(e, UnOp):
        return _UNOPS[e.op](eval_expr(e.operand, env))
    if isinstance(e, Where):
        return jnp.where(eval_expr(e.cond, env), eval_expr(e.t, env), eval_expr(e.f, env))
    if isinstance(e, Call):
        return e.fn(*(eval_expr(a, env) for a in e.args))
    raise TypeError(f"unknown expression node {type(e)}")


def expr_vars(e: Expr) -> set[str]:
    """All Var names referenced by ``e``."""
    out: set[str] = set()
    _walk(e, lambda n: out.add(n.name) if isinstance(n, Var) else None)
    return out


def expr_cols(e: Expr) -> set[str]:
    out: set[str] = set()
    _walk(e, lambda n: out.add(n.name) if isinstance(n, Col) else None)
    return out


def _walk(e: Expr, visit: Callable[[Expr], None]) -> None:
    visit(e)
    if isinstance(e, BinOp):
        _walk(e.lhs, visit); _walk(e.rhs, visit)
    elif isinstance(e, UnOp):
        _walk(e.operand, visit)
    elif isinstance(e, Where):
        _walk(e.cond, visit); _walk(e.t, visit); _walk(e.f, visit)
    elif isinstance(e, Call):
        for a in e.args:
            _walk(a, visit)


def substitute(e: Expr, mapping: Mapping[str, Expr]) -> Expr:
    """Replace Var references by expressions (used by code motion / FOR
    rewrite)."""
    if isinstance(e, Var) and e.name in mapping:
        return mapping[e.name]
    if isinstance(e, BinOp):
        return BinOp(e.op, substitute(e.lhs, mapping), substitute(e.rhs, mapping))
    if isinstance(e, UnOp):
        return UnOp(e.op, substitute(e.operand, mapping))
    if isinstance(e, Where):
        return Where(substitute(e.cond, mapping), substitute(e.t, mapping), substitute(e.f, mapping))
    if isinstance(e, Call):
        return Call(e.name, e.fn, tuple(substitute(a, mapping) for a in e.args))
    return e


def vars_to_cols(e: Expr, names: Iterable[str]) -> Expr:
    """Rewrite Var(v)->Col(c) per a fetch binding (used by acyclic code
    motion to turn a loop predicate into a query predicate)."""
    names = set(names)
    if isinstance(e, Var) and e.name in names:
        return Col(e.name)
    if isinstance(e, BinOp):
        return BinOp(e.op, vars_to_cols(e.lhs, names), vars_to_cols(e.rhs, names))
    if isinstance(e, UnOp):
        return UnOp(e.op, vars_to_cols(e.operand, names))
    if isinstance(e, Where):
        return Where(vars_to_cols(e.cond, names), vars_to_cols(e.t, names), vars_to_cols(e.f, names))
    if isinstance(e, Call):
        return Call(e.name, e.fn, tuple(vars_to_cols(a, names) for a in e.args))
    return e


# --------------------------------------------------------------------------
# Statements
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Stmt:
    pass


@dataclass(frozen=True)
class Assign(Stmt):
    var: str
    expr: Expr


@dataclass(frozen=True)
class If(Stmt):
    cond: Expr
    then: tuple[Stmt, ...]
    orelse: tuple[Stmt, ...] = ()

    def __init__(self, cond, then, orelse=()):
        object.__setattr__(self, "cond", cond)
        object.__setattr__(self, "then", tuple(then))
        object.__setattr__(self, "orelse", tuple(orelse))


@dataclass(frozen=True)
class InsertLocal(Stmt):
    """INSERT INTO a *local* table variable (supported per paper §4.2:
     'DML operations on local table variables ... are supported')."""
    table_var: str
    values: tuple[Expr, ...]

    def __init__(self, table_var, values):
        object.__setattr__(self, "table_var", table_var)
        object.__setattr__(self, "values", tuple(values))


def stmt_uses(s: Stmt) -> set[str]:
    """Var names *used* (read) by a statement (non-recursive into branches:
    for If, only the condition; branch statements are separate CFG nodes)."""
    if isinstance(s, Assign):
        return expr_vars(s.expr)
    if isinstance(s, If):
        return expr_vars(s.cond)
    if isinstance(s, InsertLocal):
        out: set[str] = set()
        for e in s.values:
            out |= expr_vars(e)
        out.add(s.table_var)
        return out
    raise TypeError(type(s))


def stmt_defs(s: Stmt) -> set[str]:
    if isinstance(s, Assign):
        return {s.var}
    if isinstance(s, If):
        return set()
    if isinstance(s, InsertLocal):
        return {s.table_var}
    raise TypeError(type(s))


def body_vars(stmts: Sequence[Stmt]) -> set[str]:
    """All variables referenced (used or defined) in a statement list,
    recursively — this is V_Δ of paper Eq. 1 (columns excluded)."""
    out: set[str] = set()
    for s in flatten(stmts):
        out |= stmt_uses(s) | stmt_defs(s)
    return out


def body_cols(stmts: Sequence[Stmt]) -> set[str]:
    out: set[str] = set()
    for s in flatten(stmts):
        if isinstance(s, Assign):
            out |= expr_cols(s.expr)
        elif isinstance(s, If):
            out |= expr_cols(s.cond)
        elif isinstance(s, InsertLocal):
            for e in s.values:
                out |= expr_cols(e)
    return out


def assigned_vars(stmts: Sequence[Stmt]) -> set[str]:
    out: set[str] = set()
    for s in flatten(stmts):
        out |= stmt_defs(s)
    return out


def flatten(stmts: Sequence[Stmt]) -> list[Stmt]:
    """Depth-first list of statements including branch bodies."""
    out: list[Stmt] = []
    for s in stmts:
        out.append(s)
        if isinstance(s, If):
            out.extend(flatten(s.then))
            out.extend(flatten(s.orelse))
    return out


# --------------------------------------------------------------------------
# Loops and programs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class CursorLoop:
    """CL(Q, Δ).  ``query`` is any object implementing the QuerySource
    protocol (``columns`` property; ``order_by`` property; ``execute``) —
    the relational layer provides LogicalPlan.  ``fetch`` binds query
    columns to loop variables in FETCH order."""
    query: Any
    fetch: tuple[tuple[str, str], ...]  # (var_name, column_name)
    body: tuple[Stmt, ...]

    def __init__(self, query, fetch, body):
        object.__setattr__(self, "query", query)
        object.__setattr__(self, "fetch", tuple((v, c) for v, c in fetch))
        object.__setattr__(self, "body", tuple(body))

    @property
    def fetch_vars(self) -> tuple[str, ...]:
        return tuple(v for v, _ in self.fetch)


@dataclass(frozen=True)
class ForLoop:
    """FOR (var=init; var </<= bound; var+=step) { body } — §8.2.
    init/bound/step are expressions over program variables (values need not
    be statically determinable, exactly as the paper requires)."""
    var: str
    init: Expr
    bound: Expr
    step: Expr
    body: tuple[Stmt, ...]
    inclusive: bool = True

    def __init__(self, var, init, bound, step, body, inclusive=True):
        object.__setattr__(self, "var", var)
        object.__setattr__(self, "init", wrap(init))
        object.__setattr__(self, "bound", wrap(bound))
        object.__setattr__(self, "step", wrap(step))
        object.__setattr__(self, "body", tuple(body))
        object.__setattr__(self, "inclusive", inclusive)


@dataclass(frozen=True)
class Program:
    """The module enclosing the cursor loop (e.g. the UDF in Figure 1).

    ``params``: formal parameters (defined at entry).
    ``pre``:    statements before the loop.
    ``loop``:   the cursor loop (or ForLoop before rewriting).
    ``post``:   statements after the loop.
    ``returns``: variables returned (their liveness extends to exit).
    ``var_dtypes``: optional dtype hints for state variables.
    ``local_tables``: name -> (column dtypes tuple, capacity) for local
                      table variables (InsertLocal targets).
    """
    name: str
    params: tuple[str, ...]
    pre: tuple[Stmt, ...]
    loop: Union[CursorLoop, ForLoop]
    post: tuple[Stmt, ...]
    returns: tuple[str, ...]
    var_dtypes: Mapping[str, Any] = field(default_factory=dict)
    local_tables: Mapping[str, Any] = field(default_factory=dict)

    def __init__(self, name, params, pre, loop, post, returns,
                 var_dtypes=None, local_tables=None):
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "params", tuple(params))
        object.__setattr__(self, "pre", tuple(pre))
        object.__setattr__(self, "loop", loop)
        object.__setattr__(self, "post", tuple(post))
        object.__setattr__(self, "returns", tuple(returns))
        object.__setattr__(self, "var_dtypes", dict(var_dtypes or {}))
        object.__setattr__(self, "local_tables", dict(local_tables or {}))


# Convenience builders ------------------------------------------------------

def let(var: str, e: Any) -> Assign:
    return Assign(var, wrap(e))


def minimum(a: Any, b: Any) -> Expr:
    return BinOp("min", wrap(a), wrap(b))


def maximum(a: Any, b: Any) -> Expr:
    return BinOp("max", wrap(a), wrap(b))
