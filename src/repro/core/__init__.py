"""repro.core — Aggify: cursor-loop → custom-aggregate compilation (the
paper's contribution), plus the aggregation contract and its parallel
execution combinators used across the framework (relational engine, decode
attention, SSD scan, MoE dispatch)."""
from .aggregate import (Aggregate, associative_scan, chunked, shard_merge,
                        streaming, tree_reduce)
from .aggify import (AggifyAnalysis, CustomAggregate, NotAggifyable,
                     RewrittenProgram, aggify, analyze_loop, build_aggregate,
                     check_applicability, exec_stmts, is_aggifyable)
from .cfg import CFG, FETCH_STATUS
from .code_motion import apply_acyclic_code_motion
from .dataflow import analyze
from .executors import (agg_call_values, execute_agg_call, fused_eligible,
                        grouped_agg_call, run_aggify, run_cursor,
                        run_rewritten)
from .for_loops import rewrite_for
from .loop_ir import (Assign, BinOp, Call, Col, Const, CursorLoop, Expr,
                      ForLoop, If, InsertLocal, Program, Stmt, UnOp, Var,
                      Where, let, maximum, minimum, wrap)

__all__ = [
    "Aggregate", "associative_scan", "chunked", "shard_merge", "streaming",
    "tree_reduce", "AggifyAnalysis", "CustomAggregate", "NotAggifyable",
    "RewrittenProgram", "aggify", "analyze_loop", "build_aggregate",
    "check_applicability", "exec_stmts", "is_aggifyable", "CFG",
    "FETCH_STATUS", "apply_acyclic_code_motion", "analyze",
    "agg_call_values", "execute_agg_call", "fused_eligible",
    "grouped_agg_call", "run_aggify",
    "run_cursor", "run_rewritten", "rewrite_for", "Assign", "BinOp", "Call",
    "Col", "Const", "CursorLoop", "Expr", "ForLoop", "If", "InsertLocal",
    "Program", "Stmt", "UnOp", "Var", "Where", "let", "maximum", "minimum",
    "wrap",
]
