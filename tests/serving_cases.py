"""Shared case runner for the serving differential fuzzer.

A *case* is a plain dict of ints/strings/floats — deterministically
expanded into (table, plan, parameter stream) by ``build_case`` — so the
hypothesis fuzzer (tests/test_serving_differential.py) and the checked-in
seed corpus (tests/test_serving_corpus.py) replay the exact same code
path; a fuzzer failure minimizes to a dict that goes straight into
``CORPUS`` and reproduces without hypothesis installed.

``run_case`` asserts bit-for-bit parity across every route that applies:

* sort-free vs the numpy oracle (grouping by canonical key words — the
  bitwise semantics keyslot.py documents: ±0 collapse, NaNs group per
  bit pattern);
* sorted vs sort-free and sorted vs oracle — skipped when the case
  carries NaN keys, where the routes *diverge by design* (the sorted
  route's value-equality adjacency splinters NaNs into one group per
  row; the bitwise route groups them);
* server-cached (compiled-plan + slot-table caches) vs fresh, twice, so
  the second call exercises a warm cache;
* batched (concurrent ``submit`` coalesced into one vmapped launch) vs
  sequential.

Aggregate inputs are integer-valued and small (|v| ≤ 2, |w| ≤ 8) so
every float32 summation order is exact and "parity" can mean *equality*,
not tolerance."""
from __future__ import annotations

import os
from contextlib import contextmanager

import jax.numpy as jnp
import numpy as np

from repro.core.loop_ir import Col, Var
from repro.relational import Table, execute
from repro.relational import keyslot
from repro.relational.plan import Filter, GroupAgg, Scan
from repro.serve import AggServer

#: GroupAgg ops the fuzzer draws from; arg-extremum ops aggregate the
#: ("v", "w") pair (payload w of the first row attaining v's extremum)
OPS = ("sum", "count", "min", "max", "mean", "prod", "argmin", "argmax")

#: key-column generators by drawn dtype name.  64-bit inputs
#: intentionally pass through jnp's default-config canonicalization
#: (int64→int32, float64→float32 when x64 is off) — the parity contract
#: is over the table as stored, whatever the config stores.
KEY_DTYPES = ("int32", "int16", "int64", "float32", "float64", "bool")

#: float key value pool: exercises ±0 collapse; NaN appended per-case
_FLOAT_KEYS = (0.0, -0.0, 1.5, -2.25, 3.5, -0.5)


@contextmanager
def _env(name: str, value):
    old = os.environ.get(name)
    try:
        if value is None:
            os.environ.pop(name, None)
        else:
            os.environ[name] = value
        yield
    finally:
        if old is None:
            os.environ.pop(name, None)
        else:
            os.environ[name] = old


def build_case(case: dict):
    """Expand a case dict into (table, plan, param-env stream)."""
    rng = np.random.default_rng(case["seed"])
    n = case["n"]
    card = case.get("card", 5)
    nan_keys = case.get("nan_keys", False)
    cols = {}
    keys = []
    for i, dt in enumerate(case["key_dtypes"]):
        name = f"k{i}"
        keys.append(name)
        if dt == "bool":
            cols[name] = rng.integers(0, 2, n).astype(bool)
        elif dt.startswith("int"):
            cols[name] = rng.integers(0, card, n).astype(dt)
        else:
            pool = list(_FLOAT_KEYS[:max(2, card)])
            if nan_keys:
                pool[0] = np.nan
            cols[name] = np.asarray(pool, dt)[rng.integers(0, len(pool), n)]
    cols["v"] = rng.integers(-2, 3, n).astype(np.float32)
    cols["w"] = rng.integers(-8, 9, n).astype(np.float32)
    valid = rng.random(n) >= case.get("invalid_frac", 0.0)
    if not valid.any():
        valid[0] = True
    t = Table({k: jnp.asarray(v) for k, v in cols.items()},
              jnp.asarray(valid))

    schema = tuple(keys) + ("v", "w")
    child = Scan("T", schema)
    if case.get("filtered", False):
        child = Filter(child, Col("v") >= Var("lo"))
    aggs = []
    for i, op in enumerate(case["aggs"]):
        col = None if op == "count" else \
            ("v", "w") if op in ("argmin", "argmax") else "v"
        aggs.append((f"a{i}", op, col))
    plan = _intern(GroupAgg(child, tuple(keys), tuple(aggs),
                            max_groups=case.get("max_groups")))
    envs = [{"lo": float(p)} for p in case.get("params", ())] \
        if case.get("filtered", False) else [{}]
    return t, plan, tuple(keys), tuple(aggs), envs


# one plan object per structure: the server caches per plan identity, so
# interning lets 200 fuzz examples share executables instead of each
# example retracing its structurally-identical plan
_PLANS: dict = {}


def _intern(plan):
    return _PLANS.setdefault(plan, plan)


# one server across all cases — exactly how production reuses caches;
# update_table per case exercises the invalidation path constantly
_SERVER = None


def server() -> AggServer:
    global _SERVER
    if _SERVER is None:
        _SERVER = AggServer({"T": Table.from_columns(z=np.zeros(1))},
                            max_batch=8, batch_window_s=0.0)
    return _SERVER


# -- oracle -----------------------------------------------------------------


def _group_rows(t: Table, keys, env):
    """Row-index lists per group, keyed by canonical-word byte tuples, in
    first-appearance order — the bitwise grouping semantics."""
    words = np.asarray(keyslot.key_words_for(t.columns[k] for k in keys))
    mask = np.asarray(t.mask())
    if env:   # parameterized filter semantics of the fuzz plan
        mask = mask & (np.asarray(t.columns["v"]) >= np.float32(env["lo"]))
    groups: dict = {}
    for i in np.nonzero(mask)[0]:
        groups.setdefault(words[i].tobytes(), []).append(int(i))
    return groups


def oracle(t: Table, keys, aggs, env) -> dict:
    """numpy reference: canonical-word grouping + float32 aggregation in
    the same formulas the engine uses (exact on integer-valued data)."""
    v = np.asarray(t.columns["v"])
    w = np.asarray(t.columns["w"])
    out = {}
    for wkey, rows in _group_rows(t, keys, env).items():
        gv = v[rows].astype(np.float32)
        vals = {}
        for name, op, _col in aggs:
            if op == "sum":
                vals[name] = np.float32(gv.sum())
            elif op == "count":
                vals[name] = np.int32(len(rows))
            elif op == "min":
                vals[name] = np.float32(gv.min())
            elif op == "max":
                vals[name] = np.float32(gv.max())
            elif op == "mean":
                vals[name] = np.float32(gv.sum()) / np.float32(len(rows))
            elif op == "prod":
                vals[name] = np.float32(np.prod(gv))
            elif op in ("argmin", "argmax"):
                best = gv.min() if op == "argmin" else gv.max()
                first = rows[int(np.nonzero(gv == best)[0][0])]
                vals[name] = np.float32(w[first])
            else:
                raise ValueError(op)
        out[wkey] = vals
    return out


def result_groups(table: Table, keys, aggs) -> dict:
    """A result Table's valid rows as {canonical-word bytes: {agg: value}}
    — the order-insensitive form every route comparison uses."""
    words = np.asarray(keyslot.key_words_for(table.columns[k] for k in keys))
    mask = np.asarray(table.mask())
    out = {}
    for i in np.nonzero(mask)[0]:
        wkey = words[i].tobytes()
        assert wkey not in out, "duplicate group row in result"
        out[wkey] = {name: np.asarray(table.columns[name])[i]
                     for name, _op, _col in aggs}
    return out


def assert_same_groups(got: dict, want: dict, label: str):
    assert set(got) == set(want), \
        f"{label}: group sets differ ({len(got)} vs {len(want)})"
    for wkey, vals in want.items():
        for name, ref in vals.items():
            g = got[wkey][name]
            assert np.array_equal(np.asarray(g), np.asarray(ref),
                                  equal_nan=True), \
                f"{label}: {name} differs: {g!r} != {ref!r}"


# -- the differential runner ------------------------------------------------


def run_case(case: dict) -> None:
    t, plan, keys, aggs, envs = build_case(case)
    cat = {"T": t}
    srv = server()
    srv.update_table("T", t)

    for env in envs:
        ref = oracle(t, keys, aggs, env)
        # fresh sort-free (the default route when a bound is declared)
        r_sf = execute(plan, cat, env)
        assert_same_groups(result_groups(r_sf, keys, aggs), ref,
                           "sortfree vs oracle")
        # fresh sorted route
        with _env("REPRO_GROUPAGG_SORTFREE", "off"):
            r_sorted = execute(plan, cat, env)
        if not case.get("nan_keys", False):
            assert_same_groups(result_groups(r_sorted, keys, aggs), ref,
                               "sorted vs oracle")
        # server-cached vs fresh — twice, so the second run is warm
        for _ in range(2):
            r_cached = srv.execute(plan, env)
            assert_same_groups(result_groups(r_cached, keys, aggs), ref,
                               "cached vs fresh")

    # batched vs sequential: the whole parameter stream concurrently
    if len(envs) > 1:
        futs = [srv.submit(plan, env) for env in envs]
        for fut, env in zip(futs, envs):
            got = result_groups(fut.result(timeout=120), keys, aggs)
            want = result_groups(srv.execute(plan, env), keys, aggs)
            assert_same_groups(got, want, "batched vs sequential")


# -- seed corpus ------------------------------------------------------------
# Regressions replay without hypothesis: every past fuzzer failure (and a
# hand-picked spread of the generator's corners) lives here as data.

CORPUS = [
    # single int key, the moment family, declared bound
    {"seed": 1, "n": 160, "key_dtypes": ("int32",), "card": 6,
     "aggs": ("sum", "count", "min", "max"), "max_groups": 24},
    # inferred bound (max_groups absent): server sketches + validates
    {"seed": 2, "n": 192, "key_dtypes": ("int32",), "card": 5,
     "aggs": ("sum", "mean")},
    # float key incl. ±0 collapse
    {"seed": 3, "n": 150, "key_dtypes": ("float32",), "card": 6,
     "aggs": ("sum", "prod"), "max_groups": 16},
    # NaN keys: bitwise grouping (sorted-route comparison skipped)
    {"seed": 4, "n": 144, "key_dtypes": ("float32",), "card": 4,
     "nan_keys": True, "aggs": ("sum", "count"), "max_groups": 16},
    # 64-bit key dtypes through default-config canonicalization
    {"seed": 5, "n": 176, "key_dtypes": ("int64", "float64"), "card": 3,
     "aggs": ("max", "argmin"), "max_groups": 32},
    # composite key with bool, arg-extrema, invalid rows
    {"seed": 6, "n": 208, "key_dtypes": ("bool", "int16"), "card": 4,
     "invalid_frac": 0.3, "aggs": ("argmax", "argmin", "sum"),
     "max_groups": 16},
    # parameterized filter child: executable cache + batching, slots
    # derived inside the trace (child is not a Scan)
    {"seed": 7, "n": 168, "key_dtypes": ("int32",), "card": 5,
     "filtered": True, "params": (-1.0, 0.0, 1.0, 2.0),
     "aggs": ("sum", "count", "max"), "max_groups": 16},
    # repeated parameters: same-shape requests coalesce
    {"seed": 8, "n": 160, "key_dtypes": ("int32", "float32"), "card": 3,
     "filtered": True, "params": (0.0, 0.0, 1.0, 0.0, 1.0),
     "aggs": ("mean", "min"), "max_groups": 32},
    # heavy invalidity + tiny table still above the sort-free floor
    {"seed": 9, "n": 136, "key_dtypes": ("int32",), "card": 2,
     "invalid_frac": 0.6, "aggs": ("prod", "sum", "argmax"),
     "max_groups": 8},
]
