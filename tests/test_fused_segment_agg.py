"""The fused grouped execution path (``mode='fused'``).

Four layers under test, all in Pallas interpret mode so CI needs no TPU:

1. the multi-column, segment-tiled kernel vs the pure-jnp oracle;
2. band pruning: the compact O(row_blocks + seg_tiles) grid executes the
   step count ``pruned_grid_steps`` predicts (ISSUE 2 acceptance bound on
   the sorted N=200k / S=8192 workload), matches the unpruned
   cross-product grid bit-for-bit, and validates the sorted-``segs``
   precondition instead of silently mis-aggregating;
3. grouped ``AggCall`` parity: ``mode='fused'`` must equal ``mode='stream'``
   (the sequential per-group semantics) on TPC-H-style grouped loops,
   including empty contributions, single-row segments, and segment counts
   exceeding one kernel tile;
4. the engine's built-in ``GroupAgg`` served from the fused kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Assign, BinOp, Col, Const, CursorLoop, If, Program,
                        Var, aggify, build_aggregate, fused_eligible, let,
                        run_rewritten)
from repro.core.executors import _resolve_grouped_mode
from repro.kernels import ref
from repro.kernels.segment_agg import (LANE, default_block_segs,
                                       full_grid_steps, fused_segment_agg,
                                       pruned_grid_steps, segment_agg)
from repro.relational import GroupAgg, Scan, Table, execute
from repro.relational.plan import AggCall, Filter

from helpers import fig1_program


# --------------------------------------------------------------------------
# 1. kernel: multi-column + segment tiling vs oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,nseg,ncols,block_rows,block_segs", [
    (64, 8, 1, 16, 8),          # single column, single tile
    (200, 50, 3, 32, 16),       # 4 segment tiles
    (500, 300, 2, 128, 128),    # 3 tiles, wide segment range
    (100, 7, 4, 256, None),     # rows < block, default tile
])
def test_fused_kernel_vs_oracle(n, nseg, ncols, block_rows, block_segs):
    rng = np.random.default_rng(n * ncols + nseg)
    segs = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    vals = rng.uniform(-10, 10, (n, ncols)).astype(np.float32)
    valid = rng.random((n, ncols)) < 0.85
    got = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                            jnp.asarray(valid), nseg, block_rows=block_rows,
                            block_segs=block_segs, backend="interpret")
    want = ref.fused_segment_agg_ref(jnp.asarray(vals), jnp.asarray(segs),
                                     jnp.asarray(valid), nseg)
    assert got.shape == (ncols, 4, nseg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_kernel_jnp_backend_matches_interpret():
    rng = np.random.default_rng(3)
    n, nseg = 150, 40
    segs = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    vals = rng.uniform(-5, 5, (n, 2)).astype(np.float32)
    valid = rng.random((n, 2)) < 0.7
    a = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                          jnp.asarray(valid), nseg, backend="jnp")
    b = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                          jnp.asarray(valid), nseg, block_segs=16,
                          backend="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_fused_kernel_per_column_masks():
    """Each column carries its own validity — differently-guarded updates
    share one pass but aggregate different row subsets."""
    segs = jnp.asarray(np.array([0, 0, 1, 1], np.int32))
    vals = jnp.asarray(np.array([[1., 10.], [2., 20.], [3., 30.], [4., 40.]],
                                np.float32))
    valid = jnp.asarray(np.array([[True, False], [True, True],
                                  [False, True], [True, True]]))
    out = np.asarray(fused_segment_agg(vals, segs, valid, 2,
                                       backend="interpret"))
    assert out[0, 0, 0] == 3.0 and out[0, 1, 0] == 2.0      # col0 seg0
    assert out[1, 0, 0] == 20.0 and out[1, 1, 0] == 1.0     # col1 seg0
    assert out[1, 2, 1] == 30.0 and out[1, 3, 1] == 40.0    # col1 seg1 min/max


def test_legacy_single_column_api_unchanged():
    segs = jnp.asarray(np.array([0, 0, 2, 2], np.int32))
    vals = jnp.asarray(np.array([1., 2., 3., 4.], np.float32))
    valid = jnp.asarray(np.array([True, True, False, False]))
    got = segment_agg(vals, segs, valid, 3, block_rows=4, interpret=True)
    assert got.shape == (3,) + () or got.shape == (4, 3)
    assert float(got[0, 0]) == 3.0
    assert float(got[1, 2]) == 0.0
    assert np.isinf(float(got[2, 2]))


def test_default_block_segs_alignment_and_budget():
    """Satellite invariants: every tile width is a multiple of the 128-lane
    VPU width (no ragged membership-mask reduces), at least one lane tile,
    at most the segment range rounded up to a lane multiple, and within
    the VMEM budget whenever the budget admits one lane group."""
    for nseg in (1, 10, 100, 512, 8192, 1 << 20):
        for br in (8, 128, 256, 1024, 4096):
            bs = default_block_segs(nseg, br)
            assert bs % LANE == 0
            assert bs >= LANE
            assert bs <= -(-nseg // LANE) * LANE      # lane-rounded range cap
    bs = default_block_segs(1 << 20, 256)
    assert bs * 256 <= 1 << 19                        # mask fits the budget
    assert default_block_segs(1 << 20, 4096) == LANE  # budget floor: 1 lane tile
    assert default_block_segs(10, 256) == LANE        # small ranges pad up


# --------------------------------------------------------------------------
# 2. band pruning: executed steps, parity, sorted-precondition guard
# --------------------------------------------------------------------------


def _sorted_workload(n, nseg, ncols=1, seed=2):
    rng = np.random.default_rng(seed)
    segs = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    vals = rng.uniform(-10, 10, (n, ncols)).astype(np.float32)
    valid = rng.random((n, ncols)) < 0.9
    return segs, vals, valid


def test_pruned_vs_unpruned_and_oracle_parity():
    """The pruned grid visits every intersecting (row_block, seg_tile)
    pair in the same order the cross-product grid does — same arithmetic,
    bit-identical output — while executing far fewer steps."""
    segs, vals, valid = _sorted_workload(5000, 600, ncols=3)
    kw = dict(block_rows=128, block_segs=128, backend="interpret")
    pr = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                           jnp.asarray(valid), 600, **kw)
    un = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                           jnp.asarray(valid), 600, prune=False, **kw)
    want = ref.fused_segment_agg_ref(jnp.asarray(vals), jnp.asarray(segs),
                                     jnp.asarray(valid), 600)
    assert np.array_equal(np.asarray(pr), np.asarray(un))
    np.testing.assert_allclose(np.asarray(pr), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    executed = pruned_grid_steps(segs, 600, 128, 128)
    full = full_grid_steps(5000, 600, 128, 128)
    assert executed <= (5000 // 128 + 1) + 2 * (600 // 128 + 1)
    assert executed * 3 < full


def test_pruned_grid_steps_acceptance_200k():
    """ISSUE 2 acceptance: a sorted N=200k / S=8192 workload executes at
    most row_blocks + 2·seg_tiles grid steps — vs the row_blocks ×
    seg_tiles cross product the unpruned grid walks."""
    n, nseg = 200_000, 8192
    rng = np.random.default_rng(42)
    segs = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    bs = default_block_segs(nseg, 256)
    row_blocks = -(-n // 256)
    seg_tiles = -(-nseg // bs)
    executed = pruned_grid_steps(segs, nseg, 256)
    assert executed <= row_blocks + 2 * seg_tiles
    assert full_grid_steps(n, nseg, 256) == row_blocks * seg_tiles
    assert executed * 3 < full_grid_steps(n, nseg, 256)


def test_pruned_interpret_parity_200k():
    """Acceptance workload under the interpreter: the band-pruned kernel
    == the unpruned kernel == the jnp oracle on N=200k / S=8192."""
    n, nseg = 200_000, 8192
    rng = np.random.default_rng(42)
    segs = jnp.asarray(np.sort(rng.integers(0, nseg, n)).astype(np.int32))
    vals = jnp.asarray(rng.uniform(-10, 10, n).astype(np.float32))
    valid = jnp.ones(n, bool)
    pr = fused_segment_agg(vals, segs, valid, nseg, backend="interpret")
    un = fused_segment_agg(vals, segs, valid, nseg, backend="interpret",
                           prune=False)
    want = fused_segment_agg(vals, segs, valid, nseg, backend="jnp")
    assert np.array_equal(np.asarray(pr), np.asarray(un))
    np.testing.assert_allclose(np.asarray(pr), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_pruned_unvisited_tiles_hold_identities():
    """Sparse segment use (all rows in segment 0 of a wide range): the
    pruned grid never visits most output tiles, which must still read the
    moment identities [0, 0, +inf, -inf], not uninitialized memory."""
    n, nseg = 256, 600
    vals = jnp.ones((n, 1), jnp.float32)
    segs = jnp.zeros(n, jnp.int32)
    out = np.asarray(fused_segment_agg(vals, segs, jnp.ones((n, 1), bool),
                                       nseg, backend="interpret",
                                       block_rows=128, block_segs=128))
    assert out[0, 0, 0] == n and out[0, 1, 0] == n
    assert np.all(out[0, 0, 1:] == 0) and np.all(out[0, 1, 1:] == 0)
    assert np.all(np.isposinf(out[0, 2, 1:]))
    assert np.all(np.isneginf(out[0, 3, 1:]))


def test_pruning_validates_sorted_precondition():
    """Unsorted segs under pruning: concrete input raises eagerly; traced
    input poisons the output with NaN (never a silently wrong aggregate);
    prune=False remains order-independent."""
    segs, vals, valid = _sorted_workload(400, 50)
    bad = segs[::-1].copy()
    kw = dict(block_rows=64, block_segs=16, backend="interpret")
    with pytest.raises(ValueError, match="sorted"):
        fused_segment_agg(jnp.asarray(vals), jnp.asarray(bad),
                          jnp.asarray(valid), 50, **kw)
    un = fused_segment_agg(jnp.asarray(vals), jnp.asarray(bad),
                           jnp.asarray(valid), 50, prune=False, **kw)
    want_bad = ref.fused_segment_agg_ref(jnp.asarray(vals),
                                         jnp.asarray(bad),
                                         jnp.asarray(valid), 50)
    np.testing.assert_allclose(np.asarray(un), np.asarray(want_bad),
                               rtol=1e-5, atol=1e-5)

    f = jax.jit(lambda s: fused_segment_agg(
        jnp.asarray(vals), s, jnp.asarray(valid), 50, **kw))
    assert np.all(np.isnan(np.asarray(f(jnp.asarray(bad)))))
    want = ref.fused_segment_agg_ref(jnp.asarray(vals), jnp.asarray(segs),
                                     jnp.asarray(valid), 50)
    np.testing.assert_allclose(np.asarray(f(jnp.asarray(segs))),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


def test_assume_sorted_skips_guard():
    """Callers that sort by construction (the grouped executors) skip both
    the eager check and the traced NaN guard."""
    segs, vals, valid = _sorted_workload(300, 40)
    out = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                            jnp.asarray(valid), 40, backend="interpret",
                            block_rows=64, block_segs=16,
                            assume_sorted=True)
    want = ref.fused_segment_agg_ref(jnp.asarray(vals), jnp.asarray(segs),
                                     jnp.asarray(valid), 40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# 3. grouped AggCall: fused == stream on TPC-H-style loops
# --------------------------------------------------------------------------


def _catalog(n=600, nparts=37, seed=0):
    rng = np.random.default_rng(seed)
    return {"PARTSUPP": Table.from_columns(
        ps_partkey=np.sort(rng.integers(0, nparts, n)).astype(np.int32),
        ps_suppkey=rng.integers(0, 100, n).astype(np.int32),
        ps_supplycost=rng.uniform(1, 100, n).astype(np.float32))}


_PS_SCHEMA = ("ps_partkey", "ps_suppkey", "ps_supplycost")


def _sum_count_prog():
    """Mean-style pattern: guarded sum + count (the mean decomposition)."""
    return Program(
        "sumCount", params=(),
        pre=[let("tot", Const(0.0)), let("cnt", Const(0.0))],
        loop=CursorLoop(
            Scan("PARTSUPP", _PS_SCHEMA),
            fetch=[("c", "ps_supplycost")],
            body=[If(Var("c") > Const(20.0),
                     [Assign("tot", Var("tot") + Var("c"))]),
                  Assign("cnt", Var("cnt") + Const(1.0))]),
        post=[], returns=("tot", "cnt"))


def _minmax_prog():
    return Program(
        "minMax", params=(),
        pre=[let("lo", Const(1e9)), let("hi", Const(-1e9))],
        loop=CursorLoop(
            Scan("PARTSUPP", _PS_SCHEMA),
            fetch=[("c", "ps_supplycost")],
            body=[Assign("lo", BinOp("min", Var("lo"), Var("c"))),
                  Assign("hi", BinOp("max", Var("hi"), Var("c")))]),
        post=[], returns=("lo", "hi"))


def _grouped_call(prog, mode, strip_filter=False):
    rp = aggify(prog)
    child = rp.agg_call.child
    if strip_filter:
        assert isinstance(child, Filter)
        child = child.child
    return AggCall(child, rp.agg_call.aggregate, rp.agg_call.param_binding,
                   rp.agg_call.ordered, rp.agg_call.sort_keys,
                   rp.agg_call.sort_desc, group_keys=("ps_partkey",),
                   mode=mode), rp


def _assert_grouped_parity(prog, env, cat, strip_filter=False,
                           monkeypatch=None):
    ref_call, _ = _grouped_call(prog, "stream", strip_filter)
    want = execute(ref_call, cat, env).to_numpy()
    fused_call, _ = _grouped_call(prog, "fused", strip_filter)
    got = execute(fused_call, cat, env).to_numpy()
    assert set(want) == set(got)
    for k in want:
        np.testing.assert_allclose(np.asarray(want[k], np.float32),
                                   np.asarray(got[k], np.float32),
                                   rtol=1e-5, atol=1e-5)
    return want


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_grouped_fused_parity_sum_count(backend, monkeypatch):
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", backend)
    env = {"tot": jnp.float32(0.0), "cnt": jnp.float32(0.0)}
    _assert_grouped_parity(_sum_count_prog(), env, _catalog())


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_grouped_fused_parity_minmax(backend, monkeypatch):
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", backend)
    env = {"lo": jnp.float32(1e9), "hi": jnp.float32(-1e9)}
    _assert_grouped_parity(_minmax_prog(), env, _catalog())


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_grouped_fused_parity_argmin_q2(backend, monkeypatch):
    """The paper's Figure-1 minCostSupp loop, decorrelated per part:
    arg_group key extremum from the kernel, payload gather on jnp."""
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", backend)
    rng = np.random.default_rng(5)
    n = 400
    cat = {
        "PARTSUPP": Table.from_columns(
            ps_partkey=np.sort(rng.integers(0, 23, n)).astype(np.int32),
            ps_suppkey=rng.integers(0, 40, n).astype(np.int32),
            ps_supplycost=rng.uniform(1, 50, n).astype(np.float32)),
        "SUPPLIER": Table.from_columns(
            s_suppkey=np.arange(40, dtype=np.int32),
            s_name=rng.permutation(40).astype(np.int32)),
    }
    env = {"lb": jnp.float32(4.0), "minCost": jnp.float32(100000.0),
           "suppName": jnp.int32(-1)}
    _assert_grouped_parity(fig1_program(), env, cat, strip_filter=True)


def test_grouped_fused_empty_contribution_groups(monkeypatch):
    """A guard that excludes every row of some groups: those segments must
    fall back to the pre-loop state (min identity +inf never leaks)."""
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", "interpret")
    n = 60
    rng = np.random.default_rng(9)
    cost = rng.uniform(1, 10, n).astype(np.float32)
    key = np.sort(rng.integers(0, 6, n)).astype(np.int32)
    cost[key % 2 == 0] = 5.0      # even groups never pass the >100 guard
    cat = {"PARTSUPP": Table.from_columns(
        ps_partkey=key, ps_suppkey=np.zeros(n, np.int32),
        ps_supplycost=cost)}
    prog = Program(
        "guardedMin", params=(),
        pre=[let("mn", Const(777.0))],
        loop=CursorLoop(
            Scan("PARTSUPP", _PS_SCHEMA),
            fetch=[("c", "ps_supplycost")],
            body=[If(Var("c") > Const(100.0),
                     [Assign("mn", BinOp("min", Var("mn"), Var("c")))])]),
        post=[], returns=("mn",))
    env = {"mn": jnp.float32(777.0)}
    out = _assert_grouped_parity(prog, env, cat)
    assert np.all(out["mn"] == 777.0)     # nothing ever passes the guard


def test_grouped_fused_single_row_segments(monkeypatch):
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", "interpret")
    n = 12
    cat = {"PARTSUPP": Table.from_columns(
        ps_partkey=np.arange(n, dtype=np.int32),            # every row its own group
        ps_suppkey=np.zeros(n, np.int32),
        ps_supplycost=np.linspace(1, 12, n).astype(np.float32))}
    env = {"tot": jnp.float32(0.0), "cnt": jnp.float32(0.0)}
    _assert_grouped_parity(_sum_count_prog(), env, cat)


def test_grouped_fused_segments_exceed_one_tile(monkeypatch):
    """More segments than one kernel tile: force 8-segment tiles over a
    90-group input so the grid walks 12 segment tiles."""
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", "interpret")
    import importlib
    sa = importlib.import_module("repro.kernels.segment_agg")
    monkeypatch.setattr(sa, "default_block_segs", lambda *a, **k: 8)
    env = {"lo": jnp.float32(1e9), "hi": jnp.float32(-1e9)}
    _assert_grouped_parity(_minmax_prog(), env,
                           _catalog(n=700, nparts=90, seed=11))


def test_fused_stream_parity_acceptance_workload(monkeypatch):
    """Acceptance workload at the engine level: grouped AggCall over 200k
    rows / 8192 groups, fused (band-pruned interpret kernel) == stream
    (the sequential segmented scan)."""
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", "interpret")
    env = {"tot": jnp.float32(0.0), "cnt": jnp.float32(0.0)}
    _assert_grouped_parity(_sum_count_prog(), env,
                           _catalog(n=200_000, nparts=8192, seed=13))


# --------------------------------------------------------------------------
# 4. mode selection + ungrouped fused + engine GroupAgg
# --------------------------------------------------------------------------


def test_auto_selects_fused_for_eligible_grouped():
    call, rp = _grouped_call(_sum_count_prog(), "auto")
    assert fused_eligible(rp.aggregate)
    assert _resolve_grouped_mode(call, rp.aggregate) == "fused"
    assert _resolve_grouped_mode(
        AggCall(call.child, call.aggregate, call.param_binding,
                group_keys=call.group_keys, mode="stream"),
        rp.aggregate) == "scan"


def test_fused_mode_rejects_unrecognized():
    """A data-dependent recurrence (cumulative product of state) has no
    moment decomposition — fused must refuse, stream must run."""
    prog = Program(
        "cumret", params=(),
        pre=[let("acc", Const(1.0))],
        loop=CursorLoop(
            Scan("PARTSUPP", _PS_SCHEMA),
            fetch=[("c", "ps_supplycost")],
            body=[Assign("acc", Var("acc") * (Var("acc") + Var("c")))]),
        post=[], returns=("acc",))
    agg = build_aggregate(prog)
    assert not fused_eligible(agg)
    call, _ = _grouped_call(prog, "fused")
    with pytest.raises(ValueError, match="fused"):
        execute(call, _catalog(), {"acc": jnp.float32(1.0)})


def test_ungrouped_fused_equals_stream():
    prog = _sum_count_prog()
    cat = _catalog()
    want = run_rewritten(aggify(prog), cat, {}, mode="stream")
    got = run_rewritten(aggify(prog), cat, {}, mode="fused")
    for k in want:
        np.testing.assert_allclose(np.asarray(want[k]), np.asarray(got[k]),
                                   rtol=1e-5)


def test_float64_fields_keep_exact_jnp_path():
    """The kernel accumulates in f32; with x64 enabled, f64 fields must
    route to the jnp segment path in their own dtype — a sum of values
    beyond f32's exact-integer range stays exact (run in a subprocess so
    the x64 flag cannot leak into other tests)."""
    import subprocess
    import sys
    code = """
import jax, numpy as np, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import Assign, Const, CursorLoop, Program, Var, aggify, let
from repro.relational import Scan, Table, execute
from repro.relational.plan import AggCall
big = float(2 ** 24)
cat = {"T": Table.from_columns(g=np.array([0, 0, 1], np.int32),
                               v=np.array([big, 1.0, 3.0], np.float64))}
prog = Program("s", params=(), pre=[let("acc", Const(0.0))],
               loop=CursorLoop(Scan("T", ("g", "v")), fetch=[("x", "v")],
                               body=[Assign("acc", Var("acc") + Var("x"))]),
               post=[], returns=("acc",), var_dtypes={"acc": jnp.float64})
rp = aggify(prog)
def call(mode):
    return AggCall(rp.agg_call.child, rp.agg_call.aggregate,
                   rp.agg_call.param_binding, group_keys=("g",), mode=mode)
out = execute(call("auto"), cat, {"acc": jnp.float64(0.0)}).to_numpy()
assert out["acc"].dtype == np.float64, out["acc"].dtype
assert out["acc"][0] == big + 1.0, out["acc"]          # f32 would round
# an explicit fused request over f64-only fields is refused, not silently
# downgraded to the kernel-free jnp pass
try:
    execute(call("fused"), cat, {"acc": jnp.float64(0.0)})
except ValueError as e:
    assert "f32" in str(e), e
else:
    raise AssertionError("mode='fused' over f64-only fields should raise")
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**__import__("os").environ,
                                       "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_engine_groupagg_fused_parity(backend, monkeypatch):
    rng = np.random.default_rng(21)
    n = 300
    cat = {"L": Table.from_columns(
        k=np.sort(rng.integers(0, 19, n)).astype(np.int32),
        v=rng.uniform(-50, 50, n).astype(np.float32))}
    plan = GroupAgg(Scan("L", ("k", "v")), ("k",),
                    (("s", "sum", "v"), ("n", "count", None),
                     ("mn", "min", "v"), ("mx", "max", "v"),
                     ("avg", "mean", "v")))
    monkeypatch.setenv("REPRO_GROUPAGG_FUSED", "off")
    want = execute(plan, cat).to_numpy()
    monkeypatch.setenv("REPRO_GROUPAGG_FUSED", backend)
    got = execute(plan, cat).to_numpy()
    assert set(want) == set(got)
    for k in want:
        np.testing.assert_allclose(np.asarray(want[k], np.float32),
                                   np.asarray(got[k], np.float32),
                                   rtol=1e-5, atol=1e-4)
    assert got["n"].dtype == want["n"].dtype
