"""Sort-free grouped aggregation (relational/keyslot.py + the
``layout='unsorted'`` kernel mode + the sort-free dispatch in
engine.GroupAgg / grouped AggCall / launch.sharded_agg).

Covers: canonical key words and the quadratic-probe slotting (incl. a
degenerate constant hash — collisions are *resolved*, never assumed
away), overflow validation (concrete raise / traced poison), bit-for-bit
parity of the sort-free routes against the sorted ones over every
commutative op (built-in GroupAgg incl. argmin/argmax, fused and
recognized grouped AggCall, guarded empty-contribution groups, invalid
rows in the overflow slot), the unsorted kernel layout on the jnp AND
interpret backends, route dispatch (ordered calls and 'last' updates
stay sorted; the kill switch works), the structural sort census as a
tier-1 test, the variadic one-``lax.sort`` ``Table.sort_by`` satellite,
the stable ``_gather_join`` satellite, a subprocess 8-way-mesh run with
groups straddling shards, and the timing acceptance bound (sort-free
fused sum/count beats sorted on the bench shape).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.relational import GroupAgg, Scan, Table, execute
from repro.relational.keyslot import (canonical_key_words,
                                      check_slot_overflow, key_words_for,
                                      slot_ids_from_words,
                                      slot_segment_ids)

AGGS = (("s", "sum", "v"), ("c", "count", None), ("mn", "min", "v"),
        ("mx", "max", "v"), ("avg", "mean", "v"), ("p", "prod", "v"),
        ("am", "argmin", ("v", "w")), ("ax", "argmax", ("v", "w")))


def _table(n, ngroups, seed=0, shuffle=True, invalid_every=0):
    """Integer-valued f32 values so every accumulation order is exact —
    the sort-free scatter order must then match the sorted segment order
    bit for bit."""
    rng = np.random.default_rng(seed)
    k = rng.integers(0, ngroups, n).astype(np.int32)
    if not shuffle:
        k = np.sort(k)
    t = Table.from_columns(
        k=k, v=rng.integers(-9, 9, n).astype(np.float32),
        w=rng.integers(0, 1000, n).astype(np.int32))
    if invalid_every:
        t = t.filter(jnp.asarray(np.arange(n) % invalid_every != 0))
    return t


def _aligned(t: Table, key: str = "k") -> dict:
    rows = t.to_numpy()
    order = np.argsort(rows[key], kind="stable")
    return {k: np.asarray(v)[order] for k, v in rows.items()}


def _both_routes(plan, cat, monkeypatch):
    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "off")
    want = _aligned(execute(plan, cat))
    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "on")
    got = _aligned(execute(plan, cat))
    return want, got


# --------------------------------------------------------------------------
# keyslot: canonical words + slotting
# --------------------------------------------------------------------------


def test_canonical_words_group_equality():
    w = key_words_for([
        jnp.asarray([0.0, -0.0, 1.5, np.nan, np.nan], jnp.float32),
        jnp.asarray([1, 1, 2, 3, 3], jnp.int32)])
    s, _, _, unpl = slot_ids_from_words(w, jnp.ones(5, bool), 128)
    s = np.asarray(s)
    assert int(unpl) == 0
    assert s[0] == s[1]                     # −0.0 groups with +0.0
    assert s[3] == s[4]                     # NaN keys share a bit-group
    assert len({int(s[0]), int(s[2]), int(s[3])}) == 3


def test_canonical_words_small_int_and_bool():
    for col in (jnp.asarray([-3, 0, 7, -3], jnp.int8),
                jnp.asarray([True, False, True, True]),
                jnp.asarray([1.5, -1.5, 1.5, 0.25], jnp.float16)):
        (w,) = canonical_key_words(col)
        assert w.dtype == jnp.uint32
        c = np.asarray(col)
        ww = np.asarray(w)
        for i in range(len(c)):
            for j in range(len(c)):
                assert (c[i] == c[j]) == (ww[i] == ww[j])


def test_slotting_same_key_same_slot_distinct_keys_distinct_slots():
    t = _table(4000, 150, seed=3)
    seg, owner, occ, unpl = map(np.asarray,
                                slot_segment_ids(t, ("k",), 256))
    assert unpl == 0
    k = np.asarray(t.columns["k"])
    slot_of = {}
    for i in range(len(k)):
        assert 0 <= seg[i] < 256
        slot_of.setdefault(int(k[i]), int(seg[i]))
        assert slot_of[int(k[i])] == seg[i]
    assert len(set(slot_of.values())) == len(slot_of)
    # dense claim-order prefix; owner rows really carry the slot's key
    assert occ.sum() == len(slot_of) and occ[:len(slot_of)].all()
    for key, s in slot_of.items():
        assert k[owner[s]] == key


def test_slotting_invalid_rows_park_in_overflow():
    n = 600
    t = Table({"k": jnp.asarray(np.arange(n, dtype=np.int32) % 40)},
              jnp.asarray(np.arange(n) % 3 == 0))
    seg, _, _, unpl = slot_segment_ids(t, ("k",), 128)
    seg = np.asarray(seg)
    assert int(unpl) == 0
    assert (seg[np.arange(n) % 3 != 0] == 128).all()
    assert (seg[np.arange(n) % 3 == 0] < 128).all()


def test_slotting_resolves_constant_hash_collisions(monkeypatch):
    """With EVERY key hashing identically, placement degenerates to pure
    quadratic probing — distinct keys must still land on distinct slots
    (collisions are resolved by key equality, not assumed away)."""
    import repro.relational.keyslot as ks
    monkeypatch.setattr(ks, "_hash_words",
                        lambda w: jnp.zeros(w.shape[:1], jnp.uint32))
    k = np.arange(64, dtype=np.int32).repeat(5)
    np.random.default_rng(0).shuffle(k)
    t = Table.from_columns(k=k)
    seg, owner, occ, unpl = map(np.asarray,
                                ks.slot_segment_ids(t, ("k",), 128))
    assert unpl == 0
    slots = {int(kk): int(ss) for kk, ss in zip(k, seg)}
    assert len(set(slots.values())) == 64
    assert occ.sum() == 64


def test_slotting_full_bucket_load():
    k = np.arange(128, dtype=np.int32).repeat(3)
    np.random.default_rng(1).shuffle(k)
    seg, _, occ, unpl = map(np.asarray, slot_segment_ids(
        Table.from_columns(k=k), ("k",), 128))
    assert unpl == 0 and occ.all() and len(np.unique(seg)) == 128


def test_slot_overflow_concrete_raises_traced_poisons():
    t = Table.from_columns(k=np.arange(200, dtype=np.int32),
                           v=np.ones(200, np.float32))
    plan = GroupAgg(Scan("T", ("k", "v")), ("k",),
                    (("s", "sum", "v"),), max_groups=100)
    with pytest.raises(ValueError, match="beyond the declared dense"):
        execute(plan, {"T": t})
    out = jax.jit(lambda tt: execute(plan, {"T": tt}))(t)
    assert np.isnan(np.asarray(out.columns["s"])).all()
    # and the guard helper itself
    assert check_slot_overflow(0, 128) is None
    with pytest.raises(ValueError):
        check_slot_overflow(5, 128)


# --------------------------------------------------------------------------
# built-in GroupAgg parity (sort-free vs sorted, aligned by key)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("invalid_every", [0, 3])
def test_groupagg_sortfree_parity_all_ops(monkeypatch, invalid_every):
    monkeypatch.setenv("REPRO_GROUPAGG_FUSED", "jnp")
    t = _table(4000, 150, invalid_every=invalid_every)
    plan = GroupAgg(Scan("T", ("k", "v", "w")), ("k",), AGGS,
                    max_groups=150)
    want, got = _both_routes(plan, {"T": t}, monkeypatch)
    assert set(want) == set(got)
    for c in want:
        assert np.array_equal(want[c], got[c]), c


def test_groupagg_sortfree_parity_interpret_kernel(monkeypatch):
    """The exact Pallas lowering (interpret mode) under layout='unsorted'
    — the cross-product grid's one-hot reduce is order-independent."""
    monkeypatch.setenv("REPRO_GROUPAGG_FUSED", "interpret")
    t = _table(1500, 60, seed=5)
    plan = GroupAgg(Scan("T", ("k", "v", "w")), ("k",),
                    (("s", "sum", "v"), ("c", "count", None),
                     ("mn", "min", "v"), ("am", "argmin", ("v", "w"))),
                    max_groups=60)
    want, got = _both_routes(plan, {"T": t}, monkeypatch)
    for c in want:
        assert np.array_equal(want[c], got[c]), c


def test_groupagg_sortfree_multikey_and_float_keys(monkeypatch):
    monkeypatch.setenv("REPRO_GROUPAGG_FUSED", "jnp")
    rng = np.random.default_rng(7)
    n = 2000
    t = Table.from_columns(
        a=rng.integers(0, 8, n).astype(np.int32),
        b=(rng.integers(0, 7, n) * 0.5).astype(np.float32),
        v=rng.integers(-9, 9, n).astype(np.float32))
    plan = GroupAgg(Scan("T", ("a", "b", "v")), ("a", "b"),
                    (("s", "sum", "v"), ("c", "count", None)),
                    max_groups=64)
    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "off")
    w = execute(plan, {"T": t}).to_numpy()
    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "on")
    g = execute(plan, {"T": t}).to_numpy()
    wo = np.lexsort((w["b"], w["a"]))
    go = np.lexsort((g["b"], g["a"]))
    for c in w:
        assert np.array_equal(np.asarray(w[c])[wo], np.asarray(g[c])[go]), c


# --------------------------------------------------------------------------
# grouped AggCall (custom aggregates)
# --------------------------------------------------------------------------


def _grouped_call(prog, mode, max_groups):
    from repro.core import aggify
    from repro.relational.plan import AggCall
    rp = aggify(prog)
    return AggCall(rp.agg_call.child, rp.agg_call.aggregate,
                   rp.agg_call.param_binding, rp.agg_call.ordered,
                   rp.agg_call.sort_keys, rp.agg_call.sort_desc,
                   group_keys=("ps_partkey",), mode=mode,
                   max_groups=max_groups)


def _ps_catalog(n, ngroups, seed=0):
    rng = np.random.default_rng(seed)
    return {"PARTSUPP": Table.from_columns(
        ps_partkey=rng.integers(0, ngroups, n).astype(np.int32),
        ps_suppkey=rng.integers(0, 100, n).astype(np.int32),
        ps_supplycost=rng.integers(1, 100, n).astype(np.float32))}


@pytest.mark.parametrize("mode", ["fused", "recognized"])
@pytest.mark.parametrize("workload", ["sum_count", "minmax", "argmin"])
def test_agg_call_sortfree_parity(monkeypatch, mode, workload):
    from benchmarks.group_agg import _programs
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", "jnp")
    prog, env = _programs()[workload]
    cat = _ps_catalog(3000, 120, seed=2)
    call = _grouped_call(prog, mode, 120)
    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "off")
    want = _aligned(execute(call, cat, env), "ps_partkey")
    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "on")
    got = _aligned(execute(call, cat, env), "ps_partkey")
    for c in want:
        assert np.array_equal(want[c], got[c]), c


def test_agg_call_sortfree_guarded_empty_groups(monkeypatch):
    """A guard that excludes EVERY row of some groups: their outputs must
    fall back to the pre-loop state on both routes, bit for bit."""
    from repro.core import (Assign, Const, CursorLoop, If, Program, Var,
                            let)
    from benchmarks.group_agg import _programs  # noqa: F401  (idiom ref)
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", "jnp")
    scan = Scan("PARTSUPP", ("ps_partkey", "ps_suppkey", "ps_supplycost"))
    prog = Program(
        "guardedSum", params=(),
        pre=[let("tot", Const(-1.0))],
        loop=CursorLoop(scan, fetch=[("c", "ps_supplycost")],
                        body=[If(Var("c") > Const(90.0),
                                 [Assign("tot", Var("tot") + Var("c"))])]),
        post=[], returns=("tot",))
    cat = _ps_catalog(2000, 50, seed=3)
    env = {"tot": jnp.float32(-1.0)}
    call = _grouped_call(prog, "fused", 50)
    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "off")
    want = _aligned(execute(call, cat, env), "ps_partkey")
    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "on")
    got = _aligned(execute(call, cat, env), "ps_partkey")
    for c in want:
        assert np.array_equal(want[c], got[c]), c


# --------------------------------------------------------------------------
# dispatch: what fires sort-free and what must not
# --------------------------------------------------------------------------


def _slot_spy(monkeypatch):
    import repro.relational.keyslot as ks
    calls = []
    orig = ks.slot_segment_ids

    def spy(*a, **kw):
        calls.append(a)
        return orig(*a, **kw)

    monkeypatch.setattr(ks, "slot_segment_ids", spy)
    return calls


def test_sortfree_requires_declared_bound(monkeypatch):
    calls = _slot_spy(monkeypatch)
    t = _table(500, 20)
    execute(GroupAgg(Scan("T", ("k", "v", "w")), ("k",),
                     (("s", "sum", "v"),)), {"T": t})
    assert not calls                      # no bound declared -> sorted
    execute(GroupAgg(Scan("T", ("k", "v", "w")), ("k",),
                     (("s", "sum", "v"),), max_groups=20), {"T": t})
    assert len(calls) == 1


def test_sortfree_kill_switch(monkeypatch):
    calls = _slot_spy(monkeypatch)
    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "off")
    t = _table(500, 20)
    execute(GroupAgg(Scan("T", ("k", "v", "w")), ("k",),
                     (("s", "sum", "v"),), max_groups=20), {"T": t})
    assert not calls


def test_ordered_agg_call_stays_sorted(monkeypatch):
    """Eq.-6 ordered invocation (the fig-2 running-product shape) must
    keep the sorted route: its semantics depend on the iteration order."""
    from repro.core import aggify
    from repro.relational.plan import AggCall
    from tests.helpers import fig2_catalog, fig2_program
    calls = _slot_spy(monkeypatch)
    prog = fig2_program()
    rp = aggify(prog)
    call = AggCall(rp.agg_call.child, rp.agg_call.aggregate,
                   rp.agg_call.param_binding, rp.agg_call.ordered,
                   rp.agg_call.sort_keys, rp.agg_call.sort_desc,
                   group_keys=("investor_id",), mode="auto", max_groups=8)
    out = execute(call, fig2_catalog(),
                  {"id": jnp.int32(1), "cumulativeROI": jnp.float32(1.0)})
    assert not calls                      # ordered -> never sort-free
    assert out.capacity > 0


def test_sortfree_sort_census_tier1():
    """Tier-1 face of the CI spy: the sort-free lowering of the grouped
    bench programs contains ZERO row-sized sorts, the sorted route at
    least one, and sort-free adds no row-sized gathers."""
    from benchmarks.sortfree_spy import sortfree_census
    counts = sortfree_census(2_000, 64, "jnp")
    for name, c in counts.items():
        assert c["row_sorts_sortfree"] == 0, (name, c)
        assert c["row_sorts_sorted"] >= 1, (name, c)
        assert c["row_gathers_sortfree"] <= c["row_gathers_sorted"], \
            (name, c)


# --------------------------------------------------------------------------
# kernel layout='unsorted'
# --------------------------------------------------------------------------


def _unsorted_workload(n, nseg, seed=11):
    rng = np.random.default_rng(seed)
    segs = rng.integers(0, nseg, n).astype(np.int32)      # NOT sorted
    vals = rng.integers(-50, 50, (n, 2)).astype(np.float32)
    valid = rng.random((n, 2)) < 0.8
    return segs, vals, valid


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_kernel_unsorted_layout_matches_sorted_oracle(backend):
    from repro.kernels.segment_agg import fused_segment_agg
    segs, vals, valid = _unsorted_workload(3000, 97)
    order = np.argsort(segs, kind="stable")
    moms = (("sum", "count", "min", "max", "argmin_first"),
            ("sum", "max", "argmax_last"))
    got = fused_segment_agg(vals, segs, valid, 97, moments=moms,
                            layout="unsorted", backend=backend)
    want = fused_segment_agg(vals[order], segs[order], valid[order], 97,
                             moments=moms, backend="jnp")
    got, want = np.asarray(got), np.asarray(want)
    assert np.array_equal(got[:, :4], want[:, :4])
    # index rows: sorted-space indices map back through the permutation
    for c, row in ((0, 4), (1, 5)):
        for g in range(97):
            w = want[c, row, g]
            if np.isfinite(w):
                assert order[int(w)] == int(got[c, row, g]), (c, g)
            else:
                assert w == got[c, row, g]


def test_kernel_unsorted_layout_skips_sorted_validation():
    from repro.kernels.segment_agg import fused_segment_agg
    segs, vals, valid = _unsorted_workload(800, 40)
    # sorted layout rejects concrete unsorted input; unsorted accepts it
    with pytest.raises(ValueError, match="sorted"):
        fused_segment_agg(vals, segs, valid, 40, backend="interpret")
    out = fused_segment_agg(vals, segs, valid, 40, backend="interpret",
                            layout="unsorted")
    assert np.isfinite(np.asarray(out)[:, 0]).all()
    with pytest.raises(ValueError, match="layout"):
        fused_segment_agg(vals, segs, valid, 40, layout="diagonal")


# --------------------------------------------------------------------------
# satellites: variadic sort_by + stable join pick
# --------------------------------------------------------------------------


def test_sort_by_is_one_variadic_sort():
    from repro.analysis.jaxpr_spy import sort_output_sizes
    t = _table(1000, 30, invalid_every=4)
    for keys, desc in ((["k"], ()), (["k", "v"], [False, True]),
                       (["k", "v", "w"], [True, False, False])):
        j = jax.make_jaxpr(
            lambda ks=keys, d=desc: tuple(
                t.sort_by(ks, d).columns.values()))()
        assert len(sort_output_sizes(j)) == 1, keys


def test_sort_by_parity_with_lexsort_oracle():
    rng = np.random.default_rng(4)
    n = 1000
    t = Table({"a": jnp.asarray(rng.integers(0, 50, n).astype(np.int32)),
               "b": jnp.asarray(rng.uniform(-5, 5, n).astype(np.float32))},
              jnp.asarray(rng.random(n) < 0.8))
    st = t.sort_by(["a", "b"], [False, True])
    m, a, b = (np.asarray(x) for x in (t.mask(), t.columns["a"],
                                       t.columns["b"]))
    order = np.lexsort((np.arange(n), np.where(m, -b, np.inf),
                        np.where(m, a, np.iinfo(np.int32).max), ~m))
    assert np.array_equal(np.asarray(st.columns["a"]), a[order])
    assert np.array_equal(np.asarray(st.columns["b"]), b[order])
    assert np.array_equal(np.asarray(st.mask()), m[order])


def test_gather_join_duplicate_right_keys_deterministic():
    """_gather_join is documented for unique right keys; with duplicates
    the stable sort must make the pick deterministic: the smallest
    original right row among equal keys."""
    from repro.relational.engine import _gather_join
    lt = Table.from_columns(x=np.array([7, 8], np.int32))
    rt = Table.from_columns(
        x=np.array([8, 7, 7, 8, 7], np.int32),
        y=np.array([100, 101, 102, 103, 104], np.int32))
    out = _gather_join(lt, rt, "x", "x", "inner")
    assert np.array_equal(np.asarray(out.columns["y"]), [101, 100])


# --------------------------------------------------------------------------
# sharded: subprocess 8-way mesh, groups straddling shards
# --------------------------------------------------------------------------


def test_sharded_sortfree_in_subprocess_8way_mesh():
    code = """
import os, numpy as np, jax, jax.numpy as jnp
os.environ["REPRO_GROUPAGG_FUSED"] = "jnp"
assert jax.device_count() == 8, jax.device_count()
from jax.sharding import Mesh
from repro.relational import GroupAgg, Scan, Table, execute

mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(9)
n, ng = 4096, 60
t = Table.from_columns(
    k=rng.integers(0, ng, n).astype(np.int32),   # unsorted: every group straddles shards
    v=rng.integers(-40, 40, n).astype(np.float32),
    p=rng.integers(0, 1000, n).astype(np.int32))
plan = GroupAgg(Scan("L", ("k", "v", "p")), ("k",),
                (("s", "sum", "v"), ("c", "count", None),
                 ("mn", "min", "v"), ("mx", "max", "v"),
                 ("am", "argmin", ("v", "p"))), max_groups=ng)
os.environ["REPRO_GROUPAGG_SORTFREE"] = "off"
want = execute(plan, {"L": t}).to_numpy()
os.environ.pop("REPRO_GROUPAGG_SORTFREE")

import repro.launch.sharded_agg as sa
calls = []
orig = sa.sharded_sortfree_segment_agg
def spy(*a, **kw):
    calls.append(a[4])
    return orig(*a, **kw)
sa.sharded_sortfree_segment_agg = spy
out = execute(plan, {"L": t.shard_rows(mesh, "data")})
got = out.to_numpy()
assert calls == [129], calls          # bucket(60) -> 128-lane floor + overflow
assert out.capacity == 129
ws, gs = np.argsort(want["k"]), np.argsort(got["k"])
for c in want:
    assert np.array_equal(np.asarray(want[c])[ws], np.asarray(got[c])[gs]), c

# cross-shard tie: one giant all-tying group -> first-attaining row wins
t2 = Table.from_columns(k=np.zeros(4096, np.int32),
                        v=np.full(4096, 7.0, np.float32),
                        p=np.arange(4096).astype(np.int32))
plan2 = GroupAgg(Scan("L", ("k", "v", "p")), ("k",),
                 (("am", "argmin", ("v", "p")),), max_groups=2)
g2 = execute(plan2, {"L": t2.shard_rows(mesh, "data")}).to_numpy()
assert g2["am"][0] == 0, g2["am"]
print("OK")
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ,
           "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "") +
                         " --xla_force_host_platform_device_count=8"),
           "PYTHONPATH": os.path.abspath(src) + os.pathsep +
                         os.environ.get("PYTHONPATH", "")}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr


# --------------------------------------------------------------------------
# acceptance: sort-free fused sum/count beats the sorted fused path
# --------------------------------------------------------------------------


def test_sortfree_beats_sorted_fused_sum_count(monkeypatch):
    """The bench-shape acceptance bound (also a CI gate on the fresh
    bench artifact): same bounded fused sum/count GroupAgg, route pinned
    sorted vs sort-free — deleting the sort must win wall-clock."""
    from benchmarks.group_agg import _catalog
    from benchmarks.util import time_fn
    monkeypatch.setenv("REPRO_GROUPAGG_FUSED", "jnp")
    n, ng = 50_000, 512
    cat = _catalog(n, ng)
    plan = GroupAgg(Scan("PARTSUPP",
                         ("ps_partkey", "ps_suppkey", "ps_supplycost")),
                    ("ps_partkey",),
                    (("s", "sum", "ps_supplycost"), ("c", "count", None)),
                    max_groups=ng)

    def timed():
        fn = jax.jit(lambda: execute(plan, cat))
        return time_fn(lambda: fn().columns, repeats=5, warmup=2)

    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "off")
    us_sorted = timed()
    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "on")
    us_free = timed()
    assert us_free < us_sorted, (us_free, us_sorted)
