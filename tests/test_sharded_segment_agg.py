"""The mesh-sharded fused segmented-aggregation path (launch/sharded_agg.py).

Two tiers:

* **Direct tests** need an 8-way host mesh
  (``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the CI
  multi-device step sets it before jax initializes); on a single-device
  run they skip.  They cover kernel-level parity (bitwise for
  integer-valued f32 data, where shard-boundary re-association is exact),
  segments straddling shard boundaries, empty shards, the
  ``shard_merge``-fold ↔ collective-merge equivalence, and the transparent
  ``GroupAgg`` / grouped ``AggCall`` routing for a ``Table.shard_rows``
  input.
* **A subprocess test** keeps the same coverage in plain tier-1 (one
  device): it spawns an interpreter with the flag and asserts the
  end-to-end parity + routing there.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.sharded_agg import row_sharded_mesh

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def mesh():
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:8]).reshape(8), ("data",))


def _sorted_int_workload(n, nseg, ncols=1, seed=7):
    """Integer-valued f32 data: every summation order is exact, so the
    sharded merge must match the single-device kernel bit-for-bit."""
    rng = np.random.default_rng(seed)
    segs = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    vals = rng.integers(-50, 50, (n, ncols)).astype(np.float32)
    valid = rng.random((n, ncols)) < 0.8
    return segs, vals, valid


# --------------------------------------------------------------------------
# detection (runs on any device count)
# --------------------------------------------------------------------------


def test_row_sharded_mesh_ignores_unsharded_and_none():
    assert row_sharded_mesh(jnp.arange(8), None) is None


def test_row_sharded_mesh_kill_switch(monkeypatch, mesh=None):
    monkeypatch.setenv("REPRO_SEGAGG_SHARDED", "off")
    assert row_sharded_mesh(jnp.arange(8)) is None


@needs_mesh
def test_row_sharded_mesh_detects_committed_rows(mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    a = jax.device_put(jnp.arange(64, dtype=jnp.float32),
                       NamedSharding(mesh, P("data")))
    got = row_sharded_mesh(a)
    assert got is not None and got[1] == "data"
    # replicated arrays don't route
    b = jax.device_put(jnp.arange(64, dtype=jnp.float32),
                       NamedSharding(mesh, P()))
    assert row_sharded_mesh(b) is None


# --------------------------------------------------------------------------
# kernel-level parity on the 8-way mesh
# --------------------------------------------------------------------------


@needs_mesh
def test_sharded_kernel_bitwise_parity(mesh):
    from repro.kernels.segment_agg import fused_segment_agg
    from repro.launch.sharded_agg import sharded_fused_segment_agg
    segs, vals, valid = _sorted_int_workload(4096, 300, ncols=2)
    single = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                               jnp.asarray(valid), 300, backend="jnp")
    shd = sharded_fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                                    jnp.asarray(valid), 300, mesh=mesh,
                                    axis="data", backend="jnp")
    assert np.array_equal(np.asarray(single), np.asarray(shd))


@needs_mesh
def test_sharded_interpret_kernel_per_shard(mesh):
    """The band-pruned Pallas kernel (interpret mode) runs inside
    shard_map: each shard's contiguous sorted slice keeps the pruning
    precondition."""
    from repro.kernels.segment_agg import fused_segment_agg
    from repro.launch.sharded_agg import sharded_fused_segment_agg
    segs, vals, valid = _sorted_int_workload(2048, 300)
    single = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                               jnp.asarray(valid), 300, backend="jnp")
    shd = sharded_fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                                    jnp.asarray(valid), 300, mesh=mesh,
                                    axis="data", backend="interpret",
                                    block_rows=128, block_segs=128)
    np.testing.assert_allclose(np.asarray(shd), np.asarray(single),
                               rtol=1e-5, atol=1e-5)


@needs_mesh
def test_segments_straddle_shard_boundaries(mesh):
    """One giant segment spanning every shard + per-row segments at the
    tail: the psum/pmin/pmax merge must reassemble both shapes."""
    from repro.kernels.segment_agg import fused_segment_agg
    from repro.launch.sharded_agg import sharded_fused_segment_agg
    n = 64
    segs = np.concatenate([np.zeros(40, np.int32),
                           np.arange(1, 25, dtype=np.int32)])
    vals = np.arange(n, dtype=np.float32)[:, None]
    valid = np.ones((n, 1), bool)
    single = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                               jnp.asarray(valid), 25, backend="jnp")
    shd = sharded_fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                                    jnp.asarray(valid), 25, mesh=mesh,
                                    axis="data", backend="jnp")
    assert np.array_equal(np.asarray(single), np.asarray(shd))


@needs_mesh
def test_empty_and_uneven_shards(mesh):
    """n=9 rows over 8 shards: padding fills the tail shards with invalid
    rows, which must contribute exactly the moment identities."""
    from repro.kernels.segment_agg import fused_segment_agg
    from repro.launch.sharded_agg import sharded_fused_segment_agg
    rng = np.random.default_rng(11)
    n = 9
    segs = np.sort(rng.integers(0, 5, n)).astype(np.int32)
    vals = rng.integers(0, 10, (n, 1)).astype(np.float32)
    single = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                               jnp.ones((n, 1), bool), 5, backend="jnp")
    shd = sharded_fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                                    jnp.ones((n, 1), bool), 5, mesh=mesh,
                                    axis="data", backend="jnp")
    assert np.array_equal(np.asarray(single), np.asarray(shd))


@needs_mesh
def test_shard_merge_fold_matches_collective_merge(mesh):
    """moment_merge_aggregate under core.aggregate.shard_merge (all-gather
    + ordered fold) == the native psum/pmin/pmax merge — the sharded path
    really is the shard_merge algebra."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.aggregate import shard_merge
    from repro.kernels.segment_agg import fused_segment_agg
    from repro.launch.sharded_agg import (moment_merge_aggregate,
                                          sharded_fused_segment_agg)
    segs, vals, valid = _sorted_int_workload(4096, 128, ncols=2)
    locals_ = [
        fused_segment_agg(jnp.asarray(vals[i * 512:(i + 1) * 512]),
                          jnp.asarray(segs[i * 512:(i + 1) * 512]),
                          jnp.asarray(valid[i * 512:(i + 1) * 512]),
                          128, backend="jnp")
        for i in range(8)]
    agg = moment_merge_aggregate(2, 128)

    def fold(loc):
        return shard_merge(agg, loc[0], "data")

    folded = shard_map(fold, mesh=mesh, in_specs=(P("data"),),
                       out_specs=P(), check_rep=False)(jnp.stack(locals_))
    shd = sharded_fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                                    jnp.asarray(valid), 128, mesh=mesh,
                                    axis="data", backend="jnp")
    assert np.array_equal(np.asarray(folded), np.asarray(shd))


# --------------------------------------------------------------------------
# transparent engine routing
# --------------------------------------------------------------------------


def _route_counter(monkeypatch):
    import repro.launch.sharded_agg as sa
    calls = []
    orig = sa.sharded_fused_segment_agg

    def spy(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(sa, "sharded_fused_segment_agg", spy)
    return calls


@needs_mesh
def test_groupagg_routes_row_sharded_table(mesh, monkeypatch):
    from repro.relational import GroupAgg, Scan, Table, execute
    rng = np.random.default_rng(3)
    n = 640
    key = np.sort(rng.integers(0, 37, n)).astype(np.int32)
    val = rng.integers(-40, 40, n).astype(np.float32)
    t = Table.from_columns(k=key, v=val)
    plan = GroupAgg(Scan("L", ("k", "v")), ("k",),
                    (("s", "sum", "v"), ("c", "count", None),
                     ("mn", "min", "v"), ("mx", "max", "v"),
                     ("avg", "mean", "v")))
    want = execute(plan, {"L": t}).to_numpy()
    calls = _route_counter(monkeypatch)
    got = execute(plan, {"L": t.shard_rows(mesh, "data")}).to_numpy()
    assert calls, "row-sharded GroupAgg did not take the distributed path"
    assert set(want) == set(got)
    for k in want:
        assert np.array_equal(np.asarray(want[k], np.float32),
                              np.asarray(got[k], np.float32)), k


@needs_mesh
def test_grouped_aggcall_routes_row_sharded_table(mesh, monkeypatch):
    from repro.core import (Assign, Const, CursorLoop, If, Program, Var,
                            aggify, let)
    from repro.relational import Scan, Table, execute
    from repro.relational.plan import AggCall
    rng = np.random.default_rng(5)
    n = 640
    key = np.sort(rng.integers(0, 23, n)).astype(np.int32)
    cost = rng.integers(1, 50, n).astype(np.float32)
    schema = ("ps_partkey", "ps_suppkey", "ps_supplycost")
    prog = Program(
        "sumCount", params=(),
        pre=[let("tot", Const(0.0)), let("cnt", Const(0.0))],
        loop=CursorLoop(
            Scan("PARTSUPP", schema),
            fetch=[("c", "ps_supplycost")],
            body=[If(Var("c") > Const(20.0),
                     [Assign("tot", Var("tot") + Var("c"))]),
                  Assign("cnt", Var("cnt") + Const(1.0))]),
        post=[], returns=("tot", "cnt"))
    cat = {"PARTSUPP": Table.from_columns(
        ps_partkey=key, ps_suppkey=np.zeros(n, np.int32),
        ps_supplycost=cost)}
    rp = aggify(prog)
    call = AggCall(rp.agg_call.child, rp.agg_call.aggregate,
                   rp.agg_call.param_binding, rp.agg_call.ordered,
                   rp.agg_call.sort_keys, rp.agg_call.sort_desc,
                   group_keys=("ps_partkey",), mode="fused")
    env = {"tot": jnp.float32(0.0), "cnt": jnp.float32(0.0)}
    want = execute(call, cat, env).to_numpy()
    cat_sh = {"PARTSUPP": cat["PARTSUPP"].shard_rows(mesh, "data")}
    calls = _route_counter(monkeypatch)
    got = execute(call, cat_sh, env).to_numpy()
    assert calls, "row-sharded grouped AggCall did not take the " \
                  "distributed path"
    for k in want:
        assert np.array_equal(np.asarray(want[k]), np.asarray(got[k])), k


# --------------------------------------------------------------------------
# tier-1 coverage without the flag: spawn a flagged interpreter
# --------------------------------------------------------------------------


def test_sharded_path_in_subprocess_8way_mesh():
    """Runs the end-to-end sharded story (kernel bitwise parity + GroupAgg
    routing) in a subprocess with an 8-way host mesh, so plain tier-1 (one
    device, per tests/conftest.py) still exercises the distributed path."""
    code = """
import numpy as np, jax, jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()
from jax.sharding import Mesh
from repro.kernels.segment_agg import fused_segment_agg
from repro.launch.sharded_agg import sharded_fused_segment_agg
from repro.relational import GroupAgg, Scan, Table, execute

mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(7)
n, nseg = 4096, 300
segs = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
vals = rng.integers(-50, 50, (n, 2)).astype(np.float32)
valid = rng.random((n, 2)) < 0.8
single = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                           jnp.asarray(valid), nseg, backend="jnp")
shd = sharded_fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                                jnp.asarray(valid), nseg, mesh=mesh,
                                axis="data", backend="jnp")
assert np.array_equal(np.asarray(single), np.asarray(shd))

key = np.sort(rng.integers(0, 37, 640)).astype(np.int32)
val = rng.integers(-40, 40, 640).astype(np.float32)
t = Table.from_columns(k=key, v=val)
plan = GroupAgg(Scan("L", ("k", "v")), ("k",),
                (("s", "sum", "v"), ("c", "count", None),
                 ("mn", "min", "v"), ("mx", "max", "v")))
want = execute(plan, {"L": t}).to_numpy()
import repro.launch.sharded_agg as sa
calls = []
orig = sa.sharded_fused_segment_agg
sa.sharded_fused_segment_agg = lambda *a, **k: (calls.append(1),
                                                orig(*a, **k))[1]
got = execute(plan, {"L": t.shard_rows(mesh, "data")}).to_numpy()
assert calls, "GroupAgg did not route through the sharded path"
for k in want:
    assert np.array_equal(np.asarray(want[k], np.float32),
                          np.asarray(got[k], np.float32)), k
print("OK")
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ,
           "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "") +
                         " --xla_force_host_platform_device_count=8"),
           "PYTHONPATH": os.path.abspath(src) + os.pathsep +
                         os.environ.get("PYTHONPATH", "")}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr
