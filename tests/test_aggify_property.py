"""Property-based equivalence: random loop bodies drawn from a grammar ×
random tables ⇒ cursor == aggify for every execution mode that applies
(Theorem 4.2, tested mechanically).

The whole module skips when ``hypothesis`` is not installed (it is an
optional dev dependency — the CI image and the hermetic container only
guarantee jax + pytest); under ``REPRO_REQUIRE_HYPOTHESIS=1`` (the CI
contract, see tests/hypothesis_gate.py) a missing install is a hard
error instead, so the property surface cannot silently vanish."""
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_gate import require_hypothesis

hypothesis = require_hypothesis()
import hypothesis.strategies as st           # noqa: E402
from hypothesis import given, settings       # noqa: E402

from repro.core import (Assign, BinOp, Col, Const, CursorLoop, If, Program,
                        UnOp, Var, aggify, build_aggregate, let, run_aggify,
                        run_cursor)
from repro.relational import Scan, Table
from repro.relational.plan import OrderBy

COLS = ("a", "b", "k")


def _table(draw):
    n = draw(st.integers(min_value=1, max_value=40))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    return Table.from_columns(
        a=rng.uniform(-4, 4, n).astype(np.float32),
        b=rng.uniform(-4, 4, n).astype(np.float32),
        k=rng.integers(0, 5, n).astype(np.int32),
    )


@st.composite
def acyclic_expr(draw, depth=0):
    """Expressions over fetch vars va/vb and outer params p0/p1."""
    leaf = st.sampled_from([Var("va"), Var("vb"), Var("p0"), Var("p1"),
                            Const(1.0), Const(0.5), Const(-2.0)])
    if depth >= 2 or draw(st.booleans()):
        return draw(leaf)
    op = draw(st.sampled_from(["+", "-", "*", "min", "max"]))
    return BinOp(op, draw(acyclic_expr(depth + 1)), draw(acyclic_expr(depth + 1)))


@st.composite
def update_stmt(draw, field):
    kind = draw(st.sampled_from(["sum", "prod", "min", "max", "last",
                                 "guarded_sum", "argmin", "argmax",
                                 "affine"]))
    e = draw(acyclic_expr())
    if kind == "sum":
        return Assign(field, Var(field) + e)
    if kind == "prod":
        # clamp contributions to keep products finite
        return Assign(field, Var(field) * BinOp("min", BinOp("max", e, Const(-1.5)), Const(1.5)))
    if kind == "min":
        return Assign(field, BinOp("min", Var(field), e))
    if kind == "max":
        return Assign(field, BinOp("max", Var(field), e))
    if kind == "last":
        return Assign(field, e)
    if kind == "guarded_sum":
        g = BinOp(draw(st.sampled_from(["<", ">", "<=", ">="])),
                  draw(acyclic_expr()), draw(acyclic_expr()))
        return If(g, [Assign(field, Var(field) + e)])
    if kind == "affine":
        # NOT recognizable (cyclic multiply): exercises stream fallback
        return Assign(field, Var(field) * Const(0.9) + e)
    op = "<" if kind == "argmin" else ">"
    return If(BinOp(op, e, Var(field)), [Assign(field, e)])


@st.composite
def loop_program(draw):
    nfields = draw(st.integers(1, 3))
    fields = [f"f{i}" for i in range(nfields)]
    body = [draw(update_stmt(f)) for f in fields]
    ordered = draw(st.booleans())
    q = Scan("T", COLS)
    if ordered:
        q = OrderBy(q, ("k",))
    loop = CursorLoop(q, fetch=[("va", "a"), ("vb", "b")], body=body)
    pre = [let(f, Const(float(draw(st.integers(-3, 3))))) for f in fields]
    prog = Program("prop", params=("p0", "p1"), pre=pre, loop=loop,
                   post=[], returns=tuple(fields))
    table = _table(draw)
    p0 = float(draw(st.integers(-2, 2)))
    p1 = float(draw(st.integers(-2, 2)))
    return prog, table, {"p0": p0, "p1": p1}


@settings(max_examples=40, deadline=None)
@given(loop_program())
def test_cursor_equals_aggify_auto(case):
    prog, table, params = case
    cat = {"T": table}
    ref = run_cursor(prog, cat, params)
    got = run_aggify(prog, cat, params, mode="auto")
    for k in ref:
        np.testing.assert_allclose(np.asarray(ref[k]), np.asarray(got[k]),
                                   rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(loop_program())
def test_cursor_equals_aggify_stream(case):
    prog, table, params = case
    cat = {"T": table}
    ref = run_cursor(prog, cat, params)
    got = run_aggify(prog, cat, params, mode="stream")
    for k in ref:
        np.testing.assert_allclose(np.asarray(ref[k]), np.asarray(got[k]),
                                   rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(loop_program(), st.integers(1, 16))
def test_chunked_matches_stream_when_mergeable(case, nc):
    prog, table, params = case
    agg = build_aggregate(prog)
    if not agg.mergeable:
        return
    cat = {"T": table}
    ref = run_aggify(prog, cat, params, mode="stream")
    got = run_aggify(prog, cat, params, mode="chunked", num_chunks=nc)
    for k in ref:
        np.testing.assert_allclose(np.asarray(ref[k]), np.asarray(got[k]),
                                   rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(loop_program())
def test_rewrite_is_stable(case):
    """Rewriting twice produces the same aggregate signature (idempotence
    of the analysis)."""
    prog, _, _ = case
    a1 = build_aggregate(prog)
    a2 = build_aggregate(prog)
    assert a1.fields == a2.fields
    assert a1.accum_params == a2.accum_params
    assert a1.terminate_vars == a2.terminate_vars
