"""Dense segment-id bound for grouped aggregation (relational/group_bound.py).

Covers the bound subsystem end to end: bucketing, resolution, overflow
validation (concrete raise / traced poison), parity of the bounded grouped
executors against the capacity-sized ones (built-in ``GroupAgg`` and
grouped ``AggCall``, per-op and fused), the ``Table.declare_group_bound``
hint and its propagation through row ops, the shrunken moment tensor /
kernel grid, the sharded path with a bound smaller than the shard count
(subprocess 8-way mesh), and the satellite fixes (grouped ``var_dtypes``
threading, fused-vs-per-op count/mean dtype parity incl. x64, and the
``deferred_init`` × explicit-mode conflict).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.relational import GroupAgg, Scan, Table, execute
from repro.relational.group_bound import (LANE, bucket_group_bound,
                                          check_group_overflow,
                                          resolve_group_bound)
from repro.relational.plan import AggCall

AGGS = (("s", "sum", "v"), ("c", "count", None), ("mn", "min", "v"),
        ("mx", "max", "v"), ("avg", "mean", "v"))


def _table(n, ngroups, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return Table.from_columns(
        k=np.sort(rng.integers(0, ngroups, n)).astype(np.int32),
        v=rng.uniform(0, 10, n).astype(dtype))


def _plan(max_groups=None):
    return GroupAgg(Scan("T", ("k", "v")), ("k",), AGGS,
                    max_groups=max_groups)


def _rows(t: Table) -> dict:
    return t.to_numpy()


# --------------------------------------------------------------------------
# bucketing + resolution
# --------------------------------------------------------------------------


def test_bucket_group_bound():
    assert bucket_group_bound(1) == 128
    assert bucket_group_bound(128) == 128
    assert bucket_group_bound(129) == 256
    assert bucket_group_bound(500) == 512
    assert bucket_group_bound(512) == 512
    assert bucket_group_bound(513) == 1024
    for bad in (0, -3):
        with pytest.raises(ValueError):
            bucket_group_bound(bad)


def test_buckets_are_lane_aligned_powers_of_two():
    from repro.kernels.segment_agg import LANE as KERNEL_LANE
    assert LANE == KERNEL_LANE   # group_bound mirrors the kernel lane width
    for mg in (1, 7, 128, 200, 1000, 5000):
        b = bucket_group_bound(mg)
        assert b >= mg and b % LANE == 0 and b & (b - 1) == 0


def test_resolve_group_bound():
    # undeclared: legacy capacity sizing, nothing to validate
    assert resolve_group_bound(None, 50_000) == (50_000, None)
    # declared: bucket + a dedicated overflow slot
    assert resolve_group_bound(100, 50_000) == (129, 128)
    assert resolve_group_bound(2000, 50_000) == (2049, 2048)
    # a bucket at/above capacity is a no-op (no shape win to be had)
    assert resolve_group_bound(100, 64) == (64, None)
    assert resolve_group_bound(120, 129) == (129, None)


def test_check_group_overflow_concrete():
    assert check_group_overflow(jnp.int32(5), None) is None
    assert check_group_overflow(jnp.int32(128), 128) is None
    with pytest.raises(ValueError, match="129 groups"):
        check_group_overflow(jnp.int32(129), 128)


# --------------------------------------------------------------------------
# built-in GroupAgg under a dense bound
# --------------------------------------------------------------------------


def test_groupagg_bounded_parity_and_dense_output(monkeypatch):
    t = _table(5000, 100)
    want = execute(_plan(), {"T": t})
    # declaring the bound now ALSO flips the route to sort-free (hash
    # slotting), whose groups come back in claim order — align by key
    got = execute(_plan(max_groups=100), {"T": t})
    assert want.capacity == 5000 and got.capacity == 129
    w, g = _rows(want), _rows(got)
    assert set(w) == set(g)
    ws, gs = np.argsort(w["k"]), np.argsort(g["k"])
    for k in w:
        np.testing.assert_allclose(w[k][ws], g[k][gs], rtol=1e-6), k
    # and the sorted-route bounded executor (sort-free off) keeps the
    # legacy key-ordered dense prefix, positionally comparable
    monkeypatch.setenv("REPRO_GROUPAGG_SORTFREE", "off")
    got2 = execute(_plan(max_groups=100), {"T": t})
    assert got2.capacity == 129
    g2 = _rows(got2)
    for k in w:
        np.testing.assert_allclose(w[k], g2[k], rtol=1e-6), k


def test_groupagg_table_hint_routes_dense():
    t = _table(5000, 100).declare_group_bound(100)
    got = execute(_plan(), {"T": t})
    assert got.capacity == 129
    # plan-level declaration beats the table hint
    assert execute(_plan(max_groups=300), {"T": t}).capacity == 513


def test_group_bound_survives_row_ops():
    t = _table(256, 10).declare_group_bound(10)
    # the hint stores the BUCKET (pytree-aux stable across nearby bounds)
    assert t.group_bound == 128
    assert t.filter(t.columns["v"] > 1).group_bound == 128
    assert t.sort_by(["k"]).group_bound == 128
    assert t.project(["k", "v"]).group_bound == 128
    assert t.compress().group_bound == 128
    assert t.head(16).group_bound == 128
    # and through a plan pipeline into the grouped executor
    from repro.core.loop_ir import Col
    plan = GroupAgg(Scan("T", ("k", "v")).filter(Col("v") > 1.0),
                    ("k",), AGGS)
    assert execute(plan, {"T": t}).capacity == 129


def test_group_bound_dropped_when_new_columns_appear():
    """Ops that mint columns the declaration never covered (joins,
    computed projections, with_column) must NOT carry the bound — a
    grouping by the new column could exceed it on a perfectly valid
    query."""
    from repro.core.loop_ir import Col
    from repro.relational.plan import Join, Project
    t = _table(256, 10).declare_group_bound(10)
    assert t.with_column("w", t.columns["v"] * 2).group_bound is None
    # computed projection drops it; pure column selection keeps it
    scan = Scan("T", ("k", "v"))
    computed = Project(scan, (("k", Col("k")), ("w", Col("v") * 2.0)))
    from repro.relational.engine import _exec
    assert _exec(computed, {"T": t}, {}).group_bound is None
    assert _exec(scan.select("k", "v"), {"T": t}, {}).group_bound == 128
    # join output drops it (right side introduces uncovered columns)
    r = Table.from_columns(k=np.arange(10, dtype=np.int32),
                           name=np.arange(10, dtype=np.int32) + 100)
    j = Join(scan, Scan("R", ("k", "name")), "k", "k", "inner")
    assert _exec(j, {"T": t, "R": r}, {}).group_bound is None


def test_declared_buckets_share_one_jit_trace():
    traces = []

    @jax.jit
    def agg(table):
        traces.append(1)
        return execute(_plan(), {"T": table})

    t = _table(5000, 100)
    agg(t.declare_group_bound(100))
    agg(t.declare_group_bound(101))   # same bucket → same treedef
    assert len(traces) == 1


def test_poison_overflow_covers_bools_and_unsigned():
    from repro.relational.group_bound import poison_overflow
    cols = {"f": jnp.ones(4, jnp.float32), "i": jnp.ones(4, jnp.int32),
            "u": jnp.ones(4, jnp.uint32), "b": jnp.ones(4, bool)}
    out = poison_overflow(cols, jnp.bool_(False))
    assert np.all(np.isnan(np.asarray(out["f"])))
    assert np.all(np.asarray(out["i"]) == np.iinfo(np.int32).min)
    # unsigned min is 0 — a plausible aggregate — so unsigned poisons to max
    assert np.all(np.asarray(out["u"]) == np.iinfo(np.uint32).max)
    assert not np.any(np.asarray(out["b"]))
    # no-guard path is the identity
    assert poison_overflow(cols, None) is cols


def test_nseg_equals_bound_is_accepted():
    # exactly bucket-many groups: the edge the overflow slot must not eat
    n, g = 1024, 128
    t = Table.from_columns(k=np.arange(n, dtype=np.int32) % g,
                           v=np.ones(n, np.float32))
    out = execute(_plan(max_groups=128), {"T": t})
    r = _rows(out)
    assert len(r["k"]) == 128
    np.testing.assert_allclose(r["c"], np.full(128, n // g))


def test_empty_groups_and_all_invalid():
    t = _table(512, 3)
    out = execute(_plan(max_groups=100), {"T": t})
    assert int(out.count()) == 3          # bound ≫ actual groups
    tinv = Table(dict(t.columns), jnp.zeros(512, bool))
    oinv = execute(_plan(max_groups=100), {"T": tinv})
    assert int(oinv.count()) == 0         # every row parks in overflow


def test_overflow_concrete_raises_eagerly():
    t = _table(5000, 300)                 # 300 groups > bucket(100) = 128
    with pytest.raises(ValueError, match="dense bound"):
        execute(_plan(max_groups=100), {"T": t})


def test_overflow_traced_poisons_outputs():
    t = _table(5000, 300)
    out = jax.jit(lambda: execute(_plan(max_groups=100), {"T": t}))()
    assert np.all(np.isnan(np.asarray(out.columns["s"])))
    assert np.all(np.isnan(np.asarray(out.columns["avg"])))
    # integer columns cannot hold NaN: dtype-minimum sentinel
    c = np.asarray(out.columns["c"])
    assert np.all(c == np.iinfo(c.dtype).min)


def test_traced_in_bound_input_not_poisoned():
    t = _table(5000, 100)
    want = _rows(execute(_plan(), {"T": t}))
    got = _rows(jax.jit(lambda: execute(_plan(max_groups=100), {"T": t}))())
    ws, gs = np.argsort(want["k"]), np.argsort(got["k"])  # sort-free: claim order
    for k in want:
        np.testing.assert_allclose(want[k][ws], got[k][gs], rtol=1e-6), k


def test_bounded_fused_moment_tensor_is_group_sized(monkeypatch):
    """Acceptance: with max_groups declared, the fused GroupAgg pass
    allocates a (C, 4, ~S) moment tensor, not (C, 4, capacity)."""
    import repro.kernels.segment_agg   # noqa: F401 — the package re-exports
    ka = sys.modules["repro.kernels.segment_agg"]  # a same-named function
    seen = []
    orig = ka.fused_segment_agg

    def spy(vals, segs, valid, num_segments, **kw):
        out = orig(vals, segs, valid, num_segments, **kw)
        seen.append((num_segments, out.shape))
        return out

    monkeypatch.setattr(ka, "fused_segment_agg", spy)
    monkeypatch.setenv("REPRO_GROUPAGG_FUSED", "jnp")
    t = _table(5000, 100)
    execute(_plan(), {"T": t})
    assert seen.pop() == (5000, (1, 4, 5000))
    execute(_plan(max_groups=100), {"T": t})
    assert seen.pop() == (129, (1, 4, 129))


def test_bounded_grid_steps_shrink():
    """The pruned grid's seg_tiles term is sized by num_segments: a dense
    bound drops the launched grid to the bare row walk on the bench
    shape."""
    from repro.kernels.segment_agg import (launched_grid_steps,
                                           moment_tensor_bytes)
    n = 50_000
    cap_steps = launched_grid_steps(n, n)
    bounded_steps = launched_grid_steps(n, 513)
    assert bounded_steps < cap_steps
    assert cap_steps == 220 and bounded_steps == 196   # the bench shape
    assert moment_tensor_bytes(1, 513) * 90 < moment_tensor_bytes(1, n)


# --------------------------------------------------------------------------
# grouped AggCall under a dense bound
# --------------------------------------------------------------------------


def _sum_count_call(mode="auto", max_groups=None):
    from repro.core import Assign, Const, CursorLoop, If, Program, Var, let
    from repro.core.aggify import aggify
    schema = ("ps_partkey", "ps_suppkey", "ps_supplycost")
    prog = Program(
        "sumCount", params=(),
        pre=[let("tot", Const(0.0)), let("cnt", Const(0.0))],
        loop=CursorLoop(Scan("PARTSUPP", schema),
                        fetch=[("c", "ps_supplycost")],
                        body=[If(Var("c") > Const(5.0),
                                 [Assign("tot", Var("tot") + Var("c"))]),
                              Assign("cnt", Var("cnt") + Const(1.0))]),
        post=[], returns=("tot", "cnt"))
    rp = aggify(prog)
    return AggCall(rp.agg_call.child, rp.agg_call.aggregate,
                   rp.agg_call.param_binding, rp.agg_call.ordered,
                   rp.agg_call.sort_keys, rp.agg_call.sort_desc,
                   group_keys=("ps_partkey",), mode=mode,
                   max_groups=max_groups)


def _ps_catalog(n, ngroups, seed=3):
    rng = np.random.default_rng(seed)
    return {"PARTSUPP": Table.from_columns(
        ps_partkey=np.sort(rng.integers(0, ngroups, n)).astype(np.int32),
        ps_suppkey=np.zeros(n, np.int32),
        ps_supplycost=rng.uniform(1, 10, n).astype(np.float32))}


def test_grouped_aggcall_bounded_parity():
    cat = _ps_catalog(2000, 60)
    env = {"tot": jnp.float32(0.0), "cnt": jnp.float32(0.0)}
    want = execute(_sum_count_call(), cat, env)
    for mode in ("auto", "recognized", "stream"):
        got = execute(_sum_count_call(mode, max_groups=60), cat, env)
        assert got.capacity == 129
        w, g = _rows(want), _rows(got)
        # auto/recognized now dispatch sort-free under a declared bound:
        # groups come back in claim order, so align by key
        ws, gs = np.argsort(w["ps_partkey"]), np.argsort(g["ps_partkey"])
        for k in w:
            np.testing.assert_allclose(w[k][ws], g[k][gs],
                                       rtol=1e-6), (mode, k)


def test_grouped_aggcall_fused_kernel_bounded(monkeypatch):
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", "interpret")
    cat = _ps_catalog(1024, 40)
    env = {"tot": jnp.float32(0.0), "cnt": jnp.float32(0.0)}
    want = _rows(execute(_sum_count_call("stream"), cat, env))
    got = _rows(execute(_sum_count_call("fused", max_groups=40), cat, env))
    ws = np.argsort(want["ps_partkey"])
    gs = np.argsort(got["ps_partkey"])   # sort-free fused: claim order
    for k in want:
        np.testing.assert_allclose(want[k][ws], got[k][gs], rtol=1e-5), k


def test_grouped_aggcall_overflow():
    cat = _ps_catalog(2000, 300)
    env = {"tot": jnp.float32(0.0), "cnt": jnp.float32(0.0)}
    with pytest.raises(ValueError, match="dense bound"):
        execute(_sum_count_call(max_groups=100), cat, env)
    out = jax.jit(
        lambda: execute(_sum_count_call(max_groups=100), cat, env))()
    assert np.all(np.isnan(np.asarray(out.columns["tot"])))


# --------------------------------------------------------------------------
# sharded path: bound smaller than the shard count (subprocess 8-way mesh)
# --------------------------------------------------------------------------


def test_sharded_bounded_in_subprocess_8way_mesh():
    code = """
import numpy as np, jax, jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()
from jax.sharding import Mesh
from repro.relational import GroupAgg, Scan, Table, execute

mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(9)
n = 640
t = Table.from_columns(
    k=np.sort(rng.integers(0, 3, n)).astype(np.int32),   # 3 groups < 8 shards
    v=rng.integers(-40, 40, n).astype(np.float32))
plan = GroupAgg(Scan("L", ("k", "v")), ("k",),
                (("s", "sum", "v"), ("c", "count", None),
                 ("mn", "min", "v"), ("mx", "max", "v")))
want = execute(plan, {"L": t}).to_numpy()
import repro.launch.sharded_agg as sa
calls = []
orig = sa.sharded_sortfree_segment_agg   # bounded sharded now = sort-free
def spy(vals, kw_, valid, rowm, num_segments, *a, **kw):
    calls.append(num_segments)
    return orig(vals, kw_, valid, rowm, num_segments, *a, **kw)
sa.sharded_sortfree_segment_agg = spy
bounded = GroupAgg(plan.child, plan.keys, plan.aggs, max_groups=3)
out = execute(bounded, {"L": t.shard_rows(mesh, "data")})
got = out.to_numpy()
assert calls == [129], calls     # all-reduce payload is bound-sized
assert out.capacity == 129
ws = np.argsort(want["k"]); gs = np.argsort(got["k"])
for k in want:
    assert np.array_equal(np.asarray(want[k], np.float32)[ws],
                          np.asarray(got[k], np.float32)[gs]), k
print("OK")
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ,
           "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "") +
                         " --xla_force_host_platform_device_count=8"),
           "PYTHONPATH": os.path.abspath(src) + os.pathsep +
                         os.environ.get("PYTHONPATH", "")}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr


# --------------------------------------------------------------------------
# satellites: dtype parity, var_dtypes threading, deferred_init conflict
# --------------------------------------------------------------------------


def _groupagg_dtypes(fused: bool, monkeypatch):
    monkeypatch.setenv("REPRO_GROUPAGG_FUSED", "jnp" if fused else "off")
    t = _table(512, 10)
    out = execute(_plan(), {"T": t})
    return {k: np.asarray(v).dtype for k, v in out.columns.items()}


def test_fused_vs_per_op_count_mean_dtype_parity(monkeypatch):
    fused = _groupagg_dtypes(True, monkeypatch)
    per_op = _groupagg_dtypes(False, monkeypatch)
    assert fused == per_op
    assert fused["c"] == np.int32 and fused["avg"] == np.float32


def test_fused_vs_per_op_dtype_parity_x64(monkeypatch):
    prev = jax.config.jax_enable_x64
    try:
        jax.config.update("jax_enable_x64", True)
        fused = _groupagg_dtypes(True, monkeypatch)
        per_op = _groupagg_dtypes(False, monkeypatch)
        assert fused["c"] == per_op["c"] == np.int64
        assert fused["avg"] == per_op["avg"]
    finally:
        jax.config.update("jax_enable_x64", prev)


def test_grouped_var_dtypes_resolution():
    """grouped_agg_call must resolve missing aggregate fields via
    var_dtypes (the ungrouped path always did) instead of forcing
    float32."""
    from repro.core.aggify import build_aggregate
    from repro.core.executors import execute_agg_call
    from repro.core.loop_ir import Col, Var
    from tests.helpers import fig1_catalog, fig1_program

    prog = fig1_program()
    agg = build_aggregate(prog)
    from repro.relational.plan import Join
    q = Join(Scan("PARTSUPP", ("ps_partkey", "ps_suppkey", "ps_supplycost")),
             Scan("SUPPLIER", ("s_suppkey", "s_name")),
             left_key="ps_suppkey", right_key="s_suppkey", how="inner")
    call = AggCall(child=q, aggregate=agg,
                   param_binding=(("pCost", Col("ps_supplycost")),
                                  ("sName", Col("s_name")),
                                  ("minCost", Var("minCost")),
                                  ("lb", Var("lb"))),
                   group_keys=("ps_partkey",))
    env = {"minCost": jnp.float32(100000.0), "lb": jnp.float32(0.0)}
    # suppName deliberately absent from env: dtype must come from
    # var_dtypes, not the float32 fallback
    out = execute_agg_call(call, fig1_catalog(), env,
                           var_dtypes=prog.var_dtypes)
    assert np.asarray(out.columns["suppName"]).dtype == np.int32
    got = out.to_numpy()
    assert dict(zip(got["ps_partkey"], got["suppName"])) == {0: 101, 1: 101}
    # the engine's plan-execution path (execute(AggCall)) has no
    # var_dtypes parameter: the aggregate carries Program.var_dtypes
    # itself, so the dtype survives there too
    out2 = execute(call, fig1_catalog(), env)
    assert np.asarray(out2.columns["suppName"]).dtype == np.int32


def test_deferred_init_rejects_explicit_parallel_modes():
    from repro.core import Assign, Const, CursorLoop, Program, Var, let
    from repro.core.aggify import aggify
    from repro.core.executors import run_rewritten
    cat = {"T": Table.from_columns(x=np.array([1., 2., 3.], np.float32))}
    prog = Program(
        "s", params=(), pre=[let("acc", Const(0.0))],
        loop=CursorLoop(Scan("T", ("x",)), fetch=[("vx", "x")],
                        body=[Assign("acc", Var("acc") + Var("vx"))]),
        post=[], returns=("acc",))
    rp = aggify(prog)
    for mode in ("recognized", "chunked", "fused"):
        with pytest.raises(ValueError, match="deferred_init"):
            run_rewritten(rp, cat, mode=mode, deferred_init=True)
    # auto / explicit stream still run (deferred streaming fold)
    a = run_rewritten(rp, cat, deferred_init=True)
    b = run_rewritten(rp, cat, mode="stream", deferred_init=True)
    assert float(a["acc"]) == float(b["acc"]) == 6.0
