"""Training substrate: optimizer convergence, checkpoint atomicity +
elastic restore, data-pipeline determinism, compression error feedback,
straggler monitor, end-to-end tiny training run."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher, host_batch
from repro.models import LM
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.optimizer import (AdamWConfig, adamw_update, compress_int8,
                                   decompress_int8, init_error_state,
                                   init_opt_state, schedule)
from repro.train.train_step import StragglerMonitor, make_train_step


def test_adamw_quadratic_convergence():
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = init_opt_state(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - jnp.asarray([1.0, 1.0])) ** 2)

    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        params, opt, _ = adamw_update(cfg, params, g, opt)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)


def test_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10,
                      total_steps=100)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9]                       # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-4            # peak
    assert lrs[-1] < 2.2e-4                      # decays toward min


def test_compression_error_feedback_unbiased():
    """Error feedback makes the *accumulated* quantized signal track the
    true signal."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(128) * 1e-3, jnp.float32)
    err = jnp.zeros(128, jnp.float32)
    acc = jnp.zeros(128, jnp.float32)
    for _ in range(50):
        q, s, err = compress_int8(g_true, err)
        acc = acc + decompress_int8(q, s)
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true),
                               atol=2e-5)


def test_checkpoint_roundtrip_and_rotation(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    for s in (1, 2, 3, 4):
        save_checkpoint(d, s, tree, keep=2)
    assert latest_step(d) == 4
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]
    restored = restore_checkpoint(d, 4, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_detects_corruption(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"a": jnp.arange(4, dtype=jnp.float32)}
    path = save_checkpoint(d, 1, tree)
    # corrupt the array file
    fn = [f for f in os.listdir(path) if f.endswith(".bin")][0]
    with open(os.path.join(path, fn), "r+b") as f:
        f.seek(0)
        f.write(b"\xff\xff")
    with pytest.raises(IOError):
        restore_checkpoint(d, 1, tree)


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore under a different device layout: global array identical."""
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(d, 7, tree)
    # single-device 'mesh' — resharding API path (device_put w/ sharding)
    from jax.sharding import SingleDeviceSharding
    shard = {"w": SingleDeviceSharding(jax.devices()[0])}
    restored = restore_checkpoint(d, 7, tree, shardings=shard)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_data_pipeline_determinism_and_sharding():
    base = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    a = host_batch(base, step=5)
    b = host_batch(base, step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = host_batch(base, step=6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding partitions the global batch
    h0 = host_batch(DataConfig(100, 16, 8, 3, n_hosts=2, host_id=0), 5)
    h1 = host_batch(DataConfig(100, 16, 8, 3, n_hosts=2, host_id=1), 5)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetcher():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=4)
    pf = Prefetcher(cfg, start_step=0)
    s0, b0 = next(pf)
    s1, b1 = next(pf)
    pf.close()
    assert (s0, s1) == (0, 1)
    ref = host_batch(cfg, 0)
    np.testing.assert_array_equal(b0["tokens"], ref["tokens"])


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0)
    for _ in range(10):
        assert not mon.observe(1.0)
    assert mon.observe(5.0)           # flagged
    assert mon.flagged == 1
    assert not mon.observe(1.05)      # watermark not poisoned


def test_microbatched_step_matches_single():
    cfg = get_config("h2o-danube-1.8b").reduced()
    lm = LM(cfg, q_chunk=16, kv_chunk=16)
    params = lm.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                     cfg.vocab),
    }
    s1 = make_train_step(lm.loss, opt_cfg, microbatches=1)
    s2 = make_train_step(lm.loss, opt_cfg, microbatches=2)
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-2)


def test_tiny_training_reduces_loss():
    """End-to-end: a few steps on a tiny dense model reduce loss on a
    learnable (repetitive) synthetic task."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    lm = LM(cfg, q_chunk=16, kv_chunk=16)
    params = lm.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr_peak=5e-3, warmup_steps=2, total_steps=40,
                          weight_decay=0.0)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(lm.loss, opt_cfg))
    rng = np.random.default_rng(0)
    seq = np.tile(np.arange(16) % 7, (8, 1)).astype(np.int32)
    batch = {"tokens": jnp.asarray(seq), "labels": jnp.asarray(np.roll(seq, -1, 1))}
    losses = []
    for _ in range(30):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]
