"""Per-kernel interpret-mode sweeps vs the pure-jnp oracles (shape × dtype
grids), per the kernel contract in src/repro/kernels/."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attn import decode_attention
from repro.kernels.segment_agg import segment_agg
from repro.kernels.ssd_scan import ssd_scan


# --------------------------------------------------------------------------
# segment_agg
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,nseg,block", [
    (64, 8, 16), (100, 5, 32), (256, 128, 256), (1000, 17, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_agg_sweep(n, nseg, block, dtype):
    rng = np.random.default_rng(n + nseg)
    segs = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    vals = rng.uniform(-10, 10, n).astype(np.float32)
    valid = rng.random(n) < 0.9
    v = jnp.asarray(vals, dtype)
    got = segment_agg(v, jnp.asarray(segs), jnp.asarray(valid), nseg,
                      block_rows=block, interpret=True)
    want = ref.segment_agg_ref(v, jnp.asarray(segs), jnp.asarray(valid), nseg)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_segment_agg_all_invalid_segment():
    segs = jnp.asarray(np.array([0, 0, 2, 2], np.int32))
    vals = jnp.asarray(np.array([1., 2., 3., 4.], np.float32))
    valid = jnp.asarray(np.array([True, True, False, False]))
    got = segment_agg(vals, segs, valid, 3, block_rows=4, interpret=True)
    assert float(got[0, 0]) == 3.0        # sum seg0
    assert float(got[1, 2]) == 0.0        # count seg2
    assert np.isinf(float(got[2, 2]))     # min of empty = +inf


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bh,g,d,s,chunk", [
    (2, 8, 128, 256, 128), (1, 16, 128, 300, 128), (4, 8, 256, 512, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(bh, g, d, s, chunk, dtype):
    rng = np.random.default_rng(bh * 100 + s)
    q = jnp.asarray(rng.standard_normal((bh, g, d)), dtype)
    k = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    kv_len = jnp.asarray(rng.integers(1, s + 1, bh).astype(np.int32))
    got = decode_attention(q, k, v, kv_len, chunk=chunk, interpret=True)
    want = ref.decode_attention_ref(q, k, v, kv_len)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_tiny_cache():
    """kv_len=1: attends a single position exactly."""
    q = jnp.ones((1, 8, 128), jnp.float32)
    k = jnp.ones((1, 256, 128), jnp.float32)
    v = jnp.concatenate([jnp.full((1, 1, 128), 7.0),
                         jnp.zeros((1, 255, 128))], axis=1)
    out = decode_attention(q, k, v, jnp.asarray([1], jnp.int32),
                           chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 7.0, rtol=1e-6)


# --------------------------------------------------------------------------
# SSD scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bh,t,p,n,chunk", [
    (2, 128, 64, 16, 32), (1, 256, 128, 32, 64), (3, 64, 32, 8, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(bh, t, p, n, chunk, dtype):
    rng = np.random.default_rng(t + p)
    x = jnp.asarray(rng.standard_normal((bh, t, p)) * 0.5, dtype)
    log_a = jnp.asarray(-np.abs(rng.standard_normal((bh, t))) * 0.1,
                        jnp.float32)
    b = jnp.asarray(rng.standard_normal((bh, t, n)) * 0.3, dtype)
    c = jnp.asarray(rng.standard_normal((bh, t, n)) * 0.3, dtype)
    got = ssd_scan(x, log_a, b, c, chunk=min(chunk, t), interpret=True)
    want = ref.ssd_scan_ref(x, log_a, b, c)
    tol = 2e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ssd_chunk_invariance():
    """The chunked execution (Merge across chunks) is invariant to chunk
    size — the associativity property Aggify's chunked executor relies on."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 128, 32)) * 0.5, jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.standard_normal((1, 128))) * 0.2,
                        jnp.float32)
    b = jnp.asarray(rng.standard_normal((1, 128, 8)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((1, 128, 8)) * 0.3, jnp.float32)
    outs = [np.asarray(ssd_scan(x, log_a, b, c, chunk=cs, interpret=True))
            for cs in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-4, atol=2e-4)


def test_ssd_chunked_jnp_matches_ref():
    """The chunked jnp lowering path (kernel math, no Pallas) must match
    the sequential oracle for several chunk sizes."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 128, 32)) * 0.5, jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.standard_normal((2, 128))) * 0.15,
                        jnp.float32)
    b = jnp.asarray(rng.standard_normal((2, 128, 8)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((2, 128, 8)) * 0.3, jnp.float32)
    want = ref.ssd_scan_ref(x, log_a, b, c)
    for chunk in (16, 32, 64, 128):
        got = ref.ssd_scan_chunked(x, log_a, b, c, chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
