"""Adaptive probe-table sizing (the carried ROADMAP item): the
distinct-count sketch, not the worst-case EXPAND ceiling, sizes the
scatter table each probe round touches — and probing must stay a
handful of rounds even at the sketch's target load factor.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.relational import keyslot
from repro.relational.keyslot import (EXPAND, adaptive_expand,
                                      key_words_for, probe_rounds,
                                      slot_ids_from_words, slot_segment_ids)
from repro.relational.table import Table

#: generous ceiling for "a handful of probe rounds" — the fixed-EXPAND
#: table historically finished in ≤ ~4 rounds on full buckets; adaptive
#: shrinking must not push it anywhere near table-sized probing
MAX_ROUNDS = 16


def _table(n, card, seed=0):
    rng = np.random.default_rng(seed)
    return Table({"k": jnp.asarray(rng.integers(0, card, n)
                                   .astype(np.int32)),
                  "v": jnp.asarray(rng.uniform(0, 1, n)
                                   .astype(np.float32))},
                 jnp.ones(n, bool))


def _partition(table, seg, bucket):
    """Group partition as {frozenset of row indices}: slot numbers are
    probe-order and legitimately differ across table sizes — the
    *partition* may not."""
    seg = np.asarray(seg)
    groups = {}
    for i, s in enumerate(seg):
        if s < bucket:
            groups.setdefault(int(s), []).append(i)
    return {frozenset(rows) for rows in groups.values()}


def test_adaptive_expand_formula():
    # tiny key set in a big bucket: floor
    assert adaptive_expand(1, 4096) == 4
    assert adaptive_expand(100, 4096) == 4
    # full bucket: target load 1/8 → expand 8
    assert adaptive_expand(512, 512) == 8
    # overflow-bound estimates clamp at the fixed ceiling
    assert adaptive_expand(4096, 128) == EXPAND
    # monotone in the estimate, always a power of two in [4, EXPAND]
    prev = 0
    for est in (1, 32, 64, 128, 256, 512, 1024):
        e = adaptive_expand(est, 512)
        assert e >= prev and 4 <= e <= EXPAND and e & (e - 1) == 0
        prev = e


def test_expand_validation():
    words = key_words_for([jnp.arange(8, dtype=jnp.int32)])
    with pytest.raises(ValueError, match="expand"):
        slot_ids_from_words(words, jnp.ones(8, bool), 8, expand=3)


@pytest.mark.parametrize("expand", [4, 8, EXPAND])
def test_partition_identical_across_expands(expand):
    """Correctness never rides on the table size: every expand ≥ the
    floor yields the same grouping partition and zero overflow for a key
    set within the bucket."""
    n, card, bucket = 3000, 512, 512        # FULL bucket — worst load
    t = _table(n, card, seed=1)
    words = key_words_for([t.columns["k"]])
    seg, _own, _occ, ovf = slot_ids_from_words(
        words, t.mask(), bucket, expand=expand)
    assert int(ovf) == 0
    assert probe_rounds() is not None and probe_rounds() <= MAX_ROUNDS, \
        f"expand={expand}: {probe_rounds()} probe rounds"
    ref_seg, _o, _c, ref_ovf = slot_ids_from_words(
        words, t.mask(), bucket, expand=EXPAND)
    assert int(ref_ovf) == 0
    assert _partition(t, seg, bucket) == _partition(t, ref_seg, bucket)


def test_probe_rounds_bounded_at_target_load():
    """The regression this satellite exists for: at the adaptive target
    load factor (est ≈ bucket, expand 8 → load 1/8) the probe loop must
    terminate in a handful of rounds, not O(√table)."""
    n, card, bucket = 4096, 512, 512
    t = _table(n, card, seed=2)
    seg, _own, _occ, ovf = slot_segment_ids(t, ("k",), bucket)
    assert int(ovf) == 0
    assert probe_rounds() is not None and probe_rounds() <= MAX_ROUNDS


def test_adaptive_matches_fixed_ceiling(monkeypatch):
    """Sketch-driven sizing (default) and the pinned ceiling
    (REPRO_KEYSLOT_ADAPTIVE=off) agree on the grouping partition."""
    n, card, bucket = 2000, 100, 512    # sparse bucket → adaptive shrinks
    t = _table(n, card, seed=3)
    seg_a, _o1, _c1, ovf_a = slot_segment_ids(t, ("k",), bucket)
    monkeypatch.setenv("REPRO_KEYSLOT_ADAPTIVE", "off")
    seg_f, _o2, _c2, ovf_f = slot_segment_ids(t, ("k",), bucket)
    assert int(ovf_a) == 0 and int(ovf_f) == 0
    assert _partition(t, seg_a, bucket) == _partition(t, seg_f, bucket)


def test_adaptive_skipped_under_tracing():
    """A traced build cannot run the concrete sketch — it must fall back
    to the fixed ceiling, not crash."""
    import jax

    t = _table(256, 16, seed=4)

    def run(k):
        traced = Table({"k": k, "v": t.columns["v"]}, t.mask())
        seg, _own, _occ, ovf = slot_segment_ids(traced, ("k",), 64)
        return seg, ovf

    seg, ovf = jax.jit(run)(t.columns["k"])
    assert int(ovf) == 0
    want, _o, _c, _v = slot_segment_ids(t, ("k",), 64)
    assert _partition(t, np.asarray(seg), 64) == \
        _partition(t, np.asarray(want), 64)
