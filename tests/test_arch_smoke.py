"""Per-architecture smoke tests: REDUCED config of the same family — one
forward pass, one train-grad step, and one decode step on CPU; asserts
output shapes and finiteness (no NaNs/Infs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import LM

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["img_ctx"] = jax.random.normal(
            ks[2], (B, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_loss(arch_id):
    cfg = get_config(arch_id).reduced()
    lm = LM(cfg, q_chunk=16, kv_chunk=16, ssd_chunk=8)
    key = jax.random.PRNGKey(0)
    params = lm.init(key)
    batch = _batch(cfg, key)
    logits, aux, _ = lm.forward(params, batch["tokens"],
                                img_ctx=batch.get("img_ctx"),
                                frames=batch.get("frames"))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = lm.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_grad_step(arch_id):
    cfg = get_config(arch_id).reduced()
    lm = LM(cfg, q_chunk=16, kv_chunk=16, ssd_chunk=8)
    key = jax.random.PRNGKey(1)
    params = lm.init(key)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(lm.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert flat, "no gradients produced"
    for g in flat:
        assert bool(jnp.all(jnp.isfinite(g))), "non-finite gradient"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step(arch_id):
    cfg = get_config(arch_id).reduced()
    lm = LM(cfg, q_chunk=16, kv_chunk=16, ssd_chunk=8)
    key = jax.random.PRNGKey(2)
    params = lm.init(key)
    extra = {}
    if cfg.family == "vlm":
        extra["img_ctx"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        frames = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model),
                                   jnp.float32)
        extra["enc_out"] = lm._audio_encoder(params, frames)
    cache = lm.init_cache(B, 64, params=params, **extra)
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(3):
        logits, cache = lm.decode_step(params, cache, tok)
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits
    (KV-cache correctness), dense family."""
    cfg = get_config("qwen3-14b").reduced()
    lm = LM(cfg, q_chunk=16, kv_chunk=16)
    key = jax.random.PRNGKey(3)
    params = lm.init(key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab)
    full_logits, _, _ = lm.forward(params, toks)
    cache = lm.init_cache(1, 32, params=params)
    outs = []
    for i in range(8):
        step_logits, cache = lm.decode_step(params, cache, toks[:, i:i + 1])
        outs.append(step_logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_ssm():
    """Recurrent decode must match the chunked SSD scan (aggregate merge
    correctness end-to-end)."""
    cfg = get_config("mamba2-2.7b").reduced()
    lm = LM(cfg, ssd_chunk=4)
    key = jax.random.PRNGKey(4)
    params = lm.init(key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab)
    full_logits, _, _ = lm.forward(params, toks)
    cache = lm.init_cache(1, 32, params=params)
    outs = []
    for i in range(8):
        step_logits, cache = lm.decode_step(params, cache, toks[:, i:i + 1])
        outs.append(step_logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_param_counts_sane():
    """Analytic param counts in the expected ballpark for the full configs."""
    expect = {
        "qwen1.5-32b": (30e9, 36e9),
        "qwen3-14b": (13e9, 17e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "command-r-35b": (32e9, 40e9),
        "llama-3.2-vision-90b": (75e9, 95e9),
        "olmoe-1b-7b": (6e9, 8e9),
        # 17B is the ACTIVE count; total = 16 experts × 48 layers ≈ 100B
        "llama4-scout-17b-a16e": (90e9, 115e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "hymba-1.5b": (1.1e9, 2.1e9),
        "whisper-small": (0.15e9, 0.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_head_padding_exact_equivalence():
    """Padded heads are zero-weighted: the padded model computes the EXACT
    same function (the §Perf TP-sharding transform is semantics-free)."""
    cfg = get_config("qwen1.5-32b").reduced()   # reduced: 2 heads, kv 2
    key = jax.random.PRNGKey(5)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)

    lm0 = LM(cfg, q_chunk=16, kv_chunk=16)
    p0 = lm0.init(key)
    ref, _, _ = lm0.forward(p0, toks)

    lm1 = LM(cfg, q_chunk=16, kv_chunk=16, pad_heads_multiple=3)  # 2 -> 3
    assert lm1.cfg.n_heads == 3 and lm1.logical_cfg.n_heads == 2
    p1 = lm1.init(key)
    # graft the REAL head weights from the unpadded init so the function
    # is comparable (random inits differ otherwise)
    import numpy as np_

    def graft(dst, src, axis, n):
        dst = np_.asarray(dst).copy()
        sl = [slice(None)] * dst.ndim
        sl[axis] = slice(0, n)
        dst[tuple(sl)] = np_.asarray(src)
        return jnp.asarray(dst)

    blocks0, blocks1 = p0["blocks"], p1["blocks"]
    a0, a1 = blocks0["attn"], blocks1["attn"]
    for k, axis, n in [("wq", -2, 2), ("wk", -2, 2), ("wv", -2, 2),
                       ("bq", -2, 2), ("bk", -2, 2), ("bv", -2, 2),
                       ("wo", -3, 2)]:
        if k in a1:
            a1[k] = graft(a1[k], a0[k], axis, n)
    p1_full = dict(p1)
    p1_full["embed"] = p0["embed"]
    p1_full["final_norm"] = p0["final_norm"]
    blocks1 = dict(blocks1)
    blocks1["attn"] = a1
    blocks1["mlp"] = blocks0["mlp"]
    blocks1["norm1"] = blocks0["norm1"]
    blocks1["norm2"] = blocks0["norm2"]
    p1_full["blocks"] = blocks1
    got, _, _ = lm1.forward(p1_full, toks)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)
