"""Concurrency stress for the aggregate-serving layer.

An 8-way thread pool hammers ONE server with mixed-shape parameterized
requests (two plans × a parameter pool, sync ``execute`` and batched
``submit`` interleaved) and asserts:

* NO retrace storm — the trace counter stays within the number of
  distinct shape buckets (plan × batch-size bucket), however the racing
  requests happen to coalesce;
* slot tables build once per (table version, key set, bucket) no matter
  how many threads contend;
* results are deterministic: every response equals the fresh
  single-threaded reference.

The sharded variant reuses the subprocess 8-way host-mesh pattern of
test_sharded_segment_agg.py: a row-sharded catalog table is served
through the cached GLOBAL slot assignment (the provide_slots override
bypasses the per-shard launcher), stays bit-identical to the unsharded
reference, and still slots exactly once."""
import math
import os
import subprocess
import sys
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.relational import Table, execute
from repro.relational.plan import Filter, GroupAgg, Scan
from repro.serve import AggServer

from repro.core.loop_ir import Col, Var

SCHEMA = ("k", "v")


def _catalog():
    rng = np.random.default_rng(11)
    n = 2048
    return {"T": Table.from_columns(
        k=rng.integers(0, 40, n).astype(np.int32),
        v=rng.integers(-3, 4, n).astype(np.float32))}


def _plans():
    child = Filter(Scan("T", SCHEMA), Col("v") >= Var("lo"))
    scan = Scan("T", SCHEMA)
    return (
        # parameterized tiles (Filter child → slots derive in-trace)
        GroupAgg(child, ("k",), (("s", "sum", "v"), ("c", "count", None)),
                 max_groups=48),
        GroupAgg(child, ("k",), (("mx", "max", "v"), ("mn", "min", "v")),
                 max_groups=200),
        # scan tiles (Scan child → server-cached slot tables; the two
        # declared bounds bucket differently → two slot builds total)
        GroupAgg(scan, ("k",), (("s", "sum", "v"), ("c", "count", None)),
                 max_groups=48),
        GroupAgg(scan, ("k",), (("mx", "max", "v"), ("mn", "min", "v")),
                 max_groups=200),
    )


def _norm(t: Table) -> dict:
    out = t.to_numpy()
    keys = np.argsort(out["k"], kind="stable")
    return {c: tuple(np.asarray(v)[keys].tolist()) for c, v in out.items()}


def test_threadpool_stress_no_retrace_storm_deterministic():
    cat = _catalog()
    plans = _plans()
    params = [{"lo": float(x)} for x in (-3.0, -1.0, 0.0, 1.0, 2.0)]
    work_params = {i: (params if i < 2 else [{}])
                   for i in range(len(plans))}
    ref = {(i, p.get("lo")): _norm(execute(plans[i], cat, p))
           for i, ps in work_params.items() for p in ps}

    max_batch = 8
    srv = AggServer(cat, max_batch=max_batch, batch_window_s=0.001)
    rng = np.random.default_rng(0)
    work = []
    for i in rng.integers(0, len(plans), 200):
        ps = work_params[int(i)]
        work.append((int(i), ps[rng.integers(0, len(ps))]))

    def worker(chunk):
        got = []
        for n, (i, p) in enumerate(chunk):
            if n % 4 == 0:     # mix the serialized sync path in
                got.append(((i, p.get("lo")), _norm(srv.execute(plans[i], p))))
            else:
                got.append(((i, p.get("lo")),
                            srv.submit(plans[i], p)))
        return got

    with ThreadPoolExecutor(max_workers=8) as pool:
        chunks = [work[i::8] for i in range(8)]
        results = [r for f in [pool.submit(worker, c) for c in chunks]
                   for r in f.result()]
    srv.close()

    for key, got in results:
        if not isinstance(got, dict):
            got = _norm(got.result(timeout=120))
        assert got == ref[key], f"nondeterministic result for {key}"

    # retrace storm check: traces bounded by distinct shape buckets =
    # parameterized plans × batch-size buckets ({1,2,4,8} under
    # max_batch=8) + one bucket per parameterless scan tile, NOT by the
    # 200 requests
    buckets = int(math.log2(max_batch)) + 1
    assert srv.stats.traces <= 2 * buckets + 2
    # one slot table per (table version, key set, bucket): the two scan
    # tiles declare different buckets, so exactly two builds however 8
    # threads contend
    assert srv.stats.slot_builds == 2
    assert srv.stats.requests == 200


def test_sharded_serving_in_subprocess_8way_mesh():
    code = """
import numpy as np, jax, jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()
from jax.sharding import Mesh
from repro.relational import Table, execute
from repro.relational.plan import GroupAgg, Scan
from repro.serve import AggServer
import repro.launch.sharded_agg as sa

mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(7)
n = 4096
t = Table.from_columns(k=rng.integers(0, 37, n).astype(np.int32),
                       v=rng.integers(-40, 40, n).astype(np.float32))
plan = GroupAgg(Scan("T", ("k", "v")), ("k",),
                (("s", "sum", "v"), ("c", "count", None),
                 ("mx", "max", "v")), max_groups=64)
want = execute(plan, {"T": t}).to_numpy()

launcher_calls = []
orig = sa.sharded_sortfree_segment_agg
sa.sharded_sortfree_segment_agg = lambda *a, **k: (launcher_calls.append(1),
                                                   orig(*a, **k))[1]
srv = AggServer({"T": t.shard_rows(mesh, "data")})
outs = [srv.execute(plan) for _ in range(3)]
# stable cross-call global slot assignment: one build, one trace, and the
# per-shard launcher never runs — the cached global slots go through GSPMD
assert srv.stats.slot_builds == 1, srv.stats
assert srv.stats.traces == 1, srv.stats
assert not launcher_calls, "cached-slot serving must bypass the launcher"
o0 = outs[0].to_numpy()
for o in outs[1:]:
    on = o.to_numpy()
    assert all(np.array_equal(on[k], o0[k]) for k in on)
order = np.argsort(o0["k"], kind="stable")
worder = np.argsort(want["k"], kind="stable")
for k in want:
    assert np.array_equal(np.asarray(want[k])[worder],
                          np.asarray(o0[k])[order]), k
print("OK")
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ,
           "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "") +
                         " --xla_force_host_platform_device_count=8"),
           "PYTHONPATH": os.path.abspath(src) + os.pathsep +
                         os.environ.get("PYTHONPATH", "")}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr
