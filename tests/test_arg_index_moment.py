"""The in-kernel arg-extremum INDEX MOMENT (ISSUE 4 tentpole).

Four layers:

1. kernel — index rows (4/5) vs the hit-detection oracle for every tie
   order, including duplicate extremal keys *straddling a row-block
   boundary* (the lexicographic block merge), pruned == unpruned, and the
   moment-contract validation;
2. grouped ``AggCall`` — ``mode='fused'`` must match ``mode='stream'``
   (the sequential per-group semantics) BIT-FOR-BIT for all four
   comparison ops, with duplicate extremal keys inside a segment and
   across the executor's default 256-row kernel blocks; the wide-int
   key-expression bugfix routes to the exact jnp path;
3. engine ``GroupAgg`` — the new argmin/argmax built-in ops;
4. structure — the fused arg lowering issues NO row-capacity-sized gather
   (jaxpr spies shared with ``benchmarks/arg_gather_spy.py``), and the
   sharded arg-merge keeps every collective O(num_segments) (subprocess
   8-way mesh, duplicate extrema straddling shard boundaries).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Assign, BinOp, Const, CursorLoop, If, Program, Var,
                        aggify, let)
from repro.kernels import ref
from repro.kernels.segment_agg import (INDEX_EXACT_ROWS, fused_segment_agg,
                                       normalize_moments)
from repro.relational import GroupAgg, Scan, Table, execute
from repro.relational.plan import AggCall

TIES = (("argmin_first", True, True), ("argmin_last", True, False),
        ("argmax_first", False, True), ("argmax_last", False, False))


def _pick(idx_row, n, tie_first):
    idx_row = np.asarray(idx_row)
    if tie_first:
        return np.where(idx_row < n, idx_row, n).astype(np.int32)
    return np.where(idx_row >= 0, idx_row, -1).astype(np.int32)


# --------------------------------------------------------------------------
# 1. kernel: index rows vs oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
@pytest.mark.parametrize("mname,minimize,tie_first", TIES)
def test_kernel_index_moment_vs_oracle(backend, mname, minimize, tie_first):
    rng = np.random.default_rng(3)
    n, nseg = 500, 60
    segs = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    vals = rng.integers(-5, 5, (n, 2)).astype(np.float32)   # dense ties
    valid = rng.random((n, 2)) < 0.8
    out = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                            jnp.asarray(valid), nseg, block_rows=64,
                            block_segs=16, backend=backend,
                            moments=("sum", "count", mname))
    assert out.shape == (2, 6, nseg)
    for c in range(2):
        want = ref.segment_arg_index_ref(
            jnp.asarray(vals[:, c]), jnp.asarray(segs),
            jnp.asarray(valid[:, c]), nseg, minimize=minimize,
            tie_first=tie_first)
        got = _pick(out[c, 4 if minimize else 5], n, tie_first)
        assert np.array_equal(got, np.asarray(want))


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_kernel_ties_straddle_row_block_boundary(backend):
    """One segment spans several 16-row kernel blocks; the extremal key
    repeats at rows 14 and 18 — across the block boundary.  First-
    attaining must pick 14, last-attaining 18 (the lexicographic merge of
    resident vs block extremum, not whichever block came last)."""
    n = 48
    segs = np.zeros(n, np.int32)
    vals = np.full((n, 1), 5.0, np.float32)
    vals[14] = vals[18] = -3.0
    valid = np.ones((n, 1), bool)
    for mname, tie_first in (("argmin_first", True), ("argmin_last", False)):
        out = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                                jnp.asarray(valid), 1, block_rows=16,
                                block_segs=128, backend=backend,
                                moments=(mname,))
        got = _pick(out[0, 4], n, tie_first)
        assert got[0] == (14 if tie_first else 18), (mname, got[0])


def test_kernel_pruned_equals_unpruned_with_index():
    rng = np.random.default_rng(11)
    n, nseg = 3000, 600     # multiple segment tiles at block_segs=128
    segs = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
    vals = rng.integers(-4, 4, (n, 1)).astype(np.float32)
    valid = rng.random((n, 1)) < 0.9
    kw = dict(block_rows=128, block_segs=128, backend="interpret",
              moments=("argmin_first", "argmax_last"))
    pr = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                           jnp.asarray(valid), nseg, **kw)
    un = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                           jnp.asarray(valid), nseg, prune=False, **kw)
    assert np.array_equal(np.asarray(pr), np.asarray(un))
    want = fused_segment_agg(jnp.asarray(vals), jnp.asarray(segs),
                             jnp.asarray(valid), nseg, backend="jnp",
                             moments=("argmin_first", "argmax_last"))
    assert np.array_equal(np.asarray(pr[:, 4:]), np.asarray(want[:, 4:]))


def test_moment_contract_validation():
    v = jnp.zeros((8, 1), jnp.float32)
    s = jnp.zeros(8, jnp.int32)
    g = jnp.ones((8, 1), bool)
    with pytest.raises(ValueError, match="tie|direction|columns"):
        fused_segment_agg(v, s, g, 2, backend="jnp",
                          moments=("argmin_first", "argmin_last"))
    with pytest.raises(ValueError, match="unknown"):
        fused_segment_agg(v, s, g, 2, backend="jnp", moments=("argmin",))
    # index moments imply the matching extremum row
    ms = normalize_moments(("argmax_first",), 1)
    assert "max" in ms[0]
    # row counts beyond f32-exact indices are refused (shape-level check,
    # so eval_shape suffices — no 2^24-row array is materialized)
    big = INDEX_EXACT_ROWS + 8
    with pytest.raises(ValueError, match="2\\^24"):
        jax.eval_shape(
            lambda v, sg, gd: fused_segment_agg(
                v, sg, gd, 2, backend="jnp", moments=("argmin_first",)),
            jax.ShapeDtypeStruct((big, 1), jnp.float32),
            jax.ShapeDtypeStruct((big,), jnp.int32),
            jax.ShapeDtypeStruct((big, 1), jnp.bool_))


def test_index_gate_matches_kernel_padding():
    """The executors' use-index gate and the kernel's raise share ONE
    predicate over the PADDED row count: a count just under 2^24 whose
    block padding reaches the ceiling must fall back to the legacy pick,
    not trip the kernel's ValueError mid-trace."""
    from repro.kernels.segment_agg import index_moment_ok
    assert index_moment_ok(INDEX_EXACT_ROWS - 256)
    assert not index_moment_ok(INDEX_EXACT_ROWS - 100)  # pads up to 2^24
    assert not index_moment_ok(INDEX_EXACT_ROWS)

    def shape_only(n):
        return jax.eval_shape(
            lambda v, sg, gd: fused_segment_agg(
                v, sg, gd, 2, backend="jnp", moments=("argmin_first",)),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.bool_))

    shape_only(INDEX_EXACT_ROWS - 256)          # largest admissible count
    with pytest.raises(ValueError, match="2\\^24"):
        shape_only(INDEX_EXACT_ROWS - 100)


# --------------------------------------------------------------------------
# 2. grouped AggCall: fused == stream bit-for-bit, all four ops
# --------------------------------------------------------------------------


_SCHEMA = ("ps_partkey", "ps_suppkey", "ps_supplycost")


def _arg_prog(op, init):
    cond = {"<": Var("c") < Var("mc"), "<=": Var("c") <= Var("mc"),
            ">": Var("c") > Var("mc"), ">=": Var("c") >= Var("mc")}[op]
    return Program(
        "argx", params=(),
        pre=[let("mc", Const(init)), let("bs", Const(-1))],
        loop=CursorLoop(Scan("PARTSUPP", _SCHEMA),
                        fetch=[("c", "ps_supplycost"),
                               ("s", "ps_suppkey")],
                        body=[If(cond, [Assign("mc", Var("c")),
                                        Assign("bs", Var("s"))])]),
        post=[], returns=("mc", "bs"), var_dtypes={"bs": jnp.int32})


def _grouped(prog, mode):
    rp = aggify(prog)
    return AggCall(rp.agg_call.child, rp.agg_call.aggregate,
                   rp.agg_call.param_binding, rp.agg_call.ordered,
                   rp.agg_call.sort_keys, rp.agg_call.sort_desc,
                   group_keys=("ps_partkey",), mode=mode)


def _tie_catalog(n=600, ngroups=23, seed=5):
    """Integer-valued costs in a narrow range: every group has duplicate
    extremal keys, and at n=600 the duplicates straddle the executor's
    default 256-row kernel blocks.  Payloads are unique row ids, so a
    wrong tie pick cannot cancel out."""
    rng = np.random.default_rng(seed)
    return {"PARTSUPP": Table.from_columns(
        ps_partkey=np.sort(rng.integers(0, ngroups, n)).astype(np.int32),
        ps_suppkey=np.arange(n, dtype=np.int32),
        ps_supplycost=rng.integers(1, 5, n).astype(np.float32))}


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
@pytest.mark.parametrize("op", ["<", "<=", ">", ">="])
def test_grouped_arg_parity_bitwise(op, backend, monkeypatch):
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", backend)
    cat = _tie_catalog()
    init = 1e9 if op in ("<", "<=") else -1e9
    env = {"mc": jnp.float32(init), "bs": jnp.int32(-1)}
    prog = _arg_prog(op, init)
    want = execute(_grouped(prog, "stream"), cat, env).to_numpy()
    got = execute(_grouped(prog, "fused"), cat, env).to_numpy()
    assert set(want) == set(got)
    for k in want:
        assert np.array_equal(np.asarray(want[k]), np.asarray(got[k])), k


def test_grouped_arg_empty_contribution_groups(monkeypatch):
    """A guard that excludes every row of some groups: the pre-loop state
    must survive (the index row's empty sentinel gates the beat)."""
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", "interpret")
    n = 60
    rng = np.random.default_rng(9)
    key = np.sort(rng.integers(0, 6, n)).astype(np.int32)
    cost = rng.integers(1, 5, n).astype(np.float32)
    cat = {"PARTSUPP": Table.from_columns(
        ps_partkey=key, ps_suppkey=np.arange(n, dtype=np.int32),
        ps_supplycost=cost)}
    prog = Program(
        "guardedArg", params=(),
        pre=[let("mc", Const(1e9)), let("bs", Const(-7))],
        loop=CursorLoop(Scan("PARTSUPP", _SCHEMA),
                        fetch=[("c", "ps_supplycost"),
                               ("s", "ps_suppkey")],
                        body=[If(BinOp("and", Var("c") > Const(100.0),
                                       Var("c") < Var("mc")),
                                 [Assign("mc", Var("c")),
                                  Assign("bs", Var("s"))])]),
        post=[], returns=("mc", "bs"), var_dtypes={"bs": jnp.int32})
    env = {"mc": jnp.float32(1e9), "bs": jnp.int32(-7)}
    want = execute(_grouped(prog, "stream"), cat, env).to_numpy()
    got = execute(_grouped(prog, "fused"), cat, env).to_numpy()
    for k in want:
        assert np.array_equal(np.asarray(want[k]), np.asarray(got[k])), k
    assert np.all(got["bs"] == -7)      # nothing ever passes the guard


def test_wide_int_key_expression_routes_to_exact_path(monkeypatch):
    """Bugfix: the kernel casts key expressions to f32 before comparing;
    an int32 key column (values may exceed 2^24) must therefore route to
    the jnp path even when the key FIELD is f32 — the kernel must never
    see an arg-extremum over a wide-int key."""
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", "jnp")
    import importlib
    sk = importlib.import_module("repro.kernels.segment_agg")
    seen = []
    orig = sk.fused_segment_agg

    def spy(*a, **k):
        seen.append(k.get("moments"))
        return orig(*a, **k)

    monkeypatch.setattr(sk, "fused_segment_agg", spy)
    n = 40
    cat = {"PARTSUPP": Table.from_columns(
        ps_partkey=np.sort(np.arange(n) % 4).astype(np.int32),
        ps_suppkey=((1 << 24) + np.arange(n)).astype(np.int32),  # wide key
        ps_supplycost=np.arange(n, dtype=np.float32))}
    prog = Program(
        "argWide", params=(),
        pre=[let("mk", Const(1e18)), let("bc", Const(-1.0)),
             let("tot", Const(0.0))],
        loop=CursorLoop(Scan("PARTSUPP", _SCHEMA),
                        fetch=[("k", "ps_suppkey"),
                               ("c", "ps_supplycost")],
                        body=[Assign("tot", Var("tot") + Var("c")),
                              If(Var("k") < Var("mk"),
                                 [Assign("mk", Var("k")),
                                  Assign("bc", Var("c"))])]),
        post=[], returns=("mk", "bc", "tot"))
    env = {"mk": jnp.float32(1e18), "bc": jnp.float32(-1.0),
           "tot": jnp.float32(0.0)}
    want = execute(_grouped(prog, "stream"), cat, env).to_numpy()
    got = execute(_grouped(prog, "fused"), cat, env).to_numpy()
    for k in want:
        np.testing.assert_allclose(np.asarray(want[k]), np.asarray(got[k]),
                                   rtol=1e-6), k
    # the sum update still went through the kernel; the arg update did not
    assert seen, "fused path never reached the kernel"
    flat = [m for ms in seen for m in ms]
    assert any("sum" in ms for ms in flat)
    assert not any("argmin" in m or "argmax" in m or m in ("min", "max")
                   for ms in flat for m in ms), flat


# --------------------------------------------------------------------------
# 3. engine GroupAgg argmin/argmax
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["off", "jnp", "interpret"])
def test_groupagg_arg_ops(backend, monkeypatch):
    monkeypatch.setenv("REPRO_GROUPAGG_FUSED", backend)
    rng = np.random.default_rng(3)
    n = 300
    key = np.sort(rng.integers(0, 19, n)).astype(np.int32)
    cost = rng.integers(-4, 4, n).astype(np.float32)
    pay = np.arange(n, dtype=np.int32)
    t = Table.from_columns(k=key, c=cost, p=pay)
    plan = GroupAgg(Scan("L", ("k", "c", "p")), ("k",),
                    (("best", "argmin", ("c", "p")),
                     ("worst", "argmax", ("c", "p")),
                     ("n", "count", None)))
    got = execute(plan, {"L": t}).to_numpy()
    best, worst = {}, {}
    for i in range(n):
        g = key[i]
        if g not in best or cost[i] < best[g][0]:
            best[g] = (cost[i], pay[i])
        if g not in worst or cost[i] > worst[g][0]:
            worst[g] = (cost[i], pay[i])
    groups = sorted(best)
    assert np.array_equal(got["best"],
                          np.array([best[g][1] for g in groups]))
    assert np.array_equal(got["worst"],
                          np.array([worst[g][1] for g in groups]))


def test_groupagg_wide_int_key_exact(monkeypatch):
    """Keys above 2^24 that collide in f32 stay on the exact per-op path:
    the true (integer-compared) extremum row wins."""
    monkeypatch.setenv("REPRO_GROUPAGG_FUSED", "jnp")
    t = Table.from_columns(
        k=np.array([0, 0, 1, 1], np.int32),
        c=np.array([(1 << 24) + 2, (1 << 24) + 1, 5, 3], np.int32),
        p=np.array([10, 20, 30, 40], np.int32))
    plan = GroupAgg(Scan("L", ("k", "c", "p")), ("k",),
                    (("b", "argmin", ("c", "p")),))
    got = execute(plan, {"L": t}).to_numpy()
    assert np.array_equal(got["b"], [20, 40])


# --------------------------------------------------------------------------
# 4. structure: no row-sized gathers; sharded arg-merge O(num_segments)
# --------------------------------------------------------------------------


def test_arg_select_tail_has_no_row_sized_gather():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.arg_gather_spy import tail_gather_sizes
    n = 4096
    sizes = tail_gather_sizes(n=n, num_segments=129)
    assert sizes, "expected the payload take in the tail"
    assert all(s < n for s in sizes), sizes


def test_whole_program_gathers_match_no_arg_baseline():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.arg_gather_spy import whole_program_row_gathers
    counts = whole_program_row_gathers(2_000, 64, "interpret")
    assert counts["fused_argmin"] == counts["fused_minmax_baseline"], counts
    assert counts["fused_argmin_legacy_select"] > counts["fused_argmin"], \
        counts


def test_sharded_arg_merge_in_subprocess_8way_mesh():
    """8-way host mesh in a subprocess (plain tier-1 has one device):
    duplicate extremal keys STRADDLE SHARD BOUNDARIES, first- and last-
    attaining picks must match the stream executor bit-for-bit, payloads
    come back from the shard-local gather, and every collective in the
    sharded program is O(num_segments) — never row-sized."""
    code = """
import numpy as np, jax, jax.numpy as jnp, os
assert jax.device_count() == 8, jax.device_count()
from jax.sharding import Mesh
from repro.core import Assign, Const, CursorLoop, If, Program, Var, aggify, let
from repro.relational import GroupAgg, Scan, Table, execute
from repro.relational.plan import AggCall

mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
n, ngroups = 640, 7          # ~91 rows per group: every group straddles shards
rng = np.random.default_rng(13)
key = np.sort(rng.integers(0, ngroups, n)).astype(np.int32)
cost = rng.integers(1, 4, n).astype(np.float32)     # duplicate extrema
supp = np.arange(n, dtype=np.int32)
schema = ("ps_partkey", "ps_suppkey", "ps_supplycost")
cat = {"PARTSUPP": Table.from_columns(ps_partkey=key, ps_suppkey=supp,
                                      ps_supplycost=cost)}
cat_sh = {"PARTSUPP": cat["PARTSUPP"].shard_rows(mesh, "data")}

def prog(op, init):
    cond = {"<": Var("c") < Var("mc"), "<=": Var("c") <= Var("mc"),
            ">": Var("c") > Var("mc"), ">=": Var("c") >= Var("mc")}[op]
    return Program("argx", params=(),
        pre=[let("mc", Const(init)), let("bs", Const(-1))],
        loop=CursorLoop(Scan("PARTSUPP", schema),
                        fetch=[("c", "ps_supplycost"), ("s", "ps_suppkey")],
                        body=[If(cond, [Assign("mc", Var("c")),
                                        Assign("bs", Var("s"))])]),
        post=[], returns=("mc", "bs"), var_dtypes={"bs": jnp.int32})

import repro.launch.sharded_agg as sa
for op in ("<", "<=", ">", ">="):
    init = 1e9 if op in ("<", "<=") else -1e9
    p = prog(op, init)
    rp = aggify(p)
    env = {"mc": jnp.float32(init), "bs": jnp.int32(-1)}
    def call(mode):
        return AggCall(rp.agg_call.child, rp.agg_call.aggregate,
                       rp.agg_call.param_binding, rp.agg_call.ordered,
                       rp.agg_call.sort_keys, rp.agg_call.sort_desc,
                       group_keys=("ps_partkey",), mode=mode)
    want = execute(call("stream"), cat, env).to_numpy()
    calls = []
    orig = sa.sharded_fused_segment_agg
    sa.sharded_fused_segment_agg = lambda *a, **k: (
        calls.append(len(k.get("payloads", ()))), orig(*a, **k))[1]
    got = execute(call("fused"), cat_sh, env).to_numpy()
    sa.sharded_fused_segment_agg = orig
    assert calls and calls[0] == 1, (op, calls)
    for k in want:
        assert np.array_equal(np.asarray(want[k]), np.asarray(got[k])), (op, k)

# GroupAgg argmin/argmax over the sharded table
t = Table.from_columns(k=key, c=cost, p=supp)
plan = GroupAgg(Scan("L", ("k", "c", "p")), ("k",),
                (("best", "argmin", ("c", "p")),
                 ("worst", "argmax", ("c", "p"))))
want = execute(plan, {"L": t}).to_numpy()
got = execute(plan, {"L": t.shard_rows(mesh, "data")}).to_numpy()
for k in want:
    assert np.array_equal(np.asarray(want[k]), np.asarray(got[k])), k

# every collective of the sharded arg program is O(num_segments)
from repro.analysis.jaxpr_spy import iter_eqns
from repro.kernels.segment_agg import fused_segment_agg
import math
segs = np.cumsum(np.concatenate([[1], key[1:] != key[:-1]])) - 1
nseg = 129   # bucketed bound + overflow
def run(v, s, g, pv):
    return sa.sharded_fused_segment_agg(
        v, s, g, nseg, mesh=mesh, axis="data", backend="jnp",
        moments=("argmin_first",), assume_sorted=True,
        payloads=((0, True, (pv,)),))
closed = jax.make_jaxpr(run)(
    jnp.asarray(cost[:, None]), jnp.asarray(segs.astype(np.int32)),
    jnp.ones((n, 1), bool), jnp.asarray(supp))
psum_sizes = [math.prod(eqn.outvars[0].aval.shape)
              for eqn in iter_eqns(closed)
              if eqn.primitive.name in ("psum", "pmin", "pmax")]
assert psum_sizes, "no collectives traced"
assert max(psum_sizes) < n, (max(psum_sizes), n)   # O(S), never O(rows)
print("OK")
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ,
           "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "") +
                         " --xla_force_host_platform_device_count=8"),
           "PYTHONPATH": os.path.abspath(src) + os.pathsep +
                         os.environ.get("PYTHONPATH", "")}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr
