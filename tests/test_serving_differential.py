"""Hypothesis differential fuzzer for the aggregate-serving layer.

Random (schema, key dtypes incl. int64/f64/NaN keys, agg set, group
bound, parameter stream) cases — drawn as the same plain dicts the seed
corpus stores — run through ``serving_cases.run_case``, which asserts
bit-for-bit parity of cached-vs-fresh, sort-free-vs-sorted and
batched-vs-sequential execution against the numpy oracle.  Failures
shrink to a dict that goes straight into ``serving_cases.CORPUS`` and
replays without hypothesis (test_serving_corpus.py).

Module gating: skips-with-reason locally, hard-fails under
``REPRO_REQUIRE_HYPOTHESIS=1`` (the CI contract); CI also pins
``REPRO_FUZZ_EXAMPLES=200`` for the acceptance depth."""
from hypothesis_gate import fuzz_examples, require_hypothesis

require_hypothesis()

import hypothesis.strategies as st            # noqa: E402
from hypothesis import HealthCheck, given, settings  # noqa: E402

from serving_cases import KEY_DTYPES, OPS, run_case  # noqa: E402


@st.composite
def serving_case(draw):
    filtered = draw(st.booleans())
    nkeys = draw(st.integers(1, 2))
    key_dtypes = tuple(draw(st.sampled_from(KEY_DTYPES))
                       for _ in range(nkeys))
    # agg set: 1–3 distinct ops, order-normalized so structurally equal
    # plans intern to one server entry (bounded trace count)
    aggs = tuple(sorted(draw(
        st.sets(st.sampled_from(OPS), min_size=1, max_size=3))))
    case = {
        "seed": draw(st.integers(0, 2**31 - 1)),
        # ≥ 136 rows: the 128-slot minimum bucket must sit below the row
        # capacity for the dense bound (and the sort-free route) to engage
        "n": draw(st.integers(136, 256)),
        "key_dtypes": key_dtypes,
        "card": draw(st.integers(2, 6)),
        "nan_keys": draw(st.booleans())
        and any(d.startswith("float") for d in key_dtypes),
        "invalid_frac": draw(st.sampled_from((0.0, 0.2, 0.5))),
        "aggs": aggs,
        "filtered": filtered,
    }
    # declared vs inferred dense bound (None → the server's sketch)
    if draw(st.booleans()):
        case["max_groups"] = draw(st.integers(4, 64))
    if filtered:
        case["params"] = tuple(
            float(draw(st.integers(-2, 2)))
            for _ in range(draw(st.integers(1, 5))))
    return case


@settings(max_examples=fuzz_examples(20), deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(serving_case())
def test_differential_routes(case):
    run_case(case)
