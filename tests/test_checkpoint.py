"""Durable checkpoint/restore battery (serve/checkpoint.py).

The contract under test (docs/serving.md "Durability & consistency"):

* checkpoint → kill (new process modeled as a fresh ``AggServer`` over
  the live table) → restore → replay yields BIT-identical snapshots to
  the uninterrupted server, across the fused-op battery of the
  incremental-ingest tests — including rows ingested after the
  checkpoint (replayed through the normal fold path, one catch-up
  fold, never a re-seed);
* a torn payload write (``checkpoint_write`` fault) and read-path bit
  rot (``restore_corrupt`` fault) surface as typed
  ``CheckpointCorrupt`` and install NOTHING — snapshots recompute and
  stay correct, never silently wrong;
* a catalog that diverged from the watermark (rows replaced) quietly
  declines rehydration — the residency re-seeds from live data;
* files commit atomically (temp-then-rename, manifest last; no ``.tmp``
  litter) and sequence numbers increase so restore takes the newest;
* ``REPRO_SERVE_CKPT=off`` turns both verbs into no-ops.
"""
import glob
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.relational import Table, execute, keyslot
from repro.relational.plan import GroupAgg, Scan
from repro.reliability import faults
from repro.serve import AggServer, CheckpointCorrupt, ServeRequest

SCHEMA = ("k", "v", "p")


def _plan(max_groups=128):
    return GroupAgg(Scan("T", SCHEMA), ("k",),
                    (("s", "sum", "v"), ("c", "count", None),
                     ("mn", "min", "v"), ("mx", "max", "v"),
                     ("me", "mean", "v"),
                     ("am", "argmin", ("v", "p")),
                     ("ax", "argmax", ("v", "p"))),
                    max_groups=max_groups)


def _mk_table(n=512, card=40, seed=0, spare=512):
    # integer-valued f32 payloads: every moment is f32-exact, so replayed
    # folds and the uninterrupted server agree BITWISE (== on dicts)
    rng = np.random.default_rng(seed)
    cap = n + spare
    cols = {"k": rng.integers(0, card, cap).astype(np.int32),
            "v": rng.integers(-40, 40, cap).astype(np.float32),
            "p": rng.integers(0, 10_000, cap).astype(np.int32)}
    valid = np.arange(cap) < n
    return Table({c: jnp.asarray(a) for c, a in cols.items()},
                 jnp.asarray(valid))


def _batch(nb, card, seed):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, card, nb).astype(np.int32),
            "v": rng.integers(-40, 40, nb).astype(np.float32),
            "p": rng.integers(0, 10_000, nb).astype(np.int32)}


def _groups(t: Table) -> dict:
    out = t.to_numpy()
    return {int(out["k"][i]):
            tuple(float(out[c][i]) for c in ("s", "c", "mn", "mx", "me",
                                             "am", "ax"))
            for i in range(len(out["s"]))}


def _reference(srv: AggServer, plan) -> dict:
    return _groups(execute(plan, {"T": srv.table("T")}))


def _primed_server(tmp_path, pre_batches=3, seed=0):
    """A server with a seeded + folded residency, checkpointed."""
    srv = AggServer({"T": _mk_table(seed=seed)})
    plan = _plan()
    srv.snapshot(plan)
    for i in range(pre_batches):
        srv.ingest("T", _batch(48, 60, seed=100 + i))
    mpath = srv.checkpoint(str(tmp_path))
    return srv, plan, mpath


# ---------------------------------------------------------------------------
# the headline: checkpoint → kill → restore → replay, bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("post_batches", [0, 1, 3])
def test_checkpoint_restore_replay_bit_parity(tmp_path, post_batches):
    srv, plan, mpath = _primed_server(tmp_path)
    assert mpath is not None and os.path.exists(mpath)
    assert srv.stats.checkpoints == 1
    # rows ingested AFTER the checkpoint: the restore must replay them
    for i in range(post_batches):
        srv.ingest("T", _batch(32, 60, seed=200 + i))
    truth = _groups(srv.snapshot(plan))

    # "kill": a fresh server over the live table — no process memory
    srv2 = AggServer({"T": srv.table("T")})
    assert srv2.restore(str(tmp_path)) == 1
    assert srv2.stats.restores == 1
    plan2 = _plan()     # a fresh, structurally identical plan object
    got = _groups(srv2.snapshot(plan2))
    assert got == truth
    # the suffix replayed through the fold path: at most one catch-up
    # fold, never a re-seed (slot_builds counts the seed's build)
    assert srv2.stats.folds == (1 if post_batches else 0)
    assert srv2.stats.slot_builds == 0
    # and the residency keeps folding afterwards
    srv2.ingest("T", _batch(16, 60, seed=300))
    assert _groups(srv2.snapshot(plan2)) == _reference(srv2, plan2)
    srv.close()
    srv2.close()


def test_restored_snapshot_version_reaches_live_watermark(tmp_path):
    srv, plan, _ = _primed_server(tmp_path)
    srv.ingest("T", _batch(32, 60, seed=210))
    live_version = srv.table("T").version
    srv2 = AggServer({"T": srv.table("T")})
    srv2.restore(str(tmp_path))
    plan2 = _plan()
    res = srv2.serve(ServeRequest(plan=plan2, consistency="snapshot"))
    assert res.version == live_version
    # a subsequent epoch read serves the caught-up epoch lock-free
    res2 = srv2.serve(ServeRequest(plan=plan2, consistency="epoch"))
    assert res2.version == live_version
    assert srv2.stats.epoch_reads >= 1
    srv.close()
    srv2.close()


def test_restore_replays_appends_recorded_before_first_snapshot(tmp_path):
    """Ingests that land on the NEW server before its first snapshot are
    chained on top of the synthetic checkpoint step — one catch-up fold
    covers both the pre-restart suffix and the fresh batches."""
    srv, plan, _ = _primed_server(tmp_path)
    srv.ingest("T", _batch(32, 60, seed=220))       # pre-restart suffix
    srv2 = AggServer({"T": srv.table("T")})
    srv2.restore(str(tmp_path))
    srv2.ingest("T", _batch(24, 60, seed=221))      # lands BEFORE snapshot
    plan2 = _plan()
    assert _groups(srv2.snapshot(plan2)) == _reference(srv2, plan2)
    assert srv2.stats.slot_builds == 0              # never re-seeded
    srv.close()
    srv2.close()


# ---------------------------------------------------------------------------
# corruption: torn writes and bit rot are typed, never silently wrong
# ---------------------------------------------------------------------------


def test_torn_checkpoint_write_detected_at_restore(tmp_path):
    srv = AggServer({"T": _mk_table(seed=1)})
    plan = _plan()
    srv.snapshot(plan)
    with faults.inject("checkpoint_write:1"):
        mpath = srv.checkpoint(str(tmp_path))
    assert mpath is not None        # the writer didn't notice the tear
    srv2 = AggServer({"T": srv.table("T")})
    with pytest.raises(CheckpointCorrupt, match="checksum"):
        srv2.restore(str(tmp_path))
    assert srv2.stats.restores == 0
    # nothing installed: the snapshot re-seeds and is correct
    plan2 = _plan()
    builds0 = keyslot.slot_build_count()
    assert _groups(srv2.snapshot(plan2)) == _reference(srv2, plan2)
    assert keyslot.slot_build_count() > builds0     # re-seeded from live
    srv.close()
    srv2.close()


def test_restore_bit_rot_detected(tmp_path):
    srv, plan, _ = _primed_server(tmp_path, seed=2)
    srv2 = AggServer({"T": srv.table("T")})
    with faults.inject("restore_corrupt:1"):
        with pytest.raises(CheckpointCorrupt) as ei:
            srv2.restore(str(tmp_path))
    assert ei.value.path and ei.value.path.endswith(".npz")
    assert not srv2._restored       # all-or-nothing: nothing staged
    plan2 = _plan()
    assert _groups(srv2.snapshot(plan2)) == _reference(srv2, plan2)
    srv.close()
    srv2.close()


def test_truncated_manifest_is_typed(tmp_path):
    srv, plan, mpath = _primed_server(tmp_path, seed=3)
    with open(mpath, "r+") as f:    # crash mid-manifest-write, modeled
        f.truncate(os.path.getsize(mpath) // 2)
    srv2 = AggServer({"T": srv.table("T")})
    with pytest.raises(CheckpointCorrupt, match="manifest"):
        srv2.restore(str(tmp_path))
    srv.close()
    srv2.close()


def test_diverged_catalog_declines_rehydration(tmp_path):
    """update_table after the checkpoint: the watermark rows no longer
    match, so the restore stages but rehydration declines and the
    snapshot re-seeds — correct, just not incremental."""
    srv, plan, _ = _primed_server(tmp_path, seed=4)
    t = srv.table("T")
    t2 = t.with_column("v", jnp.asarray(np.asarray(t.columns["v"]) * 2))
    srv2 = AggServer({"T": t2})
    assert srv2.restore(str(tmp_path)) == 1
    plan2 = _plan()
    builds0 = keyslot.slot_build_count()
    assert _groups(srv2.snapshot(plan2)) == _reference(srv2, plan2)
    assert keyslot.slot_build_count() > builds0     # seeded from live data
    srv.close()
    srv2.close()


# ---------------------------------------------------------------------------
# file mechanics: atomic commit, newest-wins sequencing
# ---------------------------------------------------------------------------


def test_atomic_files_and_sequencing(tmp_path):
    srv, plan, m1 = _primed_server(tmp_path, seed=5)
    srv.ingest("T", _batch(32, 60, seed=400))
    srv.snapshot(plan)              # fold the batch in before checkpoint 2
    m2 = srv.checkpoint(str(tmp_path))
    assert m2 != m1
    assert not glob.glob(str(tmp_path / "*.tmp"))   # rename committed all
    truth = _groups(srv.snapshot(plan))
    # restore takes the NEWEST checkpoint: zero replay folds needed
    srv2 = AggServer({"T": srv.table("T")})
    srv2.restore(str(tmp_path))
    plan2 = _plan()
    assert _groups(srv2.snapshot(plan2)) == truth
    assert srv2.stats.folds == 0
    srv.close()
    srv2.close()


def test_checkpoint_without_residents_is_none(tmp_path):
    srv = AggServer({"T": _mk_table(seed=6)})
    assert srv.checkpoint(str(tmp_path)) is None
    assert srv.stats.checkpoints == 0
    srv2 = AggServer({"T": _mk_table(seed=6)})
    assert srv2.restore(str(tmp_path)) == 0     # empty dir: no manifest
    srv.close()
    srv2.close()


def test_kill_switch_disables_both_verbs(tmp_path, monkeypatch):
    srv, plan, _ = _primed_server(tmp_path, seed=7)
    monkeypatch.setenv("REPRO_SERVE_CKPT", "off")
    assert srv.checkpoint(str(tmp_path)) is None
    srv2 = AggServer({"T": srv.table("T")})
    assert srv2.restore(str(tmp_path)) == 0
    plan2 = _plan()
    # snapshots recompute exactly as if no checkpoint existed
    assert _groups(srv2.snapshot(plan2)) == _reference(srv2, plan2)
    srv.close()
    srv2.close()
