"""Cache-invalidation battery for the aggregate-serving layer.

The contract under test (docs/serving.md):

* the slot table is built exactly ONCE per (table version, key set,
  bucket) — repeated parameterized calls amortize slotting to zero;
* ``update_table`` is the REPLACE verb: it rebuilds the slot table
  exactly once, FROM THE NEW VERSION (spied on
  ``relational/keyslot.py``), and invalidates the executables of every
  plan scanning the table — content may have changed arbitrarily, so
  nothing derived from the old version survives.  A stale slot read is
  structurally impossible because slot arrays are executable *arguments*
  keyed by ``Table.version``;
* ``append_rows`` is the APPEND verb: executables SURVIVE (no retrace
  while rows fit the spare capacity) and the slot table EXTENDS
  incrementally instead of rebuilding (tests/test_incremental_ingest.py
  holds the full append/ingest battery);
* a user-declared bound that overflows raises eagerly at the slot build;
  an inferred bound grows and revalidates instead;
* ``REPRO_AGG_SERVE=off`` kills every cache but stays correct."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.relational import Table, concat, execute
from repro.relational import keyslot
from repro.relational.plan import GroupAgg, Scan
from repro.serve import AggServer

N = 160
SCHEMA = ("k", "v")


def _table(n=N, card=12, seed=0):
    # explicit all-true mask: a later ``filter`` then mutates the mask
    # VALUES without changing the pytree structure (None → array would
    # be a structural change, which legitimately retraces)
    rng = np.random.default_rng(seed)
    return Table({"k": jnp.asarray(rng.integers(0, card, n).astype(np.int32)),
                  "v": jnp.asarray(rng.integers(-4, 5, n).astype(np.float32))},
                 jnp.ones(n, bool))


def _plan(max_groups=24):
    return GroupAgg(Scan("T", SCHEMA), ("k",),
                    (("s", "sum", "v"), ("c", "count", None),
                     ("mx", "max", "v")), max_groups=max_groups)


def _groups(t: Table) -> dict:
    out = t.to_numpy()
    return {int(k): (s, c, m) for k, s, c, m in
            zip(out["k"], out["s"], out["c"], out["mx"])}


def test_slot_table_built_exactly_once_across_repeats():
    t = _table()
    srv = AggServer({"T": t})
    plan = _plan()
    before = keyslot.slot_build_count()
    ref = _groups(srv.execute(plan))
    for _ in range(4):
        assert _groups(srv.execute(plan)) == ref
    assert srv.stats.slot_builds == 1
    assert srv.stats.slot_hits == 4
    # the keyslot-level spy agrees: one probe-loop build total — the
    # executable's in-trace call was intercepted by provide_slots
    assert keyslot.slot_build_count() - before == 1
    assert srv.stats.traces == 1


def test_mutation_rebuilds_slots_once_from_new_version(monkeypatch):
    t = _table()
    srv = AggServer({"T": t})
    plan = _plan()

    eager_builds = []   # versions of CONCRETE (eager) probe builds
    orig = keyslot.slot_state_build

    def spy(table, keys, bucket, expand=None):
        import jax as _jax
        if not isinstance(next(iter(table.columns.values())),
                          _jax.core.Tracer):
            eager_builds.append(table.version)
        return orig(table, keys, bucket, expand)

    monkeypatch.setattr(keyslot, "slot_state_build", spy)

    srv.execute(plan)
    srv.execute(plan)
    assert eager_builds == [t.version]

    # REPLACE: content changed arbitrarily (filter mutates the mask), so
    # the slot table rebuilds once from the NEW version and the plan's
    # executables are invalidated (the replace contract)
    t2 = t.filter(jnp.asarray(np.asarray(t.columns["v"]) >= 0))
    srv.update_table("T", t2)
    got = _groups(srv.execute(plan))
    srv.execute(plan)

    assert eager_builds == [t.version, t2.version]   # rebuilt once, new version
    assert srv.stats.slot_builds == 2
    # stale-read impossible: rebuilt slots + fresh executable == fresh
    assert got == _groups(execute(plan, {"T": t2}))
    assert got != _groups(execute(plan, {"T": t}))


def test_update_table_invalidates_executables():
    # the REPLACE verb drops every executable of every plan scanning the
    # table — even for a shape-compatible swap the old trace may have
    # folded stale content decisions in, so nothing derived from the old
    # version survives (append_rows is the verb that keeps them; see
    # tests/test_incremental_ingest.py)
    t = _table()
    srv = AggServer({"T": t})
    plan = _plan()
    srv.execute(plan)
    traces = srv.stats.traces
    t2 = t.with_column("v", jnp.asarray(
        np.asarray(t.columns["v"]) * np.float32(2.0)))
    srv.update_table("T", t2)
    got = _groups(srv.execute(plan))
    assert srv.stats.traces == traces + 1            # replace: retrace
    assert srv.stats.slot_builds == 2                # new version: one rebuild
    assert got == _groups(execute(plan, {"T": t2}))


def test_append_mutation_retraces_and_stays_correct():
    t = _table()
    srv = AggServer({"T": t})
    plan = _plan()
    srv.execute(plan)
    traces = srv.stats.traces
    extra = _table(n=32, card=12, seed=9)
    t2 = concat(t, extra)                            # capacity grows
    srv.update_table("T", t2)
    got = _groups(srv.execute(plan))
    assert srv.stats.traces == traces + 1            # new shape bucket
    assert srv.stats.slot_builds == 2
    assert got == _groups(execute(plan, {"T": t2}))


def test_declared_overflow_raises_eagerly():
    rng = np.random.default_rng(3)
    n = 400
    t = Table.from_columns(k=rng.permutation(n).astype(np.int32),
                           v=np.ones(n, np.float32))
    srv = AggServer({"T": t})
    # ~400 distinct keys vs a 128-slot bucket: the server's eager slot
    # build must raise (the engine contract), not poison inside a trace
    with pytest.raises(ValueError, match="beyond the declared dense bound"):
        srv.execute(_plan(max_groups=16))


def test_inferred_bound_grows_on_mutation():
    rng = np.random.default_rng(4)
    t = Table.from_columns(
        k=rng.integers(0, 60, 400).astype(np.int32),
        v=rng.integers(-4, 5, 400).astype(np.float32))
    srv = AggServer({"T": t})
    plan = _plan(max_groups=None)                    # server sketches a bound
    srv.execute(plan)
    d = srv.describe(plan)
    assert d["inferred"] and d["bound"] == 128
    # the mutated table carries ~340 distinct keys — past the inferred
    # bucket: the build overflow doubles the bound until it validates
    extra = Table.from_columns(
        k=(1000 + rng.permutation(300)).astype(np.int32),
        v=np.ones(300, np.float32))
    t2 = concat(t, extra)
    srv.update_table("T", t2)
    got = _groups(srv.execute(plan))
    assert srv.describe(plan)["bound"] == 512
    assert got == _groups(execute(plan, {"T": t2}))


def test_kill_switch_disables_caches(monkeypatch):
    monkeypatch.setenv("REPRO_AGG_SERVE", "off")
    t = _table()
    srv = AggServer({"T": t})
    plan = _plan()
    ref = _groups(execute(plan, {"T": t}))
    assert _groups(srv.execute(plan)) == ref
    assert _groups(srv.submit(plan).result(timeout=60)) == ref
    assert srv.stats.requests == 0 and srv.stats.traces == 0
    assert srv.stats.slot_builds == 0
