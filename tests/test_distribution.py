"""Distribution-layer tests that run on ONE device: sharding-rule
assignment logic (divisibility fallbacks), the aggregate Merge under a
sharded execution (via vmap-simulated shards), and attention partial-merge
equivalence — the math that the multi-chip mesh executes over ICI."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.aggregate import Aggregate, chunked, streaming
from repro.launch.sharding import _assign
from repro.models.attention import decode_attention_jnp, softmax_aggregate


class FakeMesh:
    """Duck-typed mesh: only .shape (dict) and .axis_names are used by the
    assignment helper."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_assign_prefers_first_dividing_axis():
    # 64 heads divide 16 → model on dim 2
    spec = _assign(MESH, (64, 8192, 64, 128), [(2, "model"), (1, "model")])
    assert spec == P(None, None, "model", None)
    # 40 heads do NOT divide 16 → fall to d_model
    spec = _assign(MESH, (64, 5120, 40, 128), [(2, "model"), (1, "model")])
    assert spec == P(None, "model", None, None)


def test_assign_axis_used_once():
    spec = _assign(MESH, (16, 16), [(0, "model"), (1, "model")])
    assert spec == P("model", None)


def test_assign_tuple_axes():
    spec = _assign(MESH_MP, (256, 4096), [(0, ("pod", "data"))])
    assert spec == P(("pod", "data"), None)
    # batch=1 can't shard
    spec = _assign(MESH_MP, (1, 4096), [(0, ("pod", "data"))])
    assert spec == P(None, None)


def test_param_and_opt_specs_cover_tree():
    from repro.configs import get_config
    from repro.launch.sharding import opt_specs, param_specs
    from repro.models import LM
    from repro.train.optimizer import init_opt_state
    cfg = get_config("qwen3-14b").reduced()
    lm = LM(cfg)
    params = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    spec = param_specs(MESH, cfg, params)
    # spec tree mirrors the param tree exactly
    assert jax.tree.structure(spec, is_leaf=lambda x: isinstance(x, P)) \
        == jax.tree.structure(params)
    opt = jax.eval_shape(init_opt_state, params)
    ospec = opt_specs(MESH, cfg, opt, spec)
    assert set(ospec) == {"master", "m", "v", "step"}


def test_softmax_aggregate_merge_matches_monolithic():
    """Splitting a KV cache into shards, accumulating locally and merging
    (the ICI flash-decode combine) equals monolithic softmax attention."""
    rng = np.random.default_rng(0)
    d = 16
    s = 64
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    k = jnp.asarray(rng.standard_normal((s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((s, d)), jnp.float32)
    logits = k @ q / np.sqrt(d)

    agg = softmax_aggregate(d)
    # 4 'shards' of 16 rows each: local accumulate, then ordered merge
    partials = []
    for i in range(4):
        st = agg.identity()
        for j in range(16):
            st = agg.accumulate(st, {"s": logits[16 * i + j],
                                     "v": v[16 * i + j]})
        partials.append(st)
    merged = partials[0]
    for p in partials[1:]:
        merged = agg.merge(merged, p)
    got = agg.terminate(merged)

    w = jax.nn.softmax(logits)
    want = w @ v
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_decode_attention_shard_split_equivalence():
    """decode_attention_jnp over a split cache + aggregate merge == over
    the full cache (what XLA's partitioner computes when S is sharded)."""
    rng = np.random.default_rng(1)
    b, h, d, s = 2, 4, 16, 64
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    kv_len = jnp.asarray([64, 40], jnp.int32)
    want = decode_attention_jnp(q, k, v, kv_len)

    # manual two-shard merge, per (b, h) scalar-state folds
    agg = softmax_aggregate(d)
    got = np.zeros((b, h, d), np.float32)
    for bi in range(b):
        for hi in range(h):
            partials = []
            for shard in range(2):
                st = agg.identity()
                for j in range(32):
                    pos = shard * 32 + j
                    logit = jnp.where(pos < kv_len[bi],
                                      k[bi, pos, hi] @ q[bi, hi] / np.sqrt(d),
                                      -1e30)
                    st = agg.accumulate(st, {"s": logit, "v": v[bi, pos, hi]})
                partials.append(st)
            merged = agg.merge(partials[0], partials[1])
            got[bi, hi] = np.asarray(agg.terminate(merged))
    np.testing.assert_allclose(got, np.asarray(want), rtol=2e-4, atol=2e-4)


def test_chunked_aggregate_under_vmap_batching():
    """chunked() composes with vmap — per-row group parallelism (how the
    grouped executor maps onto VPU lanes)."""
    def init():
        return {"s": jnp.zeros((), jnp.float32)}

    agg = Aggregate(
        "sum", init,
        lambda st, row: {"s": st["s"] + row["x"]},
        lambda st: st["s"],
        merge=lambda a, b: {"s": a["s"] + b["s"]},
        identity=init)
    rows = {"x": jnp.arange(24, dtype=jnp.float32).reshape(4, 6)}
    out = jax.vmap(lambda r: chunked(agg, r, None, num_chunks=3))(rows)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(rows["x"].sum(axis=1)))
