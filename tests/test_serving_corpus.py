"""Seed-corpus replay for the serving differential fuzzer: every case in
``serving_cases.CORPUS`` runs through the full route-parity battery
WITHOUT hypothesis — failures found by the fuzzer get minimized into the
corpus and stay reproducible in any environment (the hermetic container
only guarantees jax + pytest)."""
import pytest

from serving_cases import CORPUS, run_case


@pytest.mark.parametrize("case", CORPUS,
                         ids=[f"seed{c['seed']}" for c in CORPUS])
def test_corpus_case(case):
    run_case(case)
