"""Join-semantics battery under both lookup routes (keyslot hash vs
legacy argsort) + whole-plan fusion parity gates.

Every case runs bit-for-bit three ways where applicable: hash route,
legacy route (``REPRO_JOIN_HASH=off``), numpy oracle — and the fused
chain (``relational/fuse.py``) against the per-node materialized plan
(``REPRO_PLAN_FUSE=off``) on the jnp AND interpret kernel backends.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.loop_ir import BinOp, Col, Const
from repro.relational import (Filter, GroupAgg, Join, Limit, Project,
                              Scan, Table, execute)

HOWS = ("inner", "left", "semi", "anti")


# --------------------------------------------------------------------------
# oracle
# --------------------------------------------------------------------------


def _oracle_join(lk, lvalid, rk, rvalid, rcols, how):
    """Row-by-row numpy reference: each valid left row matched against
    the smallest valid right row with an equal key (value equality — NaN
    never matches)."""
    n = len(lk)
    out_valid = np.zeros(n, bool)
    gathered = {c: np.zeros(n, v.dtype) for c, v in rcols.items()}
    for i in range(n):
        if not lvalid[i]:
            continue
        match = None
        for j in range(len(rk)):
            if rvalid[j] and rk[j] == lk[i]:
                match = j
                break
        if how == "semi":
            out_valid[i] = match is not None
        elif how == "anti":
            out_valid[i] = match is None
        elif how == "inner":
            out_valid[i] = match is not None
            if match is not None:
                for c in gathered:
                    gathered[c][i] = rcols[c][match]
        else:                                  # left
            out_valid[i] = True
            if match is not None:
                for c in gathered:
                    gathered[c][i] = rcols[c][match]
    return out_valid, gathered


def _routes(plan, cat, monkeypatch):
    """Execute under the hash route and the legacy route."""
    outs = []
    for route in ("on", "off"):
        monkeypatch.setenv("REPRO_JOIN_HASH", route)
        outs.append(execute(plan, cat))
    monkeypatch.delenv("REPRO_JOIN_HASH")
    return outs


def _rows(t):
    cols = t.to_numpy()
    names = sorted(cols)
    return sorted(zip(*(cols[c] for c in names)))


# --------------------------------------------------------------------------
# both lookup routes vs the oracle, all hows
# --------------------------------------------------------------------------


@pytest.mark.parametrize("how", HOWS)
@pytest.mark.parametrize("kdtype", [np.int32, np.float32])
def test_join_routes_match_oracle(how, kdtype, monkeypatch):
    """Duplicate right keys (stable smallest-row pick), invalid rows on
    both sides, unmatched left rows — hash vs legacy vs numpy oracle."""
    rng = np.random.default_rng(3)
    n, m = 200, 40
    lk = rng.integers(0, 30, n).astype(kdtype)
    rk = rng.integers(0, 30, m).astype(kdtype)   # duplicates guaranteed
    rv = (rng.normal(size=m) * 10).astype(np.float32)
    lvalid = rng.random(n) > 0.15
    rvalid = rng.random(m) > 0.25
    cat = {
        "L": Table({"k": jnp.asarray(lk),
                    "lv": jnp.arange(n, dtype=jnp.int32)},
                   jnp.asarray(lvalid)),
        "R": Table({"k": jnp.asarray(rk), "w": jnp.asarray(rv)},
                   jnp.asarray(rvalid)),
    }
    plan = Join(Scan("L", ("k", "lv")), Scan("R", ("k", "w")),
                "k", "k", how)
    hashed, legacy = _routes(plan, cat, monkeypatch)
    assert _rows(hashed) == _rows(legacy)

    want_valid, want_cols = _oracle_join(lk, lvalid, rk, rvalid,
                                         {"w": rv}, how)
    got = hashed.to_numpy()
    keep = want_valid
    assert np.array_equal(got["lv"], np.arange(n, dtype=np.int32)[keep])
    if how in ("inner", "left"):
        assert np.array_equal(got["w"], want_cols["w"][keep])


def test_join_duplicate_right_keys_stable_smallest_row(monkeypatch):
    """Contract-violating duplicate right keys: both routes pick the
    SMALLEST original right row deterministically."""
    lt = Table.from_columns(x=np.array([7, 8], np.int32))
    rt = Table.from_columns(
        x=np.array([8, 7, 7, 8, 7], np.int32),
        y=np.array([100, 101, 102, 103, 104], np.int32))
    plan = Join(Scan("L", ("x",)), Scan("R", ("x", "y")), "x", "x")
    hashed, legacy = _routes(plan, {"L": lt, "R": rt}, monkeypatch)
    assert list(hashed.to_numpy()["y"]) == [101, 100]
    assert list(legacy.to_numpy()["y"]) == [101, 100]


def test_join_float_nan_and_negative_zero(monkeypatch):
    """Join equality is VALUE equality: NaN keys never match (either
    side), while -0.0 matches +0.0 — on both routes."""
    nan = np.float32(np.nan)
    lt = Table.from_columns(
        k=np.array([nan, -0.0, 1.5, nan], np.float32),
        row=np.arange(4, dtype=np.int32))
    rt = Table.from_columns(
        k=np.array([0.0, 1.5, nan], np.float32),
        w=np.array([10, 20, 30], np.int32))
    plan = Join(Scan("L", ("k", "row")), Scan("R", ("k", "w")), "k", "k")
    hashed, legacy = _routes(plan, {"L": lt, "R": rt}, monkeypatch)
    for out in (hashed, legacy):
        got = out.to_numpy()
        assert list(got["row"]) == [1, 2]       # -0.0 and 1.5 only
        assert list(got["w"]) == [10, 20]


def test_join_semi_anti_preserve_group_bound(monkeypatch):
    """semi/anti keep the left rows only — the declared bound survives;
    inner/left mint right columns — it must not."""
    lt = Table.from_columns(
        k=np.array([1, 2, 9], np.int32),
        v=np.ones(3, np.float32)).declare_group_bound(4)
    rt = Table.from_columns(k=np.array([1, 2], np.int32),
                            w=np.zeros(2, np.float32))
    cat = {"L": lt, "R": rt}
    for route in ("on", "off"):
        monkeypatch.setenv("REPRO_JOIN_HASH", route)
        for how, keeps in (("semi", True), ("anti", True),
                           ("inner", False), ("left", False)):
            out = execute(Join(Scan("L", ("k", "v")), Scan("R", ("k", "w")),
                               "k", "k", how), cat)
            want = lt.group_bound if keeps else None
            assert out.group_bound == want, (route, how)


def test_join_wide_keys_exact_x64(monkeypatch):
    """Keys above 2^24 stay exact on both routes (the historical
    ``lk.astype(rk.dtype)`` bug rounded them through float32)."""
    prev = jax.config.jax_enable_x64
    try:
        jax.config.update("jax_enable_x64", True)
        # int64 keys beyond 2^32: exact equality, both routes
        big = (1 << 40) + 7
        lt = Table.from_columns(k=np.array([big, 5], np.int64),
                                row=np.arange(2, dtype=np.int32))
        rt = Table.from_columns(k=np.array([big, 11], np.int64),
                                w=np.array([1, 2], np.int32))
        plan = Join(Scan("L", ("k", "row")), Scan("R", ("k", "w")),
                    "k", "k")
        hashed, legacy = _routes(plan, {"L": lt, "R": rt}, monkeypatch)
        for out in (hashed, legacy):
            got = out.to_numpy()
            assert list(got["row"]) == [0] and list(got["w"]) == [1]

        # f64 2^24+1 against f32 neighbours: promotion must go UP to
        # f64 (np lattice) — casting down to f32 would round 2^24+1
        # onto 2^24 and fabricate a match
        lt2 = Table.from_columns(k=np.array([(1 << 24) + 1], np.float64))
        rt2 = Table.from_columns(
            k=np.array([1 << 24, (1 << 24) + 2], np.float32),
            w=np.array([1, 2], np.int32))
        plan2 = Join(Scan("L", ("k",)), Scan("R", ("k", "w")), "k", "k")
        h2, l2 = _routes(plan2, {"L": lt2, "R": rt2}, monkeypatch)
        assert len(h2.to_numpy()["k"]) == 0
        assert len(l2.to_numpy()["k"]) == 0
    finally:
        jax.config.update("jax_enable_x64", prev)


# --------------------------------------------------------------------------
# Limit: first-n valid rows, no compaction
# --------------------------------------------------------------------------


def test_limit_first_n_valid_rows_no_compaction():
    t = Table({"v": jnp.arange(8, dtype=jnp.int32)},
              jnp.asarray(np.array([0, 1, 1, 0, 1, 1, 1, 0], bool)))
    out = execute(Limit(Scan("T", ("v",)), 3), {"T": t})
    assert list(out.to_numpy()["v"]) == [1, 2, 4]
    assert out.capacity == t.capacity           # mask math, not compaction


def test_limit_and_join_census_tier1():
    """Tier-1 face of benchmarks/join_spy: the fused filter-join-agg
    lowering traces to ZERO row-sized sorts and no more row-sized
    gathers than the materialized plan (which keeps its sort — detector
    sanity), and the Limit lowering is compaction-free."""
    from benchmarks.join_spy import join_census, limit_census
    c = join_census(0.0005, "jnp")
    assert c["fused_sorts"] == 0, c
    assert c["materialized_sorts"] >= 1, c
    assert c["fused_gathers"] <= c["materialized_gathers"], c
    lc = limit_census(4096)
    assert lc["limit_sorts"] == 0 and lc["limit_gathers"] == 0, lc
    assert lc["compress_sorts"] >= 1 and lc["compress_gathers"] >= 1, lc


# --------------------------------------------------------------------------
# fusion pass: pattern match + parity
# --------------------------------------------------------------------------


def _chain_cat(seed=0, n=3000, m=64):
    rng = np.random.default_rng(seed)
    lk = rng.integers(0, m, n).astype(np.int32)
    rk = np.arange(m, dtype=np.int32)
    rng.shuffle(rk)
    return {
        "L": Table({"lk": jnp.asarray(lk),
                    "lv": jnp.asarray(rng.normal(size=n)
                                      .astype(np.float32))},
                   jnp.asarray(rng.random(n) > 0.1)),
        "R": Table({"rk": jnp.asarray(rk),
                    "rv": jnp.asarray(rng.normal(size=m)
                                      .astype(np.float32)),
                    "flag": jnp.asarray(rng.random(m) > 0.3)},
                   jnp.ones(m, bool)),
    }, m


def _join(how="inner"):
    return Join(Scan("L", ("lk", "lv")), Scan("R", ("rk", "rv", "flag")),
                "lk", "rk", how)


def test_match_chain_patterns():
    from repro.relational.fuse import match_chain
    pred = Col("lv") > Const(0.0)
    # Filter*/Project* down to an equi inner/left join: matches
    c = match_chain(Filter(Filter(_join(), pred), Col("flag")))
    assert c is not None and len(c.preds) == 2
    sel = Project(_join(), (("a", Col("lk")), ("b", Col("rv"))))
    c2 = match_chain(Filter(sel, Col("b") > Const(0.0)))
    assert c2 is not None and c2.resolve("a") == "lk"
    assert c2.preds[0].lhs.name == "rv"         # pred renamed b -> rv
    # bails: computed projection, semi join, bare scan, unknown column
    assert match_chain(Project(_join(), (("a", Col("lv") * 2.0),))) is None
    assert match_chain(Filter(_join("semi"), pred)) is None
    assert match_chain(Scan("L", ("lk",))) is None
    assert match_chain(
        Filter(Project(_join(), (("a", Col("lk")),)), pred)) is None


def _group_result(t, key):
    """Group rows keyed and sorted by ``key`` (slot order differs
    between routes) as {col: array} ready for tolerant comparison."""
    cols = t.to_numpy()
    order = np.argsort(cols[key], kind="stable")
    return {c: np.asarray(v)[order] for c, v in cols.items()}


def _assert_groups_match(a, b, context=""):
    assert set(a) == set(b), context
    for c in a:
        np.testing.assert_allclose(a[c], b[c], rtol=1e-5, atol=1e-5,
                                   err_msg=f"{context} col={c}")


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_fused_chain_parity(backend, monkeypatch):
    """Fused Filter→Join→GroupAgg vs the per-node materialized plan,
    identical group results — grouping by the join key (the seg-feed
    path: probe output = segment ids) AND by a gathered right column
    (plain fused path), on both kernel backends."""
    if backend == "interpret":
        cat, m = _chain_cat(n=400, m=16)
    else:
        cat, m = _chain_cat()
    monkeypatch.setenv("REPRO_SEGAGG_BACKEND", backend)
    monkeypatch.setenv("REPRO_GROUPAGG_FUSED", backend)
    pred = BinOp("and", Col("lv") > Const(-0.5), Col("flag"))
    for keys, mg in ((("lk",), m), (("rv",), m)):
        plan = GroupAgg(Filter(_join(), pred), keys,
                        (("s", "sum", "lv"), ("c", "count", None),
                         ("mx", "max", "lv")), max_groups=mg)
        monkeypatch.setenv("REPRO_PLAN_FUSE", "on")
        fused = _group_result(execute(plan, cat), keys[0])
        monkeypatch.setenv("REPRO_PLAN_FUSE", "off")
        unfused = _group_result(execute(plan, cat), keys[0])
        _assert_groups_match(fused, unfused, f"{backend} {keys}")


def test_fused_left_join_chain_parity(monkeypatch):
    cat, m = _chain_cat(seed=5)
    plan = GroupAgg(Filter(_join("left"), Col("lv") > Const(-1.0)),
                    ("lk",), (("s", "sum", "rv"), ("c", "count", None)),
                    max_groups=m)
    monkeypatch.setenv("REPRO_PLAN_FUSE", "on")
    fused = _group_result(execute(plan, cat), "lk")
    monkeypatch.setenv("REPRO_PLAN_FUSE", "off")
    _assert_groups_match(fused, _group_result(execute(plan, cat), "lk"),
                         "left-join chain")


def test_fused_project_rename_chain_parity(monkeypatch):
    """Project renames fold through: pred + agg columns resolve through
    the name mapping."""
    cat, m = _chain_cat(seed=7)
    sel = Project(_join(), (("key", Col("lk")), ("val", Col("lv")),
                            ("f", Col("flag"))))
    plan = GroupAgg(Filter(sel, Col("f")), ("key",),
                    (("s", "sum", "val"),), max_groups=m)
    monkeypatch.setenv("REPRO_PLAN_FUSE", "on")
    fused = _group_result(execute(plan, cat), "key")
    monkeypatch.setenv("REPRO_PLAN_FUSE", "off")
    _assert_groups_match(fused, _group_result(execute(plan, cat), "key"),
                         "project-rename chain")


def test_seg_feed_skips_slot_build(monkeypatch):
    """Grouping by the join key feeds the probe output straight into the
    kernel: ZERO keyslot slot builds on the fused route (the probe IS
    the slot assignment), at least one when materialized."""
    from repro.relational import keyslot
    cat, m = _chain_cat(seed=2)
    plan = GroupAgg(_join(), ("lk",), (("s", "sum", "lv"),), max_groups=m)
    monkeypatch.setenv("REPRO_PLAN_FUSE", "on")
    b0 = keyslot.slot_build_count()
    execute(plan, cat).to_numpy()
    assert keyslot.slot_build_count() == b0     # probe fed the kernel
    monkeypatch.setenv("REPRO_PLAN_FUSE", "off")
    execute(plan, cat).to_numpy()
    assert keyslot.slot_build_count() > b0


def test_fused_chain_grouped_agg_call_parity(monkeypatch):
    """The core/executors dispatch (grouped AggCall) consumes the fused
    chain too: parity with the materialized route."""
    from repro.core.aggify import build_aggregate
    from repro.core.executors import execute_agg_call
    from tests.helpers import fig1_catalog, fig1_program

    prog = fig1_program()
    agg = build_aggregate(prog)
    from repro.core.loop_ir import Var
    q = Filter(Join(Scan("PARTSUPP",
                         ("ps_partkey", "ps_suppkey", "ps_supplycost")),
                    Scan("SUPPLIER", ("s_suppkey", "s_name")),
                    "ps_suppkey", "s_suppkey", "inner"),
               Col("ps_supplycost") < Const(1e6))
    from repro.relational.plan import AggCall
    call = AggCall(child=q, aggregate=agg,
                   param_binding=(("pCost", Col("ps_supplycost")),
                                  ("sName", Col("s_name")),
                                  ("minCost", Var("minCost")),
                                  ("lb", Var("lb"))),
                   group_keys=("ps_partkey",))
    env = {"minCost": jnp.float32(100000.0), "lb": jnp.float32(0.0)}
    outs = {}
    for route in ("on", "off"):
        monkeypatch.setenv("REPRO_PLAN_FUSE", route)
        out = execute_agg_call(call, fig1_catalog(), env,
                               var_dtypes=prog.var_dtypes).to_numpy()
        outs[route] = dict(zip(out["ps_partkey"], out["suppName"]))
    assert outs["on"] == outs["off"] == {0: 101, 1: 101}


# --------------------------------------------------------------------------
# sharded: subprocess 8-way mesh, fused chain parity
# --------------------------------------------------------------------------


def test_sharded_fused_chain_in_subprocess_8way_mesh():
    code = """
import os, numpy as np, jax, jax.numpy as jnp
os.environ["REPRO_GROUPAGG_FUSED"] = "jnp"
assert jax.device_count() == 8, jax.device_count()
from jax.sharding import Mesh
from repro.core.loop_ir import Col, Const
from repro.relational import Filter, GroupAgg, Join, Scan, Table, execute

mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(17)
n, m = 4096, 60
lt = Table.from_columns(
    lk=rng.integers(0, m, n).astype(np.int32),
    lv=rng.integers(-40, 40, n).astype(np.float32))
rt = Table.from_columns(
    rk=np.arange(m, dtype=np.int32),
    rv=rng.integers(0, 9, m).astype(np.float32))
plan = GroupAgg(
    Filter(Join(Scan("L", ("lk", "lv")), Scan("R", ("rk", "rv")),
                "lk", "rk"), Col("rv") > Const(2.0)),
    ("lk",), (("s", "sum", "lv"), ("c", "count", None)), max_groups=m)

os.environ["REPRO_PLAN_FUSE"] = "off"
want = execute(plan, {"L": lt, "R": rt}).to_numpy()
os.environ["REPRO_PLAN_FUSE"] = "on"
got = execute(plan, {"L": lt.shard_rows(mesh, "data"), "R": rt}).to_numpy()
ws, gs = np.argsort(want["lk"]), np.argsort(got["lk"])
for c in want:
    assert np.array_equal(np.asarray(want[c])[ws], np.asarray(got[c])[gs]), c
print("OK")
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ,
           "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "") +
                         " --xla_force_host_platform_device_count=8"),
           "PYTHONPATH": os.path.abspath(src) + os.pathsep +
                         os.environ.get("PYTHONPATH", "")}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr
