"""Built-in aggregate library: every executor path (streaming / chunked /
tree-reduce) agrees with numpy for every builtin, across chunk counts —
including the nontrivial-Merge cases (avg, Chan-merge variance)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.aggregates import BUILTINS
from repro.core.aggregate import chunked, streaming, tree_reduce

RNG = np.random.default_rng(0)
X = RNG.uniform(-5, 5, 97).astype(np.float32)


def _rows(name):
    if name in ("argmin", "argmax"):
        return {"key": jnp.asarray(X),
                "payload": jnp.arange(97, dtype=jnp.int32)}
    return {"x": jnp.asarray(X)}


def _expect(name):
    return {
        "sum": X.sum(), "count": 97, "min": X.min(), "max": X.max(),
        "avg": X.mean(), "argmin": int(X.argmin()),
        "argmax": int(X.argmax()), "var": X.var(),
    }[name]


@pytest.mark.parametrize("name", sorted(BUILTINS))
@pytest.mark.parametrize("mode", ["streaming", "chunked4", "chunked13",
                                  "tree"])
def test_builtin_executors_agree(name, mode):
    agg = BUILTINS[name]()
    rows = _rows(name)
    if mode == "streaming":
        got = streaming(agg, rows)
    elif mode == "tree":
        got = tree_reduce(agg, rows)
    else:
        got = chunked(agg, rows, num_chunks=int(mode[7:]))
    np.testing.assert_allclose(np.asarray(got, np.float64), _expect(name),
                               rtol=1e-4, atol=1e-4)


def test_argmin_tie_prefers_first():
    x = jnp.asarray(np.array([3.0, 1.0, 1.0, 2.0], np.float32))
    rows = {"key": x, "payload": jnp.arange(4, dtype=jnp.int32)}
    agg = BUILTINS["argmin"]()
    for nc in (1, 2, 4):
        got = chunked(agg, rows, num_chunks=nc)
        assert int(got) == 1, f"nc={nc}: first attaining row must win"


def test_argmax_tie_prefers_first():
    x = jnp.asarray(np.array([1.0, 3.0, 3.0, 2.0], np.float32))
    rows = {"key": x, "payload": jnp.arange(4, dtype=jnp.int32)}
    agg = BUILTINS["argmax"]()
    for nc in (1, 2, 4):
        got = chunked(agg, rows, num_chunks=nc)
        assert int(got) == 1, f"nc={nc}: first attaining row must win"
