"""§8 enhancements: acyclic code motion, FOR-loop rewriting, nested loops
(decorrelation via grouped AggCall), and local-table DML support."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Assign, BinOp, Col, Const, CursorLoop, ForLoop, If,
                        InsertLocal, Program, UnOp, Var, aggify,
                        apply_acyclic_code_motion, build_aggregate,
                        grouped_agg_call, is_aggifyable, let, rewrite_for,
                        run_aggify, run_cursor)
from repro.core.aggify import NotAggifyable, check_applicability
from repro.relational import Filter, Join, Scan, Table, execute
from repro.relational.plan import AggCall, Project

from helpers import fig1_catalog, fig1_program


# --- §8.1 acyclic code motion ------------------------------------------------

def test_guard_hoisted_to_where():
    """The paper's own example: (@pCost > @lb) moves into the WHERE clause;
    the cyclic conjunct (@pCost < @minCost) stays."""
    prog = fig1_program()
    moved = apply_acyclic_code_motion(prog, hoist_exprs=False)
    body = moved.loop.body
    assert len(body) == 1
    cond = body[0].cond
    assert isinstance(cond, BinOp) and cond.op == "<"   # only cyclic conjunct
    # results unchanged
    cat = fig1_catalog()
    for lb in (-1.0, 4.0, 8.0):
        a = run_cursor(prog, cat, {"pkey": 0, "lb": lb})
        b = run_cursor(moved, cat, {"pkey": 0, "lb": lb})
        c = run_aggify(moved, cat, {"pkey": 0, "lb": lb})
        assert int(a["suppName"]) == int(b["suppName"]) == int(c["suppName"])


def test_expression_hoisted_to_projection():
    """(monthlyROI + 1) moves into Q as a projected column (§8.1: 'even
    within statements that are part of a data dependence cycle, expressions
    can be pulled out')."""
    from helpers import fig2_catalog, fig2_program
    prog = fig2_program()
    moved = apply_acyclic_code_motion(prog)
    assert any(v.startswith("__acm_") for v in moved.loop.fetch_vars)
    cat = fig2_catalog()
    a = run_cursor(prog, cat, {"id": 1})
    b = run_cursor(moved, cat, {"id": 1})
    c = run_aggify(moved, cat, {"id": 1})
    np.testing.assert_allclose(np.asarray(a["cumulativeROI"]),
                               np.asarray(b["cumulativeROI"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a["cumulativeROI"]),
                               np.asarray(c["cumulativeROI"]), rtol=1e-6)


# --- §8.2 FOR loops -----------------------------------------------------------

def test_for_loop_rewrite_and_aggify():
    prog = Program(
        "sumsq", params=("n",),
        pre=[let("acc", Const(0.0))],
        loop=ForLoop("i", Const(0), Var("n"), Const(1),
                     [Assign("acc", Var("acc")
                             + UnOp("float", Var("i")) * UnOp("float", Var("i")))],
                     inclusive=False),
        post=[], returns=("acc",))
    p = rewrite_for(prog, capacity=256)
    for n in (0, 1, 5, 100):
        ref = float(sum(i * i for i in range(n)))
        rc = run_cursor(p, {}, {"n": n})
        ra = run_aggify(p, {}, {"n": n})
        assert float(rc["acc"]) == ref
        assert float(ra["acc"]) == ref


def test_for_loop_dynamic_bounds():
    """§8.2: 'the values need not be statically determinable' — bounds come
    from program variables at run time."""
    prog = Program(
        "rng", params=("lo", "hi", "step"),
        pre=[let("cnt", Const(0.0))],
        loop=ForLoop("i", Var("lo"), Var("hi"), Var("step"),
                     [Assign("cnt", Var("cnt") + 1.0)], inclusive=True),
        post=[], returns=("cnt",))
    p = rewrite_for(prog, capacity=512)
    got = run_aggify(p, {}, {"lo": 4, "hi": 20, "step": 2})
    assert float(got["cnt"]) == 9.0


# --- §6.3.1 nested loops / grouped decorrelation -------------------------------

def test_grouped_agg_call_decorrelates_fig1():
    """Instead of invoking minCostSupp per part (correlated), group by
    ps_partkey and run the custom aggregate once per group — the Aggify+
    execution strategy for the Figure-1 query."""
    prog = fig1_program()
    cat = fig1_catalog()
    agg = build_aggregate(prog)
    q = Join(Scan("PARTSUPP", ("ps_partkey", "ps_suppkey", "ps_supplycost")),
             Scan("SUPPLIER", ("s_suppkey", "s_name")),
             left_key="ps_suppkey", right_key="s_suppkey", how="inner")
    call = AggCall(child=q, aggregate=agg,
                   param_binding=(("pCost", Col("ps_supplycost")),
                                  ("sName", Col("s_name")),
                                  ("minCost", Var("minCost")),
                                  ("lb", Var("lb"))),
                   group_keys=("ps_partkey",))
    env = {"minCost": jnp.float32(100000.0), "lb": jnp.float32(4.0),
           "suppName": jnp.int32(-1)}
    out = execute(call, cat, env).to_numpy()
    got = dict(zip(out["ps_partkey"], out["suppName"]))
    # per-part reference via the scalar UDF
    for pk in (0, 1):
        ref = run_cursor(prog, cat, {"pkey": pk, "lb": 4.0})
        assert int(got[pk]) == int(ref["suppName"])


def test_grouped_scan_fallback_matches_recognized():
    """The generic segmented-scan path must agree with the segment-
    vectorized recognized path."""
    prog = fig1_program()
    cat = fig1_catalog()
    agg = build_aggregate(prog)
    assert agg.recognized is not None
    unrec = type(agg)(**{**agg.__dict__, "recognized": None})
    q = Join(Scan("PARTSUPP", ("ps_partkey", "ps_suppkey", "ps_supplycost")),
             Scan("SUPPLIER", ("s_suppkey", "s_name")),
             left_key="ps_suppkey", right_key="s_suppkey", how="inner")
    env = {"minCost": jnp.float32(100000.0), "lb": jnp.float32(0.0),
           "suppName": jnp.int32(-1)}
    binding = (("pCost", Col("ps_supplycost")), ("sName", Col("s_name")),
               ("minCost", Var("minCost")), ("lb", Var("lb")))
    a = execute(AggCall(q, agg, binding, group_keys=("ps_partkey",)),
                cat, env).to_numpy()
    b = execute(AggCall(q, unrec, binding, group_keys=("ps_partkey",)),
                cat, env).to_numpy()
    assert list(a["suppName"]) == list(b["suppName"])


# --- §4.2 applicability + local-table DML --------------------------------------

def test_persistent_dml_rejected():
    q = Scan("T", ("x",))
    prog = Program(
        "bad", params=(), pre=[],
        loop=CursorLoop(q, fetch=[("vx", "x")],
                        body=[InsertLocal("PERSISTENT_TABLE", [Var("vx")])]),
        post=[], returns=())
    assert not is_aggifyable(prog)
    with pytest.raises(NotAggifyable):
        check_applicability(prog)


def test_local_table_insert_supported():
    """DML on local table variables is supported (§4.2) — stream-only."""
    cat = {"T": Table.from_columns(x=np.array([3., 1., 4., 1., 5.], np.float32))}
    prog = Program(
        "collect", params=(),
        pre=[let("s", Const(0.0))],
        loop=CursorLoop(Scan("T", ("x",)), fetch=[("vx", "x")],
                        body=[If(Var("vx") > 2.0,
                                 [InsertLocal("tv", [Var("vx")])]),
                              Assign("s", Var("s") + Var("vx"))]),
        post=[], returns=("s", "tv"),
        local_tables={"tv": ((jnp.float32,), 16)})
    ref = run_cursor(prog, cat)
    got = run_aggify(prog, cat)   # auto resolves to stream (local table)
    assert float(ref["s"]) == float(got["s"]) == 14.0
    (bufs_r, n_r), (bufs_g, n_g) = ref["tv"], got["tv"]
    assert int(n_r) == int(n_g) == 3
    np.testing.assert_allclose(np.asarray(bufs_r[0])[:3],
                               np.asarray(bufs_g[0])[:3])


def test_grouped_recognized_pallas_kernel_path():
    """The fused Pallas segment-aggregate kernel (interpret mode) must
    agree with the jnp segment-op path for grouped recognized aggregates."""
    import os

    from repro.core.executors import grouped_agg_call

    prog = fig1_program()
    cat = fig1_catalog()
    agg = build_aggregate(prog)
    # a pure-sum grouped aggregate exercises the kernel row
    sum_prog = Program(
        "qtySum", params=(),
        pre=[let("qty", Const(0.0))],
        loop=CursorLoop(Scan("PARTSUPP",
                             ("ps_partkey", "ps_suppkey", "ps_supplycost")),
                        fetch=[("c", "ps_supplycost")],
                        body=[Assign("qty", Var("qty") + Var("c"))]),
        post=[], returns=("qty",))
    sagg = build_aggregate(sum_prog)
    call = AggCall(Scan("PARTSUPP", ("ps_partkey", "ps_suppkey",
                                     "ps_supplycost")),
                   sagg, (("c", Col("ps_supplycost")), ),
                   group_keys=("ps_partkey",))
    env = {"qty": jnp.float32(0.0)}
    a = execute(call, cat, env).to_numpy()
    os.environ["REPRO_SEGAGG_PALLAS"] = "1"
    try:
        b = execute(call, cat, env).to_numpy()
    finally:
        del os.environ["REPRO_SEGAGG_PALLAS"]
    np.testing.assert_allclose(a["qty"], b["qty"], rtol=1e-5)
