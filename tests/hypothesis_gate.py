"""Tier-1-visible gating for the optional ``hypothesis`` dependency.

The property suites (test_aggify_property.py, the serving differential
fuzzer) need hypothesis, which the hermetic container does not ship.  A
bare ``importorskip`` would let the whole property surface silently
vanish if CI's install ever broke — so the gate is environment-aware:

* locally (default): the module skips with an explicit reason, visible
  in the tier-1 summary as a skip;
* in CI (``REPRO_REQUIRE_HYPOTHESIS=1``): a missing install is a hard
  ERROR, not a skip — the suite cannot quietly lose its fuzzers.

``fuzz_examples`` reads ``REPRO_FUZZ_EXAMPLES`` so CI can demand deeper
runs (the workflow pins 200) while local runs stay quick."""
from __future__ import annotations

import os

import pytest


def require_hypothesis():
    """Module-level gate: returns the hypothesis module, or skips the
    calling module (locally) / raises (under REPRO_REQUIRE_HYPOTHESIS=1,
    the CI contract)."""
    try:
        import hypothesis
        return hypothesis
    except ImportError as e:
        if os.environ.get("REPRO_REQUIRE_HYPOTHESIS") == "1":
            raise RuntimeError(
                "hypothesis is REQUIRED in this environment "
                "(REPRO_REQUIRE_HYPOTHESIS=1 — the CI contract) but is "
                "not installed; the property suites would silently "
                "vanish. Fix the install instead of unsetting the "
                "variable.") from e
        pytest.skip(
            "hypothesis not installed — property fuzzers skipped "
            "(optional dev dependency; CI hard-fails this via "
            "REPRO_REQUIRE_HYPOTHESIS=1; seed-corpus regressions still "
            "ran — see test_serving_corpus.py)",
            allow_module_level=True)


def fuzz_examples(default: int) -> int:
    """Example budget for a hypothesis fuzzer: REPRO_FUZZ_EXAMPLES (CI
    pins 200) or the given local default."""
    return int(os.environ.get("REPRO_FUZZ_EXAMPLES", default))
