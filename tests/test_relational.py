"""Relational engine correctness vs numpy reference semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.loop_ir import BinOp, Col, Const, Var
from repro.relational import (Filter, GroupAgg, IterSpace, Join, Limit,
                              OrderBy, Project, Scan, Table, execute)


def _cat():
    return {
        "L": Table.from_columns(
            k=np.array([3, 1, 2, 1, 3, 9], np.int32),
            v=np.array([1., 2., 3., 4., 5., 6.], np.float32)),
        "R": Table.from_columns(
            k=np.array([1, 2, 3], np.int32),
            w=np.array([10., 20., 30.], np.float32)),
    }


def test_filter_project():
    t = execute(Project(Filter(Scan("L", ("k", "v")), Col("k") < 3),
                        (("k", Col("k")), ("v2", Col("v") * 2.0))), _cat())
    out = t.to_numpy()
    assert set(out["k"]) == {1, 2}
    np.testing.assert_allclose(sorted(out["v2"]), [4., 6., 8.])


def test_inner_join_gather():
    t = execute(Join(Scan("L", ("k", "v")), Scan("R", ("k", "w")),
                     left_key="k", right_key="k", how="inner"), _cat())
    out = t.to_numpy()
    # row with k=9 drops; each left row picks up w = 10*k
    assert len(out["k"]) == 5
    np.testing.assert_allclose(out["w"], out["k"] * 10.0)


def test_semi_anti_join():
    semi = execute(Join(Scan("L", ("k", "v")), Scan("R", ("k", "w")),
                        left_key="k", right_key="k", how="semi"), _cat())
    anti = execute(Join(Scan("L", ("k", "v")), Scan("R", ("k", "w")),
                        left_key="k", right_key="k", how="anti"), _cat())
    assert len(semi.to_numpy()["k"]) == 5
    assert list(anti.to_numpy()["k"]) == [9]


def test_left_join_nulls():
    t = execute(Join(Scan("L", ("k", "v")), Scan("R", ("k", "w")),
                     left_key="k", right_key="k", how="left"), _cat())
    out = t.to_numpy()
    assert len(out["k"]) == 6
    w9 = out["w"][out["k"] == 9]
    np.testing.assert_allclose(w9, [0.0])


def test_order_by_limit():
    t = execute(Limit(OrderBy(Scan("L", ("k", "v")), ("k",), (True,)), 2), _cat())
    out = t.to_numpy()
    assert list(out["k"]) == [9, 3]


def test_group_agg():
    t = execute(GroupAgg(Scan("L", ("k", "v")), ("k",),
                         (("s", "sum", "v"), ("n", "count", None),
                          ("mn", "min", "v"), ("mx", "max", "v"))), _cat())
    out = t.to_numpy()
    got = {int(k): (s, n, mn, mx) for k, s, n, mn, mx in
           zip(out["k"], out["s"], out["n"], out["mn"], out["mx"])}
    assert got[1] == (6.0, 2, 2.0, 4.0)
    assert got[3] == (6.0, 2, 1.0, 5.0)
    assert got[9] == (6.0, 1, 6.0, 6.0)


def test_iterspace():
    sp = IterSpace(init=Const(2), bound=Var("n"), step=Const(3),
                   inclusive=True, capacity=64, column="i")
    t = execute(sp, {}, {"n": 11})
    assert list(t.to_numpy()["i"]) == [2, 5, 8, 11]


def test_sort_stability_multikey():
    cat = {"T": Table.from_columns(
        a=np.array([1, 1, 0, 0], np.int32),
        b=np.array([5, 4, 9, 8], np.int32))}
    t = execute(OrderBy(Scan("T", ("a", "b")), ("a", "b")), cat)
    out = t.to_numpy()
    assert list(out["a"]) == [0, 0, 1, 1]
    assert list(out["b"]) == [8, 9, 4, 5]


def test_compress_and_masks():
    t = Table.from_columns(x=np.arange(6, dtype=np.int32))
    t = t.filter(jnp.asarray(np.array([1, 0, 1, 0, 1, 0], bool)))
    c = t.compress()
    assert list(c.to_numpy()["x"]) == [0, 2, 4]
    assert int(c.count()) == 3
