"""Shared test fixtures: the paper's Figure-1 and Figure-2 programs, and a
tiny catalog to run them against."""
import jax.numpy as jnp
import numpy as np

from repro.core import (Assign, BinOp, Col, Const, CursorLoop, If, Program,
                        Var, let)
from repro.relational import Filter, Join, Scan, Table
from repro.relational.plan import OrderBy


def fig1_program() -> Program:
    """The minCostSupp UDF of the paper's Figure 1 (argmin-with-lower-bound
    over a join)."""
    q = Filter(
        Join(Scan("PARTSUPP", ("ps_partkey", "ps_suppkey", "ps_supplycost")),
             Scan("SUPPLIER", ("s_suppkey", "s_name")),
             left_key="ps_suppkey", right_key="s_suppkey", how="inner"),
        Col("ps_partkey").eq(Var("pkey")))
    body = [
        If(BinOp("and", Var("pCost") < Var("minCost"), Var("pCost") > Var("lb")),
           [Assign("minCost", Var("pCost")),
            Assign("suppName", Var("sName"))]),
    ]
    loop = CursorLoop(q, fetch=[("pCost", "ps_supplycost"),
                                ("sName", "s_name")], body=body)
    return Program(
        "minCostSupp", params=("pkey", "lb"),
        pre=[let("minCost", Const(100000.0)), let("suppName", Const(-1))],
        loop=loop, post=[], returns=("suppName",),
        var_dtypes={"suppName": jnp.int32, "minCost": jnp.float32})


def fig1_catalog():
    return {
        "PARTSUPP": Table.from_columns(
            ps_partkey=np.array([0, 0, 0, 1, 1, 1], np.int32),
            ps_suppkey=np.array([0, 1, 2, 0, 1, 2], np.int32),
            ps_supplycost=np.array([5.0, 3.0, 8.0, 7.0, 2.0, 9.0], np.float32)),
        "SUPPLIER": Table.from_columns(
            s_suppkey=np.array([0, 1, 2], np.int32),
            s_name=np.array([100, 101, 102], np.int32)),
    }


def fig2_program() -> Program:
    """The cumulative time-weighted ROI loop of the paper's Figure 2
    (ordered product aggregate)."""
    q = OrderBy(Filter(Scan("MONTHLY", ("investor_id", "month", "roi")),
                       Col("investor_id").eq(Var("id"))), ("month",))
    return Program(
        "computeCumulativeReturn", params=("id",),
        pre=[let("cumulativeROI", Const(1.0))],
        loop=CursorLoop(q, fetch=[("monthlyROI", "roi")],
                        body=[Assign("cumulativeROI",
                                     Var("cumulativeROI")
                                     * (Var("monthlyROI") + 1.0))]),
        post=[Assign("cumulativeROI", Var("cumulativeROI") - 1.0)],
        returns=("cumulativeROI",))


def fig2_catalog():
    return {"MONTHLY": Table.from_columns(
        investor_id=np.array([1, 1, 1, 2, 1], np.int32),
        month=np.array([2, 0, 1, 0, 3], np.int32),
        roi=np.array([0.10, 0.05, -0.02, 0.5, 0.07], np.float32))}
