"""Chaos battery for the serving guard (serve/guard.py + reliability/).

Every injected failure must surface as the matching typed ``ServeError``
— or be absorbed by the recovery ladder and produce a **bit-correct**
result against the numpy oracle of tests/serving_cases.py.  Faults are
deterministic (named sites, shot counts, no randomness), so each test
replays exactly; the ``inject`` table is process-global, which is fine
under pytest's sequential runner.
"""
from __future__ import annotations

import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.reliability import faults
from repro.reliability.faults import FaultInjected
from repro.serve import (AggServer, BackendFailure, BoundOverflow,
                         DeadlineExceeded, PoisonedResult, QueueFull,
                         ServeError, ServerClosed, SlotTableStale)

from serving_cases import assert_same_groups, build_case, oracle, result_groups

# a hung drain or deadlocked dispatcher must fail, not stall the suite
# (enforced in CI where pytest-timeout is installed; a registered no-op
# marker locally)
pytestmark = pytest.mark.timeout(300)

# ~6 distinct keys under a declared bound — the everyday shape
CASE_SMALL = {"seed": 1, "n": 160, "key_dtypes": ("int32",), "card": 6,
              "aggs": ("sum", "count", "min", "max"), "max_groups": 24}

# ~400 distinct keys, bound INFERRED from the sketch — the shape where
# an undershooting sketch actually overflows its first bucket
CASE_WIDE = {"seed": 11, "n": 1600, "key_dtypes": ("int32",), "card": 400,
             "aggs": ("sum", "count")}

# parameterized filter child: multiple request signatures + vmapped lanes
CASE_FILTERED = {"seed": 7, "n": 168, "key_dtypes": ("int32",), "card": 5,
                 "filtered": True, "params": (-1.0, 0.0, 1.0, 2.0),
                 "aggs": ("sum", "count", "max"), "max_groups": 16}


def _fresh(case, **kw):
    t, plan, keys, aggs, envs = build_case(case)
    kw.setdefault("max_batch", 8)
    kw.setdefault("batch_window_s", 0.0)
    srv = AggServer({"T": t}, **kw)
    return srv, t, plan, keys, aggs, envs


def _check(srv_result, t, keys, aggs, env, label):
    assert_same_groups(result_groups(srv_result, keys, aggs),
                       oracle(t, keys, aggs, env), label)


# ---------------------------------------------------------------------------
# registry mechanics + env hook liveness
# ---------------------------------------------------------------------------


def test_unknown_site_rejected():
    with pytest.raises(ValueError, match="unknown fault site"):
        faults.configure("not_a_site")


def test_shot_counts_consume_exactly():
    with faults.inject(""):                     # pin a disarmed baseline
        with faults.inject("selftest:2"):       # (CI arms selftest via env)
            assert faults.fire("selftest")
            assert faults.fire("selftest")
            assert not faults.fire("selftest")
        assert not faults.fire("selftest")      # restored (disarmed)


def test_env_hook_is_live():
    """REPRO_FAULTS arms the table at import — the CI chaos step runs the
    suite under REPRO_FAULTS=selftest and this test proves the hook came
    live end-to-end; without the env it proves the same in a
    subprocess."""
    spec = os.environ.get("REPRO_FAULTS")
    if spec:
        assert faults.active_spec() == spec
        if "selftest" in spec:
            assert faults.fired("selftest") or faults.fire("selftest")
        return
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.reliability import faults; "
         "assert faults.active_spec() == 'selftest'; "
         "assert faults.fire('selftest'); print('LIVE')"],
        env={**os.environ, "REPRO_FAULTS": "selftest",
             "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert "LIVE" in out.stdout


# ---------------------------------------------------------------------------
# structured errors: declared bound overflow, typed on the future
# ---------------------------------------------------------------------------


def test_declared_overflow_is_typed_boundoverflow():
    case = dict(CASE_WIDE, max_groups=2)    # bucket 128 << ~400 groups
    srv, t, plan, keys, aggs, envs = _fresh(case)
    with srv:
        with pytest.raises(BoundOverflow,
                           match="beyond the declared dense bound"):
            srv.execute(plan, {})
        fut = srv.submit(plan, {})
        err = fut.exception(timeout=120)
        assert isinstance(err, BoundOverflow)
        assert isinstance(err, ValueError)      # legacy contract holds
        assert isinstance(err, ServeError)


# ---------------------------------------------------------------------------
# poison detection + bounded bound recovery
# ---------------------------------------------------------------------------


def test_sketch_undershoot_grows_inferred_bound():
    """An undershooting sketch infers a too-small bound; the eager slot
    build catches the overflow and double-and-rebuilds until it fits —
    the request never fails and the result is bit-correct."""
    srv, t, plan, keys, aggs, envs = _fresh(CASE_WIDE)
    with srv, faults.inject("sketch_undershoot"):
        out = srv.execute(plan, {})
    _check(out, t, keys, aggs, {}, "undershoot-grown vs oracle")
    d = srv.describe(plan)
    assert d["inferred"]
    assert d["bound"] is not None and d["bound"] >= 400


def test_bound_unvalidated_poison_detected_and_retried():
    """The full ladder: the sketch undershoots AND the eager validation
    is skipped once, so a poisoned launch actually reaches the detector
    — which converts it to a doubled-bound retry, not NaNs."""
    srv, t, plan, keys, aggs, envs = _fresh(CASE_WIDE)
    with srv, faults.inject("sketch_undershoot:1,bound_unvalidated:1"):
        out = srv.execute(plan, {})
    _check(out, t, keys, aggs, {}, "poison-retried vs oracle")
    assert srv.guard_stats.poisoned >= 1
    assert srv.guard_stats.poison_retries >= 1


def test_poisoned_declared_bound_is_typed_not_silent():
    """A poisoned launch whose bound was user-declared cannot be grown —
    it must surface as PoisonedResult, never as NaNs in the caller's
    hands."""
    case = dict(CASE_WIDE, max_groups=2)
    srv, t, plan, keys, aggs, envs = _fresh(case)
    with srv, faults.inject("bound_unvalidated:1"):
        with pytest.raises(PoisonedResult, match="poison stamp"):
            srv.execute(plan, {})
    assert srv.guard_stats.poisoned == 1
    assert srv.guard_stats.poison_retries == 0


# ---------------------------------------------------------------------------
# slot-table staleness
# ---------------------------------------------------------------------------


def test_slot_stale_detected_and_rebuilt():
    srv, t, plan, keys, aggs, envs = _fresh(CASE_SMALL)
    with srv:
        with faults.inject("slot_stale:1"):
            _check(srv.execute(plan, {}), t, keys, aggs, {},
                   "stale-build launch vs oracle")
        # the corrupt tag is detected on the next hit; one rebuild heals
        _check(srv.execute(plan, {}), t, keys, aggs, {},
               "post-stale launch vs oracle")
        assert srv.guard_stats.stale_rebuilds == 1
        _check(srv.execute(plan, {}), t, keys, aggs, {}, "healed")
        assert srv.guard_stats.stale_rebuilds == 1     # healed for good


def test_slot_stale_unbounded_surfaces_typed():
    srv, t, plan, keys, aggs, envs = _fresh(CASE_SMALL)
    with srv, faults.inject("slot_stale"):
        srv.execute(plan, {})                   # build (tag corrupted)
        with pytest.raises(SlotTableStale):
            srv.execute(plan, {})               # rebuilds re-corrupt: bounded
    assert srv.guard_stats.stale_rebuilds >= 2


# ---------------------------------------------------------------------------
# backend failure → degradation ladder → recovery
# ---------------------------------------------------------------------------


def test_backend_failure_degrades_trips_and_recovers():
    clk = [0.0]
    srv, t, plan, keys, aggs, envs = _fresh(
        CASE_SMALL, breaker_threshold=2, breaker_cooldown_s=10.0,
        breaker_clock=lambda: clk[0])
    with srv:
        with faults.inject("backend_exc"):
            # every primary launch raises; the ladder serves each request
            # on the degraded jnp executable — callers see only results
            for i in range(3):
                _check(srv.execute(plan, {}), t, keys, aggs, {},
                       f"degraded launch {i} vs oracle")
        gs = srv.guard_stats
        assert gs.degraded_launches == 3
        # threshold 2: two recorded failures trip the breaker; launch 3
        # goes straight to the degraded path without touching the primary
        assert gs.backend_failures == 2
        assert gs.breaker_trips == 1
        assert srv.describe(plan)["breakers"][()] == "open"
        # faults disarmed + cool-down elapsed: the half-open probe takes
        # the primary again, succeeds, and the breaker closes
        clk[0] = 11.0
        assert srv.describe(plan)["breakers"][()] == "half-open"
        _check(srv.execute(plan, {}), t, keys, aggs, {},
               "recovered launch vs oracle")
        assert srv.guard_stats.breaker_recoveries == 1
        assert srv.describe(plan)["breakers"][()] == "closed"


# ---------------------------------------------------------------------------
# kernel / shard launch sites (wiring) + both-rungs-fail → BackendFailure
# ---------------------------------------------------------------------------


def _fused_aggcall_catalog():
    """A grouped AggCall in fused mode — the plan shape whose launch
    passes through core.executors._grouped_fused (GroupAgg roots take
    the engine's per-op path on CPU and never reach that site)."""
    from repro.core import (Assign, BinOp, Const, CursorLoop, Program, Var,
                            aggify, let)
    from repro.relational import Scan, Table
    from repro.relational.plan import AggCall
    prog = Program(
        "groupedMinMax", params=(),
        pre=[let("lo", Const(1e9)), let("hi", Const(-1e9))],
        loop=CursorLoop(
            Scan("PS", ("pk", "cost")),
            fetch=[("c", "cost")],
            body=[Assign("lo", BinOp("min", Var("lo"), Var("c"))),
                  Assign("hi", BinOp("max", Var("hi"), Var("c")))]),
        post=[], returns=("lo", "hi"))
    rp = aggify(prog)
    call = AggCall(rp.agg_call.child, rp.agg_call.aggregate,
                   rp.agg_call.param_binding, rp.agg_call.ordered,
                   rp.agg_call.sort_keys, rp.agg_call.sort_desc,
                   group_keys=("pk",), mode="fused")
    rng = np.random.default_rng(0)
    cat = {"PS": Table.from_columns(
        pk=np.sort(rng.integers(0, 13, 300)).astype(np.int32),
        cost=rng.uniform(1, 100, 300).astype(np.float32))}
    env = {"lo": np.float32(1e9), "hi": np.float32(-1e9)}
    return call, cat, env


def test_kernel_launch_site_wired():
    from repro.relational import execute
    call, cat, env = _fused_aggcall_catalog()
    with faults.inject("kernel_launch:1"):
        with pytest.raises(FaultInjected) as ei:
            execute(call, cat, env)
        assert ei.value.site == "kernel_launch"
    # exhausted: the same call now runs and the site costs nothing
    out = execute(call, cat, env)
    assert np.asarray(out.mask()).sum() == 13


def test_backend_failure_both_rungs_is_typed():
    """When the degraded jnp rung dies too (kernel_launch fires during
    its trace), the caller gets BackendFailure with the cause chained —
    never a raw exception."""
    call, cat, env = _fused_aggcall_catalog()
    srv = AggServer(cat, batch_window_s=0.0)
    with srv, faults.inject("backend_exc,kernel_launch"):
        with pytest.raises(BackendFailure) as ei:
            srv.execute(call, env)
        assert isinstance(ei.value.__cause__, FaultInjected)
    assert srv.guard_stats.backend_failures == 1
    assert srv.guard_stats.degraded_launches == 1


def test_shard_launch_site_wired():
    from repro.launch.sharded_agg import (sharded_fused_segment_agg,
                                          sharded_sortfree_segment_agg)
    with faults.inject("shard_launch:2"):
        with pytest.raises(FaultInjected) as ei:
            sharded_fused_segment_agg(
                np.zeros((4, 1)), np.zeros(4, np.int32),
                np.ones((4, 1), bool), 4, mesh=None)
        assert ei.value.site == "shard_launch"
        with pytest.raises(FaultInjected):
            sharded_sortfree_segment_agg(
                np.zeros((4, 1)), np.zeros((4, 1), np.uint32),
                np.ones((4, 1), bool), np.ones(4, bool), 4, 4, mesh=None)


# ---------------------------------------------------------------------------
# deadlines, backpressure, dispatcher supervision, drain
# ---------------------------------------------------------------------------


def test_deadline_shed_in_queue():
    srv, t, plan, keys, aggs, envs = _fresh(CASE_SMALL)
    with srv, faults.inject("dispatcher_stall:1"):
        fut = srv.submit(plan, {}, deadline=0.05)   # stall 0.25s > deadline
        err = fut.exception(timeout=120)
    assert isinstance(err, DeadlineExceeded)
    assert srv.guard_stats.deadline_shed == 1


def test_unexpired_deadline_serves():
    srv, t, plan, keys, aggs, envs = _fresh(CASE_SMALL)
    with srv:
        fut = srv.submit(plan, {}, deadline=300.0)
        _check(fut.result(timeout=120), t, keys, aggs, {},
               "deadline-ok vs oracle")
    assert srv.guard_stats.deadline_shed == 0


def test_queue_full_rejects_typed():
    srv, t, plan, keys, aggs, envs = _fresh(CASE_FILTERED, max_queue=2)
    with srv:
        # hold the launch lock so dequeued work blocks and the queue fills
        with srv._lock:
            futs = [srv.submit(plan, envs[i % len(envs)])
                    for i in range(4)]
        rejected = [f for f in futs
                    if isinstance(f.exception(timeout=120), QueueFull)]
        served = [f for f in futs if f not in rejected]
        assert rejected, "admission queue never pushed back"
        assert srv.guard_stats.queue_rejects == len(rejected)
        for f in served:
            assert f.result(timeout=120) is not None


def test_dispatcher_death_respawns_and_serves():
    srv, t, plan, keys, aggs, envs = _fresh(CASE_SMALL)
    with srv, faults.inject("dispatcher_die:1"):
        fut = srv.submit(plan, {})
        _check(fut.result(timeout=120), t, keys, aggs, {},
               "post-respawn launch vs oracle")
    assert srv.guard_stats.dispatcher_restarts == 1


def test_close_drains_under_load():
    srv, t, plan, keys, aggs, envs = _fresh(CASE_FILTERED)
    futs = [srv.submit(plan, envs[i % len(envs)]) for i in range(20)]
    srv.close(drain=True)
    for i, fut in enumerate(futs):
        env = envs[i % len(envs)]
        _check(fut.result(timeout=120), t, keys, aggs, env,
               f"drained request {i} vs oracle")
    with pytest.raises(ServerClosed):
        srv.submit(plan, envs[0])
    with pytest.raises(RuntimeError):       # legacy contract holds
        srv.submit(plan, envs[0])


def test_close_without_drain_fails_queue_typed():
    srv, t, plan, keys, aggs, envs = _fresh(CASE_SMALL)
    with faults.inject("dispatcher_stall:1"):
        futs = [srv.submit(plan, {}) for _ in range(3)]
        srv.close(drain=False)
    for fut in futs:
        assert isinstance(fut.exception(timeout=120), ServerClosed)


def test_close_drain_racing_ingest_commits_or_typed():
    """``close(drain=True)`` racing concurrent ``ingest`` calls: every
    ingest either commits IN FULL (its rows land and the resident state
    converges to them) or fails typed ``ServerClosed`` having changed
    nothing — never a half-committed append or torn resident state."""
    import jax.numpy as jnp
    from repro.relational import Table, execute
    from repro.relational.plan import GroupAgg, Scan

    rng = np.random.default_rng(21)
    cap, n0, nb = 1024, 256, 16
    cols = {"k": rng.integers(0, 30, cap).astype(np.int32),
            "v": rng.integers(-9, 9, cap).astype(np.float32)}
    t = Table({c: jnp.asarray(a) for c, a in cols.items()},
              jnp.asarray(np.arange(cap) < n0))
    plan = GroupAgg(Scan("T", ("k", "v")), ("k",),
                    (("s", "sum", "v"), ("c", "count", None)),
                    max_groups=64)
    srv = AggServer({"T": t})
    srv.snapshot(plan)                       # seed the residency
    outcomes = []

    def one(i):
        r = np.random.default_rng(100 + i)
        b = {"k": r.integers(0, 30, nb).astype(np.int32),
             "v": r.integers(-9, 9, nb).astype(np.float32)}
        try:
            outcomes.append(("ok", srv.ingest("T", b)))
        except ServerClosed:
            outcomes.append(("closed", None))

    threads = [threading.Thread(target=one, args=(i,)) for i in range(8)]
    for th in threads:
        th.start()
    time.sleep(0.002)
    srv.close(drain=True)
    for th in threads:
        th.join(timeout=120)
    assert len(outcomes) == 8
    committed = [o for o in outcomes if o[0] == "ok"]
    live = srv.table("T")
    # committed ingests landed in full; refused ones changed nothing
    assert int(np.asarray(live.mask()).sum()) == n0 + nb * len(committed)
    assert srv.stats.ingests == len(committed)
    # resident state never half-committed: snapshot == full recompute
    def groups(tab):
        out = tab.to_numpy()
        return {int(out["k"][i]): (float(out["s"][i]), float(out["c"][i]))
                for i in range(len(out["s"]))}
    assert groups(srv.snapshot(plan)) == \
        groups(execute(plan, {"T": live}))


def test_concurrent_load_with_faults_stays_correct():
    """Mixed chaos under concurrency: a dispatcher death and a backend
    failure mid-stream; every future still resolves to a typed error or
    a bit-correct result."""
    srv, t, plan, keys, aggs, envs = _fresh(CASE_FILTERED)
    results = {}

    def client(i):
        env = envs[i % len(envs)]
        fut = srv.submit(plan, env)
        try:
            results[i] = (env, fut.result(timeout=120))
        except ServeError as e:
            results[i] = (env, e)

    with srv, faults.inject("dispatcher_die:1,backend_exc:2"):
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(24)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=180)
    assert len(results) == 24
    for i, (env, got) in results.items():
        if isinstance(got, ServeError):
            continue    # typed failure is an acceptable outcome
        _check(got, t, keys, aggs, env, f"chaos request {i} vs oracle")
