"""Poison-sentinel contract: every sentinel round-trips through the
traced bound checks and is recognized by the serving detector.

The contract has three parties that must agree bit-for-bit on what
"poisoned" means per dtype: ``group_bound.poison_overflow`` (the
writer), ``serve.guard.is_poisoned`` (the reader), and
``group_bound.poison_sentinel`` (the shared definition both consult).
These tests pin the round trip for every output dtype through BOTH
traced validation paths — ``check_group_overflow`` (sorted route) and
``check_slot_overflow`` (sort-free route) — so the detector can never
silently diverge from the poisoner.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.relational.group_bound import (check_group_overflow,
                                          poison_overflow, poison_sentinel)
from repro.relational.keyslot import check_slot_overflow
from repro.relational.table import Table
from repro.serve.guard import is_poisoned

DTYPES = ("float32", "float16", "int32", "int16", "uint32", "bool")


def _expected(dtype):
    d = np.dtype(dtype)
    if np.issubdtype(d, np.floating):
        return np.nan
    if d == np.bool_:
        return False
    if np.issubdtype(d, np.unsignedinteger):
        return np.iinfo(d).max
    return np.iinfo(d).min


@pytest.mark.parametrize("dtype", DTYPES)
def test_sentinel_definition(dtype):
    s = poison_sentinel(dtype)
    assert s is not None
    assert jnp.dtype(s.dtype) == jnp.dtype(dtype)
    assert np.array_equal(np.asarray(s), np.asarray(_expected(dtype),
                                                    dtype), equal_nan=True)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("route", ["group", "slot"])
def test_sentinel_roundtrip_traced(dtype, route):
    """Traced bound check fails → poison_overflow writes the sentinel to
    the whole column; check passes → identity.  Both validated paths."""
    ones = jnp.ones(5, dtype)

    def run(count):
        if route == "group":
            ok = check_group_overflow(count, 2)       # count > 2 → poison
        else:
            ok = check_slot_overflow(count - 2, 2)    # unplaced > 0 → poison
        return poison_overflow({"a": ones}, ok)["a"]

    poisoned = np.asarray(jax.jit(run)(jnp.int32(3)))
    want = np.full(5, _expected(dtype), dtype)
    assert np.array_equal(poisoned, want, equal_nan=True), \
        f"{route}/{dtype}: {poisoned!r} != {want!r}"

    clean = np.asarray(jax.jit(run)(jnp.int32(2)))
    assert np.array_equal(clean, np.ones(5, dtype))


@pytest.mark.parametrize("dtype", ["float32", "float16", "int32", "int16",
                                   "uint32"])
def test_detector_recognizes_each_strong_sentinel(dtype):
    n = 8
    bad = jnp.full(n, poison_sentinel(dtype))
    t = Table({"a": bad}, jnp.ones(n, bool))
    assert is_poisoned(t)


def test_detector_requires_every_strong_column():
    """A legitimate NaN aggregate (NaN inputs through a sum) must not
    false-positive: poisoning stamps all columns or none."""
    n = 4
    t = Table({"a": jnp.full(n, jnp.nan, jnp.float32),
               "b": jnp.ones(n, jnp.float32)}, jnp.ones(n, bool))
    assert not is_poisoned(t)


def test_detector_ignores_invalid_rows():
    """Sentinels parked in invalid rows (the overflow slot, unoccupied
    slots) are normal — only valid rows count."""
    valid = jnp.array([True, True, False, False])
    t = Table({"a": jnp.array([1.0, 2.0, jnp.nan, jnp.nan], jnp.float32)},
              valid)
    assert not is_poisoned(t)


def test_detector_bool_only_is_undetectable():
    """False is an everyday bool value, so an all-bool table cannot be
    poison-checked — documented as undetectable, never as a false
    positive."""
    t = Table({"a": jnp.zeros(4, bool)}, jnp.ones(4, bool))
    assert not is_poisoned(t)


def test_detector_empty_result_is_clean():
    t = Table({"a": jnp.full(4, jnp.nan, jnp.float32)}, jnp.zeros(4, bool))
    assert not is_poisoned(t)


def test_stamp_added_only_for_bool_only_outputs():
    """The bool-only blind-spot fix: when NO output column can carry a
    strong sentinel, ``poison_overflow`` adds the auxiliary f32 stamp
    column (0.0 clean / NaN poisoned); any strong column present means
    no stamp (the normal all-or-none scan already works)."""
    from repro.relational.group_bound import STAMP_COL

    bools = {"a": jnp.ones(3, bool)}
    mixed = {"a": jnp.ones(3, bool), "b": jnp.ones(3, jnp.float32)}
    assert STAMP_COL not in poison_overflow(mixed, jnp.array(False))
    assert STAMP_COL not in poison_overflow(dict(bools), None)  # no guard
    stamped = poison_overflow(dict(bools), jnp.array(False))
    assert np.isnan(np.asarray(stamped[STAMP_COL])).all()
    clean = poison_overflow(dict(bools), jnp.array(True))
    assert np.array_equal(np.asarray(clean[STAMP_COL]),
                          np.zeros(3, np.float32))


def test_bool_only_sortfree_output_is_now_detectable():
    """Regression for the bool-only blind spot through the real route: a
    bool key and a bool aggregate used to make a poisoned result
    indistinguishable from data; the stamp column closes that, and the
    serving layer strips it after the scan."""
    from repro.relational.group_bound import STAMP_COL
    from repro.relational.keyslot import sortfree_result
    from repro.serve.guard import strip_poison_stamp

    n, bucket = 16, 4
    t = Table({"k": jnp.asarray(np.arange(n) % 2 == 0)},
              jnp.ones(n, bool))

    def run(unplaced):
        rep = jnp.zeros(bucket + 1, jnp.int32)
        out_valid = jnp.ones(bucket + 1, bool)
        return sortfree_result(t, ("k",), rep, out_valid, unplaced, bucket,
                               {"any": jnp.ones(bucket + 1, bool)})

    poisoned = jax.jit(run)(jnp.int32(7))
    assert STAMP_COL in poisoned.columns
    assert is_poisoned(poisoned)            # the blind spot is closed
    clean = jax.jit(run)(jnp.int32(0))
    assert not is_poisoned(clean)
    stripped = strip_poison_stamp(clean)
    assert STAMP_COL not in stripped.columns
    assert set(stripped.columns) == {"k", "any"}
    # identity on tables that never carried the stamp
    assert strip_poison_stamp(stripped) is stripped


def test_poisoned_end_to_end_through_sortfree_route():
    """The whole-column stamp as the executors actually produce it: a
    traced slot-overflow guard fails and every output column (keys and
    aggregates) reads its sentinel."""
    from repro.relational.keyslot import sortfree_result

    rng = np.random.default_rng(3)
    n, bucket = 64, 4
    t = Table({"k": jnp.asarray(rng.integers(0, 40, n).astype(np.int32)),
               "v": jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))},
              jnp.ones(n, bool))

    def run(unplaced):
        rep = jnp.zeros(bucket + 1, jnp.int32)
        out_valid = jnp.ones(bucket + 1, bool)
        return sortfree_result(t, ("k",), rep, out_valid, unplaced, bucket,
                               {"s": jnp.ones(bucket + 1, jnp.float32)})

    poisoned = jax.jit(run)(jnp.int32(7))
    assert is_poisoned(poisoned)
    clean = jax.jit(run)(jnp.int32(0))
    assert not is_poisoned(clean)
