"""Faithfulness tests: the analysis sets and end-to-end results the paper
derives for its two running examples (Figures 1, 2, 5, 6, 7, 8)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (aggify, analyze_loop, build_aggregate, run_aggify,
                        run_cursor, run_rewritten)

from helpers import fig1_catalog, fig1_program, fig2_catalog, fig2_program

MODES = ("stream", "chunked", "recognized", "auto")


# --- §5 illustrations: the exact sets the paper derives --------------------

def test_fig1_analysis_sets():
    ana, _, _ = analyze_loop(fig1_program())
    assert ana.v_delta == {"pCost", "minCost", "lb", "suppName", "sName"}
    assert ana.v_fetch == {"pCost", "sName"}
    assert ana.v_local == set()
    assert ana.v_fields == {"minCost", "lb", "suppName"}     # V_F \ isInit
    assert set(ana.p_accum) == {"pCost", "sName", "minCost", "lb"}
    assert ana.v_init == {"minCost", "lb"}
    assert ana.v_term == ("suppName",)


def test_fig2_analysis_sets():
    ana, _, _ = analyze_loop(fig2_program())
    assert ana.v_delta == {"cumulativeROI", "monthlyROI"}
    assert ana.v_fetch == {"monthlyROI"}
    assert ana.v_fields == {"cumulativeROI"}
    assert set(ana.p_accum) == {"monthlyROI", "cumulativeROI"}
    assert ana.v_init == {"cumulativeROI"}
    assert ana.v_term == ("cumulativeROI",)


def test_fig1_accumulate_params_order():
    """Figure 5: Accumulate(pCost, sName, pMinCost, pLb) — fetch params in
    FETCH order come first."""
    agg = build_aggregate(fig1_program())
    assert agg.fetch_params == ("pCost", "sName")
    assert set(agg.outer_params) == {"minCost", "lb"}


# --- §6/§7: the rewrite preserves semantics ---------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_fig1_equivalence(mode):
    prog, cat = fig1_program(), fig1_catalog()
    for pkey, lb in [(0, 4.0), (0, -1.0), (1, 0.0), (1, 8.0), (7, 0.0)]:
        ref = run_cursor(prog, cat, {"pkey": pkey, "lb": lb})
        got = run_aggify(prog, cat, {"pkey": pkey, "lb": lb}, mode=mode)
        assert int(ref["suppName"]) == int(got["suppName"]), (pkey, lb, mode)


@pytest.mark.parametrize("mode", MODES)
def test_fig2_equivalence(mode):
    prog, cat = fig2_program(), fig2_catalog()
    for inv in (1, 2, 3):
        ref = run_cursor(prog, cat, {"id": inv})
        got = run_aggify(prog, cat, {"id": inv}, mode=mode)
        np.testing.assert_allclose(np.asarray(ref["cumulativeROI"]),
                                   np.asarray(got["cumulativeROI"]),
                                   rtol=1e-6)


def test_deferred_init_matches_eager():
    """§5.2: deferred field initialization (the paper's Init-takes-no-args
    workaround) must agree with the JAX-native eager init."""
    prog, cat = fig1_program(), fig1_catalog()
    a = run_aggify(prog, cat, {"pkey": 0, "lb": 4.0}, mode="stream",
                   deferred_init=True)
    b = run_aggify(prog, cat, {"pkey": 0, "lb": 4.0}, mode="stream")
    assert int(a["suppName"]) == int(b["suppName"])


def test_empty_input_preserves_program_state():
    """§7: on an empty Q the loop never runs; P_n = P_0.  The rewritten
    query must produce the same (the pre-loop value of V_term vars)."""
    prog, cat = fig1_program(), fig1_catalog()
    ref = run_cursor(prog, cat, {"pkey": 99, "lb": 0.0})
    for mode in MODES:
        got = run_aggify(prog, cat, {"pkey": 99, "lb": 0.0}, mode=mode)
        assert int(got["suppName"]) == int(ref["suppName"]) == -1


def test_dead_code_elimination():
    """§6.2: '@pCost and @sName are no longer required, and are removed' —
    our pre-statement DCE keeps only definitions feeding the rewrite."""
    rp = aggify(fig1_program())
    kept = {s.var for s in rp.pre}
    assert "minCost" in kept and "suppName" in kept


def test_rewrite_reuses_query_unmodified():
    """§6.2: 'The cursor query Q remains unchanged, and is now the subquery
    in the FROM clause.'"""
    prog = fig1_program()
    rp = aggify(prog)
    assert rp.agg_call.child is prog.loop.query


def test_order_enforcement_rule():
    """Eq. 6: ORDER BY in Q forces Sort below a streaming aggregate."""
    prog = fig2_program()
    rp = aggify(prog)
    assert rp.agg_call.ordered
    assert rp.agg_call.sort_keys == ("month",)


def test_chunked_num_chunks_sweep():
    prog, cat = fig2_program(), fig2_catalog()
    ref = run_cursor(prog, cat, {"id": 1})
    for c in (1, 2, 3, 4, 8, 64):
        got = run_aggify(prog, cat, {"id": 1}, mode="chunked", num_chunks=c)
        np.testing.assert_allclose(np.asarray(ref["cumulativeROI"]),
                                   np.asarray(got["cumulativeROI"]), rtol=1e-6)


def test_interpreted_cursor_matches_scan_cursor():
    prog, cat = fig1_program(), fig1_catalog()
    a = run_cursor(prog, cat, {"pkey": 0, "lb": 4.0}, interpreted=True)
    b = run_cursor(prog, cat, {"pkey": 0, "lb": 4.0})
    assert int(a["suppName"]) == int(b["suppName"])
