import os
import sys

# Smoke tests and benches must see exactly ONE device; the dry-run (and only
# the dry-run) forces 512 placeholder host devices via its own env handling.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # CI installs pytest-timeout and runs with --timeout; locally the
    # plugin may be absent, so register its marker as a documented no-op
    # instead of tripping the unknown-marker warning
    if not config.pluginmanager.hasplugin("timeout"):
        config.addinivalue_line(
            "markers",
            "timeout(seconds): per-test wall-clock limit (enforced by "
            "pytest-timeout in CI; no-op when the plugin is absent)")
