import os
import sys

# Smoke tests and benches must see exactly ONE device; the dry-run (and only
# the dry-run) forces 512 placeholder host devices via its own env handling.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
