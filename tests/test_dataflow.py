"""CFG + dataflow analysis unit tests (paper §3.2), validated against the
paper's own worked examples on the Figure-1 program."""
import pytest

from repro.core import CFG, FETCH_STATUS, analyze
from repro.core.aggify import analyze_loop

from helpers import fig1_program, fig2_program


def test_cfg_shape_fig1():
    g = CFG.of_program(fig1_program())
    kinds = [n.kind for n in g.nodes]
    assert kinds.count("fetch") == 2
    assert kinds.count("while") == 1
    assert kinds[0] == "entry" and "exit" in kinds
    # back edge: final fetch -> while header
    hdr = g.loop_header
    fetches = [n.nid for n in g.nodes if n.kind == "fetch"]
    assert hdr in g.nodes[fetches[-1]].succs
    # body nodes flagged
    assert g.body_nodes, "body nodes must be tracked"


def test_reaching_definitions_lb():
    """Paper §3.2.3: 'consider the use of the variable @lb inside the loop
    ... at least two definitions reach this use' (the parameter default and
    any pre-loop assignment).  Our Figure-1 variant has the entry (param)
    definition reaching the body use."""
    prog = fig1_program()
    g = CFG.of_program(prog)
    dfa = analyze(g)
    body_if = next(n for n in g.nodes
                   if n.kind == "if" and n.nid in g.body_nodes)
    defs = dfa.defs_reaching_use(body_if.nid, "lb")
    assert g.entry in defs  # the parameter definition reaches the use
    assert all(d not in g.body_nodes for d in defs)


def test_liveness_fig1():
    """Paper §3.2.4: 'the only variable that is live at the end of the loop
    is @suppName'."""
    prog = fig1_program()
    g = CFG.of_program(prog)
    dfa = analyze(g)
    live = dfa.live_in[g.loop_exit_point] - {FETCH_STATUS}
    assert live == {"suppName"}


def test_ud_du_inverse():
    g = CFG.of_program(fig1_program())
    dfa = analyze(g)
    for (use, var), defs in dfa.ud.items():
        for d in defs:
            assert use in dfa.du[(d, var)]
    for (d, var), uses in dfa.du.items():
        for u in uses:
            assert d in dfa.ud[(u, var)]


def test_fetch_vars_defined_outside_and_inside():
    """The first FETCH sits before the while header (outside the body) —
    this is what puts fetch variables into P_accum via Eq. 2."""
    prog = fig1_program()
    ana, dfa, g = analyze_loop(prog)
    assert "pCost" in ana.p_accum and "sName" in ana.p_accum


def test_loop_with_pre_and_post_liveness():
    prog = fig2_program()
    g = CFG.of_program(prog)
    dfa = analyze(g)
    live = dfa.live_in[g.loop_exit_point] - {FETCH_STATUS}
    assert "cumulativeROI" in live
