"""Overlapped ingest/query soak for epoch-consistent serving.

The tentpole contract (docs/serving.md "Durability & consistency"):
``consistency="epoch"`` reads capture the resident's published epoch
with NO server lock — a long fold or ``update_table`` in another thread
never blocks them — and every read is internally consistent at SOME
watermark: the decoded groups are bit-equal to a serial oracle of the
table at exactly the version the result reports, never a torn mix of
pre- and post-fold state.

The soak interleaves one ingest writer, ≥8 epoch-reader threads, a
checkpoint thread, and a describe/stats thread, then replays every
observation against per-version oracles computed serially up front.
The epoch invariants — ``epoch_id`` advances by exactly 1 per commit
and the watermark never moves backwards — are asserted both per reader
(sampled) and on the final state.

``fold_publish`` chaos: a crash between building the successor epoch
and the reference swap must leave readers on the pre-fold epoch (raw
mode) or be absorbed by the degraded retry (guarded mode) — in both
modes no torn state is ever observable.
"""
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.relational import Table, execute
from repro.relational.plan import GroupAgg, Scan
from repro.reliability import faults
from repro.reliability.faults import FaultInjected
from repro.serve import AggServer, ServeError, ServeRequest

pytestmark = pytest.mark.timeout(300)

SCHEMA = ("k", "v", "p")


def _plan(max_groups=256):
    return GroupAgg(Scan("T", SCHEMA), ("k",),
                    (("s", "sum", "v"), ("c", "count", None),
                     ("mn", "min", "v"), ("mx", "max", "v"),
                     ("am", "argmin", ("v", "p")),
                     ("ax", "argmax", ("v", "p"))),
                    max_groups=max_groups)


def _mk_cols(n, card, rng):
    return {"k": rng.integers(0, card, n).astype(np.int32),
            "v": rng.integers(-40, 40, n).astype(np.float32),
            "p": rng.integers(0, 10_000, n).astype(np.int32)}


def _groups(t: Table) -> dict:
    out = t.to_numpy()
    return {int(out["k"][i]):
            tuple(float(out[c][i]) for c in ("s", "c", "mn", "mx",
                                             "am", "ax"))
            for i in range(len(out["s"]))}


def _build(n=768, card=80, spare=4096, seed=0):
    rng = np.random.default_rng(seed)
    cols = _mk_cols(n + spare, card, rng)
    valid = np.arange(n + spare) < n
    return Table({c: jnp.asarray(a) for c, a in cols.items()},
                 jnp.asarray(valid))


def _serial_oracles(t0: Table, batches, plan):
    """groups-dict oracle for the table after 0..len(batches) batches,
    computed serially (the ground truth every overlapped read must
    match at its reported watermark)."""
    oracles = []
    t = t0
    for i in range(len(batches) + 1):
        oracles.append(_groups(execute(plan, {"T": t})))
        if i < len(batches):
            b = batches[i]
            mask = np.asarray(t.mask())
            pos = np.flatnonzero(~mask)[: len(b["k"])]
            cols = {c: np.asarray(a).copy() for c, a in t.columns.items()}
            for c in cols:
                cols[c][pos] = b[c]
            mask = mask.copy()
            mask[pos] = True
            t = Table({c: jnp.asarray(a) for c, a in cols.items()},
                      jnp.asarray(mask))
    return oracles


# ---------------------------------------------------------------------------
# the soak
# ---------------------------------------------------------------------------


def test_overlapped_ingest_epoch_readers_see_no_torn_state(tmp_path):
    N_BATCHES, NB, N_READERS = 24, 64, 8
    rng = np.random.default_rng(1)
    batches = [_mk_cols(NB, 120, rng) for _ in range(N_BATCHES)]
    t0 = _build(seed=1)
    plan = _plan()
    oracles = _serial_oracles(t0, batches, plan)

    srv = AggServer({"T": t0})
    srv.snapshot(plan)                      # seed the residency
    version_of = {srv.table("T").version: 0}    # version → batch count
    observations = []                       # (version, groups) per read
    obs_lock = threading.Lock()
    stop = threading.Event()
    errors = []

    def writer():
        try:
            for i, b in enumerate(batches):
                try:
                    v = srv.ingest("T", b)
                except ServeError:
                    # the CI soak step arms fold fault sites via
                    # REPRO_FAULTS; a typed fold failure is within
                    # contract — the append landed and the next fold
                    # catches the resident up through the chain
                    v = srv.table("T").version
                with obs_lock:
                    version_of[v] = i + 1
        except Exception as e:              # noqa: BLE001 — surfaced below
            errors.append(("writer", e))
        finally:
            stop.set()

    def reader(idx):
        last = (-1, None)       # (epoch_id-proxy: version, prev version)
        prev_version = None
        try:
            while not stop.is_set() or not observations:
                r = srv.serve(ServeRequest(plan=plan, consistency="epoch"))
                g = _groups(r.table)
                with obs_lock:
                    observations.append((r.version, g))
                # watermark never moves backwards within one reader
                if prev_version is not None:
                    assert r.version >= prev_version, \
                        f"reader {idx}: watermark went backwards"
                prev_version = r.version
        except Exception as e:              # noqa: BLE001 — surfaced below
            errors.append((f"reader-{idx}", e))
        _ = last

    def checkpointer():
        try:
            while not stop.is_set():
                srv.checkpoint(str(tmp_path))
                stop.wait(0.02)
        except Exception as e:              # noqa: BLE001 — surfaced below
            errors.append(("checkpointer", e))

    def inspector():
        try:
            while not stop.is_set():
                d = srv.describe(plan)
                assert d["guard"] is not None
                stop.wait(0.005)
        except Exception as e:              # noqa: BLE001 — surfaced below
            errors.append(("inspector", e))

    threads = ([threading.Thread(target=writer)]
               + [threading.Thread(target=reader, args=(i,))
                  for i in range(N_READERS)]
               + [threading.Thread(target=checkpointer),
                  threading.Thread(target=inspector)])
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=240)
        assert not th.is_alive(), "soak thread hung"
    assert not errors, errors

    # every read must match the serial oracle at its reported watermark
    assert observations, "no epoch reads happened"
    unmatched = 0
    for version, got in observations:
        i = version_of.get(version)
        assert i is not None, f"read reported unknown watermark {version}"
        assert got == oracles[i], \
            f"torn epoch: read at watermark {version} (batch {i}) " \
            f"does not match the serial oracle"
        unmatched += got != oracles[i]
    assert unmatched == 0
    assert srv.stats.epoch_reads >= len(observations) - 1
    # final state: all batches folded, snapshot equals the last oracle
    assert _groups(srv.snapshot(plan)) == oracles[-1]
    # epoch invariants on the final state: one commit per fold + seed
    res = srv._residents.get(id(plan))
    ep = res.current_epoch()
    assert ep.folds == srv.stats.folds
    assert ep.epoch_id == ep.folds + 1      # seed published epoch 1
    srv.close()


def test_update_table_racing_epoch_readers_never_torn():
    """REPLACE writes drop residents; epoch readers racing them must see
    a complete generation of SOME installed table — the pre-update epoch
    or a freshly re-admitted one — never a mix of two catalogs."""
    srv = AggServer({"T": _build(seed=9)})
    plan = _plan()
    srv.snapshot(plan)
    oracle_of = {srv.table("T").version:
                 _groups(execute(plan, {"T": srv.table("T")}))}
    obs, obs_lock = [], threading.Lock()
    stop = threading.Event()
    errors = []

    def updater():
        try:
            for rep in range(12):
                t = _build(seed=20 + (rep % 4))
                g = _groups(execute(plan, {"T": t}))
                with obs_lock:
                    oracle_of[t.version] = g
                srv.update_table("T", t)
        except Exception as e:              # noqa: BLE001 — surfaced below
            errors.append(("updater", e))
        finally:
            stop.set()

    def reader(idx):
        try:
            while not stop.is_set() or not obs:
                r = srv.serve(ServeRequest(plan=plan, consistency="epoch"))
                g = _groups(r.table)
                with obs_lock:
                    obs.append((r.version, g))
        except Exception as e:              # noqa: BLE001 — surfaced below
            errors.append((f"reader-{idx}", e))

    threads = ([threading.Thread(target=updater)]
               + [threading.Thread(target=reader, args=(i,))
                  for i in range(4)])
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=240)
        assert not th.is_alive(), "update-race thread hung"
    assert not errors, errors
    assert obs
    for version, got in obs:
        want = oracle_of.get(version)
        assert want is not None, \
            f"read reported a version {version} no update installed"
        assert got == want, \
            f"torn read: watermark {version} does not match its catalog"
    srv.close()


# ---------------------------------------------------------------------------
# lock-freedom: a reader and describe() return while a fold is stuck
# ---------------------------------------------------------------------------


def _stuck_fold_server():
    """Server whose next fold blocks until ``release`` is set; returns
    (srv, plan, in_fold event, release event, pre-fold oracle)."""
    srv = AggServer({"T": _build(seed=2)})
    plan = _plan()
    srv.snapshot(plan)
    res = srv._residents[id(plan)]
    orig_fold = res.fold
    in_fold, release = threading.Event(), threading.Event()

    def slow_fold(table, positions, **kw):
        in_fold.set()
        assert release.wait(timeout=120)
        return orig_fold(table, positions, **kw)

    res.fold = slow_fold
    return srv, plan, in_fold, release


def test_epoch_read_not_blocked_by_fold_in_flight():
    # inject("") disarms any env-armed chaos (the CI soak step) for the
    # extent: this test pins lock-freedom, not fault recovery
    with faults.inject(""):
        srv, plan, in_fold, release = _stuck_fold_server()
        pre = _groups(srv.serve(
            ServeRequest(plan=plan, consistency="epoch")).table)
        v0 = srv.table("T").version
        rng = np.random.default_rng(3)
        wr = threading.Thread(
            target=srv.ingest, args=("T", _mk_cols(32, 100, rng)))
        wr.start()
        assert in_fold.wait(timeout=120)    # the fold now holds _lock
        try:
            # the epoch read returns promptly, serves the PRE-fold epoch
            done = []

            def read():
                r = srv.serve(ServeRequest(plan=plan,
                                           consistency="epoch"))
                done.append(r)

            th = threading.Thread(target=read)
            th.start()
            th.join(timeout=30)
            assert not th.is_alive(), "epoch read blocked behind the fold"
            assert done[0].version == v0
            assert _groups(done[0].table) == pre
        finally:
            release.set()
            wr.join(timeout=120)
        # after the fold commits, the epoch read serves the successor
        r2 = srv.serve(ServeRequest(plan=plan, consistency="epoch"))
        assert r2.version == srv.table("T").version
        srv.close()


def test_describe_returns_while_fold_in_flight():
    with faults.inject(""):
        srv, plan, in_fold, release = _stuck_fold_server()
        rng = np.random.default_rng(4)
        wr = threading.Thread(
            target=srv.ingest, args=("T", _mk_cols(32, 100, rng)))
        wr.start()
        assert in_fold.wait(timeout=120)
        try:
            done = []
            th = threading.Thread(target=lambda: done.append(
                srv.describe(plan)))
            th.start()
            th.join(timeout=30)
            assert not th.is_alive(), "describe() blocked behind the fold"
            assert done and done[0]["bound"] is not None
        finally:
            release.set()
            wr.join(timeout=120)
        srv.close()


# ---------------------------------------------------------------------------
# fold_publish chaos: crash between build and swap
# ---------------------------------------------------------------------------


def test_fold_publish_crash_leaves_prefold_epoch_raw():
    """Guard OFF: the injected crash escapes raw, and the published
    epoch is still the pre-fold generation — the next snapshot replays
    the batch through the normal catch-up."""
    srv = AggServer({"T": _build(seed=5)}, guard=False)
    plan = _plan()
    srv.snapshot(plan)
    res = srv._residents[id(plan)]
    ep0 = res.current_epoch()
    rng = np.random.default_rng(6)
    with faults.inject("fold_publish:1"):
        with pytest.raises(FaultInjected):
            srv.ingest("T", _mk_cols(32, 100, rng))
    assert res.current_epoch() is ep0       # the swap never happened
    # catch-up at the next snapshot folds the appended batch
    got = _groups(srv.snapshot(plan))
    assert got == _groups(execute(plan, {"T": srv.table("T")}))
    assert res.current_epoch().epoch_id == ep0.epoch_id + 1
    srv.close()


def test_fold_publish_crash_absorbed_by_guard():
    """Guard ON: the degraded retry re-runs the fold (the fault's shots
    are spent) and commits exactly ONE successor epoch — the caller
    never sees the crash and no epoch generation is skipped."""
    srv = AggServer({"T": _build(seed=7)}, guard=True)
    plan = _plan()
    srv.snapshot(plan)
    res = srv._residents[id(plan)]
    ep0 = res.current_epoch()
    rng = np.random.default_rng(8)
    with faults.inject("fold_publish:1"):
        srv.ingest("T", _mk_cols(32, 100, rng))
    assert srv.guard_stats.degraded_launches >= 1
    ep1 = res.current_epoch()
    assert ep1.epoch_id == ep0.epoch_id + 1
    assert ep1.version == srv.table("T").version
    assert _groups(srv.snapshot(plan)) == \
        _groups(execute(plan, {"T": srv.table("T")}))
    srv.close()
