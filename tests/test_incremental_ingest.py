"""Ingest parity battery for resident incremental aggregation.

The contract under test (docs/serving.md "Incremental ingest"):

* N micro-batches folded into the resident (C, R, S) moment state ==
  one one-shot recompute over the final table — across every fused op
  (sum/count/min/max/mean/argmin/argmax), key dtypes, new-key arrival,
  overflow growth, and invalid rows in the batch payload;
* ``append_rows`` preserves compiled executables (no retrace while rows
  fit the spare capacity) and EXTENDS the slot table incrementally
  (``keyslot.slot_extend_count`` moves, ``slot_build_count`` does not),
  while ``update_table`` still invalidates both;
* an append-shaped ``update_table`` draws a ``DeprecationWarning``
  pointing at the append verbs;
* a fold failure (the ``ingest_fold`` chaos site) degrades to the jnp
  fold under the guard and NEVER corrupts the resident state;
* ``fold_moments`` is the ``shard_merge`` collective algebra applied
  host-side (pinned against ``moment_merge_aggregate``);
* the sharded fold variant (8-way host mesh, subprocess) folds a
  micro-batch into sharded resident moments with the same results;
* ``REPRO_INCR_AGG=off`` reduces ingest to append (and stays correct).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.aggregate import fold_moments
from repro.launch.sharded_agg import moment_merge_aggregate
from repro.relational import Table, execute
from repro.relational import keyslot
from repro.relational.plan import GroupAgg, Scan
from repro.reliability import faults
from repro.serve import AggServer, BoundOverflow, ServeRequest

SCHEMA = ("k", "v", "p")


def _plan(max_groups=128, keys=("k",)):
    return GroupAgg(Scan("T", SCHEMA), keys,
                    (("s", "sum", "v"), ("c", "count", None),
                     ("mn", "min", "v"), ("mx", "max", "v"),
                     ("me", "mean", "v"),
                     ("am", "argmin", ("v", "p")),
                     ("ax", "argmax", ("v", "p"))),
                    max_groups=max_groups)


def _mk_table(n=512, card=40, seed=0, spare=0, kdtype=np.int32):
    # integer-valued f32 payloads: every moment is f32-exact, so the
    # resident fold and the one-shot recompute agree BITWISE and the
    # parity dicts compare with == (no tolerance hiding a real bug)
    rng = np.random.default_rng(seed)
    cap = n + spare
    cols = {"k": rng.integers(0, card, cap).astype(kdtype),
            "v": rng.integers(-40, 40, cap).astype(np.float32),
            "p": rng.integers(0, 10_000, cap).astype(np.int32)}
    valid = np.arange(cap) < n
    return Table({c: jnp.asarray(a) for c, a in cols.items()},
                 jnp.asarray(valid))


def _batch(nb, card, seed, kdtype=np.int32):
    rng = np.random.default_rng(seed)
    return {"k": rng.integers(0, card, nb).astype(kdtype),
            "v": rng.integers(-40, 40, nb).astype(np.float32),
            "p": rng.integers(0, 10_000, nb).astype(np.int32)}


def _groups(t: Table) -> dict:
    out = t.to_numpy()
    keycols = [c for c in ("k", "k2") if c in out]
    return {tuple(int(out[c][i]) for c in keycols):
            tuple(float(out[c][i]) for c in ("s", "c", "mn", "mx", "me",
                                             "am", "ax"))
            for i in range(len(out["s"]))}


def _reference(srv: AggServer, plan) -> dict:
    return _groups(execute(plan, {"T": srv.table("T")}))


def test_fold_moments_is_the_shard_merge_algebra():
    # host-side fold == moment_merge_aggregate().merge, element for element
    rng = np.random.default_rng(3)
    C, S = 3, 17

    def rand():
        return jnp.stack(
            [jnp.asarray(rng.normal(size=(C, S)).astype(np.float32)),
             jnp.asarray(rng.integers(0, 5, (C, S)).astype(np.float32)),
             jnp.asarray(rng.normal(size=(C, S)).astype(np.float32)),
             jnp.asarray(rng.normal(size=(C, S)).astype(np.float32))],
            axis=1)

    a, b = rand(), rand()
    want = moment_merge_aggregate(C, S).merge(a, b)
    got = fold_moments(a, b)
    assert np.array_equal(np.asarray(want), np.asarray(got))
    # commutative, and identity-absorbing on the identity tensor
    assert np.array_equal(np.asarray(fold_moments(b, a)), np.asarray(got))
    ident = moment_merge_aggregate(C, S).identity()
    assert np.array_equal(np.asarray(fold_moments(a, ident)),
                          np.asarray(a))


def test_fold_moments_index_rows_merge_lexicographically():
    # R=6: the argmin row follows the min KEY row; on a key tie the
    # smaller global row index wins (first-attaining order)
    moments = (("min", "argmin_first"),)
    fills = np.asarray([0.0, 0.0, np.inf, -np.inf, np.inf, np.inf],
                       np.float32).reshape(1, 6, 1)
    a = np.tile(fills, (1, 1, 3)).astype(np.float32)
    b = a.copy()
    # slot 0: a holds key 2 at row 10, b holds key 1 at row 50 → b wins
    a[0, 2, 0], a[0, 4, 0] = 2.0, 10.0
    b[0, 2, 0], b[0, 4, 0] = 1.0, 50.0
    # slot 1: key tie at 5 — rows 30 vs 7 → row 7 wins
    a[0, 2, 1], a[0, 4, 1] = 5.0, 30.0
    b[0, 2, 1], b[0, 4, 1] = 5.0, 7.0
    # slot 2: only a has data
    a[0, 2, 2], a[0, 4, 2] = 9.0, 3.0
    m = np.asarray(fold_moments(jnp.asarray(a), jnp.asarray(b),
                                moments=moments))
    assert m[0, 2, 0] == 1.0 and m[0, 4, 0] == 50.0
    assert m[0, 2, 1] == 5.0 and m[0, 4, 1] == 7.0
    assert m[0, 2, 2] == 9.0 and m[0, 4, 2] == 3.0


@pytest.mark.parametrize("kdtype", [np.int32, np.int16, np.float32])
def test_micro_batches_fold_to_one_shot_parity(kdtype):
    # the headline contract: N folded micro-batches == one recompute
    # over the final table, for every fused op at once — including ties
    # (payload values collide freely) and NEW keys arriving mid-stream
    t = _mk_table(n=512, card=40, seed=0, spare=512, kdtype=kdtype)
    srv = AggServer({"T": t})
    plan = _plan()
    assert _groups(srv.snapshot(plan)) == _reference(srv, plan)  # seed
    for i in range(5):
        srv.ingest("T", _batch(48, 60, seed=10 + i, kdtype=kdtype))
        assert _groups(srv.snapshot(plan)) == _reference(srv, plan), i
    assert srv.stats.folds == 5 and srv.stats.ingests == 5
    # the folds were O(batch): one slot build at seed, extends after
    assert srv.stats.slot_builds <= 2   # server build + resident seed share
    srv.close()


def test_two_key_columns_fold_parity():
    t = _mk_table(n=512, card=6, seed=1, spare=256)
    t = t.with_column("k2", jnp.asarray(
        np.random.default_rng(2).integers(0, 4, t.capacity)
        .astype(np.int16)))
    srv = AggServer({"T": t})
    plan = GroupAgg(Scan("T", ("k", "k2", "v", "p")), ("k", "k2"),
                    (("s", "sum", "v"), ("c", "count", None),
                     ("mn", "min", "v"), ("mx", "max", "v"),
                     ("me", "mean", "v"),
                     ("am", "argmin", ("v", "p")),
                     ("ax", "argmax", ("v", "p"))), max_groups=64)
    assert _groups(srv.snapshot(plan)) == _reference(srv, plan)
    rng = np.random.default_rng(7)
    for i in range(3):
        nb = 32
        srv.ingest("T", {"k": rng.integers(0, 6, nb).astype(np.int32),
                         "k2": rng.integers(0, 4, nb).astype(np.int16),
                         "v": rng.integers(-9, 9, nb).astype(np.float32),
                         "p": rng.integers(0, 99, nb).astype(np.int32)})
        assert _groups(srv.snapshot(plan)) == _reference(srv, plan), i
    srv.close()


def test_batch_with_invalid_rows_is_filtered():
    t = _mk_table(n=400, card=30, seed=4, spare=300)
    srv = AggServer({"T": t})
    plan = _plan()
    srv.snapshot(plan)
    b = _batch(64, 50, seed=40)
    bt = Table({c: jnp.asarray(a) for c, a in b.items()},
               jnp.asarray(np.arange(64) % 3 != 0))   # 1/3 invalid
    srv.ingest("T", bt)
    assert _groups(srv.snapshot(plan)) == _reference(srv, plan)
    srv.close()


def test_inferred_bound_grows_through_overflowing_folds():
    # no declared bound: the server infers one from the sketch; batches
    # then introduce enough distinct keys to overflow the resident
    # bucket, and the double-and-retry (ResidentAgg.grow) absorbs them
    t = _mk_table(n=1024, card=20, seed=5, spare=1024)
    srv = AggServer({"T": t})
    plan = _plan(max_groups=None)
    srv.snapshot(plan)
    bound0 = srv.describe(plan)["bound"]
    assert bound0 is not None
    rng = np.random.default_rng(6)
    for i in range(4):
        nb = 128
        srv.ingest("T", {"k": rng.integers(0, 400, nb).astype(np.int32),
                         "v": rng.integers(-5, 5, nb).astype(np.float32),
                         "p": rng.integers(0, 99, nb).astype(np.int32)})
        assert _groups(srv.snapshot(plan)) == _reference(srv, plan), i
    srv.close()


def test_declared_bound_overflow_surfaces_typed_error_and_append_lands():
    t = _mk_table(n=1024, card=20, seed=8, spare=1024)
    srv = AggServer({"T": t}, guard=True)
    plan = _plan(max_groups=200)          # bucket 256, not growable
    srv.snapshot(plan)
    rng = np.random.default_rng(9)
    nb = 512
    big = {"k": rng.integers(0, 3000, nb).astype(np.int32),
           "v": np.ones(nb, np.float32),
           "p": np.zeros(nb, np.int32)}
    v0 = srv.table("T").version
    with pytest.raises(BoundOverflow):
        srv.ingest("T", big)
    assert srv.table("T").version != v0   # the append itself landed
    # residency dropped; snapshot falls back to a recompute — and the
    # recompute itself now exceeds the declared bound, so nothing is
    # silently wrong: the plan's own overflow contract takes over
    with pytest.raises(Exception):
        srv.snapshot(plan)
    srv.close()


def test_chaos_ingest_fold_degrades_without_corrupting_state():
    t = _mk_table(n=512, card=40, seed=11, spare=512)
    srv = AggServer({"T": t}, guard=True)
    plan = _plan()
    srv.snapshot(plan)
    with faults.inject("ingest_fold:1"):
        srv.ingest("T", _batch(48, 60, seed=50))
    # the primary fold was killed; the guard retried on the jnp path
    assert srv.guard_stats.backend_failures >= 1
    assert srv.guard_stats.degraded_launches >= 1
    assert _groups(srv.snapshot(plan)) == _reference(srv, plan)
    # and the resident state kept folding afterwards (not corrupted)
    srv.ingest("T", _batch(48, 60, seed=51))
    assert _groups(srv.snapshot(plan)) == _reference(srv, plan)
    srv.close()


def test_snapshot_catches_up_on_plain_appends():
    # append_rows does NOT fold eagerly; the next snapshot walks the
    # version chain and folds the pending positions in one catch-up
    t = _mk_table(n=512, card=40, seed=12, spare=512)
    srv = AggServer({"T": t})
    plan = _plan()
    srv.snapshot(plan)
    folds0 = srv.stats.folds
    srv.append_rows("T", _batch(32, 50, seed=60))
    srv.append_rows("T", _batch(32, 50, seed=61))
    assert srv.stats.folds == folds0          # nothing folded yet
    assert _groups(srv.snapshot(plan)) == _reference(srv, plan)
    assert srv.stats.folds == folds0 + 1      # one catch-up fold
    srv.close()


def test_append_rows_preserves_executables_and_extends_slots():
    # the acceptance criterion: appends that fit the spare capacity keep
    # the compiled executable (trace counter unchanged) and EXTEND the
    # slot table (extend counter moves, build counter does not) — while
    # update_table still invalidates both
    t = _mk_table(n=512, card=40, seed=13, spare=512)
    srv = AggServer({"T": t})
    plan = _plan()
    srv.execute(plan)
    traces = srv.stats.traces
    builds_srv = srv.stats.slot_builds
    builds_key = keyslot.slot_build_count()
    extends_key = keyslot.slot_extend_count()

    srv.append_rows("T", _batch(64, 60, seed=70))
    got = _groups(srv.execute(plan))
    assert srv.stats.traces == traces                 # executable survived
    assert srv.stats.slot_builds == builds_srv        # no rebuild …
    assert keyslot.slot_build_count() == builds_key   # … keyslot spy agrees
    assert keyslot.slot_extend_count() > extends_key  # extended instead
    assert srv.stats.slot_extends >= 1
    assert got == _reference(srv, plan)   # (the reference recompute does
    #                                       its own build — check after)

    # REPLACE: both caches go
    t2 = srv.table("T").with_column(
        "v", jnp.asarray(np.asarray(srv.table("T").columns["v"]) * 2))
    srv.update_table("T", t2)
    assert _groups(srv.execute(plan)) == _reference(srv, plan)
    assert srv.stats.traces == traces + 1             # retraced
    assert srv.stats.slot_builds == builds_srv + 1    # rebuilt
    srv.close()


def test_append_shaped_update_table_draws_deprecation_warning():
    t = _mk_table(n=256, card=20, seed=14, spare=64)
    srv = AggServer({"T": t})
    b = _batch(16, 30, seed=80)
    mask = np.asarray(t.mask()).copy()
    pos = np.flatnonzero(~mask)[:16]
    cols = {c: np.asarray(a).copy() for c, a in t.columns.items()}
    for c in cols:
        cols[c][pos] = b[c]
    mask[pos] = True
    t2 = Table({c: jnp.asarray(a) for c, a in cols.items()},
               jnp.asarray(mask))
    with pytest.warns(DeprecationWarning, match="append_rows"):
        srv.update_table("T", t2)
    # a genuine replace stays silent
    t3 = t.with_column("v", jnp.asarray(
        np.asarray(t.columns["v"]) * np.float32(3.0)))
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        srv.update_table("T", t3)
    srv.close()


def test_kill_switch_reduces_ingest_to_append(monkeypatch):
    monkeypatch.setenv("REPRO_INCR_AGG", "off")
    t = _mk_table(n=256, card=20, seed=15, spare=256)
    srv = AggServer({"T": t})
    plan = _plan()
    srv.snapshot(plan)                        # plain compute, no residency
    srv.ingest("T", _batch(32, 30, seed=90))  # == append_rows
    assert srv.stats.folds == 0
    assert srv.stats.appends == 1
    assert _groups(srv.snapshot(plan)) == _reference(srv, plan)
    srv.close()


def test_serve_request_snapshot_consistency():
    t = _mk_table(n=512, card=40, seed=16, spare=256)
    srv = AggServer({"T": t})
    plan = _plan()
    res = srv.serve(ServeRequest(plan=plan, consistency="snapshot"))
    assert _groups(res.table) == _reference(srv, plan)
    assert res.version == srv.table("T").version
    v2 = srv.ingest("T", _batch(32, 50, seed=95))
    res2 = srv.serve_async(
        ServeRequest(plan=plan, consistency="snapshot")).result(timeout=30)
    assert res2.version == v2
    assert _groups(res2.table) == _reference(srv, plan)
    with pytest.raises(ValueError):
        srv.serve(ServeRequest(plan=plan, consistency="bogus"))
    srv.close()


def test_sharded_fold_in_subprocess_8way_mesh():
    """Folds a replicated micro-batch into SHARDED resident moments on an
    8-way host mesh (subprocess — tier-1 runs single-device), asserting
    the fold routed through ``sharded_fold_batch`` and that the snapshot
    matches the one-shot recompute."""
    code = """
import numpy as np, jax, jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()
from jax.sharding import Mesh
from repro.relational import Table, execute
from repro.relational.plan import GroupAgg, Scan
from repro.serve.incremental import ResidentAgg
import repro.launch.sharded_agg as sa

mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(5)
cap, n0, nb = 1024, 768, 128
cols = {"k": rng.integers(0, 100, cap).astype(np.int32),
        "v": rng.integers(-40, 40, cap).astype(np.float32),
        "p": rng.integers(0, 10000, cap).astype(np.int32)}
t = Table({c: jnp.asarray(a) for c, a in cols.items()},
          jnp.asarray(np.arange(cap) < n0))
plan = GroupAgg(Scan("T", ("k", "v", "p")), ("k",),
                (("s", "sum", "v"), ("c", "count", None),
                 ("mn", "min", "v"), ("am", "argmin", ("v", "p")),
                 ("ax", "argmax", ("v", "p"))), max_groups=128)
res = ResidentAgg.admit(plan, "T", ("k",), t, 128)
assert res is not None
res.seed(t)
# the appended rows were pre-staged at positions n0..n0+nb; marking
# them valid and sharding the table models an ingested micro-batch
# over a row-sharded resident
t2 = Table(dict(t.columns), jnp.asarray(np.arange(cap) < n0 + nb))
t2s = t2.shard_rows(mesh, "data")
calls = []
orig = sa.sharded_fold_batch
sa.sharded_fold_batch = lambda *a, **k: (calls.append(1), orig(*a, **k))[1]
res.fold(t2s, np.arange(n0, n0 + nb))
assert calls, "fold did not take the sharded path"
got = res.snapshot(t2s).to_numpy()
want = execute(plan, {"T": t2}).to_numpy()
gd = {int(got["k"][i]): tuple(float(got[c][i])
      for c in ("s", "c", "mn", "am", "ax")) for i in range(len(got["k"]))}
wd = {int(want["k"][i]): tuple(float(want[c][i])
      for c in ("s", "c", "mn", "am", "ax")) for i in range(len(want["k"]))}
assert gd == wd, (sorted(gd.items())[:4], sorted(wd.items())[:4])
print("OK")
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = {**os.environ,
           "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "") +
                         " --xla_force_host_platform_device_count=8"),
           "PYTHONPATH": os.path.abspath(src) + os.pathsep +
                         os.environ.get("PYTHONPATH", "")}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr
