"""Structural CI gate: the fused filter-join-agg lowering contains ZERO
row-sized sort ops — and no new row-sized gathers.

The whole-plan fusion pass (relational/fuse.py + the keyslot hash join,
``engine._hash_lookup``) exists to delete the join's stable row-sized
argsort and the materialized intermediate Table from ``Join → Filter →
GroupAgg`` chains.  This spy pins that deletion on the *traced program*
for a TPC-H promo-revenue-shaped query (Q14: LINEITEM ⋈ PART, ship-date
window + promo flag filter, grouped revenue sum):

1. **Sort census** — the fused lowering traces to ZERO sort equations
   with row-sized output: no join argsort (hash build/probe replaces
   it), no group sort (the sort-free slotting route), no compress.
2. **Gather census** — the fused lowering traces to NO MORE row-sized
   gathers than the materialized per-node plan: only the columns the
   aggregate names are gathered (the probe loop's per-round lookups are
   a static handful of equations, not per-row traffic).
3. **Detector sanity** — the SAME plan under ``REPRO_JOIN_HASH=off`` +
   ``REPRO_PLAN_FUSE=off`` traces to at least one row-sized sort,
   proving the census would catch a regression to the legacy lowering.
4. **Limit census** — the prefix-sum ``Limit`` lowering registers zero
   row-sized sorts/gathers, while the ``compress()`` lowering it
   replaced shows up in both counters (detector sanity again).

Run as a module (the CI step) or import the helpers from tests:

    PYTHONPATH=src python -m benchmarks.join_spy
"""
from __future__ import annotations

import sys

import jax

from repro.analysis.jaxpr_spy import row_census
from repro.core.loop_ir import BinOp, Col, Const
from repro.relational import execute
from repro.relational.plan import Filter, GroupAgg, Join, Limit, Scan
from repro.relational.tpch import SCHEMAS, gen_tpch


def filter_join_agg_plan(n_part: int) -> GroupAgg:
    """The Q14-shaped chain: per-part promo revenue over a ship-date
    window — Join → Filter → GroupAgg with a declared dense bound."""
    join = Join(Scan("LINEITEM", SCHEMAS["LINEITEM"]),
                Scan("PART", SCHEMAS["PART"]),
                "l_partkey", "p_partkey")
    pred = BinOp("and",
                 BinOp("and", Col("l_shipdate") >= Const(100),
                       Col("l_shipdate") < Const(800)),
                 Col("p_type_promo"))
    return GroupAgg(Filter(join, pred), ("l_partkey",),
                    (("rev", "sum", "l_extendedprice"),
                     ("c", "count", None)),
                    max_groups=n_part)


def _with_env(fused: bool, backend: str, fn):
    from benchmarks.util import pin_env
    with pin_env(REPRO_JOIN_HASH="on" if fused else "off",
                 REPRO_PLAN_FUSE="on" if fused else "off",
                 REPRO_SEGAGG_BACKEND=backend,
                 REPRO_GROUPAGG_FUSED=backend):
        return fn()


def trace_chain(catalog, plan, fused: bool, backend: str = "jnp"):
    """Closed jaxpr of the chain under the fused or materialized route."""
    def run():
        t = execute(plan, catalog)
        return tuple(t.columns.values()) + (t.valid,)

    return _with_env(fused, backend, lambda: jax.make_jaxpr(run)())


def join_census(scale: float = 0.005, backend: str = "jnp",
                ) -> dict[str, int]:
    """Row-sized sort/gather counts of the fused vs materialized
    filter-join-agg lowering at the given TPC-H scale.

    Two thresholds, one per table role: the legacy join's stable argsort
    is over the BUILD side (PART — the smaller table), so the sort
    census counts from that capacity up (which also catches any
    probe-side group sort or compress); gathers scale with the PROBE
    side (LINEITEM), so the gather census counts only from the larger
    capacity up — bucket/segment-sized traffic was never the problem."""
    catalog = gen_tpch(scale)
    n_probe = catalog["LINEITEM"].capacity
    n_build = catalog["PART"].capacity
    plan = filter_join_agg_plan(n_build)
    fused = trace_chain(catalog, plan, True, backend)
    mat = trace_chain(catalog, plan, False, backend)
    f_s, f_g = row_census(fused, n_build), row_census(fused, n_probe)
    m_s, m_g = row_census(mat, n_build), row_census(mat, n_probe)
    return {"rows": n_probe, "build_rows": n_build,
            "fused_sorts": f_s["sorts"], "fused_gathers": f_g["gathers"],
            "materialized_sorts": m_s["sorts"],
            "materialized_gathers": m_g["gathers"]}


def limit_census(n: int = 20_000) -> dict[str, int]:
    """Row-sized sort/gather counts of the prefix-sum Limit lowering vs
    the compress() lowering it replaced (detector sanity)."""
    import jax.numpy as jnp

    from repro.relational.table import Table

    def table():
        v = jnp.arange(n, dtype=jnp.int32)
        return Table({"v": v}, v % 3 != 0)

    def run_limit():
        t = execute(Limit(Scan("T", ("v",)), 7), {"T": table()})
        return tuple(t.columns.values()) + (t.valid,)

    def run_compress():
        t = table().compress()
        return tuple(t.columns.values()) + (t.valid,)

    lim = row_census(jax.make_jaxpr(run_limit)(), n)
    comp = row_census(jax.make_jaxpr(run_compress)(), n)
    return {"limit_sorts": lim["sorts"], "limit_gathers": lim["gathers"],
            "compress_sorts": comp["sorts"],
            "compress_gathers": comp["gathers"]}


def main() -> int:
    failures = []
    for backend, scale in (("jnp", 0.005), ("interpret", 0.0005)):
        c = join_census(scale, backend)
        print(f"[{backend} scale={scale} rows={c['rows']}] {c}")
        if c["fused_sorts"] != 0:
            failures.append(f"[{backend}] fused filter-join-agg lowering "
                            f"still contains row-sized sorts: {c}")
        if c["materialized_sorts"] < 1:
            failures.append(f"[{backend}] detector sanity — the legacy "
                            f"route should trace to at least one "
                            f"row-sized sort: {c}")
        if c["fused_gathers"] > c["materialized_gathers"]:
            failures.append(f"[{backend}] fused lowering adds row-sized "
                            f"gathers over the materialized route: {c}")
    lc = limit_census()
    print(f"[limit] {lc}")
    if lc["limit_sorts"] != 0 or lc["limit_gathers"] != 0:
        failures.append(f"Limit lowering registers row-sized "
                        f"sorts/gathers: {lc}")
    if lc["compress_sorts"] < 1 or lc["compress_gathers"] < 1:
        failures.append(f"detector sanity — compress() should register "
                        f"in both counters: {lc}")
    if failures:
        for f in failures:
            print("FAIL:", f, file=sys.stderr)
        return 1
    print("OK: fused filter-join-agg lowering contains zero row-sized "
          "sorts and no new row-sized gathers (legacy route keeps its "
          "sort, so the census would catch a regression); Limit is "
          "compaction-free")
    return 0


if __name__ == "__main__":
    sys.exit(main())
