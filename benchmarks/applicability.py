"""Tables 1/2: applicability of Aggify over a loop corpus.

The paper measures, across RUBiS/RUBBoS/Adempiere (and 77k+ Azure UDF
cursors), what fraction of while-loops are cursor loops and how many
satisfy Aggify's preconditions.  We reproduce the *measurement* on a
synthetic corpus of loop-IR programs drawn from the same categories the
paper reports: plain cursor folds, guarded extremal updates, local-table
DML (admissible), persistent DML (inadmissible), and non-cursor while
loops (FOR loops — admissible after §8.2 rewriting)."""
from __future__ import annotations

from repro.core import (Assign, BinOp, Col, Const, CursorLoop, ForLoop, If,
                        InsertLocal, Program, Var, is_aggifyable, let,
                        rewrite_for)
from repro.relational import Scan

from .util import emit


def _corpus():
    q = Scan("T", ("a", "b"))
    mk = lambda loop, **kw: Program("p", params=(), pre=[let("s", Const(0.0))],
                                    loop=loop, post=[], returns=("s",), **kw)
    corpus: list[tuple[str, Program, bool]] = []  # (category, prog, is_cursor)
    # plain folds (sum/min/max/prod/count) — the dominant category
    for i in range(14):
        corpus.append(("fold", mk(CursorLoop(
            q, [("va", "a")],
            [Assign("s", Var("s") + Var("va"))])), True))
    # guarded extremal updates (argmin/argmax style)
    for i in range(8):
        corpus.append(("extremal", mk(CursorLoop(
            q, [("va", "a")],
            [If(Var("va") < Var("s"), [Assign("s", Var("va"))])])), True))
    # local-table DML (admissible per §4.2)
    for i in range(6):
        p = Program("p", params=(), pre=[let("s", Const(0.0))],
                    loop=CursorLoop(q, [("va", "a")],
                                    [InsertLocal("tv", [Var("va")])]),
                    post=[], returns=("s",),
                    local_tables={"tv": (("float32",), 64)})
        corpus.append(("local_dml", p, True))
    # persistent DML (NOT aggifyable — aggregates cannot mutate DB state)
    for i in range(5):
        corpus.append(("persistent_dml", mk(CursorLoop(
            q, [("va", "a")],
            [InsertLocal("PERSISTENT", [Var("va")])])), True))
    # FOR loops (non-cursor; aggifyable after the §8.2 rewrite)
    for i in range(7):
        p = Program("p", params=("n",), pre=[let("s", Const(0.0))],
                    loop=ForLoop("i", Const(0), Var("n"), Const(1),
                                 [Assign("s", Var("s") + 1.0)]),
                    post=[], returns=("s",))
        corpus.append(("for_loop", p, False))
    return corpus


def run(**_) -> None:
    corpus = _corpus()
    total = len(corpus)
    cursor_loops = sum(1 for _, _, is_c in corpus if is_c)
    ok = 0
    by_cat: dict[str, list[int]] = {}
    for cat, prog, _ in corpus:
        if isinstance(prog.loop, ForLoop):
            prog = rewrite_for(prog, capacity=64)
        good = is_aggifyable(prog)
        ok += good
        by_cat.setdefault(cat, [0, 0])
        by_cat[cat][0] += good
        by_cat[cat][1] += 1
    emit("applicability_total_loops", 0, f"n={total}")
    emit("applicability_cursor_loops", 0,
         f"{cursor_loops}({100*cursor_loops/total:.1f}%)")
    emit("applicability_aggifyable", 0, f"{ok}({100*ok/total:.1f}%)")
    for cat, (g, n) in sorted(by_cat.items()):
        emit(f"applicability_{cat}", 0, f"{g}/{n}")
