"""Figure 9(a): the TPC-H cursor-loop workload — original cursor vs Aggify
vs Aggify+ (grouped decorrelation, the Froid-composition analogue).

Execution strategies per query:
  * cursor   — materialize the cursor query (temp table), then a sequential
               row-by-row fold; correlated queries (per-part / per-order /
               per-supplier UDFs) loop over N invocations.
  * aggify   — Algorithm-1 rewrite: one pipelined query + custom aggregate
               per invocation (recognized/chunked execution).
  * aggify+  — grouped decorrelation: ONE pass with the custom aggregate
               invoked per group (𝒢 over the correlation key), replacing
               all N invocations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggify, build_aggregate, run_cursor, run_rewritten
from repro.core.executors import run_aggify
from repro.relational import execute
from repro.relational.plan import AggCall, Filter
from repro.relational.tpch import gen_tpch

from .queries import DEFAULT_PARAMS, QUERIES
from .util import emit, time_fn


def _grouped_call(prog, group_key: str):
    """Build the decorrelated (Aggify+) plan: strip the correlation filter
    from the cursor query and group by the correlation column."""
    rp = aggify(prog)
    child = rp.agg_call.child
    assert isinstance(child, Filter)          # the correlation predicate
    return AggCall(child.child, rp.agg_call.aggregate,
                   rp.agg_call.param_binding, rp.agg_call.ordered,
                   rp.agg_call.sort_keys, rp.agg_call.sort_desc,
                   group_keys=(group_key,)), rp


def run(scale: float = 0.0005, n_invocations: int = 24,
        repeats: int = 3) -> None:
    catalog = gen_tpch(scale)
    for qname, (factory, corr, group_key) in QUERIES.items():
        prog = factory()
        base = dict(DEFAULT_PARAMS[qname])
        keys = list(range(n_invocations))

        def params_for(k):
            p = dict(base)
            if corr:
                p[corr] = k
            return p

        # --- cursor (jitted per-invocation scan over the temp table) ----
        cursor_fn = jax.jit(
            lambda **kw: run_cursor(prog, catalog, kw))
        if corr:
            def do_cursor():
                return [run_cursor(prog, catalog, params_for(k))
                        for k in keys]
        else:
            def do_cursor():
                return run_cursor(prog, catalog, params_for(0))
        us_cursor = time_fn(do_cursor, repeats=repeats, warmup=1)

        # --- aggify ------------------------------------------------------
        rp = aggify(prog)
        if corr:
            def do_aggify():
                return [run_rewritten(rp, catalog, params_for(k))
                        for k in keys]
        else:
            def do_aggify():
                return run_rewritten(rp, catalog, params_for(0))
        us_aggify = time_fn(do_aggify, repeats=repeats, warmup=1)

        # --- correctness cross-check --------------------------------------
        ref = run_cursor(prog, catalog, params_for(3))
        got = run_rewritten(rp, catalog, params_for(3))
        for k in ref:
            np.testing.assert_allclose(np.asarray(ref[k], np.float32),
                                       np.asarray(got[k], np.float32),
                                       rtol=1e-3, atol=1e-3)

        emit(f"tpch_{qname}_cursor", us_cursor, f"invocations={len(keys) if corr else 1}")
        emit(f"tpch_{qname}_aggify", us_aggify,
             f"speedup={us_cursor/us_aggify:.2f}x")

        # --- aggify+ (grouped decorrelation) -----------------------------
        if group_key:
            call, rp2 = _grouped_call(prog, group_key)
            env = {p: jnp.asarray(v) for p, v in base.items()}
            # pre-loop state values for the aggregate's outer params
            from repro.core.executors import build_env
            env.update({k: v for k, v in build_env(
                prog, catalog,
                {**base, corr: 0}).items() if k not in env})
            grouped = jax.jit(lambda: execute(call, catalog, env))
            us_grouped = time_fn(lambda: grouped().columns, repeats=repeats)
            emit(f"tpch_{qname}_aggify_plus", us_grouped,
                 f"speedup={us_cursor/us_grouped:.2f}x_allgroups")
