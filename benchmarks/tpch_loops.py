"""Figure 9(a): the TPC-H cursor-loop workload — original cursor vs Aggify
vs Aggify+ (grouped decorrelation, the Froid-composition analogue).

Execution strategies per query:
  * cursor   — materialize the cursor query (temp table), then a sequential
               row-by-row fold; correlated queries (per-part / per-order /
               per-supplier UDFs) loop over N invocations.
  * aggify   — Algorithm-1 rewrite: one pipelined query + custom aggregate
               per invocation (recognized/chunked execution).
  * aggify+  — grouped decorrelation: ONE pass with the custom aggregate
               invoked per group (𝒢 over the correlation key), replacing
               all N invocations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggify, build_aggregate, run_cursor, run_rewritten
from repro.core.executors import run_aggify
from repro.relational import execute
from repro.relational.plan import AggCall, Filter
from repro.relational.tpch import gen_tpch

from .queries import DEFAULT_PARAMS, QUERIES
from .util import emit, pin_env, time_fn


def _grouped_call(prog, group_key: str):
    """Build the decorrelated (Aggify+) plan: strip the correlation filter
    from the cursor query and group by the correlation column."""
    rp = aggify(prog)
    child = rp.agg_call.child
    assert isinstance(child, Filter)          # the correlation predicate
    return AggCall(child.child, rp.agg_call.aggregate,
                   rp.agg_call.param_binding, rp.agg_call.ordered,
                   rp.agg_call.sort_keys, rp.agg_call.sort_desc,
                   group_keys=(group_key,)), rp


def run(scale: float = 0.0005, n_invocations: int = 24,
        repeats: int = 3) -> None:
    catalog = gen_tpch(scale)
    for qname, (factory, corr, group_key) in QUERIES.items():
        prog = factory()
        base = dict(DEFAULT_PARAMS[qname])
        keys = list(range(n_invocations))

        def params_for(k):
            p = dict(base)
            if corr:
                p[corr] = k
            return p

        # --- cursor (jitted per-invocation scan over the temp table) ----
        cursor_fn = jax.jit(
            lambda **kw: run_cursor(prog, catalog, kw))
        if corr:
            def do_cursor():
                return [run_cursor(prog, catalog, params_for(k))
                        for k in keys]
        else:
            def do_cursor():
                return run_cursor(prog, catalog, params_for(0))
        us_cursor = time_fn(do_cursor, repeats=repeats, warmup=1)

        # --- aggify ------------------------------------------------------
        rp = aggify(prog)
        if corr:
            def do_aggify():
                return [run_rewritten(rp, catalog, params_for(k))
                        for k in keys]
        else:
            def do_aggify():
                return run_rewritten(rp, catalog, params_for(0))
        us_aggify = time_fn(do_aggify, repeats=repeats, warmup=1)

        # --- correctness cross-check --------------------------------------
        ref = run_cursor(prog, catalog, params_for(3))
        got = run_rewritten(rp, catalog, params_for(3))
        for k in ref:
            np.testing.assert_allclose(np.asarray(ref[k], np.float32),
                                       np.asarray(got[k], np.float32),
                                       rtol=1e-3, atol=1e-3)

        emit(f"tpch_{qname}_cursor", us_cursor, f"invocations={len(keys) if corr else 1}")
        emit(f"tpch_{qname}_aggify", us_aggify,
             f"speedup={us_cursor/us_aggify:.2f}x")

        # --- aggify+ (grouped decorrelation) -----------------------------
        if group_key:
            call, rp2 = _grouped_call(prog, group_key)
            env = {p: jnp.asarray(v) for p, v in base.items()}
            # pre-loop state values for the aggregate's outer params
            from repro.core.executors import build_env
            env.update({k: v for k, v in build_env(
                prog, catalog,
                {**base, corr: 0}).items() if k not in env})
            grouped = jax.jit(lambda: execute(call, catalog, env))
            us_grouped = time_fn(lambda: grouped().columns, repeats=repeats)
            emit(f"tpch_{qname}_aggify_plus", us_grouped,
                 f"speedup={us_cursor/us_grouped:.2f}x_allgroups")


def _join_agg_oracle(catalog) -> dict[int, tuple[float, int]]:
    """Numpy reference for the Q14-shaped chain: inner join on the part
    key, ship-date window + promo filter, grouped (sum, count)."""
    li = catalog["LINEITEM"].to_numpy()
    pa = catalog["PART"].to_numpy()
    order = np.argsort(pa["p_partkey"], kind="stable")
    rk = pa["p_partkey"][order]
    pos = np.clip(np.searchsorted(rk, li["l_partkey"]), 0, len(rk) - 1)
    found = rk[pos] == li["l_partkey"]
    promo = pa["p_type_promo"][order][pos]
    keep = (found & (li["l_shipdate"] >= 100) & (li["l_shipdate"] < 800)
            & promo)
    out: dict[int, tuple[float, int]] = {}
    for k in np.unique(li["l_partkey"][keep]):
        m = keep & (li["l_partkey"] == k)
        out[int(k)] = (float(np.sum(li["l_extendedprice"][m],
                                    dtype=np.float64)), int(np.sum(m)))
    return out


def _result_map(t) -> dict[int, tuple[float, int]]:
    cols = t.to_numpy()
    return {int(k): (float(s), int(c))
            for k, s, c in zip(cols["l_partkey"], cols["rev"], cols["c"])}


def run_join_agg(scale: float = 0.05, repeats: int = 3,
                 sweep: tuple = (0.0005, 0.005, 0.05)) -> None:
    """Timed fused vs materialized filter-join-agg chain (whole-plan
    fusion acceptance): the Q14-shaped ``Join → Filter → GroupAgg`` at
    100× the default loop scale factor, parity-checked against a numpy
    oracle, plus the structural sort census and a scale-factor sweep of
    the fused chain.  Gated by ci_gate.check_join."""
    from .join_spy import filter_join_agg_plan, join_census

    catalog = gen_tpch(scale)
    n_rows = catalog["LINEITEM"].capacity
    plan = filter_join_agg_plan(catalog["PART"].capacity)

    def timed(fused: bool) -> tuple[float, dict]:
        with pin_env(REPRO_PLAN_FUSE="on" if fused else "off",
                     REPRO_JOIN_HASH="on" if fused else "off"):
            fn = jax.jit(
                lambda: tuple(execute(plan, catalog).columns.values()))
            us = time_fn(fn, repeats=repeats, warmup=1)
            res = _result_map(execute(plan, catalog))
        return us, res

    us_fused, got_fused = timed(True)
    us_mat, got_mat = timed(False)

    oracle = _join_agg_oracle(catalog)
    for got, route in ((got_fused, "fused"), (got_mat, "materialized")):
        assert set(got) == set(oracle), (
            f"{route} group keys diverge from the numpy oracle")
        for k, (s, c) in oracle.items():
            gs, gc = got[k]
            np.testing.assert_allclose(gs, s, rtol=1e-4,
                                       err_msg=f"{route} sum key={k}")
            assert gc == c, f"{route} count key={k}: {gc} != {c}"

    emit("tpch_join_agg_fused", us_fused,
         f"rows={n_rows}_speedup={us_mat / max(us_fused, 1e-9):.2f}x")
    emit("tpch_join_agg_materialized", us_mat, f"rows={n_rows}")

    c = join_census(0.005, "jnp")
    emit("tpch_join_sort_census", 0.0,
         f"fused={c['fused_sorts']}_materialized={c['materialized_sorts']}")

    parts = []
    for s in sweep:
        cat_s = gen_tpch(s)
        plan_s = filter_join_agg_plan(cat_s["PART"].capacity)
        fn = jax.jit(
            lambda: tuple(execute(plan_s, cat_s).columns.values()))
        parts.append(f"s{s}={time_fn(fn, repeats=repeats, warmup=1):.0f}us"
                     f"@{cat_s['LINEITEM'].capacity}rows")
    emit("tpch_join_agg_scale_sweep", 0.0, "_".join(parts))
